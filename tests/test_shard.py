"""Tree-sharded multi-device execution (core/shard.py).

JAX fixes the device count at backend init and this suite must see the
real single-CPU device (see conftest), so everything genuinely
multi-device runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the in-process
tests cover the machinery that works on one device (padding, key
derivation, error paths, and the D=1 mesh)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import core
from repro.core import engine_select, registry, shard

from conftest import rand_X


# --------------------------------------------------------------------------- #
# in-process (single device)
# --------------------------------------------------------------------------- #
def test_pad_forest_trees_is_noop_on_exact_multiple(small_forest):
    assert shard.pad_forest_trees(small_forest, 4) is small_forest


def test_pad_forest_trees_padding_contributes_zero(small_forest):
    padded = shard.pad_forest_trees(small_forest, 3)   # 8 → 9 trees
    assert padded.n_trees == 9
    assert int(padded.n_nodes[-1]) == 0                # single-leaf tree
    X = rand_X(small_forest, B=32)
    np.testing.assert_allclose(padded.predict_oracle(X),
                               small_forest.predict_oracle(X))


def test_pad_preserves_quantization_metadata(small_forest):
    qf = core.quantize_forest(small_forest, rand_X(small_forest, B=64))
    padded = shard.pad_forest_trees(qf, 5)
    assert padded.quant_scale == qf.quant_scale
    assert padded.threshold.dtype == qf.threshold.dtype
    np.testing.assert_array_equal(padded.feat_lo, qf.feat_lo)


def test_single_device_mesh_matches_unsharded(small_forest):
    X = rand_X(small_forest, B=24)
    for engine in ("bitvector", "gemm"):
        single = core.compile_forest(small_forest, engine=engine).predict(X)
        sp = shard.tree_sharded(small_forest, engine, n_devices=1)
        np.testing.assert_allclose(sp.predict(X), single, rtol=1e-5,
                                   atol=1e-6)


def test_too_many_devices_raises(small_forest):
    with pytest.raises(ValueError, match="n_devices"):
        shard.tree_sharded(small_forest, "bitvector", n_devices=64)


def test_every_jax_engine_is_registered_shardable():
    assert all(s.shardable for s in registry.specs("jax"))


def test_shape_key_includes_device_count(small_forest):
    k1 = engine_select.shape_key(small_forest, 64)
    k4 = engine_select.shape_key(small_forest, 64, n_devices=4)
    fp = engine_select.fingerprint_hash()
    assert k1 != k4
    assert k1.endswith(f"_dev1_fp{fp}") and k4.endswith(f"_dev4_fp{fp}")


def test_pipeline_plan_single_device_stays_unsharded(small_forest):
    pred = core.compile_plan(small_forest, engine="bitmm", n_devices=1)
    assert not isinstance(pred, shard.ShardedPredictor)
    assert not any("tree-sharded" in r.detail for r in pred.plan.records)


# --------------------------------------------------------------------------- #
# multi-device (subprocess with 8 simulated host devices)
# --------------------------------------------------------------------------- #
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
assert len(jax.devices()) == 8, jax.devices()
from repro import core
from repro.core import engine_select, registry, shard
from repro.inference.server import ForestServer

# T=10 is not divisible by 4: the zero-tree padding path is exercised
f = core.random_forest_ir(10, 16, 6, n_classes=2, seed=0)
X = np.random.default_rng(3).normal(0, 1.2, size=(32, 6))

# float: every registered engine sharded over 4 devices ≈ single-device
for engine in registry.engines("jax"):
    single = core.compile_forest(f, engine=engine).predict(X)
    sp = shard.tree_sharded(f, engine, n_devices=4)
    assert sp.n_devices == 4
    np.testing.assert_allclose(sp.predict(X), single, rtol=1e-5,
                               atol=1e-6, err_msg=engine)

# quantized: bitwise identical (exact integer partial sums divided by a
# power-of-two scale — psum reassociation is lossless)
qf = core.quantize_forest(f, X)
for engine in registry.engines("jax"):
    single = core.compile_forest(qf, engine=engine).predict(X)
    got = shard.tree_sharded(qf, engine, n_devices=4).predict(X)
    np.testing.assert_array_equal(got, single, err_msg=engine)

# 8-way shard with per-device tree count 2 (max padding pressure)
got8 = shard.tree_sharded(qf, "bitvector", n_devices=8).predict(X)
np.testing.assert_array_equal(
    got8, core.compile_forest(qf, engine="bitvector").predict(X))

# the pipeline's lower pass wires the shard wrapper for n_devices > 1
pred = core.compile_plan(f, engine="bitmm", n_devices=4)
assert isinstance(pred, shard.ShardedPredictor) and pred.n_devices == 4
assert any(r.name == "lower" and "tree-sharded" in r.detail
           for r in pred.plan.records)
np.testing.assert_allclose(
    pred.predict(X), core.compile_forest(f, engine="bitmm").predict(X),
    rtol=1e-5, atol=1e-6)

# autotuner: n_devices keys the cache and the winner serves sharded
choice = engine_select.choose(f, 32, engines=("qs", "qs-bitmm"),
                              n_devices=4, cache_path=None, repeats=1)
assert "_dev4_fp" in choice.key, choice.key
assert choice.predictor.n_devices == 4
ref = {"qs": "bitvector", "qs-bitmm": "bitmm"}[choice.engine]
np.testing.assert_allclose(
    choice.predict(X), core.compile_forest(f, engine=ref).predict(X),
    rtol=1e-5, atol=1e-6)

# serving path: ForestServer.from_forest(n_devices=...)
srv = ForestServer.from_forest(f, max_batch=8, engines=("qs",),
                               n_devices=4, cache_path=None, repeats=1)
assert srv.engine_choice.predictor.n_devices == 4
for i in range(8):
    srv.submit(X[i], arrival_s=float(i) * 1e-4)
done = srv.poll(now_s=1.0)
assert len(done) == 8
got = np.stack([r.result for r in done])
np.testing.assert_allclose(
    got, core.compile_forest(f, engine="bitvector").predict(X[:8]),
    rtol=1e-5, atol=1e-6)
print("SHARD-OK")
"""


def test_tree_sharded_multi_device_subprocess():
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARD-OK" in out.stdout
