"""Pallas flash-attention kernel: shape/dtype sweep vs the pure-jnp
oracle (models.attention.flash_attention) and a naive softmax reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention_kernel import flash_attention_bshd
from repro.models.attention import flash_attention


def _naive(q, k, v, causal):
    H, K = q.shape[2], k.shape[2]
    rep = H // K
    kr = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr)
    s = s * (q.shape[-1] ** -0.5)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vr)


SWEEP = [
    # (B, Sq, Sk, H, K, hd, causal, bq, bk)
    (1, 32, 32, 4, 4, 8, True, 8, 8),        # MHA causal
    (2, 64, 64, 6, 2, 16, True, 16, 16),     # GQA 3:1
    (2, 64, 64, 8, 1, 16, True, 32, 16),     # MQA
    (1, 48, 96, 4, 4, 8, False, 16, 32),     # cross-shaped, non-causal
    (2, 128, 128, 15, 5, 4, True, 64, 32),   # smollm-like ratios
]


@pytest.mark.parametrize("B,Sq,Sk,H,K,hd,causal,bq,bk", SWEEP)
def test_flash_kernel_matches_naive(B, Sq, Sk, H, K, hd, causal, bq, bk):
    rng = np.random.default_rng(hash((B, Sq, H)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, K, hd)), jnp.float32)
    got = flash_attention_bshd(q, k, v, causal=causal, block_q=bq,
                               block_k=bk)
    ref = _naive(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_matches_pure_jax_engine():
    """The kernel and the XLA engine (which the dry-run lowers) must agree
    — they are the same math at different memory-hierarchy levels."""
    rng = np.random.default_rng(0)
    B, S, K, R, hd = 2, 64, 3, 5, 16
    q = jnp.asarray(rng.normal(size=(B, S, K * R, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    got = flash_attention_bshd(q, k, v, causal=True, block_q=16, block_k=16)
    ref = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16,
                          n_rep=R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_flash_kernel_bf16_inputs():
    rng = np.random.default_rng(3)
    B, S, H, hd = 1, 32, 4, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.bfloat16)
    got = flash_attention_bshd(q, k, v, causal=True, block_q=8, block_k=8)
    assert got.dtype == jnp.bfloat16
    ref = _naive(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32), True)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(ref), rtol=3e-2, atol=3e-2)


def test_flash_kernel_block_shape_independence():
    rng = np.random.default_rng(4)
    B, S, H, hd = 2, 64, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    ref = None
    for bq, bk in [(8, 8), (16, 32), (64, 64)]:
        got = np.asarray(flash_attention_bshd(q, k, v, causal=True,
                                              block_q=bq, block_k=bk))
        if ref is None:
            ref = got
        else:
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6,
                                       err_msg=f"bq={bq} bk={bk}")
