"""Per-architecture smoke tests (deliverable f): REDUCED same-family
configs, one forward + one train step on CPU, asserting shapes + no NaNs.
Full configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model

B, S = 2, 32


def _inputs(cfg, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)),
                         dtype=jnp.int32)
    enc = None
    if cfg.family == "encdec":
        enc = jnp.asarray(rng.normal(0, 1, size=(B, 16, cfg.d_model)),
                          dtype=jnp.float32)
    return tokens, enc


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = Model(cfg, q_chunk=16, ssd_chunk=8, loss_chunk=16, remat=False)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    return request.param, cfg, model, params


def test_forward_shapes_no_nan(arch_setup):
    name, cfg, model, params = arch_setup
    rng = np.random.default_rng(0)
    tokens, enc = _inputs(cfg, rng)
    logits = model.forward(params, tokens, enc) if enc is not None \
        else model.forward(params, tokens)
    assert logits.shape == (B, S, cfg.vocab), name
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name


def test_train_step_finite_loss(arch_setup):
    name, cfg, model, params = arch_setup
    rng = np.random.default_rng(1)
    tokens, enc = _inputs(cfg, rng)
    args = (params, tokens) if enc is None else (params, tokens, enc)
    loss, grads = jax.value_and_grad(model.loss_fn)(*args)
    assert np.isfinite(float(loss)), name
    # loss near ln(vocab) at init (uniform predictions)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, name


def test_param_count_analytic_matches_actual(arch_setup):
    """ArchConfig.param_count() (used for MODEL_FLOPS) must track the real
    parameter tree within 2%."""
    name, cfg, model, params = arch_setup
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.02, \
        f"{name}: actual={actual} analytic={analytic}"


def test_decode_matches_forward(arch_setup):
    """Step-by-step KV/SSM-cache decode must reproduce the full forward
    logits (teacher forcing) — the core serving-correctness invariant."""
    name, cfg, model, params = arch_setup
    model_f32 = Model(cfg, compute_dtype=jnp.float32, q_chunk=16,
                      ssd_chunk=8, loss_chunk=16, remat=False)
    rng = np.random.default_rng(2)
    tokens, enc = _inputs(cfg, rng)
    S_dec = 8
    toks = tokens[:, :S_dec]
    full = model_f32.forward(params, toks, enc) if enc is not None \
        else model_f32.forward(params, toks)

    if cfg.family == "encdec":
        state = model_f32.init_decode_state(B, S_dec + 1, params=params,
                                            enc_embeds=enc,
                                            dtype=jnp.float32)
    else:
        state = model_f32.init_decode_state(B, S_dec + 1, dtype=jnp.float32)
    step = jax.jit(model_f32.decode_step)
    got = []
    for i in range(S_dec):
        logits, state = step(params, state, toks[:, i:i + 1])
        got.append(np.asarray(logits))
    got = np.stack(got, axis=1)                       # (B, S_dec, V)
    np.testing.assert_allclose(got, np.asarray(full), rtol=2e-2, atol=2e-2)


def test_full_config_values():
    """The assigned table, verbatim."""
    expect = {
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "phi3_mini_3_8b": (32, 3072, 32, 32, 8192, 32064),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "phi3_5_moe_42b": (32, 4096, 32, 8, 6400, 32064),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
    }
    for name, (L, D, H, K, F, V) in expect.items():
        cfg = get_config(name)
        assert cfg.n_layers == L and cfg.d_model == D, name
        assert cfg.n_heads == H and cfg.n_kv == K, name
        assert cfg.d_ff == F and cfg.vocab == V, name
    # MoE extras
    assert get_config("phi3_5_moe_42b").n_experts == 16
    assert get_config("grok_1_314b").n_experts == 8
    assert get_config("jamba_1_5_large_398b").n_experts == 16
    assert get_config("mamba2_370m").ssm_state == 128


def test_param_counts_in_band():
    """Headline parameter counts should land near the advertised sizes."""
    bands = {
        "chameleon_34b": (30e9, 40e9),
        "smollm_360m": (0.30e9, 0.45e9),
        "phi3_mini_3_8b": (3.3e9, 4.3e9),
        "command_r_plus_104b": (90e9, 115e9),
        "starcoder2_3b": (2.5e9, 3.6e9),
        "phi3_5_moe_42b": (38e9, 46e9),
        "grok_1_314b": (280e9, 340e9),
        "jamba_1_5_large_398b": (350e9, 440e9),
        "mamba2_370m": (0.30e9, 0.45e9),
    }
    for name, (lo, hi) in bands.items():
        n = get_config(name).param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.1f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_smaller():
    for name in ("phi3_5_moe_42b", "grok_1_314b", "jamba_1_5_large_398b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < 0.6 * cfg.param_count(), name
