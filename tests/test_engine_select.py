"""Autotuner: winner selection, two-layer caching, server integration,
and the Pallas batch-bucketing recompile bound."""
import json

import numpy as np
import pytest

from repro import core
from repro.core import engine_select
from repro.inference.server import ForestServer

CHEAP = ("qs", "qs-bitmm", "native")


@pytest.fixture(autouse=True)
def _fresh_cache():
    engine_select.clear_cache()
    yield
    engine_select.clear_cache()


def test_choose_benchmarks_and_caches(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    c1 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert c1.engine in CHEAP and not c1.from_cache
    assert set(c1.timings) == set(CHEAP)
    assert all(t > 0 for t in c1.timings.values())
    # winner really is the fastest measured engine
    assert c1.engine == min(c1.timings, key=c1.timings.get)

    # in-memory hit
    c2 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert c2.from_cache and c2.engine == c1.engine

    # disk hit (fresh process simulated by clearing the memory layer)
    engine_select.clear_cache()
    with open(cache) as f:
        assert c1.key in json.load(f)
    c3 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert c3.from_cache and c3.engine == c1.engine


def test_subset_sweep_never_answers_for_full_matrix(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    narrow = engine_select.choose(small_forest, 64, engines=("qs",),
                                  cache_path=cache, repeats=1)
    assert narrow.engine == "qs" and not narrow.from_cache
    # the qs-only entry must not satisfy a lookup for a wider engine set
    full = engine_select.choose(small_forest, 64, engines=CHEAP,
                                cache_path=cache, repeats=1)
    assert not full.from_cache and set(full.timings) == set(CHEAP)
    # ...but the wide entry answers later narrow lookups, re-deriving the
    # winner over just the requested subset
    again = engine_select.choose(small_forest, 64, engines=("qs", "native"),
                                 cache_path=cache, repeats=1)
    assert again.from_cache
    assert again.engine == min(("qs", "native"), key=full.timings.get)


def test_narrow_resweep_keeps_richer_cache_entry(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    full = engine_select.choose(small_forest, 64, engines=CHEAP,
                                cache_path=cache, repeats=1)
    # a forced qs-only re-benchmark must not clobber the CHEAP-wide entry
    engine_select.choose(small_forest, 64, engines=("qs",),
                         cache_path=cache, force=True, repeats=1)
    with open(cache) as f:
        entry = json.load(f)[full.key]
    assert set(entry["timings"]) == set(CHEAP)
    c = engine_select.choose(small_forest, 64, engines=CHEAP,
                             cache_path=cache, repeats=1)
    assert c.from_cache


def test_narrow_resweep_cannot_clobber_disk_via_memory_layer(small_forest,
                                                            tmp_path):
    """A narrow entry cached only in memory (cache_path=None) must not let
    a later forced narrow sweep erase a wider entry on disk."""
    cache = str(tmp_path / "engines.json")
    full = engine_select.choose(small_forest, 64, engines=CHEAP,
                                cache_path=cache, repeats=1)
    engine_select.clear_cache()
    engine_select.choose(small_forest, 64, engines=("qs",),
                         cache_path=None, repeats=1)   # memory-only, narrow
    engine_select.choose(small_forest, 64, engines=("qs",),
                         cache_path=cache, force=True, repeats=1)
    with open(cache) as f:
        assert set(json.load(f)[full.key]["timings"]) == set(CHEAP)


def test_partial_miss_benches_only_missing_engines(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    narrow = engine_select.choose(small_forest, 64, engines=("qs",),
                                  cache_path=cache, repeats=1)
    wider = engine_select.choose(small_forest, 64, engines=CHEAP,
                                 cache_path=cache, repeats=1)
    assert not wider.from_cache and set(wider.timings) == set(CHEAP)
    # qs was not re-benchmarked: its cached timing is reused verbatim
    assert wider.timings["qs"] == narrow.timings["qs"]


def test_partial_miss_persists_merged_union_to_disk(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    narrow = engine_select.choose(small_forest, 64, engines=("qs",),
                                  cache_path=None, repeats=1)  # memory-only
    engine_select.choose(small_forest, 64, engines=CHEAP,
                         cache_path=cache, repeats=1)
    # the memory-only qs timing reached disk along with the fresh ones
    with open(cache) as f:
        entry = json.load(f)[narrow.key]
    assert set(entry["timings"]) == set(CHEAP)


def test_memory_hit_writes_through_to_disk(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    c1 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=None, repeats=1)   # memory-only
    c2 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert c2.from_cache
    with open(cache) as f:
        assert set(json.load(f)[c1.key]["timings"]) == set(CHEAP)


def test_overlapping_sweeps_merge_coverage(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    engine_select.choose(small_forest, 64, engines=("qs", "native"),
                         cache_path=cache, repeats=1)
    c2 = engine_select.choose(small_forest, 64, engines=("qs-bitmm",),
                              cache_path=cache, repeats=1)
    assert not c2.from_cache
    # both sweeps' timings accumulated → the union now hits the cache
    c3 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert c3.from_cache and set(c3.timings) == set(CHEAP)


def test_env_cache_path_resolved_per_call(small_forest, tmp_path,
                                          monkeypatch):
    cache = tmp_path / "env_cache.json"
    monkeypatch.setenv("REPRO_ENGINE_CACHE", str(cache))
    c = engine_select.choose(small_forest, 64, engines=("qs",), repeats=1)
    assert cache.exists() and c.key in json.loads(cache.read_text())


def test_disk_hit_warms_memory_layer(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    c1 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    engine_select.clear_cache()             # simulate a fresh process
    c2 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert c2.from_cache
    assert engine_select._MEM_CACHE[c1.key]["timings"] == c2.timings


def test_choose_batch_bucketing(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    c1 = engine_select.choose(small_forest, 33, engines=CHEAP,
                              cache_path=cache, repeats=1)
    c2 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    # 33 and 64 share the 64 bucket → one sweep, one cache entry
    assert c1.key == c2.key and c2.from_cache


def test_choice_predictor_correct(small_forest):
    from conftest import rand_X
    c = engine_select.choose(small_forest, 32, engines=CHEAP,
                             cache_path=None, repeats=1)
    X = rand_X(small_forest, B=32)
    np.testing.assert_allclose(c.predict(X),
                               small_forest.predict_oracle(X),
                               rtol=1e-4, atol=1e-5)


def test_forest_server_uses_autotuned_winner(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    choice = engine_select.choose(small_forest, 16, engines=CHEAP,
                                  cache_path=cache, repeats=1)
    srv = ForestServer.from_forest(small_forest, max_batch=16,
                                   engines=CHEAP, cache_path=cache)
    # the server's decision came from the cache and matches the winner
    assert srv.engine_choice is not None
    assert srv.engine_choice.from_cache
    assert srv.engine_choice.engine == choice.engine
    assert srv.predictor is srv.engine_choice.predictor

    # and the served scores are the winner's predictions
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(16, small_forest.n_features))
    for i in range(16):
        srv.submit(feats[i], arrival_s=float(i) * 1e-4)
    done = srv.poll(now_s=1.0)
    assert len(done) == 16
    got = np.stack([r.result for r in done])
    np.testing.assert_allclose(got, choice.predict(feats), rtol=1e-5,
                               atol=1e-6)


def test_pallas_batch_bucketing_bounds_recompiles(small_forest):
    """Satellite regression: distinct batch sizes inside one power-of-two
    bucket must reuse one compiled kernel."""
    from repro.kernels.ops import bucket_rows, pallas_qs_predictor
    assert [bucket_rows(b, 32) for b in (1, 32, 33, 64, 65, 100, 129)] == \
        [32, 32, 64, 64, 128, 128, 256]
    pred = pallas_qs_predictor(small_forest, block_b=32, block_t=4)
    rng = np.random.default_rng(0)
    for B in (3, 17, 31, 32):          # one bucket: 32
        pred.predict(rng.normal(size=(B, small_forest.n_features)))
    assert pred.n_compiles == 1
    for B in (33, 50, 64):             # second bucket: 64
        pred.predict(rng.normal(size=(B, small_forest.n_features)))
    assert pred.n_compiles == 2
    if hasattr(pred._fn, "_cache_size"):    # actual jit cache, where exposed
        assert pred._fn._cache_size() == pred.n_compiles


# --------------------------------------------------------------------------- #
# cascade candidates: naming, winner wiring, and cache hygiene — cascade
# tags participate in cache entries like the _dev{n} key component does:
# entries from before the cascade axis existed must key-miss and re-sweep
# --------------------------------------------------------------------------- #
def _cascade_spec(threshold=0.9):
    from repro.cascade import CascadeSpec, MarginGate
    return CascadeSpec(stages=(4, 8), policy=MarginGate(threshold))


def test_cascade_candidates_swept_and_usable(small_forest):
    from repro.cascade import CascadePredictor
    spec = _cascade_spec()
    c = engine_select.choose(small_forest, 16, engines=("qs",),
                             cascade_specs=(spec,), cache_path=None,
                             repeats=1)
    assert set(c.timings) == {"qs", f"qs@{spec.tag()}"}
    assert "cascade=4/8:margin0.9" in spec.tag()
    # the winning predictor is buildable and correct either way
    from conftest import rand_X
    X = rand_X(small_forest, B=16)
    np.testing.assert_allclose(c.predict(X),
                               small_forest.predict_oracle(X),
                               rtol=1e-4, atol=1e-5)
    if "cascade" in c.engine:
        assert isinstance(c.predictor, CascadePredictor)


def test_old_cache_entries_keymiss_cascade_sweeps(small_forest, tmp_path):
    """An entry written before the cascade axis existed (plain engine
    timings only) must not answer a cascade sweep — partial miss, only
    the cascade candidates are benchmarked, coverage merges."""
    cache = str(tmp_path / "engines.json")
    plain = engine_select.choose(small_forest, 16, engines=("qs", "native"),
                                 cache_path=cache, repeats=1)
    # simulate a fresh process with only the old-format disk entry
    engine_select.clear_cache()
    spec = _cascade_spec()
    c = engine_select.choose(small_forest, 16, engines=("qs", "native"),
                             cascade_specs=(spec,), cache_path=cache,
                             repeats=1)
    assert not c.from_cache
    # the plain timings were reused verbatim, not re-benchmarked
    assert c.timings["qs"] == plain.timings["qs"]
    assert set(c.timings) == {"qs", "native", f"qs@{spec.tag()}",
                              f"native@{spec.tag()}"}
    # the widened entry now answers both shapes of request
    hit = engine_select.choose(small_forest, 16, engines=("qs", "native"),
                               cascade_specs=(spec,), cache_path=cache,
                               repeats=1)
    assert hit.from_cache
    plain_hit = engine_select.choose(small_forest, 16,
                                     engines=("qs", "native"),
                                     cache_path=cache, repeats=1)
    assert plain_hit.from_cache


def test_distinct_cascade_specs_never_alias(small_forest, tmp_path):
    """Different stages or thresholds → different candidate names: a
    sweep for one spec must not answer for another."""
    cache = str(tmp_path / "engines.json")
    engine_select.choose(small_forest, 16, engines=("qs",),
                         cascade_specs=(_cascade_spec(0.9),),
                         cache_path=cache, repeats=1)
    other = engine_select.choose(small_forest, 16, engines=("qs",),
                                 cascade_specs=(_cascade_spec(0.5),),
                                 cache_path=cache, repeats=1)
    assert not other.from_cache
    from repro.cascade import CascadeSpec, MarginGate
    stages = engine_select.choose(
        small_forest, 16, engines=("qs",),
        cascade_specs=(CascadeSpec((2, 8), MarginGate(0.9)),),
        cache_path=cache, repeats=1)
    assert not stages.from_cache


def test_cascade_specs_reject_multi_device(small_forest):
    with pytest.raises(ValueError, match="cascade"):
        engine_select.choose(small_forest, 16, engines=("qs",),
                             cascade_specs=(_cascade_spec(),),
                             n_devices=2, cache_path=None, repeats=1)


def test_forest_server_serves_cascade_winner(small_forest, tmp_path):
    """from_forest(cascade_specs=) serves whatever wins; when the winner
    is a cascade, exit fractions land in the serving stats."""
    from repro.cascade import CascadePredictor, CascadeSpec, MarginGate
    # a gate this aggressive on an 8-tree forest makes the cascade the
    # plausible winner, but the assertion holds either way
    spec = CascadeSpec(stages=(2, 8), policy=MarginGate(0.0))
    srv = ForestServer.from_forest(small_forest, max_batch=8,
                                   engines=("qs",), cascade_specs=(spec,),
                                   cache_path=str(tmp_path / "c.json"),
                                   repeats=1)
    assert srv.engine_choice.engine in {"qs", f"qs@{spec.tag()}"}
    rng = np.random.default_rng(0)
    for i in range(8):
        srv.submit(rng.normal(size=small_forest.n_features),
                   arrival_s=float(i) * 1e-4)
    srv.flush(now_s=1.0)
    s = srv.stats.summary()
    assert s["n_requests"] == 8
    if isinstance(srv.predictor, CascadePredictor):
        assert "exit_fractions" in s


# --------------------------------------------------------------------------- #
# cache-file robustness: garbage on disk must mean re-sweep, never a crash
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("garbage", [
    '{"truncated": {"timings": {"qs": 0.0',           # cut mid-write
    "not json at all",
    "[1, 2, 3]",                                      # valid JSON, wrong type
    '"just a string"',
    '{"key": "entry is not a dict"}',
    '{"key": {"engine": "qs"}}',                      # missing timings
    '{"key": {"timings": {"qs": "fast"}}}',           # non-numeric timing
    '{"key": {"timings": {}}}',                       # empty timings
    "",
], ids=["truncated", "not-json", "list", "string", "str-entry",
        "no-timings", "str-timing", "empty-timings", "empty-file"])
def test_garbage_cache_file_triggers_clean_resweep(small_forest, tmp_path,
                                                   garbage):
    cache = str(tmp_path / "engines.json")
    with open(cache, "w") as f:
        f.write(garbage)
    c = engine_select.choose(small_forest, 64, engines=("qs", "native"),
                             cache_path=cache, repeats=1)
    assert not c.from_cache and set(c.timings) == {"qs", "native"}
    # ...and the file was rewritten into a valid cache that now hits
    with open(cache) as f:
        data = json.load(f)
    assert set(data[c.key]["timings"]) == {"qs", "native"}
    engine_select.clear_cache()
    c2 = engine_select.choose(small_forest, 64, engines=("qs", "native"),
                              cache_path=cache, repeats=1)
    assert c2.from_cache and c2.engine == c.engine


def test_garbage_entries_dropped_but_valid_entries_kept(small_forest,
                                                        class_forest,
                                                        tmp_path):
    """A partially corrupt cache keeps its healthy entries: only the
    malformed ones are dropped (and purged on the next rewrite)."""
    cache = str(tmp_path / "engines.json")
    good = engine_select.choose(small_forest, 64, engines=("qs",),
                                cache_path=cache, repeats=1)
    with open(cache) as f:
        data = json.load(f)
    data["corrupt_key"] = {"timings": "nope"}
    with open(cache, "w") as f:
        json.dump(data, f)
    engine_select.clear_cache()
    # the healthy entry still answers
    hit = engine_select.choose(small_forest, 64, engines=("qs",),
                               cache_path=cache, repeats=1)
    assert hit.from_cache and hit.engine == good.engine
    # a sweep for a different forest rewrites the file without the junk
    engine_select.choose(class_forest, 64, engines=("qs",),
                         cache_path=cache, repeats=1)
    with open(cache) as f:
        rewritten = json.load(f)
    assert "corrupt_key" not in rewritten
    assert good.key in rewritten
