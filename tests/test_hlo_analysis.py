"""Unit tests for the trip-count-aware HLO roofline analyzer."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_computations


def _hlo(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_match_unroll():
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    def unrolled(x, w):
        for _ in range(10):
            x = x @ w
        return x

    fs = analyze(_hlo(scanned, (64, 64), (64, 64))).flops
    fu = analyze(_hlo(unrolled, (64, 64), (64, 64))).flops
    assert fs == pytest.approx(fu)
    assert fs == pytest.approx(10 * 2 * 64 ** 3, rel=0.01)


def test_nested_scan_multipliers():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    f = analyze(_hlo(nested, (32, 32), (32, 32))).flops
    assert f == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)


def test_slice_aware_bytes_not_quadratic():
    """Chunked scan reading slices of a big array must not charge the full
    array per iteration."""
    N, C = 64, 128

    def chunked(big):
        def body(acc, i):
            blk = jax.lax.dynamic_slice(big, (i * C, 0), (C, big.shape[1]))
            return acc + blk.sum(), None
        acc, _ = jax.lax.scan(body, jnp.zeros(()),
                              jnp.arange(N, dtype=jnp.int32))
        return acc

    cost = analyze(_hlo(chunked, (N * C, 16)))
    total = N * C * 16 * 4
    # slice-aware: each element read O(1) times (plus loop overheads),
    # NOT O(N) times
    assert cost.bytes_hbm < 20 * total
    assert cost.bytes_hbm > total  # but it did read the data


def test_tuple_param_computations_parsed():
    """while bodies have tuple-typed params with /*index=N*/ comments —
    the regression that silently dropped all loop collectives once."""
    hlo = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8,8]) %p), index=0
  %x = f32[8,8]{1,0} get-tuple-element((s32[], /*index=1*/f32[8,8]) %p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    st = analyze(hlo).collectives
    assert st.per_op["all-reduce"] == 8 * 8 * 4 * 7     # trip count 7
    assert st.link_bytes == 2 * 8 * 8 * 4 * 7


def test_backend_config_trip_count_precedence():
    hlo = """
HloModule m

%body (p: (f32[4])) -> (f32[4]) {
  %x = f32[4]{0} get-tuple-element((f32[4]) %p), index=0
  %ag = f32[4]{0} all-gather(%x), dimensions={0}
  ROOT %t = (f32[4]) tuple(%ag)
}

%cond (p: (f32[4])) -> pred[] {
  %c = s32[] constant(999)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (f32[4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %r = f32[4] get-tuple-element(%w), index=0
}
"""
    st = analyze(hlo).collectives
    assert st.per_op["all-gather"] == 16 * 3    # backend_config wins over 999


def test_dot_inside_fusion_counted():
    def f(x, w):
        return jax.nn.relu(x @ w) @ w

    cost = analyze(_hlo(f, (32, 32), (32, 32)))
    assert cost.flops == pytest.approx(2 * 2 * 32 ** 3, rel=0.01)


def test_parse_computation_count_real_module():
    txt = _hlo(lambda x: jnp.sin(x).sum(), (128,))
    comps, entry = parse_computations(txt)
    assert entry in comps
    assert len(comps) >= 1
