"""Fixed-point quantization (paper §5): semantics + accuracy invariants,
plus the integer end-to-end extensions (docs/QUANT.md) and regression
tests for the leaf-wraparound / non-finite-calibration bugs."""
import numpy as np
import pytest

from repro import core
from repro.core.quantize import (QuantSpec, accum_bits, feature_ranges,
                                 flint_forest, flint_key,
                                 normalize_features, quantize_forest,
                                 quantize_inputs)


def test_qspec_defaults():
    s = QuantSpec()
    assert s.default_scale == 2 ** 15 and s.dtype == np.int16
    s8 = QuantSpec(bits=8)
    assert s8.default_scale == 2 ** 7 and s8.dtype == np.int8


def test_quantize_dtype_and_metadata(small_forest):
    qf = quantize_forest(small_forest)
    assert qf.threshold.dtype == np.int16
    assert qf.leaf_value.dtype == np.int32
    assert qf.quant_scale == 2 ** 15
    assert qf.quant_bits == 16
    # original untouched
    assert small_forest.threshold.dtype == np.float32
    assert small_forest.quant_scale is None


def test_double_quantize_rejected(small_forest):
    qf = quantize_forest(small_forest)
    with pytest.raises(AssertionError):
        quantize_forest(qf)


def test_splits_only_and_leaves_only(small_forest):
    qs_only = quantize_forest(small_forest,
                              spec=QuantSpec(quantize_leaves=False))
    assert qs_only.threshold.dtype == np.int16
    assert qs_only.leaf_value.dtype == np.float32
    ql_only = quantize_forest(small_forest,
                              spec=QuantSpec(quantize_splits=False))
    assert ql_only.threshold.dtype == np.float32
    assert ql_only.leaf_value.dtype == np.int32
    # leaves-only: raw inputs pass through untouched
    X = np.random.default_rng(0).normal(size=(4, small_forest.n_features))
    np.testing.assert_array_equal(quantize_inputs(ql_only, X), X)


def test_normalization_order_preserving():
    rng = np.random.default_rng(0)
    X = rng.normal(0, 10, size=(100, 3))
    lo, hi = X.min(0), X.max(0)
    Xn = normalize_features(X, lo, hi)
    assert Xn.min() >= 0.0 and Xn.max() <= 1.0
    for f in range(3):
        order = np.argsort(X[:, f])
        assert (np.diff(Xn[order, f]) >= 0).all()


def test_quantized_prediction_close_to_float(trained_rf, magic_ds):
    """Paper Table 3: quantization changes accuracy by ≲ tenths of a point
    on well-scaled data."""
    forest = core.from_random_forest(trained_rf)
    qf = quantize_forest(forest, magic_ds.X_train)
    X, y = magic_ds.X_test, magic_ds.y_test
    p_f = core.compile_forest(forest, engine="bitvector").predict_class(X)
    p_q = core.compile_forest(qf, engine="bitvector").predict_class(X)
    acc_f = (p_f == y).mean()
    acc_q = (p_q == y).mean()
    assert abs(acc_f - acc_q) < 0.02


def test_leaf_scale_auto_shrink():
    """GBT leaves can exceed 1.0; scale must auto-shrink to fit the word."""
    f = core.random_forest_ir(4, 8, 4, seed=5)
    f.leaf_value *= 100.0                 # huge leaves
    qf = quantize_forest(f)
    assert qf.leaf_scale < 2 ** 15
    imax = np.abs(qf.leaf_value).max()
    assert imax <= 2 ** 31 - 1            # stored in int32 accumulator space
    # leaves-only quantization isolates the rounding error: traversal is
    # unchanged, so |err| ≤ T / s_leaf per class
    ql = quantize_forest(f, spec=QuantSpec(quantize_splits=False))
    X = np.random.default_rng(1).normal(size=(32, 4))
    from repro.kernels.ref import ref_oracle
    got = ref_oracle(ql, X)
    expect = f.predict_oracle(X)
    bound = f.n_trees / ql.leaf_scale + 1e-9
    assert np.abs(got - expect).max() <= bound


def test_feature_ranges_from_forest_thresholds(small_forest):
    lo, hi = feature_ranges(small_forest, None)
    assert lo.shape == (small_forest.n_features,)
    assert (hi >= lo).all()


def test_quantize_inputs_clips_outliers(trained_rf, magic_ds):
    forest = quantize_forest(core.from_random_forest(trained_rf),
                             magic_ds.X_train)
    X = magic_ds.X_test.copy()
    X[0] = 1e9                               # outlier beyond training range
    Xq = quantize_inputs(forest, X)
    assert Xq.max() <= 2 ** 15 - 1
    assert Xq.min() >= -(2 ** 15)


def test_int8_beyond_paper(trained_rf, magic_ds):
    forest = core.from_random_forest(trained_rf)
    qf = quantize_forest(forest, magic_ds.X_train, spec=QuantSpec(bits=8))
    assert qf.threshold.dtype == np.int8
    X, y = magic_ds.X_test, magic_ds.y_test
    acc_f = (core.compile_forest(forest).predict_class(X) == y).mean()
    acc_q = (core.compile_forest(qf).predict_class(X) == y).mean()
    assert abs(acc_f - acc_q) < 0.05          # int8 is coarser but usable


# --------------------------------------------------------------------------- #
# regression: silent leaf wraparound (the shrink loop used to stop at
# s_leaf <= 2, then floor(s*leaf).astype(...) wrapped for huge leaves)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("bits,boost", [(16, 2.0e9), (8, 2.0e4)])
def test_leaf_wraparound_regression(bits, boost):
    """Leaves with max|leaf| beyond half the storage dtype's range used
    to wrap on the astype: floor(2 * leaf) overflowed int32 (bits=16) /
    int16 (bits=8) and flipped sign silently.  The scale must keep
    shrinking until every quantized leaf fits ±int_max."""
    f = core.random_forest_ir(6, 8, 4, seed=9)
    f.leaf_value = np.abs(f.leaf_value) * boost      # all-positive, huge
    spec = QuantSpec(bits=bits)
    qf = quantize_forest(f, spec=spec)
    assert (qf.leaf_value >= 0).all(), "wraparound flipped leaf signs"
    assert np.abs(qf.leaf_value).max() <= spec.int_max
    # the descaled prediction still tracks the float one within the bound
    ql = quantize_forest(f, spec=QuantSpec(bits=bits,
                                           quantize_splits=False))
    X = np.random.default_rng(2).normal(size=(16, 4))
    err = np.abs(ql.predict_oracle(X) / core.leaf_scale(ql)
                 - f.predict_oracle(X)).max()
    assert err <= ql.leaf_err_bound + 1e-6 * boost


def test_leaf_err_bound_recorded(small_forest):
    qf = quantize_forest(small_forest)
    assert qf.leaf_err_bound == small_forest.n_trees / qf.leaf_scale
    assert quantize_forest(
        small_forest, spec=QuantSpec(quantize_leaves=False)
    ).leaf_err_bound is None


def test_nan_leaves_rejected(small_forest):
    """NaN leaves used to skip the shrink loop silently (NaN > x is
    False) and floor to garbage — now a loud error."""
    import dataclasses
    f = dataclasses.replace(small_forest)
    f.leaf_value = small_forest.leaf_value.copy()
    f.leaf_value[0, 0, 0] = np.nan
    with pytest.raises(ValueError, match="NaN"):
        quantize_forest(f)
    f.leaf_value[0, 0, 0] = np.inf
    with pytest.raises(ValueError):
        quantize_forest(f)


# --------------------------------------------------------------------------- #
# regression: non-finite calibration rows poisoned feat_lo/feat_hi
# --------------------------------------------------------------------------- #
def test_feature_ranges_masks_nonfinite_rows(small_forest):
    """One NaN/±inf sensor row used to make a feature's range NaN/inf and
    every normalized input NaN with no error raised; non-finite entries
    are now masked per column."""
    rng = np.random.default_rng(3)
    X = rng.normal(0, 1, size=(50, small_forest.n_features))
    lo0, hi0 = feature_ranges(small_forest, X)
    Xbad = np.concatenate([X, np.full((1, X.shape[1]), np.nan),
                           np.full((1, X.shape[1]), np.inf),
                           np.full((1, X.shape[1]), -np.inf)])
    lo, hi = feature_ranges(small_forest, Xbad)
    assert np.isfinite(lo).all() and np.isfinite(hi).all()
    np.testing.assert_array_equal(lo, lo0)
    np.testing.assert_array_equal(hi, hi0)
    # and quantization end-to-end stays usable with dirty calibration
    qf = quantize_forest(small_forest, Xbad)
    Xq = quantize_inputs(qf, X)
    assert np.isfinite(Xq.astype(np.float64)).all()


def test_feature_ranges_all_nonfinite_column():
    f = core.random_forest_ir(2, 4, 3, seed=4)
    X = np.random.default_rng(5).normal(size=(10, 3))
    X[:, 1] = np.nan                         # dead sensor: whole column
    lo, hi = feature_ranges(f, X)
    assert np.isfinite(lo).all() and np.isfinite(hi).all()
    assert hi[1] > lo[1]


# --------------------------------------------------------------------------- #
# integer end-to-end: accum_bits + FLInt key map (docs/QUANT.md)
# --------------------------------------------------------------------------- #
def test_accum_bits_contract(small_forest):
    qf = quantize_forest(small_forest, spec=QuantSpec(int_accum=True))
    bits = accum_bits(qf)
    worst = int(np.abs(qf.leaf_value.astype(np.int64))
                .max(axis=(1, 2)).sum())
    assert bits in (16, 32)
    assert worst <= np.iinfo(np.int16 if bits == 16 else np.int32).max
    # tiny scale → worst case fits int16
    q16 = quantize_forest(small_forest,
                          spec=QuantSpec(scale=8.0, int_accum=True))
    assert accum_bits(q16) == 16
    with pytest.raises(ValueError, match="integer"):
        accum_bits(small_forest)             # float leaves


def test_int_accum_requires_quantized_leaves(small_forest):
    with pytest.raises(ValueError, match="int_accum"):
        quantize_forest(small_forest,
                        spec=QuantSpec(int_accum=True,
                                       quantize_leaves=False))


def test_flint_key_is_strictly_monotone():
    vals = np.array([-np.inf, -1e30, -2.5, -1.0, -np.float32(1e-38).item(),
                     -0.0, 0.0, np.float32(1e-38).item(), 1.0, 2.5, 1e30,
                     np.inf], dtype=np.float32)
    keys = flint_key(vals)
    assert keys.dtype == np.int32
    # strictly increasing except the -0.0/+0.0 pair (equal floats may
    # key apart, ordered floats never invert)
    assert (np.diff(keys.astype(np.int64)) >= 0).all()
    assert keys[4] < keys[5] <= keys[6] < keys[7]
    # NaN keys above every threshold key: always traverses right
    assert flint_key(np.float32(np.nan)) == np.iinfo(np.int32).max
    assert flint_key(np.float32(np.nan)) > flint_key(np.float32(np.inf))
    # predicate equivalence on random pairs
    rng = np.random.default_rng(6)
    a = rng.normal(0, 1e3, 1000).astype(np.float32)
    b = rng.normal(0, 1e3, 1000).astype(np.float32)
    np.testing.assert_array_equal(flint_key(a) <= flint_key(b), a <= b)


def test_flint_forest_semantics(small_forest):
    ff = flint_forest(small_forest)
    assert ff.flint and ff.threshold.dtype == np.int32
    assert small_forest.flint is False       # original untouched
    X = np.random.default_rng(7).normal(
        size=(32, small_forest.n_features)).astype(np.float32)
    np.testing.assert_array_equal(ff.predict_oracle(quantize_inputs(ff, X)),
                                  small_forest.predict_oracle(X))
    with pytest.raises(AssertionError):
        flint_forest(ff)                     # double-keying rejected
    with pytest.raises(AssertionError):
        quantize_forest(ff)                  # flint ⊕ quantize


def test_eeg_merging_collapse():
    """Paper Table 4: heavy-tailed features → quantization collapses unique
    thresholds (EEG), while bounded features (mnist-like) are unaffected."""
    from repro.data import datasets
    from repro.trees.random_forest import RandomForest, RandomForestConfig
    eeg = datasets.load("eeg", n=2000)
    rf = RandomForest(RandomForestConfig(n_trees=32, max_leaves=16,
                                         seed=0)).fit(eeg.X_train,
                                                      eeg.y_train)
    forest = core.from_random_forest(rf)
    frac_float = core.merge_stats(forest)
    qf = quantize_forest(forest, eeg.X_train)
    frac_quant = core.merge_stats(qf)
    assert frac_quant < frac_float * 0.9      # ≥10% collapse
