"""Fixed-point quantization (paper §5): semantics + accuracy invariants."""
import numpy as np
import pytest

from repro import core
from repro.core.quantize import (QuantSpec, feature_ranges,
                                 normalize_features, quantize_forest,
                                 quantize_inputs)


def test_qspec_defaults():
    s = QuantSpec()
    assert s.default_scale == 2 ** 15 and s.dtype == np.int16
    s8 = QuantSpec(bits=8)
    assert s8.default_scale == 2 ** 7 and s8.dtype == np.int8


def test_quantize_dtype_and_metadata(small_forest):
    qf = quantize_forest(small_forest)
    assert qf.threshold.dtype == np.int16
    assert qf.leaf_value.dtype == np.int32
    assert qf.quant_scale == 2 ** 15
    assert qf.quant_bits == 16
    # original untouched
    assert small_forest.threshold.dtype == np.float32
    assert small_forest.quant_scale is None


def test_double_quantize_rejected(small_forest):
    qf = quantize_forest(small_forest)
    with pytest.raises(AssertionError):
        quantize_forest(qf)


def test_splits_only_and_leaves_only(small_forest):
    qs_only = quantize_forest(small_forest,
                              spec=QuantSpec(quantize_leaves=False))
    assert qs_only.threshold.dtype == np.int16
    assert qs_only.leaf_value.dtype == np.float32
    ql_only = quantize_forest(small_forest,
                              spec=QuantSpec(quantize_splits=False))
    assert ql_only.threshold.dtype == np.float32
    assert ql_only.leaf_value.dtype == np.int32
    # leaves-only: raw inputs pass through untouched
    X = np.random.default_rng(0).normal(size=(4, small_forest.n_features))
    np.testing.assert_array_equal(quantize_inputs(ql_only, X), X)


def test_normalization_order_preserving():
    rng = np.random.default_rng(0)
    X = rng.normal(0, 10, size=(100, 3))
    lo, hi = X.min(0), X.max(0)
    Xn = normalize_features(X, lo, hi)
    assert Xn.min() >= 0.0 and Xn.max() <= 1.0
    for f in range(3):
        order = np.argsort(X[:, f])
        assert (np.diff(Xn[order, f]) >= 0).all()


def test_quantized_prediction_close_to_float(trained_rf, magic_ds):
    """Paper Table 3: quantization changes accuracy by ≲ tenths of a point
    on well-scaled data."""
    forest = core.from_random_forest(trained_rf)
    qf = quantize_forest(forest, magic_ds.X_train)
    X, y = magic_ds.X_test, magic_ds.y_test
    p_f = core.compile_forest(forest, engine="bitvector").predict_class(X)
    p_q = core.compile_forest(qf, engine="bitvector").predict_class(X)
    acc_f = (p_f == y).mean()
    acc_q = (p_q == y).mean()
    assert abs(acc_f - acc_q) < 0.02


def test_leaf_scale_auto_shrink():
    """GBT leaves can exceed 1.0; scale must auto-shrink to fit the word."""
    f = core.random_forest_ir(4, 8, 4, seed=5)
    f.leaf_value *= 100.0                 # huge leaves
    qf = quantize_forest(f)
    assert qf.leaf_scale < 2 ** 15
    imax = np.abs(qf.leaf_value).max()
    assert imax <= 2 ** 31 - 1            # stored in int32 accumulator space
    # leaves-only quantization isolates the rounding error: traversal is
    # unchanged, so |err| ≤ T / s_leaf per class
    ql = quantize_forest(f, spec=QuantSpec(quantize_splits=False))
    X = np.random.default_rng(1).normal(size=(32, 4))
    from repro.kernels.ref import ref_oracle
    got = ref_oracle(ql, X)
    expect = f.predict_oracle(X)
    bound = f.n_trees / ql.leaf_scale + 1e-9
    assert np.abs(got - expect).max() <= bound


def test_feature_ranges_from_forest_thresholds(small_forest):
    lo, hi = feature_ranges(small_forest, None)
    assert lo.shape == (small_forest.n_features,)
    assert (hi >= lo).all()


def test_quantize_inputs_clips_outliers(trained_rf, magic_ds):
    forest = quantize_forest(core.from_random_forest(trained_rf),
                             magic_ds.X_train)
    X = magic_ds.X_test.copy()
    X[0] = 1e9                               # outlier beyond training range
    Xq = quantize_inputs(forest, X)
    assert Xq.max() <= 2 ** 15 - 1
    assert Xq.min() >= -(2 ** 15)


def test_int8_beyond_paper(trained_rf, magic_ds):
    forest = core.from_random_forest(trained_rf)
    qf = quantize_forest(forest, magic_ds.X_train, spec=QuantSpec(bits=8))
    assert qf.threshold.dtype == np.int8
    X, y = magic_ds.X_test, magic_ds.y_test
    acc_f = (core.compile_forest(forest).predict_class(X) == y).mean()
    acc_q = (core.compile_forest(qf).predict_class(X) == y).mean()
    assert abs(acc_f - acc_q) < 0.05          # int8 is coarser but usable


def test_eeg_merging_collapse():
    """Paper Table 4: heavy-tailed features → quantization collapses unique
    thresholds (EEG), while bounded features (mnist-like) are unaffected."""
    from repro.data import datasets
    from repro.trees.random_forest import RandomForest, RandomForestConfig
    eeg = datasets.load("eeg", n=2000)
    rf = RandomForest(RandomForestConfig(n_trees=32, max_leaves=16,
                                         seed=0)).fit(eeg.X_train,
                                                      eeg.y_train)
    forest = core.from_random_forest(rf)
    frac_float = core.merge_stats(forest)
    qf = quantize_forest(forest, eeg.X_train)
    frac_quant = core.merge_stats(qf)
    assert frac_quant < frac_float * 0.9      # ≥10% collapse
