"""run_loop fault-tolerance integration: straggler flagging and
preemption-checkpoint, driven through the real loop."""
import signal

import pytest

from repro.configs import get_config
from repro.distributed import checkpoint as ckpt
from repro.launch.train import Trainer, run_loop


@pytest.fixture(scope="module")
def tiny_trainer():
    cfg = get_config("smollm_360m").reduced()

    def make():
        return Trainer(cfg, batch=2, seq_len=32)
    return make


def test_straggler_flagged_in_records(tiny_trainer, monkeypatch):
    tr = tiny_trainer()
    tr.init_state()
    real_step = tr.train_step
    count = {"n": 0}

    import time as _time

    def slow_sometimes():
        count["n"] += 1
        rec = real_step()
        if count["n"] == 8:              # one injected straggler
            _time.sleep(2.0)
        return rec

    monkeypatch.setattr(tr, "train_step", slow_sometimes)
    records = run_loop(tr, steps=10, ckpt_dir=None, log_every=100)
    stragglers = [r["step"] for r in records if r.get("straggler")]
    assert stragglers == [8]


def test_preemption_checkpoints_and_exits(tiny_trainer, tmp_path,
                                          monkeypatch):
    tr = tiny_trainer()
    tr.init_state()
    real_step = tr.train_step
    count = {"n": 0}

    def step_then_sigterm():
        count["n"] += 1
        rec = real_step()
        if count["n"] == 3:
            signal.raise_signal(signal.SIGTERM)   # delivered synchronously
        return rec

    monkeypatch.setattr(tr, "train_step", step_then_sigterm)
    records = run_loop(tr, steps=100, ckpt_dir=str(tmp_path),
                       ckpt_every=1000, log_every=100)
    assert len(records) == 3                      # stopped early
    assert ckpt.latest_step(str(tmp_path)) == 3   # checkpointed on the flag
