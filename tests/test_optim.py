"""Optimizer middle-end (repro.optim): pass semantics, the equivalence
contract, pipeline/autotuner/serialization/cascade wiring.

Structure:

  * unit tests per pass on hand-built forests where the expected rewrite
    is known exactly;
  * the conformance sweep: ``-O2`` vs ``-O0`` across every registered
    engine × backend combo (Pallas in interpret mode) on adversarial
    forests — bit-exact on quantized, tolerance on float;
  * property suite: every *registered* optimizer pass preserves
    ``predict_oracle`` across the whole adversarial catalog of
    ``tests/test_conformance.py`` (deterministic) and across randomized
    forests (hypothesis, skipped cleanly offline);
  * wiring: plan records, packed round trips of optimized IR, autotuner
    ``opt_levels`` sweeps with cache key-miss hygiene, and cascade
    compatibility (stage splits over the reordered forest, sound
    ``ScoreBoundGate`` exactness).
"""
import numpy as np
import pytest

from repro import core, io, optim
from repro.core import engine_select, registry
from repro.core.quantize import quantize_inputs

from conftest import rand_X
from test_conformance import ADVERSARIAL, QUANTIZABLE, _X

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

COMBOS = [(s.name, s.backend) for s in registry.specs()]
COMBO_IDS = [f"{n}/{b}" for n, b in COMBOS]
JAX_ENGINES = list(registry.engines("jax"))


def _opt_inputs(forest, X):
    """Map caller-coordinate rows into an optimized forest's IR coords
    (what quantize_inputs does on the engine path) for oracle calls."""
    return X if forest.feat_map is None else X[:, forest.feat_map]


# --------------------------------------------------------------------------- #
# framework: registry, levels, resolve_opt
# --------------------------------------------------------------------------- #
def test_registry_has_the_five_passes_and_levels():
    assert set(optim.opt_passes()) >= {
        "compact", "dedup_thresholds", "drop_unused_features",
        "merge_equivalent_leaves", "reorder_trees"}
    assert optim.OPT_LEVELS[0] == ()
    assert set(optim.OPT_LEVELS[1]) < set(optim.OPT_LEVELS[2])
    assert all(n in optim.OPT_PASSES
               for lvl in optim.OPT_LEVELS.values() for n in lvl)


@pytest.mark.parametrize("opt,expect", [
    (None, ((), "O0")), (0, ((), "O0")),
    ("O2", (optim.OPT_LEVELS[2], "O2")),
    ("-O1", (optim.OPT_LEVELS[1], "O1")),
    ("2", (optim.OPT_LEVELS[2], "O2")),
    (("compact",), (("compact",), "compact")),
])
def test_resolve_opt_forms(opt, expect):
    assert optim.resolve_opt(opt) == expect


@pytest.mark.parametrize("bad", ["O9", 7, ("nonesuch",), "fast"])
def test_resolve_opt_rejects_garbage(bad):
    with pytest.raises(ValueError):
        optim.resolve_opt(bad)


def test_optimize_O0_is_identity(small_forest):
    res = optim.optimize(small_forest, 0)
    assert res.forest is small_forest
    assert res.stats == [] and res.verified is None


# --------------------------------------------------------------------------- #
# pass unit tests (hand-built forests, exact expected rewrites)
# --------------------------------------------------------------------------- #
def _pass(name):
    return optim.OPT_PASSES[name].fn


def test_dedup_collapses_dominated_splits():
    """Every node repeats (f=0, t=0.7): the inner splits are decided by
    the outer one, so each 3-node tree collapses to a single split."""
    forest = ADVERSARIAL["duplicate_thresholds"]()
    out = _pass("dedup_thresholds")(forest, {})
    assert int(out.n_nodes.sum()) == forest.n_trees          # 1 per tree
    assert optim.verify_equivalence(forest, out) == "allclose"


def test_dedup_constant_chain_collapses():
    forest = ADVERSARIAL["constant_threshold_chain"]()       # 3-node chain
    out = _pass("dedup_thresholds")(forest, {})
    assert int(out.n_nodes.sum()) == 1
    assert out.max_depth == 2          # from_trees convention: stump = 2


def test_dedup_canonicalizes_negative_zero():
    from repro.trees.cart import Tree, TreeNode
    l = TreeNode(value=np.array([1.0]))
    r = TreeNode(value=np.array([2.0]))
    t0 = Tree(TreeNode(feature=0, threshold=-0.0, left=l, right=r), 2, 1)
    t1 = Tree(TreeNode(feature=0, threshold=0.0,
                       left=TreeNode(value=np.array([1.0])),
                       right=TreeNode(value=np.array([2.0]))), 2, 1)
    forest = core.from_trees([t0, t1], n_features=1, n_classes=1)
    assert optim.n_unique_splits(forest) == 2                # bitwise ≠
    out = _pass("dedup_thresholds")(forest, {})
    assert optim.n_unique_splits(out) == 1                   # canonical
    assert optim.verify_equivalence(forest, out) == "allclose"


def test_dedup_resolves_inf_thresholds():
    forest = ADVERSARIAL["inf_thresholds"]()
    out = _pass("dedup_thresholds")(forest, {})
    # x <= +inf always fires, x <= -inf never (finite inputs): both
    # stumps collapse to their reached leaf
    assert int(out.n_nodes.sum()) < int(forest.n_nodes.sum())
    assert optim.verify_equivalence(forest, out) == "allclose"


def test_merge_equivalent_leaves_folds_constant_subtrees():
    from repro.trees.cart import Tree, TreeNode

    def leaf(v):
        return TreeNode(value=np.array([v]))

    # whole tree is the constant 5.0 → folds to a single leaf bottom-up
    root = TreeNode(feature=0, threshold=0.0,
                    left=TreeNode(feature=1, threshold=1.0,
                                  left=leaf(5.0), right=leaf(5.0)),
                    right=leaf(5.0))
    keep = TreeNode(feature=0, threshold=0.5, left=leaf(1.0),
                    right=leaf(2.0))
    forest = core.from_trees([Tree(root, 3, 2), Tree(keep, 2, 1)],
                             n_features=2, n_classes=1)
    out = _pass("merge_equivalent_leaves")(forest, {})
    assert out.n_nodes.tolist() == [0, 1]
    assert out.n_leaves_per_tree.tolist() == [1, 2]
    assert optim.verify_equivalence(forest, out) == "allclose"


def test_merge_keeps_distinct_leaves():
    forest = ADVERSARIAL["one_tree"]()                       # -1.0 / 1.0
    out = _pass("merge_equivalent_leaves")(forest, {})
    assert int(out.n_nodes.sum()) == int(forest.n_nodes.sum())


def test_compact_shrinks_padding_and_drops_zero_trees():
    from repro.trees.cart import Tree, TreeNode
    deep = TreeNode(feature=0, threshold=0.0,
                    left=TreeNode(feature=1, threshold=-1.0,
                                  left=TreeNode(value=np.array([1.0])),
                                  right=TreeNode(value=np.array([2.0]))),
                    right=TreeNode(value=np.array([3.0])))
    forest = core.from_trees(
        [Tree(TreeNode(value=np.array([0.0])), 1, 0),       # exact zero
         Tree(deep, 3, 2),
         Tree(TreeNode(value=np.array([4.0])), 1, 0)],      # kept constant
        n_features=2, n_classes=1)
    # padding L is inflated to 8 to give compact something to strip
    from repro.optim.rewrite import extract_tree, rebuild_forest
    fat = rebuild_forest(forest, [extract_tree(forest, t)
                                  for t in range(forest.n_trees)],
                         n_leaves=8)
    out = _pass("compact")(fat, {})
    assert out.n_trees == 2                                  # zero dropped
    assert out.n_leaves == 3                                 # L: 8 → 3
    assert optim.verify_equivalence(fat, out) == "allclose"


def test_compact_keeps_one_tree_when_everything_is_zero():
    from repro.trees.cart import Tree, TreeNode
    forest = core.from_trees(
        [Tree(TreeNode(value=np.array([0.0])), 1, 0)] * 3,
        n_features=1, n_classes=1)
    out = _pass("compact")(forest, {})
    assert out.n_trees == 1
    np.testing.assert_array_equal(out.predict_oracle(np.zeros((2, 1))),
                                  [[0.0], [0.0]])


def test_drop_unused_features_remaps_and_keeps_fullwidth_rows():
    forest = ADVERSARIAL["unused_features"]()                # d=8, uses {5}
    out = _pass("drop_unused_features")(forest, {})
    # n_features_in is the true caller-side width (8, recorded at remap
    # time), not the max(feat_map)+1 lower bound (6)
    assert out.n_features == 1 and out.n_features_in == 8
    np.testing.assert_array_equal(out.feat_map, [5])
    X = _X(forest, B=12, seed=3)
    np.testing.assert_array_equal(out.predict_oracle(X[:, out.feat_map]),
                                  forest.predict_oracle(X))
    # the engine path still takes full-width rows (transform remaps)
    pred = core.compile_forest(out, engine="bitvector")
    np.testing.assert_allclose(pred.predict(X), forest.predict_oracle(X),
                               rtol=1e-5, atol=1e-6)


def test_drop_unused_features_composes_with_existing_map():
    forest = ADVERSARIAL["unused_features"]()
    once = _pass("drop_unused_features")(forest, {})
    # artificially re-widen: tack an unused column onto the remapped IR
    import dataclasses
    wide = dataclasses.replace(once, n_features=3,
                               feat_map=np.array([5, 2, 7]),
                               feat_lo=None, feat_hi=None)
    twice = _pass("drop_unused_features")(wide, {})
    np.testing.assert_array_equal(twice.feat_map, [5])       # composed
    assert twice.n_features == 1


def test_quantize_inputs_applies_feat_map_for_float_and_quantized():
    forest = ADVERSARIAL["unused_features"]()
    X = _X(forest, B=8, seed=4)
    out = _pass("drop_unused_features")(forest, {})
    np.testing.assert_array_equal(quantize_inputs(out, X), X[:, [5]])
    qf = core.quantize_forest(forest, X)
    qout = _pass("drop_unused_features")(qf, {})
    np.testing.assert_array_equal(quantize_inputs(qout, X),
                                  quantize_inputs(qf, X)[:, [5]])


def test_quantize_after_drop_unused_aligns_calibration_columns():
    """optimize-then-quantize (the reverse of the pipeline order) must
    calibrate per-feature ranges on the *remapped* columns."""
    forest = ADVERSARIAL["unused_features"]()                # uses col 5
    X = _X(forest, B=32, seed=9)
    dropped = _pass("drop_unused_features")(forest, {})
    q_direct = core.quantize_forest(forest, X)
    q_opt = core.quantize_forest(dropped, X)
    np.testing.assert_array_equal(q_opt.feat_lo, q_direct.feat_lo[[5]])
    np.testing.assert_array_equal(quantize_inputs(q_opt, X),
                                  quantize_inputs(q_direct, X)[:, [5]])
    np.testing.assert_array_equal(
        core.compile_forest(q_opt).predict(X),
        core.compile_forest(q_direct).predict(X))


def test_reorder_trees_puts_discriminative_first():
    from repro.trees.cart import Tree, TreeNode
    const = Tree(TreeNode(value=np.array([0.5, 0.5])), 1, 0)
    disc = Tree(TreeNode(feature=0, threshold=0.0,
                         left=TreeNode(value=np.array([9.0, 0.0])),
                         right=TreeNode(value=np.array([0.0, 9.0]))), 2, 1)
    forest = core.from_trees([const, const, disc], n_features=1,
                             n_classes=2)
    # data-free fallback: leaf spread ranks the split tree first
    out = _pass("reorder_trees")(forest, {})
    assert int(out.n_nodes[0]) == 1 and out.n_nodes[1:].tolist() == [0, 0]
    # validation-set cost model agrees
    X = np.linspace(-1, 1, 32)[:, None]
    out2 = _pass("reorder_trees")(forest, {"X_calib": X})
    assert int(out2.n_nodes[0]) == 1


def test_reorder_is_deterministic_and_stable_on_ties(small_forest):
    a = _pass("reorder_trees")(small_forest, {})
    b = _pass("reorder_trees")(small_forest, {})
    np.testing.assert_array_equal(a.threshold, b.threshold)


def test_per_tree_scores_sum_to_oracle(class_forest):
    X = rand_X(class_forest, B=16)
    S = optim.per_tree_scores(class_forest, X)
    np.testing.assert_allclose(S.sum(axis=0),
                               class_forest.predict_oracle(X),
                               rtol=1e-6, atol=1e-8)


# --------------------------------------------------------------------------- #
# the equivalence contract: verification is mandatory and actually bites
# --------------------------------------------------------------------------- #
def test_verify_catches_a_broken_pass(small_forest):
    @optim.register_pass("_broken", doc="flips a leaf (test only)")
    def _broken(forest, ctx):
        import dataclasses
        lv = forest.leaf_value.copy()
        lv[0, 0] += np.ones_like(lv[0, 0])      # int- and float-safe
        return dataclasses.replace(forest, leaf_value=lv)

    try:
        with pytest.raises(optim.OptimizationError, match="diverges"):
            optim.optimize(small_forest, ("_broken",))
        qf = core.quantize_forest(small_forest,
                                  rand_X(small_forest, B=64))
        with pytest.raises(optim.OptimizationError, match="bit-exact"):
            optim.optimize(qf, ("_broken",))
    finally:
        del optim.OPT_PASSES["_broken"]


def test_optimize_quantized_reports_bitexact(small_forest):
    qf = core.quantize_forest(small_forest, rand_X(small_forest, B=64))
    res = optim.optimize(qf, 2)
    assert res.verified == "bit-exact"
    assert res.tag == "O2" and len(res.stats) == 5


@pytest.mark.parametrize("name", sorted(optim.OPT_LEVELS[2]))
@pytest.mark.parametrize("case", sorted(ADVERSARIAL))
def test_every_pass_preserves_oracle_on_catalog(case, name):
    """The satellite property: every registered optimizer pass preserves
    predict_oracle across the conformance catalog's adversarial forests,
    float and (where possible) quantized."""
    forest = ADVERSARIAL[case]()
    optim.optimize(forest, (name,))          # raises on divergence
    if case in QUANTIZABLE:
        qf = core.quantize_forest(forest, _X(forest, B=16, seed=1))
        res = optim.optimize(qf, (name,))
        assert res.verified == "bit-exact"


# --------------------------------------------------------------------------- #
# -O2 through every registered engine × backend combo (acceptance)
# --------------------------------------------------------------------------- #
def _compile(forest, name, backend, **kw):
    if backend == "pallas":
        kw.setdefault("interpret", True)
    return core.compile_forest(forest, engine=name, backend=backend, **kw)


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
def test_O2_matches_O0_for_every_engine_backend(name, backend):
    forest = ADVERSARIAL["mixed_stump_and_deep"]()
    X = _X(forest, B=12, seed=5)
    qf = core.quantize_forest(forest, X)
    q0 = _compile(qf, name, backend)
    q2 = _compile(qf, name, backend, opt=2)
    np.testing.assert_array_equal(q2.predict(X), q0.predict(X),
                                  err_msg=f"{name}/{backend} quantized")
    f0 = _compile(forest, name, backend)
    f2 = _compile(forest, name, backend, opt=2)
    np.testing.assert_allclose(f2.predict(X), f0.predict(X),
                               rtol=1e-5, atol=1e-6,
                               err_msg=f"{name}/{backend} float")


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_O2_quantized_bitexact_across_catalog(case, engine):
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=6)
    qf = core.quantize_forest(forest, X)
    p0 = _compile(qf, engine, "jax")
    p2 = _compile(qf, engine, "jax", opt=2)
    np.testing.assert_array_equal(p2.predict(X), p0.predict(X),
                                  err_msg=f"{case}/{engine}")


# --------------------------------------------------------------------------- #
# pipeline plan records
# --------------------------------------------------------------------------- #
def test_plan_records_optimizer_passes(small_forest):
    pred = core.compile_forest(small_forest, engine="bitvector", opt=2)
    names = [r.name for r in pred.plan.records]
    for p in optim.OPT_LEVELS[2]:
        assert f"opt.{p}" in names
    assert "optimize" in names
    d = pred.plan.describe()
    assert "O2" in d and "verified" in d and "nodes" in d


def test_plan_O0_keeps_single_skipped_record(small_forest):
    from repro.core.pipeline import PIPELINE
    pred = core.compile_forest(small_forest, engine="bitvector")
    assert [r.name for r in pred.plan.records] == list(PIPELINE)
    rec = [r for r in pred.plan.records if r.name == "optimize"][0]
    assert "skipped" in rec.detail


# --------------------------------------------------------------------------- #
# packed serialization of optimized IR (headers + feat_map round trip)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_optimized_forest_roundtrip(case, tmp_path):
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=7)
    qf = core.quantize_forest(forest, X)
    of = optim.optimize(qf, 2).forest
    p = str(tmp_path / "opt.repro.npz")
    io.save_forest(of, p)
    loaded = io.load_forest(p)
    if of.feat_map is None:
        assert loaded.feat_map is None
    else:
        np.testing.assert_array_equal(loaded.feat_map, of.feat_map)
        assert io.peek(p)["forest"]["n_features_in"] == of.n_features_in
    np.testing.assert_array_equal(quantize_inputs(loaded, X),
                                  quantize_inputs(of, X))
    Xq = quantize_inputs(of, X)
    np.testing.assert_array_equal(loaded.predict_oracle(Xq),
                                  of.predict_oracle(Xq))


@pytest.mark.parametrize("engine", JAX_ENGINES)
def test_optimized_predictor_artifact_roundtrip(engine, tmp_path):
    """compile -O2 → save → load → predict is bit-identical, optimizer
    plan records included (the artifact can explain how it was built)."""
    forest = ADVERSARIAL["unused_features"]()
    X = _X(forest, B=10, seed=8)
    qf = core.quantize_forest(forest, X)
    pred = core.compile_forest(qf, engine=engine, opt=2)
    p = str(tmp_path / "pred.repro.npz")
    io.save_predictor(pred, p)
    loaded = io.load_predictor(p)
    np.testing.assert_array_equal(pred.predict(X), loaded.predict(X),
                                  err_msg=engine)
    names = [r.name for r in loaded.plan.records]
    assert any(n.startswith("opt.") for n in names)


# --------------------------------------------------------------------------- #
# autotuner opt_levels sweeps + cache hygiene
# --------------------------------------------------------------------------- #
@pytest.fixture(autouse=True)
def _fresh_cache():
    engine_select.clear_cache()
    yield
    engine_select.clear_cache()


def test_autotuner_opt_sweep(small_forest):
    c = engine_select.choose(small_forest, 16, engines=("qs", "native"),
                             opt_levels=(1, 2), cache_path=None, repeats=1)
    assert set(c.timings) == {"qs", "qs@O1", "qs@O2",
                              "native", "native@O1", "native@O2"}
    assert c.engine == min(c.timings, key=c.timings.get)
    X = rand_X(small_forest, B=16)
    np.testing.assert_allclose(c.predict(X),
                               small_forest.predict_oracle(X),
                               rtol=1e-4, atol=1e-5)
    # the winner carries a plan that names its variant
    plan = c.predictor.plan
    if c.engine.endswith("@O2"):
        assert any(r.name == "optimize" and "O2" in r.detail
                   for r in plan.records)


def test_autotuner_opt_sweep_composes_with_quant(small_forest):
    c = engine_select.choose(small_forest, 16, engines=("native",),
                             quant_specs=(core.QuantSpec(bits=16),),
                             opt_levels=(2,), cache_path=None, repeats=1)
    assert set(c.timings) == {"native", "native@O2", "native@q16",
                              "native@q16@O2"}


def test_old_cache_entries_keymiss_opt_sweeps(small_forest, tmp_path):
    """The acceptance invariant: an entry written before the opt axis
    existed must key-miss an opt-level sweep (partial re-bench), never
    answer for it."""
    import json
    cache = str(tmp_path / "engines.json")
    plain = engine_select.choose(small_forest, 16, engines=("qs", "native"),
                                 cache_path=cache, repeats=1)
    engine_select.clear_cache()              # fresh process, disk only
    c = engine_select.choose(small_forest, 16, engines=("qs", "native"),
                             opt_levels=(2,), cache_path=cache, repeats=1)
    assert not c.from_cache
    assert c.timings["qs"] == plain.timings["qs"]    # reused, not re-run
    assert set(c.timings) == {"qs", "native", "qs@O2", "native@O2"}
    # widened entry answers both request shapes now
    assert engine_select.choose(small_forest, 16, engines=("qs", "native"),
                                opt_levels=(2,), cache_path=cache,
                                repeats=1).from_cache
    assert engine_select.choose(small_forest, 16, engines=("qs", "native"),
                                cache_path=cache, repeats=1).from_cache
    with open(cache) as f:
        entry = json.load(f)[plain.key]
    assert set(entry["timings"]) == set(c.timings)


def test_opt_sweep_rejects_garbage_level(small_forest):
    with pytest.raises(ValueError, match="opt level"):
        engine_select.choose(small_forest, 16, engines=("qs",),
                             opt_levels=("O9",), cache_path=None,
                             repeats=1)


def test_server_serves_opt_winner(small_forest, tmp_path):
    from repro.inference.server import ForestServer
    srv = ForestServer.from_forest(small_forest, max_batch=8,
                                   engines=("qs",), opt_levels=(2,),
                                   cache_path=str(tmp_path / "c.json"),
                                   repeats=1)
    assert srv.engine_choice.engine in {"qs", "qs@O2"}
    rng = np.random.default_rng(0)
    for i in range(8):
        srv.submit(rng.normal(size=small_forest.n_features),
                   arrival_s=float(i) * 1e-4)
    done = srv.flush(now_s=1.0)
    assert len(done) == 8


# --------------------------------------------------------------------------- #
# cascade compatibility: stage splits see the reordered forest
# --------------------------------------------------------------------------- #
def test_cascade_over_O2_forest_scoreboundgate_exact(class_forest):
    """A cascade over the optimized (reordered) forest with the sound
    bound gate keeps predict_class equal to the -O0 full forest."""
    from repro.cascade import CascadePredictor, CascadeSpec, ScoreBoundGate
    X = rand_X(class_forest, B=48)
    qf = core.quantize_forest(class_forest, X)
    of = optim.optimize(qf, 2).forest
    base = core.compile_forest(qf, engine="bitvector")
    casc = CascadePredictor(
        of, CascadeSpec((max(of.n_trees // 3, 1), of.n_trees),
                        ScoreBoundGate()), engine="bitvector")
    np.testing.assert_array_equal(casc.predict_class(X),
                                  base.predict_class(X))


def test_pipeline_opt_plus_cascade_stages_split_optimized_forest(
        class_forest):
    from repro.cascade import CascadeSpec, MarginGate
    X = rand_X(class_forest, B=32)
    qf = core.quantize_forest(class_forest, X)
    of = optim.optimize(qf, 2).forest
    pred = core.compile_forest(
        qf, engine="bitvector", opt=2,
        cascade=CascadeSpec((4, qf.n_trees), MarginGate(np.inf)))
    # the cascade's host forest is the optimized one (reordered trees)
    np.testing.assert_array_equal(pred.host_forest().threshold,
                                  of.threshold)
    base = core.compile_forest(qf, engine="bitvector")
    np.testing.assert_array_equal(pred.predict(X), base.predict(X))


def test_reorder_improves_bound_gate_exits():
    """Discriminative-first ordering lets the sound gate exit rows no
    later than the worst ordering (the pass's whole point)."""
    from repro.cascade import CascadePredictor, CascadeSpec, ScoreBoundGate
    rng = np.random.default_rng(5)
    from repro.trees.cart import Tree, TreeNode
    trees = []
    for i in range(8):       # weak (near-zero) trees first by construction
        v = 0.01 if i < 6 else 5.0
        trees.append(Tree(TreeNode(
            feature=0, threshold=float(rng.normal()),
            left=TreeNode(value=np.array([v, 0.0])),
            right=TreeNode(value=np.array([0.0, v]))), 2, 1))
    forest = core.from_trees(trees, n_features=1, n_classes=2)
    X = rng.normal(0, 1, size=(64, 1))
    stages = (4, 8)

    def mean_trees(f):
        casc = CascadePredictor(f, CascadeSpec(stages, ScoreBoundGate()),
                                engine="bitvector")
        casc.predict(X)
        return casc.mean_trees_evaluated

    plain = mean_trees(forest)
    ordered = mean_trees(_pass("reorder_trees")(forest, {"X_calib": X}))
    assert ordered <= plain
    assert ordered < forest.n_trees          # some rows actually exit


# --------------------------------------------------------------------------- #
# shared analysis (rapidscorer consumes the optimizer's unique_splits)
# --------------------------------------------------------------------------- #
def test_merge_nodes_delegates_to_optim_analysis(small_forest):
    uf, ut, inv, n = core.merge_nodes(small_forest)
    uf2, ut2, inv2, n2 = optim.unique_splits(small_forest)
    np.testing.assert_array_equal(uf, uf2)
    np.testing.assert_array_equal(ut, ut2)
    np.testing.assert_array_equal(inv, inv2)
    assert n == n2
    assert core.merge_stats(small_forest) == \
        optim.unique_fraction(small_forest)


# --------------------------------------------------------------------------- #
# hypothesis: randomized adversarial forests (CI; skipped offline)
# --------------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    from test_conformance import adversarial_forests, _widen

    @settings(max_examples=20, deadline=None)
    @given(adversarial_forests(), st.sampled_from(sorted(optim.OPT_PASSES)),
           st.integers(0, 9999))
    def test_hypothesis_every_pass_preserves_oracle(af, name, xseed):
        base, d_total, n_stumps, seed = af
        forest = _widen(base, d_total, n_stumps, seed)
        optim.optimize(forest, (name,), seed=xseed)   # raises on breakage
        qf = core.quantize_forest(
            forest, np.random.default_rng(xseed).normal(
                0, 2.0, size=(16, d_total)))
        res = optim.optimize(qf, (name,), seed=xseed)
        assert res.verified == "bit-exact"

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 10), st.integers(1, 8), st.integers(0, 9999))
    def test_hypothesis_O2_cascade_bound_gate_exact(T, k, xseed):
        from repro.cascade import CascadePredictor, CascadeSpec, \
            ScoreBoundGate
        forest = core.random_forest_ir(T, 8, 4, n_classes=2,
                                       seed=xseed % 89, full=False)
        X = np.random.default_rng(xseed).normal(0, 2.0, size=(24, 4))
        qf = core.quantize_forest(forest, X)
        of = optim.optimize(qf, 2).forest
        base = core.compile_forest(qf, engine="bitvector")
        casc = CascadePredictor(
            of, CascadeSpec((min(k, of.n_trees), of.n_trees),
                            ScoreBoundGate()), engine="bitvector")
        np.testing.assert_array_equal(casc.predict_class(X),
                                      base.predict_class(X))
