"""Engine equivalence: every *registered* engine ≡ the oracles, float and
quantized, scalar and multiclass, single- and multi-word.

The parametrization is sourced from ``core.registry`` — registering a new
engine automatically enrolls it in the shared agreement suite below
(engine × backend × float/quantized vs ``eval_scalar_numpy``)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import registry
from repro.core.quickscorer import (compile_qs, ctz32, eval_batch,
                                    eval_scalar_numpy, exit_leaf)
from repro.core.rapidscorer import compile_rs, eval_batch as rs_eval

from conftest import rand_X

ENGINES = list(registry.engines("jax"))
COMBOS = [(s.name, s.backend) for s in registry.specs()]
COMBO_IDS = [f"{n}/{b}" for n, b in COMBOS]


def _compile(forest, name, backend):
    kw = {"interpret": True} if backend == "pallas" else {}
    return core.compile_forest(forest, engine=name, backend=backend, **kw)


def scalar_oracle_f32(forest, X_raw):
    """``eval_scalar_numpy`` recast to the engines' float32 arithmetic.

    For quantized forests both sides compute exact integer leaf sums and
    divide by the same power-of-two scale, so the comparison is bitwise."""
    Xq = core.quantize_inputs(forest, np.asarray(X_raw))
    s = core.leaf_scale(forest)
    raw = eval_scalar_numpy(forest, Xq) * s        # exact int sums (f64)
    return raw.astype(np.float32) / np.float32(s)


# --------------------------------------------------------------------------- #
# bit helpers
# --------------------------------------------------------------------------- #
def test_ctz32_exhaustive_bits():
    for b in range(32):
        w = jnp.uint32(1 << b)
        assert int(ctz32(w)) == b


def test_ctz32_composite():
    assert int(ctz32(jnp.uint32(0b101000))) == 3
    assert int(ctz32(jnp.uint32(0xFFFFFFFF))) == 0


def test_exit_leaf_multiword():
    # word 0 empty, word 1 has bit 5 → leaf 37
    idx = jnp.asarray(np.array([[0, 1 << 5]], dtype=np.uint32))
    assert int(exit_leaf(idx)[0]) == 37
    idx = jnp.asarray(np.array([[1 << 31, 1 << 5]], dtype=np.uint32))
    assert int(exit_leaf(idx)[0]) == 31


# --------------------------------------------------------------------------- #
# shared agreement suite: every registered (engine × backend) combination
# vs the faithful scalar QuickScorer, float AND quantized
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="session")
def quant_forest(small_forest):
    """small_forest quantized with the paper-default 16-bit spec (all
    scales are powers of two → engine outputs must be bit-exact)."""
    return core.quantize_forest(small_forest,
                                rand_X(small_forest, B=256, seed=9))


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
def test_engine_float_agrees_with_scalar_oracle(name, backend, small_forest):
    X = rand_X(small_forest, B=12)
    pred = _compile(small_forest, name, backend)
    expect = eval_scalar_numpy(small_forest, X)
    np.testing.assert_allclose(pred.predict(X), expect, rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
def test_engine_quantized_bitexact_vs_scalar_oracle(name, backend,
                                                    quant_forest):
    X = rand_X(quant_forest, B=12, seed=7)
    pred = _compile(quant_forest, name, backend)
    expect = scalar_oracle_f32(quant_forest, X)
    np.testing.assert_array_equal(pred.predict(X), expect)


# --------------------------------------------------------------------------- #
# engines vs the vectorized traversal oracle across forest shapes
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("fixture", ["small_forest", "class_forest",
                                     "big_leaf_forest"])
def test_engine_matches_oracle(engine, fixture, request):
    forest = request.getfixturevalue(fixture)
    X = rand_X(forest, B=96)
    pred = core.compile_forest(forest, engine=engine)
    expect = forest.predict_oracle(X)
    got = pred.predict(X)
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_quantized_matches_quantized_oracle(engine, trained_rf,
                                                   magic_ds):
    forest = core.from_random_forest(trained_rf)
    qf = core.quantize_forest(forest, magic_ds.X_train)
    X = magic_ds.X_test[:96]
    pred = core.compile_forest(qf, engine=engine)
    got = pred.predict(X)
    from repro.kernels.ref import ref_oracle
    expect = ref_oracle(qf, X)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)


def test_scalar_qs_matches_batch(small_forest):
    """Faithful Algorithm 1 (sorted features, early break) ≡ predicated
    batch evaluation — validates the DESIGN.md §2.1 predication claim."""
    X = rand_X(small_forest, B=16)
    scalar = eval_scalar_numpy(small_forest, X)
    batch = np.asarray(eval_batch(compile_qs(small_forest),
                                  jnp.asarray(X)))
    np.testing.assert_allclose(scalar, batch, rtol=1e-5, atol=1e-6)


def test_rapidscorer_equals_quickscorer(class_forest):
    X = rand_X(class_forest, B=48)
    qs = np.asarray(eval_batch(compile_qs(class_forest), jnp.asarray(X)))
    rs = np.asarray(rs_eval(compile_rs(class_forest), jnp.asarray(X)))
    np.testing.assert_allclose(qs, rs, rtol=1e-6)


def test_merging_reduces_unique_nodes(trained_rf):
    """RF trees share thresholds (binned training) → merging must help."""
    forest = core.from_random_forest(trained_rf)
    frac = core.merge_stats(forest)
    assert 0.0 < frac < 1.0


def test_merge_idempotent_on_distinct_nodes():
    f = core.random_forest_ir(4, 8, 4, seed=11)
    # continuous random thresholds: collisions ~impossible
    frac = core.merge_stats(f)
    assert frac == pytest.approx(1.0)


def test_threshold_boundary_exact():
    """x == t must go LEFT (predicate is x > t for the mask)."""
    from repro.trees.cart import Tree, TreeNode
    l0 = TreeNode(value=np.array([1.0]))
    l1 = TreeNode(value=np.array([2.0]))
    root = TreeNode(feature=0, threshold=0.5, left=l0, right=l1)
    f = core.from_trees([Tree(root, 2, 1)], n_features=1, n_classes=1)
    X = np.array([[0.5], [0.5 + 1e-6]])
    for engine in ENGINES:
        got = core.compile_forest(f, engine=engine).predict(X)
        np.testing.assert_allclose(got[:, 0], [1.0, 2.0], rtol=1e-6,
                                   err_msg=engine)


def test_single_leaf_tree():
    """Degenerate trees (no splits) must contribute their constant."""
    from repro.trees.cart import Tree, TreeNode
    stump = Tree(TreeNode(value=np.array([7.0])), 1, 0)
    l0 = TreeNode(value=np.array([1.0]))
    l1 = TreeNode(value=np.array([2.0]))
    real = Tree(TreeNode(feature=0, threshold=0.0, left=l0, right=l1), 2, 1)
    f = core.from_trees([stump, real], n_features=1, n_classes=1)
    X = np.array([[-1.0], [1.0]])
    expect = np.array([[8.0], [9.0]])
    for engine in ENGINES:
        got = core.compile_forest(f, engine=engine).predict(X)
        np.testing.assert_allclose(got, expect, rtol=1e-6, err_msg=engine)


def test_gbt_forest_roundtrip(magic_ds):
    from repro.trees.gradient_boosting import (GradientBoosting,
                                               GradientBoostingConfig)
    gb = GradientBoosting(GradientBoostingConfig(
        n_trees=20, max_leaves=8, objective="l2", seed=0)).fit(
        magic_ds.X_train, magic_ds.y_train.astype(np.float64))
    forest = core.from_gradient_boosting(gb)
    X = magic_ds.X_test[:64]
    direct = gb.predict(X)
    via_ir = forest.predict_oracle(X)[:, 0]
    np.testing.assert_allclose(via_ir, direct, rtol=1e-6, atol=1e-8)
    for engine in ENGINES:
        got = core.compile_forest(forest, engine=engine).predict(X)[:, 0]
        np.testing.assert_allclose(got, direct, rtol=1e-4, atol=1e-5,
                                   err_msg=engine)


def test_softmax_gbt_class_embedding(magic_ds):
    from repro.trees.gradient_boosting import (GradientBoosting,
                                               GradientBoostingConfig)
    gb = GradientBoosting(GradientBoostingConfig(
        n_trees=12, max_leaves=8, objective="softmax", seed=0)).fit(
        magic_ds.X_train, magic_ds.y_train)
    forest = core.from_gradient_boosting(gb)
    assert forest.n_classes == 2
    X = magic_ds.X_test[:64]
    np.testing.assert_allclose(forest.predict_oracle(X), gb.predict(X),
                               rtol=1e-6, atol=1e-8)
