"""Forest IR invariants: canonicalisation, interval masks, leafidx."""
import numpy as np
import pytest

from repro import core
from repro.core.forest import WORD, _interval_bits
from repro.trees.cart import Tree, TreeNode


def test_interval_bits_basic():
    bits = _interval_bits(0, 4, 1)
    assert bits[0] == 0b1111
    bits = _interval_bits(2, 5, 1)
    assert bits[0] == 0b11100


def test_interval_bits_cross_word():
    bits = _interval_bits(30, 34, 2)
    assert bits[0] == (1 << 30) | (1 << 31)
    assert bits[1] == 0b11


def test_interval_bits_empty():
    bits = _interval_bits(5, 5, 2)
    assert (bits == 0).all()


def _manual_tree():
    """      n0(f0 <= 0.5)
            /      \
       leaf0       n1(f1 <= -1)
                   /    \
               leaf1    leaf2
    """
    l0 = TreeNode(value=np.array([1.0]))
    l1 = TreeNode(value=np.array([2.0]))
    l2 = TreeNode(value=np.array([3.0]))
    n1 = TreeNode(feature=1, threshold=-1.0, left=l1, right=l2)
    n0 = TreeNode(feature=0, threshold=0.5, left=l0, right=n1)
    return Tree(n0, 3, 2)


def test_from_trees_canonical():
    f = core.from_trees([_manual_tree()], n_features=2, n_classes=1)
    assert f.n_trees == 1 and f.n_leaves == 3
    assert f.n_nodes[0] == 2 and f.n_leaves_per_tree[0] == 3
    # preorder: node0 = root, node1 = right child
    assert f.feature[0, 0] == 0 and f.feature[0, 1] == 1
    # leaf intervals: root covers [0,3) split at 1; n1 covers [1,3) at 2
    assert (f.leaf_lo[0, 0], f.leaf_mid[0, 0], f.leaf_hi[0, 0]) == (0, 1, 3)
    assert (f.leaf_lo[0, 1], f.leaf_mid[0, 1], f.leaf_hi[0, 1]) == (1, 2, 3)
    # leaves numbered left-to-right
    assert f.leaf_value[0, :, 0].tolist() == [1.0, 2.0, 3.0]


def test_oracle_matches_hand_eval():
    f = core.from_trees([_manual_tree()], n_features=2, n_classes=1)
    X = np.array([[0.0, 0.0],      # left at root → leaf0
                  [1.0, -2.0],     # right, left → leaf1
                  [1.0, 0.0]])     # right, right → leaf2
    np.testing.assert_allclose(f.predict_oracle(X)[:, 0], [1.0, 2.0, 3.0])


def test_node_masks_clear_left_interval():
    f = core.from_trees([_manual_tree()], n_features=2, n_classes=1)
    masks = f.node_masks()
    # root mask clears leaf 0 (bit 0)
    assert masks[0, 0, 0] & 0b1 == 0
    assert masks[0, 0, 0] & 0b110 == 0b110
    # n1 mask clears leaf 1
    assert masks[0, 1, 0] & 0b10 == 0
    # padding node (index 2+, none here since N = L-1 = 2) — all nodes real


def test_init_leafidx_only_real_leaves(class_forest):
    idx = class_forest.init_leafidx()
    for t in range(class_forest.n_trees):
        n_set = sum(bin(int(w)).count("1") for w in idx[t])
        assert n_set == class_forest.n_leaves_per_tree[t]


def test_padding_invariants(class_forest):
    f = class_forest
    pad = f.feature < 0
    # padded nodes have identity masks
    masks = f.node_masks()
    assert (masks[pad] == 0xFFFFFFFF).all()


def test_oracle_matches_trainer_trees(trained_rf, magic_ds):
    forest = core.from_random_forest(trained_rf)
    X = magic_ds.X_test[:128]
    np.testing.assert_allclose(forest.predict_oracle(X),
                               trained_rf.predict_proba(X), rtol=1e-6,
                               atol=1e-9)


def test_random_forest_ir_shapes():
    f = core.random_forest_ir(5, 16, 4, n_classes=2, seed=9)
    assert f.feature.shape == (5, 15)
    assert f.leaf_value.shape == (5, 16, 2)
    assert f.n_words == 1
    f64 = core.random_forest_ir(3, 64, 4, seed=9)
    assert f64.n_words == 2
