"""Integration: training loop (checkpoint-restart determinism, watchdog,
compression) and serving (micro-batcher, forest server, LM server)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import get_config
from repro.inference.server import ForestServer, LMServer, MicroBatcher, \
    Request
from repro.launch.train import Trainer, run_loop
from repro.models.model import Model


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("smollm_360m").reduced()


@pytest.fixture(scope="module")
def trainer_f(tiny_cfg):
    def make(**kw):
        return Trainer(tiny_cfg, batch=2, seq_len=32, **kw)
    return make


# --------------------------------------------------------------------------- #
# training loop
# --------------------------------------------------------------------------- #
def test_loss_decreases(trainer_f):
    tr = trainer_f(lr=1e-2)
    tr.init_state()
    losses = [tr.train_step()["loss"] for _ in range(8)]
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_checkpoint_restart_bit_identical(trainer_f, tmp_path):
    """train 4 steps ≡ train 2, checkpoint, restore in a NEW trainer,
    train 2 — the fault-tolerance contract."""
    tr1 = trainer_f()
    tr1.init_state()
    for _ in range(2):
        tr1.train_step()
    tr1.save(str(tmp_path))
    r3 = tr1.train_step()
    r4 = tr1.train_step()

    tr2 = trainer_f()
    got = tr2.restore(str(tmp_path))
    assert got == 2
    s3 = tr2.train_step()
    s4 = tr2.train_step()
    assert s3["loss"] == pytest.approx(r3["loss"], rel=1e-5)
    assert s4["loss"] == pytest.approx(r4["loss"], rel=1e-5)


def test_compressed_grads_still_learn(trainer_f):
    tr = trainer_f(lr=1e-2, compress_grads=True)
    tr.init_state()
    losses = [tr.train_step()["loss"] for _ in range(8)]
    assert losses[-1] < losses[0]


def test_int8_opt_state_still_learns(trainer_f):
    tr = trainer_f(lr=1e-2, opt_state="int8")
    tr.init_state()
    losses = [tr.train_step()["loss"] for _ in range(12)]
    # int8 moment quantization is noisy step-to-step; compare trailing
    # vs leading averages
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_run_loop_writes_ckpt_and_log(trainer_f, tmp_path):
    tr = trainer_f()
    log = tmp_path / "log.jsonl"
    recs = run_loop(tr, steps=4, ckpt_dir=str(tmp_path / "ck"),
                    ckpt_every=2, log_path=str(log),
                    hb_dir=str(tmp_path / "hb"))
    assert len(recs) == 4
    from repro.distributed import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path / "ck")) == 4
    assert len(log.read_text().strip().splitlines()) == 4
    from repro.distributed.fault_tolerance import Heartbeat
    hb = Heartbeat.survey(str(tmp_path / "hb"), timeout_s=1e9)
    assert hb[0]["step"] == 4


def test_run_loop_resume(trainer_f, tmp_path):
    tr = trainer_f()
    run_loop(tr, steps=3, ckpt_dir=str(tmp_path / "ck"), ckpt_every=1)
    tr2 = trainer_f()
    recs = run_loop(tr2, steps=5, ckpt_dir=str(tmp_path / "ck"),
                    ckpt_every=1)
    assert [r["step"] for r in recs] == [4, 5]


# --------------------------------------------------------------------------- #
# micro-batcher
# --------------------------------------------------------------------------- #
def test_microbatcher_flush_on_size():
    mb = MicroBatcher(max_batch=4, max_wait_ms=1e9)
    for i in range(3):
        mb.add(Request(i, None, arrival_s=0.0))
    assert not mb.ready(now_s=0.001)
    mb.add(Request(3, None, arrival_s=0.0))
    assert mb.ready(now_s=0.001)
    assert len(mb.drain()) == 4 and not mb.queue


def test_microbatcher_flush_on_deadline():
    mb = MicroBatcher(max_batch=100, max_wait_ms=5.0)
    mb.add(Request(0, None, arrival_s=10.0))
    assert not mb.ready(now_s=10.004)
    assert mb.ready(now_s=10.006)


def test_microbatcher_drain_caps_at_max_batch():
    mb = MicroBatcher(max_batch=2, max_wait_ms=0.0)
    for i in range(5):
        mb.add(Request(i, None, arrival_s=0.0))
    assert len(mb.drain()) == 2
    assert len(mb.queue) == 3


def test_microbatcher_rejects_nonpositive_max_batch():
    """max_batch=0 would make drain() emit empty batches forever — the
    flush() loop would spin without making progress."""
    for bad in (0, -1):
        with pytest.raises(ValueError, match="max_batch"):
            MicroBatcher(max_batch=bad)


def test_empty_flush_and_poll_are_noops(small_forest):
    """Empty-queue flush/poll: no batches run, stats untouched."""
    pred = core.compile_forest(small_forest, engine="bitvector")
    srv = ForestServer(pred, max_batch=8, max_wait_ms=1.0)
    assert srv.flush(now_s=0.0) == []
    assert srv.poll(now_s=1e9) == []
    assert srv._run([], now_s=0.0) == []          # zero-request batch
    s = srv.stats.summary()
    assert s["n_requests"] == 0 and s["n_batches"] == 0
    assert srv.stats.batch_sizes == [] and srv.stats.latencies_ms == []


def test_record_batch_empty_is_noop():
    from repro.inference.server import ServerStats
    st = ServerStats()
    st.record_batch([])
    assert st.n_batches == 0 and st.n_requests == 0
    assert st.batch_sizes == [] and st.latencies_ms == []


# --------------------------------------------------------------------------- #
# forest server
# --------------------------------------------------------------------------- #
def test_forest_server_end_to_end(small_forest):
    pred = core.compile_forest(small_forest, engine="bitvector")
    srv = ForestServer(pred, max_batch=8, max_wait_ms=1.0)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(20, small_forest.n_features))
    direct = pred.predict(X)
    done = []
    for i in range(20):
        srv.submit(X[i], arrival_s=float(i) * 1e-4)
        done.extend(srv.poll(now_s=float(i) * 1e-4))
    done.extend(srv.flush(now_s=1.0))
    assert len(done) == 20
    got = np.stack([r.result for r in sorted(done, key=lambda r: r.rid)])
    np.testing.assert_allclose(got, direct, rtol=1e-5, atol=1e-6)
    assert srv.stats.summary()["n_requests"] == 20


def test_forest_server_save_load_cold_start(small_forest, tmp_path):
    """save() → load() restores the serving config and a predictor whose
    outputs are bit-identical — the no-recompile cold-start path."""
    qf = core.quantize_forest(small_forest,
                              np.random.default_rng(0).normal(
                                  size=(64, small_forest.n_features)))
    srv = ForestServer.from_forest(qf, max_batch=16, max_wait_ms=3.0,
                                   engines=("qs", "native"),
                                   cache_path=None, repeats=1)
    X = np.random.default_rng(1).normal(size=(8, qf.n_features))
    path = str(tmp_path / "server.repro.npz")
    srv.save(path)
    srv2 = ForestServer.load(path)
    np.testing.assert_array_equal(srv.predictor.predict(X),
                                  srv2.predictor.predict(X))
    assert srv2.batcher.max_batch == 16
    assert srv2.batcher.max_wait_ms == 3.0
    assert srv2.engine_choice == srv.engine_choice.engine
    assert srv2.stats.summary()["n_requests"] == 0      # fresh stats
    # the restored server actually serves
    srv2.submit(X[0], arrival_s=0.0)
    done = srv2.flush(now_s=1.0)
    assert len(done) == 1
    np.testing.assert_array_equal(done[0].result,
                                  srv.predictor.predict(X[:1])[0])


# --------------------------------------------------------------------------- #
# LM server
# --------------------------------------------------------------------------- #
def test_lm_server_greedy_matches_forward(tiny_cfg):
    model = Model(tiny_cfg, compute_dtype=jnp.float32, q_chunk=16,
                  ssd_chunk=8, loss_chunk=16, remat=False)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    srv = LMServer(model, params, batch=2, max_len=24)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, tiny_cfg.vocab, size=(2, 8)).astype(np.int32)
    out = srv.generate(prompts, n_new=4)
    assert out.shape == (2, 12)
    np.testing.assert_array_equal(out[:, :8], prompts)
    # first generated token == argmax of the full forward at the last prompt
    # position (greedy decode consistency)
    logits = model.forward(params, jnp.asarray(prompts))
    expect = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    np.testing.assert_array_equal(out[:, 8], expect)
