"""int8 KV-cache decode (beyond-paper: §5 applied to the decode roofline).

Correctness: quantized-cache decode must track the bf16-cache decode
closely (per-position/head scales make dequantization exact up to int8
rounding of K/V values)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import quantize_kv_token
from repro.models.model import Model


def test_quantize_kv_token_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, size=(2, 1, 4, 16)), jnp.float32)
    q, scale = quantize_kv_token(x)
    assert q.dtype == jnp.int8 and scale.shape == (2, 1, 4)
    deq = q.astype(jnp.float32) * scale[..., None]
    err = np.abs(np.asarray(deq - x))
    # max rounding error = scale/2 per (b, s, k) row
    assert (err <= np.asarray(scale)[..., None] * 0.51 + 1e-7).all()


@pytest.mark.parametrize("arch", ["smollm_360m", "phi3_mini_3_8b",
                                  "jamba_1_5_large_398b"])
def test_int8_kv_decode_tracks_f32(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, compute_dtype=jnp.float32, q_chunk=16, ssd_chunk=8,
                  loss_chunk=16, remat=False)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 8
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)

    def run(kv_quant):
        state = model.init_decode_state(B, S + 1, dtype=jnp.float32,
                                        kv_quant=kv_quant)
        if kv_quant:
            assert state["k"].dtype == jnp.int8
            assert "k_scale" in state
        step = jax.jit(model.decode_step)
        outs = []
        for i in range(S):
            logits, state = step(params, state, toks[:, i:i + 1])
            outs.append(np.asarray(logits))
        return np.stack(outs, axis=1)

    base = run(False)
    quant = run(True)
    # logits track closely; ranking of the argmax token is preserved
    np.testing.assert_allclose(quant, base, rtol=0.05, atol=0.05)
    np.testing.assert_array_equal(quant.argmax(-1), base.argmax(-1))


def test_lm_server_kv_quant_generates():
    """LMServer with the int8 cache must produce the same greedy tokens
    as the bf16-cache server on a short prompt."""
    cfg = get_config("smollm_360m").reduced()
    model = Model(cfg, compute_dtype=jnp.float32, q_chunk=16, ssd_chunk=8,
                  loss_chunk=16, remat=False)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    from repro.inference.server import LMServer
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, cfg.vocab, size=(2, 6)).astype(np.int32)
    out_bf = LMServer(model, params, batch=2, max_len=16).generate(
        prompts, n_new=4)
    out_q8 = LMServer(model, params, batch=2, max_len=16,
                      kv_quant=True).generate(prompts, n_new=4)
    np.testing.assert_array_equal(out_bf, out_q8)


def test_kv_quant_state_bytes_halved():
    cfg = get_config("smollm_360m").reduced()
    model = Model(cfg, remat=False)
    s_bf16 = jax.eval_shape(
        lambda: model.init_decode_state(4, 64, kv_quant=False))
    s_int8 = jax.eval_shape(
        lambda: model.init_decode_state(4, 64, kv_quant=True))

    def nbytes(t):
        return sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(t))

    # int8 + f32 scale per head: (1 + 4/hd) bytes/elem vs 2 bf16. The
    # reduced config's hd=16 gives 1.25/2 = 0.625; production hd=128
    # gives 1.03/2 = 0.52.
    assert nbytes(s_int8) < 0.65 * nbytes(s_bf16)
