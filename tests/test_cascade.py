"""Cascade subsystem: gate policies, calibration, the staged predictor,
pipeline/autotuner/server wiring, and packed-artifact round trips."""
import copy

import numpy as np
import pytest

from repro import core, io
from repro.cascade import (CascadePredictor, CascadeSpec,
                           FusedCascadePredictor, MarginGate, ProbaGate,
                           ScoreBoundGate, calibrate, normalize_stages,
                           policy_from_header, policy_to_header,
                           simulate_gate, tree_slice)
from repro.inference.server import ForestServer, ServerStats


@pytest.fixture(scope="module")
def qclass_forest():
    """Quantized multiclass forest — the cascade's home turf."""
    f = core.random_forest_ir(n_trees=24, n_leaves=16, n_features=8,
                              n_classes=3, seed=7, full=False)
    return core.quantize_forest(f, None)


def _X(forest, B=48, seed=0):
    return np.random.default_rng(seed).normal(
        0, 1.2, size=(B, forest.n_features))


# --------------------------------------------------------------------------- #
# stage normalization + tree slicing
# --------------------------------------------------------------------------- #
def test_normalize_stages():
    assert normalize_stages((16, 48), 192) == (16, 48, 192)
    assert normalize_stages((48, 16, 16), 192) == (16, 48, 192)
    assert normalize_stages((500,), 192) == (192,)        # clamped
    assert normalize_stages((16, 500), 192) == (16, 192)
    assert normalize_stages((192,), 192) == (192,)
    with pytest.raises(ValueError, match="positive"):
        normalize_stages((0, 16), 192)


def test_tree_slice_matches_oracle(qclass_forest):
    X = _X(qclass_forest)
    Xq = core.quantize_inputs(qclass_forest, X)
    whole = qclass_forest.predict_oracle(Xq)
    parts = sum(tree_slice(qclass_forest, a, b).predict_oracle(Xq)
                for a, b in [(0, 8), (8, 20), (20, 24)])
    np.testing.assert_array_equal(whole, parts)
    sub = tree_slice(qclass_forest, 8, 20)
    assert sub.n_trees == 12
    assert sub.quant_scale == qclass_forest.quant_scale
    assert sub.leaf_scale == qclass_forest.leaf_scale


# --------------------------------------------------------------------------- #
# gate policies
# --------------------------------------------------------------------------- #
def test_margin_gate_inf_never_fires(qclass_forest):
    g = MarginGate(np.inf)
    g.prepare(qclass_forest, (8, 24))
    assert not g.exits(np.random.default_rng(0).normal(size=(10, 3)), 0).any()


def test_margin_gate_fires_on_confident_rows(qclass_forest):
    g = MarginGate(0.5)
    g.prepare(qclass_forest, (8, 24))
    scores = np.array([[10.0, 0.1, 0.1],     # confident → exit
                       [1.0, 1.0, 1.0]])     # uniform → stay
    ex = g.exits(scores, 0)
    assert ex.tolist() == [True, False]


def test_proba_gate(qclass_forest):
    g = ProbaGate(0.9)
    g.prepare(qclass_forest, (8, 24))
    scores = np.array([[10.0, 0.1, 0.1], [1.0, 1.0, 1.0]])
    assert g.exits(scores, 0).tolist() == [True, False]


def test_margin_gate_never_fires_on_regression(small_forest):
    """C=1: no margin exists, the heuristic gates must stay closed."""
    g = MarginGate(0.1)
    g.prepare(small_forest, (4, 8))
    assert not g.exits(np.ones((5, 1)), 0).any()


def test_score_bound_gate_is_sound(qclass_forest):
    """slack=0 bound gating never changes predict_class — for any data,
    by construction."""
    base = core.compile_forest(qclass_forest, engine="bitvector")
    casc = CascadePredictor(qclass_forest,
                            CascadeSpec((6, 12, 24), ScoreBoundGate()))
    for seed in range(3):
        X = _X(qclass_forest, B=64, seed=seed)
        np.testing.assert_array_equal(casc.predict_class(X),
                                      base.predict_class(X))


def test_score_bound_gate_fires_when_provable():
    """A forest whose later trees have tiny leaves: early scores dominate
    the remaining bounds, so rows provably exit after stage 0."""
    f = core.random_forest_ir(n_trees=8, n_leaves=8, n_features=4,
                              n_classes=2, seed=3, full=False)
    f.leaf_value[4:] *= 1e-4            # trees 4..8 can barely move scores
    g = ScoreBoundGate()
    g.prepare(f, (4, 8))
    casc = CascadePredictor(f, CascadeSpec((4, 8), ScoreBoundGate()))
    casc.predict(_X(f, B=64, seed=1))
    assert casc.last_exit_counts[0] > 0            # some rows proved early
    base = core.compile_forest(f, engine="bitvector")
    X = _X(f, B=64, seed=1)
    np.testing.assert_array_equal(casc.predict_class(X),
                                  base.predict_class(X))


def test_score_bound_gate_c1_decision():
    """C=1 (GBM logit shape): exits only when the sign vs decision is
    provably fixed."""
    f = core.random_forest_ir(n_trees=6, n_leaves=8, n_features=4,
                              n_classes=1, seed=5, full=False)
    g = ScoreBoundGate()
    g.prepare(f, (3, 6))
    lo, hi = g._rest_min[0][0], g._rest_max[0][0]
    fixed_pos = np.array([[abs(lo) + 1.0]])        # score + lo > 0
    fixed_neg = np.array([[-(abs(hi) + 1.0)]])     # score + hi < 0
    undecided = np.array([[0.0]])
    assert g.exits(fixed_pos, 0).tolist() == [True]
    assert g.exits(fixed_neg, 0).tolist() == [True]
    assert g.exits(undecided, 0).tolist() == [False]


def test_policy_header_roundtrip():
    for pol in (MarginGate(0.85), ProbaGate(0.99), MarginGate(np.inf),
                ScoreBoundGate(slack=0.5, decision=1.0)):
        h = policy_to_header(pol)
        back = policy_from_header(h)
        assert type(back) is type(pol)
        assert back == pol
    with pytest.raises(ValueError, match="GatePolicy"):
        policy_from_header({"class": "repro.core.forest:Forest",
                            "config": {}})


def test_disabled_gate_header_is_strict_json():
    """MarginGate(inf) — calibrate's fallback — must serialize to
    RFC-8259 JSON: json.dumps would otherwise emit the nonstandard
    ``Infinity`` literal into the packed artifact header."""
    import json
    h = policy_to_header(MarginGate(np.inf))
    text = json.dumps(h, allow_nan=False)          # raises on Infinity
    back = policy_from_header(json.loads(text))
    assert back.threshold == np.inf


# --------------------------------------------------------------------------- #
# predictor: gating mechanics + exit accounting
# --------------------------------------------------------------------------- #
def test_exit_counts_sum_to_batch(qclass_forest):
    casc = CascadePredictor(qclass_forest,
                            CascadeSpec((6, 12), MarginGate(0.3)))
    X = _X(qclass_forest, B=37)
    casc.predict(X)
    assert casc.last_exit_counts.sum() == 37
    casc.predict(X[:5])
    assert casc.last_exit_counts.sum() == 5
    assert casc.exit_counts.sum() == 42
    np.testing.assert_allclose(casc.exit_fractions.sum(), 1.0)
    assert (qclass_forest.n_trees >= casc.mean_trees_evaluated >= 6)


def test_gated_rows_carry_prefix_scores(qclass_forest):
    """A row that exits at stage k returns exactly the cumulative score
    of stages <= k (the gate simulation is the predictor's semantics)."""
    casc = CascadePredictor(qclass_forest,
                            CascadeSpec((6, 12), MarginGate(0.3)))
    X = _X(qclass_forest, B=40, seed=2)
    got = casc.predict(X)
    cum = casc.cumulative_scores(X)
    pol = copy.copy(casc.policy)
    exit_stage, expect = simulate_gate(pol, cum)
    np.testing.assert_array_equal(got, expect)
    counts = np.bincount(exit_stage, minlength=len(casc.stages))
    np.testing.assert_array_equal(counts, casc.last_exit_counts)


def test_empty_batch(qclass_forest):
    casc = CascadePredictor(qclass_forest, CascadeSpec((6, 12)))
    out = casc.predict(np.zeros((0, qclass_forest.n_features)))
    assert out.shape == (0, 3)
    assert casc.last_exit_counts.sum() == 0


def test_predict_proba_matches_base_when_gate_off(qclass_forest):
    base = core.compile_forest(qclass_forest, engine="bitvector")
    casc = CascadePredictor(qclass_forest,
                            CascadeSpec((8, 24), MarginGate(np.inf)))
    X = _X(qclass_forest, B=16, seed=4)
    np.testing.assert_array_equal(casc.predict_proba(X),
                                  base.predict_proba(X))


def test_predictor_protocol(qclass_forest):
    from repro.core.registry import Predictor
    casc = CascadePredictor(qclass_forest, CascadeSpec((8, 24)))
    assert isinstance(casc, Predictor)
    assert casc.host_forest() is qclass_forest
    X = _X(qclass_forest, B=4)
    np.testing.assert_array_equal(
        casc.transform_inputs(X), core.quantize_inputs(qclass_forest, X))


def test_stage_recompiles_are_bucketed(qclass_forest, monkeypatch):
    """Shrinking batches must hit stage engines at power-of-two sizes:
    distinct raw batch sizes inside one bucket → one evaluated shape."""
    casc = CascadePredictor(qclass_forest,
                            CascadeSpec((6, 24), MarginGate(np.inf)))
    seen = []
    stage0 = casc.stage_predictors[0]
    orig = stage0.predict_transformed

    def spy(X):
        seen.append(X.shape[0])
        return orig(X)

    monkeypatch.setattr(stage0, "predict_transformed", spy)
    for B in (3, 9, 15, 16):
        casc.predict(_X(qclass_forest, B=B))
    assert set(seen) == {4, 16}        # buckets, not raw sizes


def test_inputs_quantized_once_not_per_stage(qclass_forest, monkeypatch):
    """A K-stage cascade must transform each batch once — not once per
    stage — while producing identical scores."""
    from repro.core import quantize as qmod
    casc = CascadePredictor(qclass_forest,
                            CascadeSpec((6, 12, 24), MarginGate(np.inf)))
    assert casc._pre_transform
    calls = []
    orig = qmod.quantize_inputs

    def spy(forest, X):
        calls.append(X.shape)
        return orig(forest, X)

    monkeypatch.setattr(qmod, "quantize_inputs", spy)
    # predictor module binds quantize_inputs at import; patch there too
    import repro.cascade.predictor as pmod
    monkeypatch.setattr(pmod, "quantize_inputs", spy)
    X = _X(qclass_forest, B=16, seed=21)
    got = casc.predict(X)
    assert len(calls) == 1
    base = core.compile_forest(qclass_forest, engine="bitvector")
    np.testing.assert_array_equal(got, base.predict(X))


def test_autotuned_cascade_winner_has_clean_exit_stats(class_forest,
                                                       monkeypatch):
    """The sweep's synthetic benchmark rows must not pollute the served
    exit accounting of a returned cascade predictor.  The cascade is
    forced to win by pinning the measured timings, so the polluted
    best-so-far predictor is exactly the one handed back."""
    from repro.core import engine_select
    engine_select.clear_cache()
    spec = CascadeSpec(stages=(2, 12), policy=MarginGate(0.0))
    cascade_name = f"qs@{spec.tag()}"

    real_bench = engine_select._bench_once

    def rigged(pred, X, repeats):
        real_bench(pred, X, repeats)       # benchmark rows really flow
        return 0.0 if isinstance(pred, CascadePredictor) else 1.0

    monkeypatch.setattr(engine_select, "_bench_once", rigged)
    c = engine_select.choose(class_forest, 16, engines=("qs",),
                             cascade_specs=(spec,), cache_path=None,
                             repeats=2)
    assert c.engine == cascade_name
    assert isinstance(c.predictor, CascadePredictor)
    assert c.predictor.exit_counts.sum() == 0
    engine_select.clear_cache()


# --------------------------------------------------------------------------- #
# satellite regression: survivor padding must be zero rows, not repeats
# of row 0 — and padding must never leak into gates or exit accounting
# --------------------------------------------------------------------------- #
def test_stage_padding_rows_are_zero_and_inert(qclass_forest, monkeypatch):
    casc = CascadePredictor(qclass_forest,
                            CascadeSpec((6, 24), MarginGate(0.3)))
    captured = []
    stage0 = casc.stage_predictors[0]
    orig = stage0.predict_transformed

    def spy(X):
        captured.append(np.asarray(X).copy())
        return orig(X)

    monkeypatch.setattr(stage0, "predict_transformed", spy)
    X = _X(qclass_forest, B=13, seed=30)
    X[0] = 50.0                  # pathological first row
    got = casc.predict(X)
    counts = casc.last_exit_counts.copy()
    assert captured[0].shape[0] == 16
    assert not np.any(captured[0][13:]), \
        "bucket padding must be zero rows, not row-0 repeats"
    # padding inertness: each row's score and exit stage are what the
    # row gets when predicted alone (any padding influence would shift
    # the gate statistics of some batch composition)
    casc.reset_exit_stats()
    rows, stages = [], []
    for i in range(13):
        rows.append(casc.predict(X[i:i + 1]))
        stages.append(int(np.flatnonzero(casc.last_exit_counts)[0]))
    np.testing.assert_array_equal(got, np.concatenate(rows))
    np.testing.assert_array_equal(
        np.bincount(stages, minlength=len(casc.stages)), counts)


# --------------------------------------------------------------------------- #
# fused execution: one jitted computation, same observable behavior
# --------------------------------------------------------------------------- #
def test_fused_spec_tag_keys_new_cache_entries(qclass_forest):
    staged_spec = CascadeSpec((6, 24), MarginGate(0.3))
    fused_spec = CascadeSpec((6, 24), MarginGate(0.3), fused=True)
    assert "cascade-fused=" in fused_spec.tag()
    assert fused_spec.tag() != staged_spec.tag()


def test_compile_forest_fused_plan_records(qclass_forest):
    pred = core.compile_forest(qclass_forest, engine="bitmm",
                               cascade=CascadeSpec((8, 24), fused=True))
    assert isinstance(pred, FusedCascadePredictor) and pred.fused
    assert "(fused)" in pred.plan.describe()
    assert "fused" in pred.describe()
    assert pred.host_syncs == 1


def test_staged_host_syncs_is_stage_count(qclass_forest):
    casc = CascadePredictor(qclass_forest, CascadeSpec((6, 12, 24)))
    assert casc.host_syncs == 3


def test_fused_matches_staged_across_batch_sizes(qclass_forest):
    staged = CascadePredictor(qclass_forest,
                              CascadeSpec((6, 12, 24), MarginGate(0.3)))
    fused = FusedCascadePredictor(
        qclass_forest, CascadeSpec((6, 12, 24), MarginGate(0.3),
                                   fused=True))
    for B in (1, 3, 37, 64):
        X = _X(qclass_forest, B=B, seed=B)
        np.testing.assert_array_equal(fused.predict(X), staged.predict(X),
                                      err_msg=f"B={B}")
        np.testing.assert_array_equal(fused.last_exit_counts,
                                      staged.last_exit_counts,
                                      err_msg=f"B={B}")
    assert fused.exit_counts.sum() == staged.exit_counts.sum() == 105


def test_fused_empty_batch(qclass_forest):
    fused = FusedCascadePredictor(qclass_forest,
                                  CascadeSpec((6, 12), fused=True))
    out = fused.predict(np.zeros((0, qclass_forest.n_features)))
    assert out.shape == (0, 3)
    assert fused.last_exit_counts.sum() == 0


def test_fused_set_policy_rebuilds_program(qclass_forest):
    """The fused trace closes over the gate — swapping the policy must
    swap the compiled behavior, not serve a stale jit."""
    fused = FusedCascadePredictor(
        qclass_forest, CascadeSpec((6, 12, 24), MarginGate(np.inf),
                                   fused=True))
    X = _X(qclass_forest, B=20, seed=31)
    fused.predict(X)
    assert fused.last_exit_counts.tolist() == [0, 0, 20]   # never exits
    fused.set_policy(MarginGate(0.0))
    fused.predict(X)
    assert fused.last_exit_counts.tolist() == [20, 0, 0]   # all exit at 0


def test_fused_server_reports_exit_fractions(qclass_forest):
    """The in-graph exit-count vector must feed ServerStats exactly like
    the staged loop's host-side accounting."""
    fused = core.compile_forest(qclass_forest, engine="bitvector",
                                cascade=CascadeSpec((6, 24),
                                                    MarginGate(0.3),
                                                    fused=True))
    srv = ForestServer(fused, max_batch=8, max_wait_ms=1.0)
    X = _X(qclass_forest, B=24, seed=12)
    for i in range(24):
        srv.submit(X[i], arrival_s=float(i) * 1e-4)
    srv.flush(now_s=1.0)
    s = srv.stats.summary()
    assert len(s["exit_fractions"]) == 2
    np.testing.assert_allclose(np.sum(s["exit_fractions"]), 1.0)
    assert sum(srv.stats.stage_exit_counts) == 24


def test_autotuner_accepts_fused_candidates(class_forest, monkeypatch):
    """A fused spec flows through engine_select.choose under its
    cascade-fused tag (key-missing pre-fusion cache entries)."""
    from repro.core import engine_select
    engine_select.clear_cache()
    spec = CascadeSpec(stages=(2, 12), policy=MarginGate(0.0), fused=True)
    assert "cascade-fused=" in spec.tag()

    real_bench = engine_select._bench_once

    def rigged(pred, X, repeats):
        real_bench(pred, X, repeats)
        return 0.0 if isinstance(pred, CascadePredictor) else 1.0

    monkeypatch.setattr(engine_select, "_bench_once", rigged)
    c = engine_select.choose(class_forest, 16, engines=("qs",),
                             cascade_specs=(spec,), cache_path=None,
                             repeats=2)
    assert c.engine == f"qs@{spec.tag()}"
    assert isinstance(c.predictor, FusedCascadePredictor)
    assert c.predictor.exit_counts.sum() == 0
    engine_select.clear_cache()


# --------------------------------------------------------------------------- #
# calibration
# --------------------------------------------------------------------------- #
def _trained_cascade(trained_rf, magic_ds, engine="bitvector"):
    qf = core.quantize_forest(core.from_random_forest(trained_rf),
                              magic_ds.X_train)
    casc = core.compile_forest(qf, engine=engine,
                               cascade=CascadeSpec((8, 32)))
    return qf, casc


def test_calibrate_respects_accuracy_floor(trained_rf, magic_ds):
    qf, casc = _trained_cascade(trained_rf, magic_ds)
    n = len(magic_ds.X_test) // 2
    res = calibrate(casc, magic_ds.X_test[:n], magic_ds.y_test[:n],
                    floor_pp=0.5)
    assert res.accuracy >= res.full_accuracy - 0.5 / 100
    assert res.mean_trees <= qf.n_trees
    # every reported candidate row is self-consistent
    for row in res.table:
        assert row["mean_trees"] <= qf.n_trees
        np.testing.assert_allclose(np.sum(row["exit_fractions"]), 1.0)
    # the winner actually installs and gates
    casc.set_policy(res.policy)
    casc.reset_exit_stats()
    acc = (casc.predict_class(magic_ds.X_test[n:])
           == magic_ds.y_test[n:]).mean()
    assert acc >= res.full_accuracy - 0.02     # held-out sanity, loose
    assert casc.exit_counts.sum() == len(magic_ds.X_test) - n


def test_calibrate_zero_floor_falls_back_to_exact(trained_rf, magic_ds):
    """floor_pp=0 admits only candidates with zero in-sample drop; the
    disabled-gate fallback guarantees one always exists."""
    _, casc = _trained_cascade(trained_rf, magic_ds)
    n = len(magic_ds.X_test) // 2
    res = calibrate(casc, magic_ds.X_test[:n], magic_ds.y_test[:n],
                    floor_pp=0.0, policies=[MarginGate(0.01)])
    assert res.accuracy >= res.full_accuracy


# --------------------------------------------------------------------------- #
# pipeline + compile_forest wiring
# --------------------------------------------------------------------------- #
def test_compile_forest_cascade_plan_records(qclass_forest):
    pred = core.compile_forest(qclass_forest, engine="bitmm",
                               cascade=CascadeSpec((8, 24)))
    assert isinstance(pred, CascadePredictor)
    names = [r.name for r in pred.plan.records]
    assert "cascade" in names and "lower" in names
    assert "stages=8/24" in pred.plan.describe()
    assert "cascade" in pred.plan.describe()


def test_cascade_rejects_multi_device(qclass_forest):
    with pytest.raises(ValueError, match="cascade"):
        core.compile_plan(qclass_forest, engine="bitvector",
                          n_devices=2, cascade=CascadeSpec((8, 24)))


# --------------------------------------------------------------------------- #
# packed artifacts
# --------------------------------------------------------------------------- #
def test_cascade_save_load_bitexact_with_thresholds(qclass_forest,
                                                    tmp_path):
    casc = core.compile_forest(qclass_forest, engine="bitvector",
                               cascade=CascadeSpec((6, 12, 24),
                                                   MarginGate(0.35)))
    X = _X(qclass_forest, B=32, seed=9)
    p = str(tmp_path / "casc.repro.npz")
    io.save_predictor(casc, p)
    assert io.peek(p)["kind"] == "cascade"
    loaded = io.load_predictor(p)
    assert isinstance(loaded, CascadePredictor)
    assert loaded.stages == casc.stages
    assert loaded.policy == casc.policy            # threshold round-trips
    np.testing.assert_array_equal(casc.predict(X), loaded.predict(X))
    np.testing.assert_array_equal(loaded.last_exit_counts,
                                  casc.last_exit_counts)
    assert "deserialize" in loaded.plan.describe()


def test_cascade_save_rejects_nonserializable_engine(qclass_forest,
                                                     tmp_path):
    casc = CascadePredictor(qclass_forest, CascadeSpec((8, 24)),
                            engine="bitvector", backend="pallas",
                            engine_kw={"interpret": True})
    with pytest.raises(ValueError, match="serial_arrays"):
        io.save_predictor(casc, str(tmp_path / "x.repro.npz"))


def test_forest_server_save_load_cascade(qclass_forest, tmp_path):
    casc = core.compile_forest(qclass_forest, engine="bitvector",
                               cascade=CascadeSpec((8, 24),
                                                   MarginGate(0.4)))
    srv = ForestServer(casc, max_batch=8, max_wait_ms=1.0)
    path = str(tmp_path / "server.repro.npz")
    srv.save(path)
    srv2 = ForestServer.load(path)
    assert isinstance(srv2.predictor, CascadePredictor)
    X = _X(qclass_forest, B=8, seed=11)
    np.testing.assert_array_equal(srv.predictor.predict(X),
                                  srv2.predictor.predict(X))
    assert srv2.batcher.max_batch == 8


# --------------------------------------------------------------------------- #
# serving: exit fractions in ServerStats
# --------------------------------------------------------------------------- #
def test_server_reports_exit_fractions(qclass_forest):
    casc = core.compile_forest(qclass_forest, engine="bitvector",
                               cascade=CascadeSpec((6, 24),
                                                   MarginGate(0.3)))
    srv = ForestServer(casc, max_batch=8, max_wait_ms=1.0)
    X = _X(qclass_forest, B=24, seed=12)
    for i in range(24):
        srv.submit(X[i], arrival_s=float(i) * 1e-4)
    srv.flush(now_s=1.0)
    s = srv.stats.summary()
    assert "exit_fractions" in s
    assert len(s["exit_fractions"]) == 2
    np.testing.assert_allclose(np.sum(s["exit_fractions"]), 1.0)
    assert sum(srv.stats.stage_exit_counts) == 24


def test_server_no_exit_fractions_for_plain_predictor(small_forest):
    pred = core.compile_forest(small_forest, engine="bitvector")
    srv = ForestServer(pred, max_batch=4, max_wait_ms=1.0)
    srv.submit(np.zeros(small_forest.n_features), arrival_s=0.0)
    srv.flush(now_s=1.0)
    assert "exit_fractions" not in srv.stats.summary()


# --------------------------------------------------------------------------- #
# satellite regression: idle ServerStats report null latencies, not 0.0
# --------------------------------------------------------------------------- #
def test_idle_server_stats_percentiles_are_null():
    s = ServerStats().summary()
    assert s["p50_ms"] is None and s["p99_ms"] is None
    assert s["n_requests"] == 0


def test_served_stats_percentiles_are_numbers(small_forest):
    pred = core.compile_forest(small_forest, engine="bitvector")
    srv = ForestServer(pred, max_batch=4, max_wait_ms=1.0)
    for i in range(4):
        srv.submit(np.zeros(small_forest.n_features),
                   arrival_s=float(i) * 1e-4)
    srv.flush(now_s=1.0)
    s = srv.stats.summary()
    assert isinstance(s["p50_ms"], float) and s["p50_ms"] > 0
    assert isinstance(s["p99_ms"], float) and s["p99_ms"] >= s["p50_ms"]
