"""Tree-training substrate: CART, Random Forest, Gradient Boosting."""
import numpy as np
import pytest

from repro.data import datasets
from repro.trees.cart import Binner, CartConfig, grow_tree
from repro.trees.gradient_boosting import (GradientBoosting,
                                           GradientBoostingConfig)
from repro.trees.random_forest import RandomForest, RandomForestConfig


@pytest.fixture(scope="module")
def ds():
    return datasets.load("magic", n=2000)


def test_binner_roundtrip(ds):
    b = Binner.fit(ds.X_train, 32)
    Xb = b.transform(ds.X_train)
    assert Xb.dtype == np.int16
    assert Xb.min() >= 0
    for f in range(ds.n_features):
        assert Xb[:, f].max() <= len(b.edges[f])


def test_binner_threshold_consistency(ds):
    """'bin <= b' and 'x <= threshold(f, b)' must agree on the data."""
    b = Binner.fit(ds.X_train, 16)
    Xb = b.transform(ds.X_train)
    for f in range(min(4, ds.n_features)):
        for bb in range(len(b.edges[f])):
            t = b.threshold(f, bb)
            np.testing.assert_array_equal(Xb[:, f] <= bb,
                                          ds.X_train[:, f] <= t)


def test_grow_tree_respects_limits(ds):
    b = Binner.fit(ds.X_train, 32)
    Xb = b.transform(ds.X_train)
    rng = np.random.default_rng(0)
    for max_leaves in (2, 8, 32):
        t = grow_tree(Xb, b, CartConfig(max_leaves=max_leaves,
                                        criterion="gini"),
                      rng, y=ds.y_train, n_classes=2)
        assert t.n_leaves <= max_leaves
    t = grow_tree(Xb, b, CartConfig(max_leaves=64, max_depth=3,
                                    criterion="gini"),
                  rng, y=ds.y_train, n_classes=2)
    assert t.max_depth_seen <= 3


def test_tree_predict_fast_equals_slow(ds):
    b = Binner.fit(ds.X_train, 32)
    Xb = b.transform(ds.X_train)
    rng = np.random.default_rng(1)
    t = grow_tree(Xb, b, CartConfig(max_leaves=16, criterion="gini"),
                  rng, y=ds.y_train, n_classes=2)
    X = ds.X_test[:200]
    np.testing.assert_allclose(t.predict(X), t.predict_slow(X))


def test_rf_beats_majority(ds):
    rf = RandomForest(RandomForestConfig(n_trees=32, max_leaves=32,
                                         seed=0)).fit(ds.X_train, ds.y_train)
    acc = (rf.predict(ds.X_test) == ds.y_test).mean()
    majority = max(np.bincount(ds.y_test)) / len(ds.y_test)
    assert acc > majority + 0.1


def test_rf_proba_sums_to_one(ds):
    rf = RandomForest(RandomForestConfig(n_trees=16, max_leaves=8,
                                         seed=0)).fit(ds.X_train, ds.y_train)
    p = rf.predict_proba(ds.X_test[:64])
    np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-9)
    assert (p >= 0).all()


def test_gbt_l2_train_loss_decreases(ds):
    y = ds.y_train.astype(np.float64)
    losses = []
    for n in (5, 20, 60):
        gb = GradientBoosting(GradientBoostingConfig(
            n_trees=n, max_leaves=8, objective="l2", seed=0)).fit(
            ds.X_train, y)
        losses.append(np.mean((gb.predict(ds.X_train) - y) ** 2))
    assert losses[0] > losses[1] > losses[2]


def test_gbt_logistic(ds):
    gb = GradientBoosting(GradientBoostingConfig(
        n_trees=40, max_leaves=8, objective="logistic", seed=0)).fit(
        ds.X_train, ds.y_train)
    acc = ((gb.predict(ds.X_test) > 0) == ds.y_test).mean()
    assert acc > 0.75


def test_gbt_softmax_multiclass():
    mn = datasets.load("mnist", n=1500)
    gb = GradientBoosting(GradientBoostingConfig(
        n_trees=60, max_leaves=8, objective="softmax", seed=0)).fit(
        mn.X_train, mn.y_train)
    acc = (gb.predict(mn.X_test).argmax(1) == mn.y_test).mean()
    assert acc > 0.5         # 10 classes, random = 0.1


def test_rf_multiclass_mnist_like():
    mn = datasets.load("mnist", n=1500)
    rf = RandomForest(RandomForestConfig(n_trees=24, max_leaves=32,
                                         seed=0)).fit(mn.X_train, mn.y_train)
    acc = (rf.predict(mn.X_test) == mn.y_test).mean()
    assert acc > 0.6
