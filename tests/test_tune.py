"""Zero-shot (-Os) autotuning: cache schema v2 + device fingerprint,
the repro.tune extractor and cost model, the predict / fallback /
feedback paths, shared-IR sweeps and optimizer-aware pruning
(docs/AUTOTUNE.md)."""
import json
import os

import numpy as np
import pytest

from repro import core, optim, tune
from repro.core import engine_select, registry
from repro.io import packed

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # container without hypothesis: CI covers it
    HAVE_HYPOTHESIS = False

CHEAP = ("qs", "qs-bitmm", "native")
TRAIN_SHAPES = [(8, 16, 6, 1), (16, 16, 8, 1), (8, 32, 6, 3),
                (24, 16, 10, 1)]


@pytest.fixture(autouse=True)
def _fresh_cache():
    engine_select.clear_cache()
    yield
    engine_select.clear_cache()


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    """A populated schema-v2 cache + a cost model trained from it —
    built once per module (full sweeps are the expensive part)."""
    td = tmp_path_factory.mktemp("tune")
    cache = str(td / "cache.json")
    engine_select.clear_cache()
    for i, (T, L, d, C) in enumerate(TRAIN_SHAPES):
        f = core.random_forest_ir(T, L, d, n_classes=C, seed=i)
        engine_select.choose(f, 64, engines=CHEAP, cache_path=cache,
                             repeats=1)
    model_path = str(td / "model.json")
    model = tune.train_from_cache(cache, save_to=model_path)
    engine_select.clear_cache()
    return {"dir": td, "cache": cache, "model": model,
            "model_path": model_path}


def _held_out(seed=99):
    return core.random_forest_ir(12, 16, 7, n_classes=1, seed=seed)


# ------------------------------------------------------------------------- #
# Satellite 1: device/backend fingerprint in the cache key
# ------------------------------------------------------------------------- #
def test_shape_key_carries_device_fingerprint(small_forest):
    key = engine_select.shape_key(small_forest, 64)
    assert key.endswith(f"_fp{engine_select.fingerprint_hash()}")


def test_foreign_machine_cache_entry_key_misses(small_forest, tmp_path,
                                                monkeypatch):
    """Regression: a cache file measured on other hardware (different
    fingerprint in the key) must re-sweep, not serve its winner."""
    cache = str(tmp_path / "engines.json")
    c1 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    # simulate "copied from another machine": rewrite the key with a
    # foreign fingerprint, as if hardware (not the file) had changed
    with open(cache) as f:
        data = json.load(f)
    foreign_key = c1.key.rsplit("_fp", 1)[0] + "_fpdeadbeef"
    with open(cache, "w") as f:
        json.dump({foreign_key: data[c1.key]}, f)
    engine_select.clear_cache()
    c2 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert not c2.from_cache        # key-missed the foreign entry

    # and the same entry *would* have hit under its own fingerprint
    engine_select.clear_cache()
    monkeypatch.setattr(engine_select, "fingerprint_hash",
                        lambda fp=None: "deadbeef")
    c3 = engine_select.choose(small_forest, 64, engines=CHEAP,
                              cache_path=cache, repeats=1)
    assert c3.from_cache and c3.key == foreign_key


def test_meta_exposes_fingerprint_as_feature(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    c = engine_select.choose(small_forest, 64, engines=("qs",),
                             cache_path=cache, repeats=1)
    with open(cache) as f:
        meta = json.load(f)[c.key]["meta"]
    assert meta["fingerprint"] == engine_select.fingerprint_hash()
    assert meta["backend"] and meta["device_kind"]
    assert meta["n_trees"] == small_forest.n_trees
    assert meta["batch"] == 64


# ------------------------------------------------------------------------- #
# Satellite 2: compile_s / bench_us recorded separately (schema v2)
# ------------------------------------------------------------------------- #
def test_entry_separates_compile_from_bench(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    c = engine_select.choose(small_forest, 64, engines=CHEAP,
                             cache_path=cache, repeats=1)
    with open(cache) as f:
        entry = json.load(f)[c.key]
    assert entry["v"] == engine_select.SCHEMA_VERSION
    assert set(entry["compile_s"]) == set(entry["bench_us"]) \
        == set(entry["timings"]) == set(CHEAP)
    for cand in CHEAP:
        assert entry["compile_s"][cand] > 0
        # bench_us is per instance: timings (secs/batch) / 64 * 1e6
        assert entry["bench_us"][cand] == pytest.approx(
            entry["timings"][cand] / 64 * 1e6)
        # first traced predict dominates steady state on these shapes
        assert entry["compile_s"][cand] > entry["timings"][cand]
    assert c.compile_s and all(v > 0 for v in c.compile_s.values())


def test_merge_unions_v2_side_tables(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    full = engine_select.choose(small_forest, 64, engines=CHEAP,
                                cache_path=cache, repeats=1)
    engine_select.choose(small_forest, 64, engines=("qs",),
                         cache_path=cache, force=True, repeats=1)
    with open(cache) as f:
        entry = json.load(f)[full.key]
    assert set(entry["compile_s"]) == set(entry["bench_us"]) == set(CHEAP)
    assert entry["v"] == engine_select.SCHEMA_VERSION


def test_v1_entry_parses_but_cannot_be_hit(small_forest, tmp_path):
    cache = str(tmp_path / "engines.json")
    v1_key = engine_select.shape_key(small_forest, 64).rsplit("_fp", 1)[0]
    with open(cache, "w") as f:
        json.dump({v1_key: {"engine": "qs", "timings": {e: 0.001
                                                        for e in CHEAP}}},
                  f)
    assert v1_key in engine_select._load_disk(cache)  # still valid v1
    c = engine_select.choose(small_forest, 64, engines=CHEAP,
                             cache_path=cache, repeats=1)
    assert not c.from_cache         # pre-fingerprint key never matches
    with open(cache) as f:
        data = json.load(f)
    assert v1_key in data and c.key in data  # coexist, no clobber


# ------------------------------------------------------------------------- #
# Tentpole (a): the extractor
# ------------------------------------------------------------------------- #
def test_parse_candidate_axes():
    p = tune.parse_candidate
    assert p("qs") == {"engine": "qs", "quant": "", "opt": "",
                       "layout": "", "cascade": "", "flint": False}
    assert p("qs-bitmm@q8i@O2")["quant"] == "q8i"
    assert p("qs-bitmm@q8i@O2")["opt"] == "O2"
    assert p("native@flint")["flint"] is True
    assert p("qs-bitmm@tree_chunk=32")["layout"] == "tree_chunk=32"
    got = p("qs@q16@dedup_thresholds+compact@cascade-fused=16/48:margin")
    assert got["opt"] == "dedup_thresholds+compact"
    assert got["cascade"] == "cascade-fused=16/48:margin"
    assert got["quant"] == "q16"


def test_extract_rows_feature_label_contract(trained):
    rows = tune.extract_rows(trained["cache"])
    assert len(rows) == len(TRAIN_SHAPES) * len(CHEAP)
    for r in rows:
        assert r["us"] > 0 and r["compile_s"] > 0
        assert r["axes"]["engine"] in CHEAP
        assert r["meta"]["fingerprint"] == engine_select.fingerprint_hash()


def test_extract_skips_v1_entries():
    rows = tune.rows_from_entries({
        "old": {"engine": "qs", "timings": {"qs": 0.001}},
        "new": {"engine": "qs", "timings": {"qs": 0.001},
                "bench_us": {"qs": 15.6}, "compile_s": {"qs": 0.2},
                "meta": {"n_trees": 8}},
    })
    assert [r["key"] for r in rows] == ["new"]


# ------------------------------------------------------------------------- #
# Tentpole (b): the cost model + versioned artifact
# ------------------------------------------------------------------------- #
def test_model_artifact_roundtrip(trained):
    m1 = trained["model"]
    m2 = tune.CostModel.load(trained["model_path"])
    meta = engine_select.shape_meta(_held_out(), 64)
    a1, a2 = m1.assess(meta, CHEAP), m2.assess(meta, CHEAP)
    assert np.allclose(a1["us"], a2["us"])
    assert a1["confidence"] == pytest.approx(a2["confidence"])
    assert list(a1["order"]) == list(a2["order"])


def test_model_artifact_rejects_newer_version(tmp_path, trained):
    path = str(tmp_path / "model.json")
    trained["model"].save(path)
    with open(path) as f:
        doc = json.load(f)
    doc["version"] = packed.COSTMODEL_VERSION + 1
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError, match="newer"):
        tune.CostModel.load(path)


def test_model_artifact_rejects_garbage(tmp_path):
    path = str(tmp_path / "model.json")
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.raises(ValueError):
        tune.CostModel.load(path)


def test_unknown_candidate_kills_confidence(trained):
    meta = engine_select.shape_meta(_held_out(), 64)
    # an engine the training cache never saw: unrankable → conf < 0
    a = trained["model"].assess(meta, ("rapidscorer",))
    assert not a["known"][0] and a["confidence"] == -1.0
    # known candidates sort ahead of unknown ones
    a = trained["model"].assess(meta, ("rapidscorer", "qs"))
    assert list(a["order"])[0] == 1


def test_confidence_is_probability_when_known(trained):
    meta = engine_select.shape_meta(_held_out(), 64)
    a = trained["model"].assess(meta, CHEAP)
    assert all(a["known"])
    assert 0.5 <= a["confidence"] <= 1.0


def test_fit_needs_rows():
    with pytest.raises(ValueError, match="training rows"):
        tune.fit_cost_model([])


# ------------------------------------------------------------------------- #
# Tentpole (c): choose(mode="predict") — zero-shot, fallback, feedback
# ------------------------------------------------------------------------- #
def test_predict_zero_shot_builds_one_plan(trained, tmp_path):
    cache = str(tmp_path / "serve_cache.json")
    f = _held_out()
    c = engine_select.choose(f, 64, engines=CHEAP, cache_path=cache,
                             mode="predict",
                             cost_model=trained["model_path"],
                             confidence_threshold=0.0, repeats=1)
    assert c.predicted and not c.from_cache
    assert c.engine in CHEAP and c.confidence >= 0.5
    assert c.predictor.plan is not None
    # feedback: the measurement landed in the cache as ground truth
    with open(cache) as f2:
        entry = json.load(f2)[c.key]
    assert set(entry["timings"]) == {c.engine}
    assert entry["meta"]["n_trees"] == f.n_trees
    rows = tune.extract_rows(cache)
    assert len(rows) == 1 and rows[0]["candidate"] == c.engine


def test_predict_os_alias_and_mode_validation(trained, tmp_path):
    f = _held_out()
    c = engine_select.choose(f, 64, engines=CHEAP, cache_path=None,
                             mode="-Os", cost_model=trained["model_path"],
                             confidence_threshold=0.0, repeats=1,
                             feedback=False)
    assert c.predicted
    with pytest.raises(ValueError, match="mode"):
        engine_select.choose(f, 64, engines=CHEAP, cache_path=None,
                             mode="banana")


def test_cache_hit_beats_the_model(trained, tmp_path):
    cache = str(tmp_path / "cache.json")
    f = _held_out()
    full = engine_select.choose(f, 64, engines=CHEAP, cache_path=cache,
                                repeats=1)
    c = engine_select.choose(f, 64, engines=CHEAP, cache_path=cache,
                             mode="predict",
                             cost_model=trained["model_path"], repeats=1)
    assert c.from_cache and not c.predicted
    assert c.engine == full.engine  # measured truth, not a prediction


def test_low_confidence_falls_back_to_topk_sweep(trained, tmp_path):
    cache = str(tmp_path / "cache.json")
    f = _held_out()
    fb = engine_select.choose(f, 64, engines=CHEAP, cache_path=cache,
                              mode="predict",
                              cost_model=trained["model_path"],
                              confidence_threshold=1.01, top_k=2,
                              repeats=1)
    assert not fb.predicted and not fb.from_cache
    assert len(fb.timings) == 2             # narrowed to top-k
    assert fb.confidence is not None and fb.confidence < 1.01
    # the narrow sweep's measurements merged into the shared cache: a
    # later full sweep reuses them, so restricting its timings to the
    # top-k set must reproduce the fallback's winner exactly
    full = engine_select.choose(f, 64, engines=CHEAP, cache_path=cache,
                                repeats=1)
    restricted = {c: full.timings[c] for c in fb.timings}
    assert fb.engine == min(restricted, key=restricted.get)


def test_no_model_falls_back_to_full_sweep(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_COST_MODEL",
                       str(tmp_path / "nonexistent.json"))
    c = engine_select.choose(_held_out(), 64, engines=CHEAP,
                             cache_path=None, mode="predict", repeats=1)
    assert not c.predicted and set(c.timings) == set(CHEAP)


def test_explicit_missing_model_path_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        engine_select.choose(_held_out(), 64, engines=CHEAP,
                             cache_path=None, mode="predict",
                             cost_model=str(tmp_path / "nope.json"))


def test_corrupt_default_model_degrades_to_sweep(tmp_path, monkeypatch):
    bad = tmp_path / "model.json"
    bad.write_text("{definitely not a model")
    monkeypatch.setenv("REPRO_COST_MODEL", str(bad))
    c = engine_select.choose(_held_out(), 64, engines=CHEAP,
                             cache_path=None, mode="predict", repeats=1)
    assert not c.predicted and set(c.timings) == set(CHEAP)


def test_predict_observability_counters(trained, tmp_path):
    from repro.obs.metrics import MetricsRegistry, set_default_registry
    mine = MetricsRegistry()
    old = set_default_registry(mine)
    try:
        f = _held_out()
        engine_select.choose(f, 64, engines=CHEAP, cache_path=None,
                             mode="predict",
                             cost_model=trained["model_path"],
                             confidence_threshold=0.0, repeats=1)
        snap = mine.snapshot()
        assert snap["repro_autotune_predict_hits_total"][
            "samples"][0]["value"] == 1
        assert snap["repro_autotune_feedback_writes_total"][
            "samples"][0]["value"] == 1
        assert snap["repro_autotune_predict_rel_error"][
            "samples"][0]["count"] == 1
        (g,) = snap["repro_autotune_predict_last_rel_error"]["samples"]
        assert g["value"] >= 0.0
        # low-confidence fallback + no-model fallback, labelled by reason
        engine_select.clear_cache()
        engine_select.choose(f, 64, engines=CHEAP, cache_path=None,
                             mode="predict",
                             cost_model=trained["model_path"],
                             confidence_threshold=1.01, top_k=2,
                             repeats=1)
        snap = mine.snapshot()
        reasons = {s["labels"]["reason"]: s["value"] for s in
                   snap["repro_autotune_fallback_sweeps_total"]["samples"]}
        assert reasons.get("low_confidence") == 1
    finally:
        set_default_registry(old)


def test_no_model_fallback_counter(monkeypatch):
    from repro.obs.metrics import MetricsRegistry, set_default_registry
    monkeypatch.setenv("REPRO_COST_MODEL", "/nonexistent/model.json")
    mine = MetricsRegistry()
    old = set_default_registry(mine)
    try:
        engine_select.choose(_held_out(), 64, engines=("qs",),
                             cache_path=None, mode="predict", repeats=1)
        snap = mine.snapshot()
        reasons = {s["labels"]["reason"]: s["value"] for s in
                   snap["repro_autotune_fallback_sweeps_total"]["samples"]}
        assert reasons.get("no_model") == 1
    finally:
        set_default_registry(old)


# ------------------------------------------------------------------------- #
# Tentpole (d): shared-IR sweeps + optimizer-aware pruning
# ------------------------------------------------------------------------- #
def _count_optimize(monkeypatch):
    calls = {"n": 0}
    real = optim.optimize

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(optim, "optimize", counting)
    return calls


def test_shared_ir_one_optimize_per_quant_opt_point(small_forest,
                                                    monkeypatch):
    calls = _count_optimize(monkeypatch)
    engine_select.choose(small_forest, 64, engines=CHEAP,
                         opt_levels=(1, 2), cache_path=None, repeats=1,
                         share_ir=True)
    assert calls["n"] == 2          # one per opt level, not per engine


def test_share_ir_off_optimizes_per_candidate(small_forest, monkeypatch):
    calls = _count_optimize(monkeypatch)
    engine_select.choose(small_forest, 64, engines=CHEAP,
                         opt_levels=(1, 2), cache_path=None, repeats=1,
                         share_ir=False)
    assert calls["n"] == len(CHEAP) * 2


def test_pruning_aliases_provably_identical_candidates(small_forest,
                                                       tmp_path):
    # an explicit pass tuple spelling out O1's exact pipeline: post-dedup
    # the two candidates are provably the same compiled artifact
    o1_spelled = ("dedup_thresholds", "merge_equivalent_leaves", "compact")
    cache = str(tmp_path / "cache.json")
    c = engine_select.choose(small_forest, 64, engines=("qs", "native"),
                             opt_levels=(1, o1_spelled), cache_path=cache,
                             repeats=1, share_ir=True)
    assert len(c.pruned) == 2
    for name in c.pruned:
        rep = f"{name.split('@')[0]}@O1"
        assert c.timings[name] == c.timings[rep]
        assert c.compile_s[name] == c.compile_s[rep]
    # aliased timings persist — the cache entry covers every candidate
    with open(cache) as f:
        entry = json.load(f)[c.key]
    assert set(entry["timings"]) == set(c.timings)


def test_pruning_never_aliases_distinct_candidates(small_forest):
    c = engine_select.choose(small_forest, 64, engines=CHEAP,
                             opt_levels=(1,), cache_path=None, repeats=1,
                             share_ir=True)
    assert c.pruned == ()           # O1 rewrites the IR; plain ≠ O1


# ------------------------------------------------------------------------- #
# Wiring: compile_forest(tune=) and the serving fleet cold start
# ------------------------------------------------------------------------- #
def test_compile_forest_tune_predict(trained, tmp_path):
    f = _held_out()
    pred = core.compile_forest(f, tune="predict", tune_batch=64,
                               engines=CHEAP,
                               cost_model=trained["model_path"],
                               confidence_threshold=0.0,
                               cache_path=str(tmp_path / "c.json"),
                               repeats=1)
    X = np.random.default_rng(0).normal(size=(16, f.n_features))
    assert pred.predict(X).shape == (16, 1)
    assert pred.plan is not None
    with pytest.raises(ValueError, match="tune="):
        core.compile_forest(f, engine="native", tune="predict")


def test_from_forests_tune_predict_fleet(trained, tmp_path):
    from repro.inference.runtime import ServingRuntime
    forests = {"a": _held_out(1), "b": _held_out(2)}
    rt = ServingRuntime.from_forests(
        forests, max_batch=64, tune="predict", engines=CHEAP,
        cost_model=trained["model_path"], confidence_threshold=0.0,
        cache_path=str(tmp_path / "fleet.json"), repeats=1)
    with rt:
        for tid, f in forests.items():
            choice = rt.tenant(tid).engine_choice
            assert choice.predicted and choice.engine in CHEAP
            x = np.random.default_rng(3).normal(size=f.n_features)
            req = rt.submit(tid, x)
            req.wait(timeout=30)
            want = choice.predictor.predict(x[None, :])[0]
            np.testing.assert_array_equal(np.asarray(req.result),
                                          np.asarray(want))


# ------------------------------------------------------------------------- #
# Satellite 3: property tests — hypothesis when available, plus a
# deterministic seed sweep of the same properties for offline containers
# ------------------------------------------------------------------------- #
def _check_predict_is_registered_compilable_bitexact(trained, T, L, d,
                                                     seed):
    """mode="predict" always returns a registered, compilable plan that
    is bit-exact-equivalent to compiling the same plan directly."""
    engine_select.clear_cache()
    f = core.random_forest_ir(T, L, d, n_classes=1, seed=seed)
    c = engine_select.choose(f, 32, engines=CHEAP, cache_path=None,
                             mode="predict",
                             cost_model=trained["model"],
                             confidence_threshold=0.0, repeats=1,
                             feedback=False)
    assert c.predicted
    base = c.engine.split("@")[0]
    assert base in registry.tune_table()            # registered
    facs = engine_select._candidate_factories(f, CHEAP, None, None, 1)
    direct = facs[c.engine]()                       # same plan, compiled
    X = np.random.default_rng(seed).normal(size=(32, f.n_features))
    np.testing.assert_array_equal(np.asarray(c.predictor.predict(X)),
                                  np.asarray(direct.predict(X)))


def _check_fallback_winner_matches_restricted_sweep(trained, seed, k,
                                                    cache):
    """The low-confidence fallback's winner equals a full sweep's winner
    restricted to the top-k candidate set (the narrow sweep's
    measurements ARE the full sweep's measurements — shared cache)."""
    engine_select.clear_cache()
    f = core.random_forest_ir(6 + seed % 7, 16, 6, n_classes=1,
                              seed=seed)
    fb = engine_select.choose(f, 32, engines=CHEAP, cache_path=cache,
                              mode="predict",
                              cost_model=trained["model"],
                              confidence_threshold=1.01, top_k=k,
                              repeats=1)
    assert not fb.predicted
    assert len(fb.timings) == min(k, len(CHEAP))
    full = engine_select.choose(f, 32, engines=CHEAP, cache_path=cache,
                                repeats=1)
    restricted = {c: full.timings[c] for c in fb.timings}
    assert fb.engine == min(restricted, key=restricted.get)


@pytest.mark.parametrize("T,L,d,seed",
                         [(2, 8, 3, 0), (12, 16, 9, 7), (5, 16, 6, 42)])
def test_predict_plan_registered_compilable_bitexact(trained, T, L, d,
                                                     seed):
    _check_predict_is_registered_compilable_bitexact(trained, T, L, d,
                                                     seed)


@pytest.mark.parametrize("seed,k", [(0, 1), (3, 2), (11, 3)])
def test_fallback_winner_equals_restricted_full_sweep(trained, tmp_path,
                                                      seed, k):
    _check_fallback_winner_matches_restricted_sweep(
        trained, seed, k, str(tmp_path / "fb.json"))


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(T=st.integers(2, 12), L=st.sampled_from([8, 16]),
           d=st.integers(3, 9), seed=st.integers(0, 10 ** 6))
    def test_hypothesis_predict_plan_bitexact(trained, T, L, d, seed):
        _check_predict_is_registered_compilable_bitexact(trained, T, L,
                                                         d, seed)

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), k=st.integers(1, 3))
    def test_hypothesis_fallback_winner_restricted(trained,
                                                   tmp_path_factory,
                                                   seed, k):
        cache = str(tmp_path_factory.mktemp("fb") / "cache.json")
        _check_fallback_winner_matches_restricted_sweep(trained, seed,
                                                        k, cache)
