"""Launch layer: HLO collective parsing, input specs, shape applicability,
mesh construction, MODEL_FLOPS accounting, tiny-mesh lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.specs import enc_len, input_specs


# --------------------------------------------------------------------------- #
# HLO collective parsing
# --------------------------------------------------------------------------- #
SYNTH_HLO = """
HloModule jit_step

%body (p: (f32[16,8])) -> (f32[16,8]) {
  %ag = f32[16,8]{1,0} all-gather(f32[4,8]{1,0} %x), dimensions={0}
  %ar = bf16[32]{0} all-reduce(bf16[32]{0} %y), to_apply=%add
  ROOT %t = tuple(%ag)
}

%cond (p: (f32[16,8])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[16,8]) -> f32[16,8] {
  %w = (f32[16,8]) while((f32[16,8]) %init), condition=%cond, body=%body
  %rs = f32[8,8]{1,0} reduce-scatter(f32[16,8]{1,0} %a), dimensions={0}
  %cp = f32[128]{0} collective-permute(f32[128]{0} %b), source_target_pairs={{0,1}}
  ROOT %r = f32[16,8] get-tuple-element(%w), index=0
}
"""


def test_collective_bytes_synthetic():
    st = collective_bytes(SYNTH_HLO)
    # while body ×10: all-gather 16*8*4 = 512 B ×10; all-reduce 32*2 ×10
    assert st.per_op["all-gather"] == 512 * 10
    assert st.per_op["all-reduce"] == 64 * 10
    assert st.per_op["reduce-scatter"] == 8 * 8 * 4
    assert st.per_op["collective-permute"] == 128 * 4
    # link weights: all-reduce counts 2× (reduce-scatter + all-gather phases)
    assert st.link_bytes == 512 * 10 + 2 * 64 * 10 + 256 + 512
    assert st.counts["all-gather"] == 1


def test_collective_bytes_empty():
    st = collective_bytes("ENTRY %main () -> f32[] { ROOT %c = f32[] constant(0) }")
    assert st.total_bytes == 0 and st.link_bytes == 0


def test_collective_bytes_real_lowering():
    """Parse an actual jax lowering with a psum over a real 1-device mesh."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(), NamedSharding(mesh, P()))

    with mesh:
        xs = jax.ShapeDtypeStruct((8, 8), jnp.float32)
        txt = jax.jit(f, in_shardings=NamedSharding(mesh, P("data"))) \
            .lower(xs).compile().as_text()
    st = collective_bytes(txt)          # may be 0 collectives on 1 device —
    assert st.total_bytes >= 0          # just must not crash on real HLO


# --------------------------------------------------------------------------- #
# input specs / applicability
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_all_cells(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        assert "sub-quadratic" in reason or "quadratic" in reason
        return
    specs = input_specs(cfg, shape)
    B = shape.global_batch
    if shape.kind == "decode":
        assert specs["tokens"].shape == (B, 1)
    else:
        assert specs["tokens"].shape == (B, shape.seq_len)
    assert specs["tokens"].dtype == jnp.int32
    if cfg.family == "encdec" and shape.kind != "decode":
        se = enc_len(cfg, shape.seq_len)
        assert specs["enc_embeds"].shape == (B, se, cfg.d_model)


def test_long500k_skips_are_exactly_the_full_attn_archs():
    skipped = [a for a in ARCH_IDS
               if not shape_applicable(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(skipped) == sorted([
        "chameleon_34b", "smollm_360m", "phi3_mini_3_8b",
        "command_r_plus_104b", "starcoder2_3b", "phi3_5_moe_42b",
        "grok_1_314b", "seamless_m4t_large_v2"])


def test_model_flops_accounting():
    from repro.launch.dryrun import _model_flops
    cfg = get_config("phi3_mini_3_8b")
    tr = SHAPES["train_4k"]
    got = _model_flops(cfg, tr)
    N = cfg.param_count()
    assert got == pytest.approx(6 * N * tr.global_batch * tr.seq_len)
    dec = SHAPES["decode_32k"]
    assert _model_flops(cfg, dec) == pytest.approx(2 * N * dec.global_batch)
    # MoE uses active params
    moe = get_config("grok_1_314b")
    assert _model_flops(moe, tr) < 6 * moe.param_count() * tr.global_batch \
        * tr.seq_len


def test_debug_mesh_and_production_mesh_shapes():
    from repro.launch.mesh import make_debug_mesh
    m = make_debug_mesh(1, 1)
    assert m.axis_names == ("data", "model")
    assert m.shape["data"] == 1
    # production mesh construction requires 256 devices — only check the
    # shape contract here (dryrun.py exercises the real thing)


def test_tiny_mesh_lowering_with_shardings():
    """End-to-end: reduced config lowers + compiles on the 1-device debug
    mesh with the same sharding-resolution code path as production."""
    from repro.distributed.sharding import data_spec, tree_shardings
    from repro.launch.mesh import make_debug_mesh
    from repro.models.model import Model
    from jax.sharding import NamedSharding

    cfg = get_config("smollm_360m").reduced()
    model = Model(cfg, q_chunk=16, ssd_chunk=8, loss_chunk=16, remat=True)
    mesh = make_debug_mesh(1, 1)
    p_shapes = jax.eval_shape(
        lambda k: model.init_params(k, jnp.float32), jax.random.PRNGKey(0))
    shards = tree_shardings(p_shapes, model.param_logical_specs(), mesh)
    toks = jax.ShapeDtypeStruct((2, 32), jnp.int32)
    tok_shard = NamedSharding(mesh, data_spec(mesh, 2, 2))
    with mesh:
        lowered = jax.jit(model.loss_fn, in_shardings=(shards, tok_shard)) \
            .lower(p_shapes, toks)
        compiled = lowered.compile()
    from repro.launch.hlo_analysis import normalize_cost_analysis
    # newer JAX returns a list of per-partition dicts, older a plain dict
    cost = normalize_cost_analysis(compiled.cost_analysis())
    assert float(cost.get("flops", 0)) > 0
