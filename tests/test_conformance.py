"""Differential conformance suite: adversarial forests × every registered
engine × float/quantized × serialization round trip.

Structure:

  * a catalog of deterministic **adversarial forests** — single-leaf
    trees, duplicate/constant thresholds, ±inf thresholds, unused
    features, 1-tree and 0-feature ensembles — each engine must agree
    with the naive traversal oracle on all of them;
  * quantized variants must be **bit-exact** across engines and **stay
    bit-exact under save/load** of both the packed IR and the compiled
    predictor artifact (the PR's acceptance invariant);
  * hypothesis strategies generate randomized adversarial forests on top
    (skipped cleanly when hypothesis isn't installed, as in the offline
    container — CI installs it).

Pallas engines run in interpret mode here (CPU): only the small
deterministic catalog includes them, the randomized sweeps stick to XLA.
"""
import numpy as np
import pytest

from repro import core, io
from repro.core import registry
from repro.trees.cart import Tree, TreeNode

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # container without hypothesis: CI covers it
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------- #
# Adversarial forest catalog
# --------------------------------------------------------------------------- #
def _leaf(*vals) -> TreeNode:
    return TreeNode(value=np.asarray(vals, dtype=np.float64))


def _split(f, t, left, right) -> TreeNode:
    return TreeNode(feature=f, threshold=t, left=left, right=right)


def _tree(root: TreeNode) -> Tree:
    def leaves(nd):
        return 1 if nd.is_leaf else leaves(nd.left) + leaves(nd.right)

    def depth(nd):
        return 0 if nd.is_leaf else 1 + max(depth(nd.left), depth(nd.right))

    return Tree(root, leaves(root), depth(root))


def _forest(roots, n_features, n_classes=1):
    return core.from_trees([_tree(r) for r in roots],
                           n_features=n_features, n_classes=n_classes)


def single_leaf_trees():
    """Every tree degenerate (no splits) — pure constants."""
    return _forest([_leaf(3.0), _leaf(-1.5), _leaf(0.25)], n_features=2)


def mixed_stump_and_deep():
    """Stumps padded against a deeper tree (ragged n_nodes)."""
    deep = _split(0, 0.0,
                  _split(1, -1.0, _leaf(1.0), _leaf(2.0)),
                  _split(1, 1.0, _leaf(3.0), _leaf(4.0)))
    return _forest([_leaf(10.0), deep, _leaf(-10.0)], n_features=2)


def duplicate_thresholds():
    """Every node the identical (feature, threshold) pair — RapidScorer's
    merge collapses the whole ensemble to one unique node."""
    def t():
        return _split(0, 0.7, _split(0, 0.7, _leaf(1.0), _leaf(2.0)),
                      _split(0, 0.7, _leaf(3.0), _leaf(4.0)))
    return _forest([t(), t(), t()], n_features=1)


def constant_threshold_chain():
    """A right-leaning chain reusing one threshold value on one feature."""
    chain = _split(0, 0.5, _leaf(1.0),
                   _split(0, 0.5, _leaf(2.0),
                          _split(0, 0.5, _leaf(3.0), _leaf(4.0))))
    return _forest([chain], n_features=3)       # + unused features


def inf_thresholds():
    """±inf thresholds: +inf sends everything left, -inf everything
    right (x <= -inf is false for finite x)."""
    t0 = _split(0, np.inf, _leaf(1.0), _leaf(99.0))
    t1 = _split(1, -np.inf, _leaf(99.0), _leaf(2.0))
    t2 = _split(0, 0.0, _split(1, np.inf, _leaf(3.0), _leaf(98.0)),
                _leaf(4.0))
    return _forest([t0, t1, t2], n_features=2)


def unused_features():
    """d=8 but only feature 5 is ever referenced."""
    t0 = _split(5, 0.1, _leaf(1.0), _leaf(2.0))
    t1 = _split(5, -0.3, _split(5, 0.8, _leaf(3.0), _leaf(4.0)),
                _leaf(5.0))
    return _forest([t0, t1], n_features=8)


def one_tree():
    return _forest([_split(0, 0.0, _leaf(-1.0), _leaf(1.0))], n_features=1)


def zero_features():
    """No features at all: every tree is a constant, X is (B, 0)."""
    return _forest([_leaf(2.0), _leaf(3.0)], n_features=0)


def multiclass_stumps():
    return _forest([_leaf(1.0, 0.0, 2.0), _leaf(0.5, 3.0, 0.0)],
                   n_features=2, n_classes=3)


ADVERSARIAL = {
    "single_leaf_trees": single_leaf_trees,
    "mixed_stump_and_deep": mixed_stump_and_deep,
    "duplicate_thresholds": duplicate_thresholds,
    "constant_threshold_chain": constant_threshold_chain,
    "inf_thresholds": inf_thresholds,
    "unused_features": unused_features,
    "one_tree": one_tree,
    "zero_features": zero_features,
    "multiclass_stumps": multiclass_stumps,
}
# quantization needs finite thresholds and at least one feature
QUANTIZABLE = sorted(set(ADVERSARIAL) - {"inf_thresholds", "zero_features"})

COMBOS = [(s.name, s.backend) for s in registry.specs()]
COMBO_IDS = [f"{n}/{b}" for n, b in COMBOS]
JAX_ENGINES = list(registry.engines("jax"))


def _X(forest, B=16, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1.5, size=(B, forest.n_features))
    if forest.n_features:
        # hit thresholds exactly: boundary rows are where engines diverge
        thr = forest.threshold[forest.feature >= 0]
        thr = thr[np.isfinite(thr.astype(np.float64))]
        for i, t in enumerate(thr[:B]):
            X[i, i % forest.n_features] = t
    return X


def _compile(forest, name, backend):
    kw = {"interpret": True} if backend == "pallas" else {}
    return core.compile_forest(forest, engine=name, backend=backend, **kw)


# --------------------------------------------------------------------------- #
# float: every registered engine × every adversarial forest vs the oracle
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
@pytest.mark.parametrize("case", sorted(ADVERSARIAL))
def test_adversarial_float_agrees_with_oracle(case, name, backend):
    forest = ADVERSARIAL[case]()
    X = _X(forest)
    expect = forest.predict_oracle(X)
    got = _compile(forest, name, backend).predict(X)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6,
                               err_msg=f"{case}/{name}/{backend}")


# --------------------------------------------------------------------------- #
# quantized: engines bit-exact among themselves and vs the quantized oracle
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_adversarial_quantized_engines_bitexact(case):
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=1)
    qf = core.quantize_forest(forest, X)
    oracle = (qf.predict_oracle(core.quantize_inputs(qf, X))
              / core.leaf_scale(qf)).astype(np.float32)
    preds = {e: _compile(qf, e, "jax").predict(X) for e in JAX_ENGINES}
    for e, got in preds.items():
        np.testing.assert_array_equal(got, oracle,
                                      err_msg=f"{case}/{e}")


# --------------------------------------------------------------------------- #
# serialization round trips (the PR acceptance invariant)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("case", sorted(ADVERSARIAL))
def test_forest_roundtrip_is_lossless(case, tmp_path):
    forest = ADVERSARIAL[case]()
    p = str(tmp_path / "f.repro.npz")
    io.save_forest(forest, p)
    loaded = io.load_forest(p)
    for fld in ("feature", "threshold", "left", "right", "leaf_lo",
                "leaf_mid", "leaf_hi", "leaf_value", "n_nodes",
                "n_leaves_per_tree"):
        np.testing.assert_array_equal(getattr(forest, fld),
                                      getattr(loaded, fld), err_msg=fld)
    assert (loaded.n_trees, loaded.n_leaves, loaded.n_classes,
            loaded.n_features, loaded.max_depth) == \
           (forest.n_trees, forest.n_leaves, forest.n_classes,
            forest.n_features, forest.max_depth)
    X = _X(forest, B=8, seed=2)
    np.testing.assert_array_equal(forest.predict_oracle(X),
                                  loaded.predict_oracle(X))


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_quantized_predictor_roundtrip_bitexact(case, engine, tmp_path):
    """compile → save → load → predict is bit-identical to the in-memory
    prediction on quantized forests, for every registered XLA engine."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=10, seed=3)
    qf = core.quantize_forest(forest, X)
    pred = _compile(qf, engine, "jax")
    p = str(tmp_path / "pred.repro.npz")
    io.save_predictor(pred, p)
    loaded = io.load_predictor(p)
    np.testing.assert_array_equal(pred.predict(X), loaded.predict(X),
                                  err_msg=f"{case}/{engine}")


@pytest.mark.parametrize("engine", JAX_ENGINES)
def test_float_predictor_roundtrip_within_tolerance(engine, tmp_path):
    forest = core.random_forest_ir(6, 16, 5, n_classes=2, seed=11,
                                   full=False)
    X = _X(forest, B=16, seed=4)
    pred = _compile(forest, engine, "jax")
    p = str(tmp_path / "pred.repro.npz")
    io.save_predictor(pred, p)
    loaded = io.load_predictor(p)
    np.testing.assert_allclose(pred.predict(X), loaded.predict(X),
                               rtol=0, atol=1e-6)


def test_quantized_forest_ir_roundtrip_preserves_quant_metadata(tmp_path):
    forest = duplicate_thresholds()
    X = _X(forest, B=32, seed=5)
    qf = core.quantize_forest(forest, X)
    p = str(tmp_path / "qf.repro.npz")
    io.save_forest(qf, p)
    loaded = io.load_forest(p)
    assert loaded.quant_scale == qf.quant_scale
    assert loaded.quant_bits == qf.quant_bits
    assert loaded.leaf_scale == qf.leaf_scale
    assert loaded.threshold.dtype == qf.threshold.dtype
    np.testing.assert_array_equal(loaded.feat_lo, qf.feat_lo)
    np.testing.assert_array_equal(loaded.feat_hi, qf.feat_hi)
    # and the compiled engines see identical inputs post-load
    np.testing.assert_array_equal(core.quantize_inputs(qf, X),
                                  core.quantize_inputs(loaded, X))


def test_import_compile_save_load_differential(tmp_path):
    """The full acceptance chain on an imported model: XGBoost dump →
    IR → quantize → compile (every XLA engine) → save → load → predict,
    loaded output bit-identical to in-memory, both matching the oracle."""
    from benchmarks.bench_coldstart import _forest_to_xgb_dump
    import json
    src = core.random_forest_ir(8, 16, 4, seed=21, full=False)
    dump_path = tmp_path / "model.json"
    dump_path.write_text(json.dumps(_forest_to_xgb_dump(src)))
    forest = io.load_model(str(dump_path))
    X = _X(forest, B=16, seed=6)
    np.testing.assert_allclose(forest.predict_oracle(X),
                               src.predict_oracle(X), rtol=1e-5, atol=1e-6)
    qf = core.quantize_forest(forest, X)
    oracle = (qf.predict_oracle(core.quantize_inputs(qf, X))
              / core.leaf_scale(qf)).astype(np.float32)
    for engine in JAX_ENGINES:
        pred = _compile(qf, engine, "jax")
        p = str(tmp_path / f"{engine}.repro.npz")
        io.save_predictor(pred, p)
        got = io.load_predictor(p).predict(X)
        np.testing.assert_array_equal(got, pred.predict(X), err_msg=engine)
        np.testing.assert_array_equal(got, oracle, err_msg=engine)


# --------------------------------------------------------------------------- #
# cascade conformance: a cascade whose gate never fires computes the same
# function as the underlying engine (docs/CASCADE.md)
# --------------------------------------------------------------------------- #
from repro.cascade import CascadePredictor, CascadeSpec, \
    FusedCascadePredictor, MarginGate, ScoreBoundGate

CASCADE_CASES = ["mixed_stump_and_deep", "multiclass_stumps",
                 "unused_features"]


def _mid_stages(forest):
    """A genuine 2-stage split when the forest allows one."""
    return (max(forest.n_trees // 2, 1), forest.n_trees)


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
@pytest.mark.parametrize("case", CASCADE_CASES)
def test_cascade_single_stage_is_the_engine(case, name, backend):
    """One stage == the plain engine call: bit-exact for every registered
    engine/backend, float included (same program, same bits)."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=13)
    base = _compile(forest, name, backend)
    kw = {"interpret": True} if backend == "pallas" else {}
    casc = CascadePredictor(forest, CascadeSpec((forest.n_trees,)),
                            engine=name, backend=backend, engine_kw=kw)
    np.testing.assert_array_equal(casc.predict(X), base.predict(X),
                                  err_msg=f"{case}/{name}/{backend}")


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_cascade_gate_off_quantized_bitexact(case, engine):
    """Multi-stage, gate disabled (threshold=inf): integer stage sums
    under a pow2 leaf scale reassociate exactly — bit-exact with the
    base engine on quantized forests for every registered XLA engine."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=14)
    qf = core.quantize_forest(forest, X)
    base = _compile(qf, engine, "jax")
    casc = CascadePredictor(qf, CascadeSpec(_mid_stages(qf),
                                            MarginGate(np.inf)),
                            engine=engine)
    np.testing.assert_array_equal(casc.predict(X), base.predict(X),
                                  err_msg=f"{case}/{engine}")


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", sorted(ADVERSARIAL))
def test_cascade_gate_off_float_agrees(case, engine):
    """Float forests: stage-split reassociation moves the sum order, so
    the gate-off cascade matches within float tolerance (and matches the
    oracle like any engine)."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=15)
    base = _compile(forest, engine, "jax")
    casc = CascadePredictor(forest, CascadeSpec(_mid_stages(forest),
                                                MarginGate(np.inf)),
                            engine=engine)
    np.testing.assert_allclose(casc.predict(X), base.predict(X),
                               rtol=1e-5, atol=1e-6,
                               err_msg=f"{case}/{engine}")


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_cascade_roundtrip_bitexact(case, engine, tmp_path):
    """compile → save → load → predict is bit-identical for cascade
    artifacts on quantized forests, thresholds included."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=10, seed=16)
    qf = core.quantize_forest(forest, X)
    casc = CascadePredictor(qf, CascadeSpec(_mid_stages(qf),
                                            MarginGate(np.inf)),
                            engine=engine)
    p = str(tmp_path / "casc.repro.npz")
    io.save_predictor(casc, p)
    loaded = io.load_predictor(p)
    assert loaded.stages == casc.stages
    assert loaded.policy == casc.policy
    np.testing.assert_array_equal(casc.predict(X), loaded.predict(X),
                                  err_msg=f"{case}/{engine}")


# --------------------------------------------------------------------------- #
# fused vs staged: the one-jit execution (cascade/fused.py) must be
# indistinguishable from the host loop — scores bit-exact on quantized
# forests, identical class decisions, identical per-stage exit counts —
# for every registered engine/backend and across save/load
# --------------------------------------------------------------------------- #
# all-exit-at-stage-0 / mixed / never-exit: the three gate regimes hit
# the no-op early-termination branch, partial compaction, and the full
# every-stage path respectively
FIRING_THRESHOLDS = [0.0, 0.5, np.inf]


def _casc_pair(qf, name, backend, policy):
    kw = {"interpret": True} if backend == "pallas" else {}
    staged = CascadePredictor(qf, CascadeSpec(_mid_stages(qf), policy),
                              engine=name, backend=backend, engine_kw=kw)
    fused = FusedCascadePredictor(
        qf, CascadeSpec(_mid_stages(qf), policy, fused=True),
        engine=name, backend=backend, engine_kw=kw)
    return staged, fused


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
@pytest.mark.parametrize("case", CASCADE_CASES)
def test_fused_matches_staged_quantized_bitexact(case, name, backend):
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=17)
    qf = core.quantize_forest(forest, X)
    for thr in FIRING_THRESHOLDS:
        staged, fused = _casc_pair(qf, name, backend, MarginGate(thr))
        tag = f"{case}/{name}/{backend}/margin{thr}"
        np.testing.assert_array_equal(fused.predict(X), staged.predict(X),
                                      err_msg=tag)
        np.testing.assert_array_equal(fused.last_exit_counts,
                                      staged.last_exit_counts, err_msg=tag)
        np.testing.assert_array_equal(fused.predict_class(X),
                                      staged.predict_class(X), err_msg=tag)


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
@pytest.mark.parametrize("case", ["mixed_stump_and_deep",
                                  "multiclass_stumps"])
def test_fused_sound_gate_matches_staged_and_base(case, name, backend):
    """ScoreBoundGate exercises both decide paths (C=1 decision band,
    C>1 interval dominance); soundness means class decisions also equal
    the plain engine's."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=18)
    qf = core.quantize_forest(forest, X)
    staged, fused = _casc_pair(qf, name, backend, ScoreBoundGate())
    tag = f"{case}/{name}/{backend}"
    np.testing.assert_array_equal(fused.predict(X), staged.predict(X),
                                  err_msg=tag)
    np.testing.assert_array_equal(fused.last_exit_counts,
                                  staged.last_exit_counts, err_msg=tag)
    if forest.n_classes > 1:
        base = _compile(qf, name, backend)
        np.testing.assert_array_equal(fused.predict_class(X),
                                      base.predict_class(X), err_msg=tag)


def test_fused_exit_counts_nontrivial_and_engine_independent():
    """Guard against a vacuous equivalence: on this forest the gate
    splits the batch across stages (neither all-exit nor none), and the
    per-stage counts agree across every XLA engine and with staged."""
    forest = core.random_forest_ir(12, 16, 6, n_classes=3, seed=7,
                                   full=False)
    X = np.random.default_rng(20).normal(0, 2.0, size=(33, 6))
    qf = core.quantize_forest(forest, X)
    seen = set()
    for name in JAX_ENGINES:
        staged, fused = _casc_pair(qf, name, "jax", MarginGate(0.35))
        staged.predict(X)
        fused.predict(X)
        np.testing.assert_array_equal(fused.last_exit_counts,
                                      staged.last_exit_counts, err_msg=name)
        seen.add(tuple(fused.last_exit_counts))
    assert len(seen) == 1
    counts = next(iter(seen))
    assert 0 < counts[0] < 33, f"gate never/always fired: {counts}"


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", CASCADE_CASES)
def test_fused_roundtrip_bitexact(case, engine, tmp_path):
    """save → load restores a FusedCascadePredictor whose scores and
    exit counts are bit-identical to the in-memory fused predictor."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=10, seed=19)
    qf = core.quantize_forest(forest, X)
    fused = FusedCascadePredictor(
        qf, CascadeSpec(_mid_stages(qf), MarginGate(0.5), fused=True),
        engine=engine)
    p = str(tmp_path / "fused.repro.npz")
    io.save_predictor(fused, p)
    loaded = io.load_predictor(p)
    assert isinstance(loaded, FusedCascadePredictor) and loaded.fused
    assert loaded.spec.fused and loaded.stages == fused.stages
    np.testing.assert_array_equal(fused.predict(X), loaded.predict(X),
                                  err_msg=f"{case}/{engine}")
    np.testing.assert_array_equal(fused.last_exit_counts,
                                  loaded.last_exit_counts,
                                  err_msg=f"{case}/{engine}")


# --------------------------------------------------------------------------- #
# integer end-to-end (docs/QUANT.md): int-accum engines bit-exact vs the
# quantized oracle for every engine × backend (Pallas in interpret mode)
# and across save/load; FLInt engines reproduce the float engines'
# decisions exactly
# --------------------------------------------------------------------------- #
from repro.core.pipeline import CompilePlan, compile_plan
from repro.core.quantize import QuantSpec, accum_bits, flint_forest


def _q_oracle(qf, X):
    return (qf.predict_oracle(core.quantize_inputs(qf, X))
            / core.leaf_scale(qf)).astype(np.float32)


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_int_accum_bitexact_every_engine_backend(case, name, backend):
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=23)
    qf = core.quantize_forest(forest, X, spec=QuantSpec(int_accum=True))
    assert qf.int_accum and qf.leaf_err_bound is not None
    got = _compile(qf, name, backend).predict(X)
    np.testing.assert_array_equal(got, _q_oracle(qf, X),
                                  err_msg=f"{case}/{name}/{backend}")


@pytest.mark.parametrize("name,backend", COMBOS, ids=COMBO_IDS)
def test_int16_accumulation_bitexact(name, backend):
    """A tiny leaf scale keeps the worst-case sum inside int16 — the
    engines then accumulate in int16 (asserted via accum_bits) and must
    still match the oracle bit-for-bit."""
    forest = ADVERSARIAL["mixed_stump_and_deep"]()
    X = _X(forest, B=12, seed=24)
    qf = core.quantize_forest(forest, X,
                              spec=QuantSpec(scale=8.0, int_accum=True))
    assert accum_bits(qf) == 16
    got = _compile(qf, name, backend).predict(X)
    np.testing.assert_array_equal(got, _q_oracle(qf, X),
                                  err_msg=f"{name}/{backend}")


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", QUANTIZABLE)
def test_int_accum_predictor_roundtrip_bitexact(case, engine, tmp_path):
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=10, seed=25)
    qf = core.quantize_forest(forest, X, spec=QuantSpec(int_accum=True))
    pred = _compile(qf, engine, "jax")
    p = str(tmp_path / "int.repro.npz")
    io.save_predictor(pred, p)
    loaded = io.load_predictor(p)
    np.testing.assert_array_equal(loaded.predict(X), _q_oracle(qf, X),
                                  err_msg=f"{case}/{engine}")


def test_int_accum_forest_roundtrip_preserves_metadata(tmp_path):
    forest = ADVERSARIAL["multiclass_stumps"]()
    X = _X(forest, B=16, seed=26)
    qf = core.quantize_forest(forest, X, spec=QuantSpec(int_accum=True))
    p = str(tmp_path / "qf.repro.npz")
    io.save_forest(qf, p)
    loaded = io.load_forest(p)
    assert loaded.int_accum and not loaded.flint
    assert loaded.leaf_err_bound == qf.leaf_err_bound
    np.testing.assert_array_equal(loaded.leaf_value, qf.leaf_value)


@pytest.mark.parametrize("engine", JAX_ENGINES)
@pytest.mark.parametrize("case", sorted(ADVERSARIAL))
def test_flint_reproduces_float_engine_exactly(case, engine):
    """FLInt rekeys f32 thresholds/inputs as monotone int32: traversal
    decisions — and therefore scores, which sum the identical f32 leaf
    table in the identical order — equal the float engine's bit-for-bit,
    ±inf thresholds included."""
    forest = ADVERSARIAL[case]()
    X = _X(forest, B=12, seed=27)
    ref = _compile(forest, engine, "jax").predict(X)
    pred = compile_plan(forest, CompilePlan(engine=engine, flint=True))
    np.testing.assert_array_equal(pred.predict(X), ref,
                                  err_msg=f"{case}/{engine}")


@pytest.mark.parametrize("engine", JAX_ENGINES)
def test_flint_predictor_roundtrip_bitexact(engine, tmp_path):
    forest = ADVERSARIAL["mixed_stump_and_deep"]()
    X = _X(forest, B=10, seed=28)
    pred = compile_plan(forest, CompilePlan(engine=engine, flint=True))
    p = str(tmp_path / "flint.repro.npz")
    io.save_predictor(pred, p)
    loaded = io.load_predictor(p)
    np.testing.assert_array_equal(loaded.predict(X), pred.predict(X),
                                  err_msg=engine)


def test_flint_forest_roundtrip_preserves_keys(tmp_path):
    forest = ADVERSARIAL["inf_thresholds"]()
    ff = flint_forest(forest)
    p = str(tmp_path / "ff.repro.npz")
    io.save_forest(ff, p)
    loaded = io.load_forest(p)
    assert loaded.flint and loaded.threshold.dtype == np.int32
    np.testing.assert_array_equal(loaded.threshold, ff.threshold)


def test_flint_rejected_on_pallas():
    forest = ADVERSARIAL["one_tree"]()
    with pytest.raises(ValueError, match="pallas"):
        compile_plan(forest, CompilePlan(engine="bitvector",
                                         backend="pallas", flint=True,
                                         engine_kw={"interpret": True}))


def test_flint_and_quant_mutually_exclusive():
    forest = ADVERSARIAL["one_tree"]()
    with pytest.raises(ValueError):
        compile_plan(forest, CompilePlan(engine="bitvector",
                                         quant=QuantSpec(), flint=True))


# --------------------------------------------------------------------------- #
# hypothesis: randomized adversarial forests (CI; skipped offline)
# --------------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    import jax.numpy as jnp
    from repro.core.baselines import (compile_gemm, compile_native,
                                      eval_gemm, eval_native)
    from repro.core.quickscorer import (compile_qs, compile_qs_bitmm,
                                        eval_batch, eval_batch_bitmm)
    from repro.core.rapidscorer import compile_rs, eval_batch as rs_eval

    @st.composite
    def adversarial_forests(draw):
        """Random forests with adversarial structure mixed in: stumps
        alongside real trees, duplicated thresholds, unused features."""
        T = draw(st.integers(1, 4))
        L = draw(st.sampled_from([2, 4, 8, 16]))
        d_used = draw(st.integers(1, 4))
        d_extra = draw(st.integers(0, 3))          # unused feature tail
        seed = draw(st.integers(0, 10_000))
        full = draw(st.booleans())
        base = core.random_forest_ir(T, L, d_used, seed=seed, full=full)
        if draw(st.booleans()):                    # duplicate thresholds
            base.threshold = np.round(base.threshold, 1)
        n_stumps = draw(st.integers(0, 2))
        return base, d_used + d_extra, n_stumps, seed

    def _widen(base, d_total, n_stumps, seed):
        """Rebuild `base` + stumps as one ensemble over d_total features."""
        rng = np.random.default_rng(seed + 1)
        f = base
        if n_stumps == 0 and d_total == base.n_features:
            return f
        # reconstruct tree list from the IR arrays via oracle-equivalent
        # padding: easiest faithful widening is to bump n_features and
        # append stump trees directly at the Forest level
        import dataclasses
        stump_vals = rng.normal(size=(n_stumps, 1, 1))
        T, L = f.n_trees + n_stumps, f.n_leaves
        def pad(a, fill):
            out = np.full((n_stumps,) + a.shape[1:], fill, dtype=a.dtype)
            return np.concatenate([a, out])
        lv = np.zeros((n_stumps, L, f.n_classes), f.leaf_value.dtype)
        lv[:, 0, :] = stump_vals[:, 0, :]
        return dataclasses.replace(
            f, n_trees=T, n_features=d_total,
            feature=pad(f.feature, -1), threshold=pad(f.threshold, 0),
            left=pad(f.left, 0), right=pad(f.right, 0),
            leaf_lo=pad(f.leaf_lo, 0), leaf_mid=pad(f.leaf_mid, 0),
            leaf_hi=pad(f.leaf_hi, 0),
            leaf_value=np.concatenate([f.leaf_value, lv]),
            n_nodes=np.concatenate([f.n_nodes,
                                    np.zeros(n_stumps, np.int32)]),
            n_leaves_per_tree=np.concatenate(
                [f.n_leaves_per_tree, np.ones(n_stumps, np.int32)]))

    @settings(max_examples=20, deadline=None)
    @given(adversarial_forests(), st.integers(1, 24), st.integers(0, 9999))
    def test_hypothesis_engines_agree_with_oracle(af, B, xseed):
        base, d_total, n_stumps, seed = af
        forest = _widen(base, d_total, n_stumps, seed)
        X = np.random.default_rng(xseed).normal(0, 2.0, size=(B, d_total))
        expect = forest.predict_oracle(X)
        Xj = jnp.asarray(X)
        got = {
            "qs": eval_batch(compile_qs(forest), Xj),
            "bitmm": eval_batch_bitmm(compile_qs_bitmm(forest), Xj),
            "rs": rs_eval(compile_rs(forest), Xj),
            "native": eval_native(compile_native(forest), Xj),
            "gemm": eval_gemm(compile_gemm(forest), Xj),
        }
        for e, y in got.items():
            np.testing.assert_allclose(np.asarray(y), expect, rtol=1e-4,
                                       atol=1e-5, err_msg=e)

    @st.composite
    def stage_splits(draw, max_trees=12):
        """Random cascade stage boundaries: 1..4 strictly increasing
        prefixes over a random tree count (the last may or may not cover
        the forest — normalize_stages must append/clamp either way)."""
        T = draw(st.integers(2, max_trees))
        ks = draw(st.lists(st.integers(1, T + 3), min_size=1, max_size=4,
                           unique=True))
        return T, tuple(sorted(ks))

    @settings(max_examples=20, deadline=None)
    @given(stage_splits(), st.integers(1, 16), st.integers(0, 9999))
    def test_hypothesis_cascade_gate_off_quantized_bitexact(split, B,
                                                            xseed):
        """Any stage split, gate disabled → bit-exact with the base
        engine on quantized forests; with the sound bound gate →
        predict_class exactly equal."""
        T, ks = split
        forest = core.random_forest_ir(T, 8, 4, n_classes=2,
                                       seed=xseed % 97, full=False)
        X = np.random.default_rng(xseed).normal(0, 2.0, size=(B, 4))
        qf = core.quantize_forest(forest, X)
        base = core.compile_forest(qf, engine="bitvector")
        off = CascadePredictor(qf, CascadeSpec(ks, MarginGate(np.inf)))
        assert off.stages[-1] == T
        np.testing.assert_array_equal(off.predict(X), base.predict(X))
        sound = CascadePredictor(qf, CascadeSpec(ks, ScoreBoundGate()))
        np.testing.assert_array_equal(sound.predict_class(X),
                                      base.predict_class(X))

    @settings(max_examples=20, deadline=None)
    @given(adversarial_forests(), st.integers(1, 16), st.integers(0, 9999))
    def test_hypothesis_leaf_err_bound_never_exceeded(af, B, xseed):
        """The tracked worst-case bound is sound: under identical
        traversal (leaves-only quantization) the descaled integer score
        never drifts from the float score by more than
        ``leaf_err_bound``."""
        base, d_total, n_stumps, seed = af
        forest = _widen(base, d_total, n_stumps, seed)
        ql = core.quantize_forest(
            forest, spec=QuantSpec(quantize_splits=False, int_accum=True))
        X = np.random.default_rng(xseed).normal(0, 2.0, size=(B, d_total))
        got = (ql.predict_oracle(X) / core.leaf_scale(ql))
        expect = forest.predict_oracle(X)
        assert ql.leaf_err_bound is not None
        assert np.abs(got - expect).max() <= ql.leaf_err_bound + 1e-9

    @settings(max_examples=20, deadline=None)
    @given(adversarial_forests(), st.integers(1, 16), st.integers(0, 9999))
    def test_hypothesis_int_accum_cannot_overflow_and_is_bitexact(af, B,
                                                                  xseed):
        """``accum_bits`` is a compile-time proof: the structural
        worst-case |leaf sum| fits the chosen accumulator, so no input
        can overflow it; and the int-accum engines stay bit-exact vs the
        quantized oracle on randomized adversarial forests."""
        base, d_total, n_stumps, seed = af
        forest = _widen(base, d_total, n_stumps, seed)
        X = np.random.default_rng(xseed).normal(0, 2.0, size=(B, d_total))
        qf = core.quantize_forest(forest, X, spec=QuantSpec(int_accum=True))
        bits = accum_bits(qf)
        worst = int(np.abs(qf.leaf_value.astype(np.int64))
                    .max(axis=(1, 2)).sum())
        assert worst <= np.iinfo(np.int16 if bits == 16 else np.int32).max
        oracle = _q_oracle(qf, X)
        Xq = jnp.asarray(core.quantize_inputs(qf, X))
        got = {
            "qs": eval_batch(compile_qs(qf), Xq),
            "bitmm": eval_batch_bitmm(compile_qs_bitmm(qf), Xq),
            "rs": rs_eval(compile_rs(qf), Xq),
            "native": eval_native(compile_native(qf), Xq),
            "gemm": eval_gemm(compile_gemm(qf), Xq),
        }
        for e, y in got.items():
            np.testing.assert_array_equal(np.asarray(y), oracle, err_msg=e)

    @settings(max_examples=20, deadline=None)
    @given(adversarial_forests(), st.integers(1, 16), st.integers(0, 9999))
    def test_hypothesis_flint_matches_float_engines(af, B, xseed):
        base, d_total, n_stumps, seed = af
        forest = _widen(base, d_total, n_stumps, seed)
        X = np.random.default_rng(xseed).normal(
            0, 2.0, size=(B, d_total)).astype(np.float32)
        ff = flint_forest(forest)
        Xk = jnp.asarray(core.quantize_inputs(ff, X))
        Xf = jnp.asarray(X)
        np.testing.assert_array_equal(
            np.asarray(eval_batch(compile_qs(ff), Xk)),
            np.asarray(eval_batch(compile_qs(forest), Xf)))
        np.testing.assert_array_equal(
            np.asarray(eval_native(compile_native(ff), Xk)),
            np.asarray(eval_native(compile_native(forest), Xf)))

    @settings(max_examples=12, deadline=None)
    @given(adversarial_forests(), st.integers(0, 9999))
    def test_hypothesis_quantized_roundtrip_bitexact(af, xseed):
        # tmp_path is function-scoped (hypothesis health check forbids
        # it under @given); a context-managed tempdir cleans up per run
        import os
        import tempfile
        base, d_total, n_stumps, seed = af
        forest = _widen(base, d_total, n_stumps, seed)
        X = np.random.default_rng(xseed).normal(0, 2.0, size=(8, d_total))
        qf = core.quantize_forest(forest, X)
        with tempfile.TemporaryDirectory() as tmp:
            p = os.path.join(tmp, "h.repro.npz")
            io.save_forest(qf, p)
            loaded = io.load_forest(p)
        Xq = core.quantize_inputs(qf, X)
        np.testing.assert_array_equal(core.quantize_inputs(loaded, X), Xq)
        np.testing.assert_array_equal(
            np.asarray(eval_batch(compile_qs(qf), jnp.asarray(Xq))),
            np.asarray(eval_batch(compile_qs(loaded), jnp.asarray(Xq))))
