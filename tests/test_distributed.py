"""Distributed substrate: checkpoint, optimizer, compression, sharding
rules, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import (compress, compress_tree,
                                           decompress, init_residuals)
from repro.distributed.fault_tolerance import (Heartbeat, PreemptionFlag,
                                               StragglerDetector,
                                               plan_elastic_restart)
from repro.distributed.optimizer import Adam, AdamConfig
from repro.distributed.sharding import resolve_spec


# --------------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------------- #
def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32),
                  "d": jnp.zeros((), jnp.float32)}}


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    got, step = ckpt.restore(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_latest_and_cleanup(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    ckpt.cleanup(str(tmp_path), keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    got, step = ckpt.restore(str(tmp_path), t)
    assert step == 5


def test_ckpt_crc_detects_corruption(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    victim = os.path.join(tmp_path, "step_00000001", "arr_0.npy")
    data = bytearray(open(victim, "rb").read())
    data[-1] ^= 0xFF
    open(victim, "wb").write(bytes(data))
    with pytest.raises(IOError, match="CRC"):
        ckpt.restore(str(tmp_path), t)


def test_ckpt_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((2, 4)), "b": {"c": jnp.ones((2,), jnp.int32),
                                         "d": jnp.zeros(())}}
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), bad)


def test_ckpt_atomic_tmp_never_latest(tmp_path):
    """A stale .tmp dir must not be treated as a checkpoint."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    os.makedirs(os.path.join(tmp_path, "step_00000099.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #
def test_adam_quadratic_convergence():
    opt = Adam(AdamConfig(lr=0.1))
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||²
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adam_int8_state_tracks_f32():
    p0 = {"w": jnp.linspace(-2, 2, 64).reshape(8, 8)}
    g = {"w": jnp.ones((8, 8)) * 0.5}
    opt_f = Adam(AdamConfig(lr=0.05, state_dtype="f32"))
    opt_q = Adam(AdamConfig(lr=0.05, state_dtype="int8"))
    pf, sf = p0, opt_f.init(p0)
    pq, sq = p0, opt_q.init(p0)
    for _ in range(20):
        pf, sf = opt_f.update(g, sf, pf)
        pq, sq = opt_q.update(g, sq, pq)
    np.testing.assert_allclose(np.asarray(pf["w"]), np.asarray(pq["w"]),
                               rtol=0.05, atol=0.05)


def test_adam_int8_state_bytes():
    p = {"w": jnp.zeros((128, 256))}
    s = Adam(AdamConfig(state_dtype="int8")).init(p)
    assert s["m"]["w"]["q"].dtype == jnp.int8
    assert s["m"]["w"]["scale"].shape == (128, 1)


def test_adam_state_logical_specs_shape():
    opt = Adam(AdamConfig(state_dtype="int8"))
    logical = {"w": ("embed", "ff")}
    specs = opt.state_logical_specs(logical)
    assert specs["m"]["w"]["q"] == ("embed", "ff")
    assert specs["step"] == ()


# --------------------------------------------------------------------------- #
# gradient compression
# --------------------------------------------------------------------------- #
def test_compress_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, size=(32, 64)).astype(np.float32))
    q, scale, resid = compress(g, jnp.zeros_like(g))
    deq = decompress(q, scale)
    # per-row max error ≤ scale/2 + rounding
    err = np.abs(np.asarray(deq - g))
    assert (err <= np.asarray(scale) * 0.51 + 1e-7).all()
    np.testing.assert_allclose(np.asarray(resid), np.asarray(g - deq),
                               rtol=1e-6, atol=1e-7)


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the *sum* of dequantized grads converges to the
    sum of true grads (residual stays bounded)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros((16, 32), np.float32)
    deq_sum = np.zeros_like(true_sum)
    resid = {"g": jnp.zeros((16, 32), jnp.float32)}
    for _ in range(50):
        g = rng.normal(0, 1, size=(16, 32)).astype(np.float32)
        true_sum += g
        deq, resid = compress_tree({"g": jnp.asarray(g)}, resid)
        deq_sum += np.asarray(deq["g"])
    # remaining deficit is exactly the residual — bounded, not growing
    gap = np.abs(true_sum - deq_sum)
    assert gap.max() < 0.1       # one int8 step of a ~N(0,1) row


def test_init_residuals_zeros():
    r = init_residuals({"a": jnp.ones((3,)), "b": jnp.ones(())})
    assert all(float(jnp.sum(jnp.abs(x))) == 0 for x in jax.tree.leaves(r))


# --------------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------------- #
class FakeMesh:
    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_resolve_spec_tp_priority():
    # ff beats heads
    spec = resolve_spec((1024, 4096), ("embed", "ff"), MESH1)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    spec = resolve_spec((64, 1024, 32, 128),
                        ("layers", "embed", "heads", "head_dim"), MESH1)
    assert spec[2] == "model"          # heads divisible by 16


def test_resolve_spec_head_dim_fallback():
    # 15 heads (smollm) not divisible by 16 → head_dim picks up TP
    spec = resolve_spec((960, 15, 64), ("embed", "heads", "head_dim"),
                        MESH1)
    assert spec[1] is None and spec[2] == "model"


def test_resolve_spec_fsdp_multi_axis():
    spec = resolve_spec((8192, 22016), ("embed", "ff"), MESH2)
    assert spec[0] == ("pod", "data") and spec[1] == "model"


def test_resolve_spec_replicated_small():
    spec = resolve_spec((3,), ("ssm_heads",), MESH1)
    assert spec == jax.sharding.PartitionSpec(None)


# --------------------------------------------------------------------------- #
# fault tolerance
# --------------------------------------------------------------------------- #
def test_straggler_detector():
    det = StragglerDetector(window=8, multiplier=3.0, grace=2)
    assert not det.observe(60.0)       # grace (compile step)
    assert not det.observe(1.0)
    for _ in range(6):
        assert not det.observe(1.0)
    assert det.observe(5.0)            # 5 > 3×1.0
    assert not det.observe(1.1)
    assert det.median == pytest.approx(1.0, rel=0.2)
    # straggler must not poison the window
    assert det.observe(5.0)


def test_heartbeat_survey(tmp_path):
    hb0 = Heartbeat(str(tmp_path), 0)
    hb1 = Heartbeat(str(tmp_path), 1)
    hb0.beat(10, now=1000.0)
    hb1.beat(10, now=900.0)            # stale
    got = Heartbeat.survey(str(tmp_path), timeout_s=30.0, now=1001.0)
    assert got[0]["alive"] and not got[1]["alive"]
    assert got[0]["step"] == 10


def test_elastic_plan_shrinks_dp_pow2():
    plan = plan_elastic_restart(alive=[0, 1, 2, 3, 4, 6], total_hosts=8,
                                dp_size=8, global_batch=256)
    assert plan.dp_size == 4
    assert plan.accum_steps == 2
    assert plan.global_batch == 256
    assert 7 in plan.dropped_hosts and 5 in plan.dropped_hosts


def test_elastic_plan_all_alive_noop():
    plan = plan_elastic_restart(alive=list(range(8)), total_hosts=8,
                                dp_size=8, global_batch=64)
    assert plan.dp_size == 8 and plan.accum_steps == 1
    assert plan.dropped_hosts == ()


def test_preemption_flag():
    f = PreemptionFlag()
    assert not f
    f.set()
    assert f
