"""Property-based tests (hypothesis) over the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import core
from repro.core.forest import WORD, _interval_bits
from repro.core.quickscorer import compile_qs, eval_batch
from repro.core.rapidscorer import compile_rs, eval_batch as rs_eval
from repro.core.baselines import (compile_gemm, compile_native, eval_gemm,
                                  eval_native)
from repro.core.quantize import QuantSpec, quantize_forest, quantize_inputs

import jax.numpy as jnp


forest_params = st.tuples(
    st.integers(1, 6),           # n_trees
    st.sampled_from([2, 4, 8, 16, 33, 64]),   # n_leaves
    st.integers(1, 12),          # n_features
    st.integers(1, 4),           # n_classes
    st.integers(0, 10_000),      # seed
    st.booleans(),               # full/unbalanced
)


@settings(max_examples=25, deadline=None)
@given(forest_params, st.integers(1, 32), st.integers(0, 10_000))
def test_all_engines_agree_with_oracle(fp, batch, xseed):
    T, L, d, C, seed, full = fp
    forest = core.random_forest_ir(T, L, d, n_classes=C, seed=seed,
                                   full=full)
    X = np.random.default_rng(xseed).normal(0, 2.0, size=(batch, d))
    expect = forest.predict_oracle(X)
    Xj = jnp.asarray(X)
    qs = np.asarray(eval_batch(compile_qs(forest), Xj))
    rs = np.asarray(rs_eval(compile_rs(forest), Xj))
    nat = np.asarray(eval_native(compile_native(forest), Xj))
    gem = np.asarray(eval_gemm(compile_gemm(forest), Xj))
    np.testing.assert_allclose(qs, expect, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(rs, expect, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(nat, expect, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gem, expect, rtol=1e-3, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 64), st.integers(0, 64), st.integers(1, 2))
def test_interval_bits_popcount(lo_raw, hi_raw, W):
    lo, hi = sorted((lo_raw % (W * WORD), hi_raw % (W * WORD)))
    bits = _interval_bits(lo, hi, W)
    total = sum(bin(int(w)).count("1") for w in bits)
    assert total == hi - lo
    # every bit in [lo, hi) is set
    for j in range(lo, hi):
        assert (int(bits[j // WORD]) >> (j % WORD)) & 1


@settings(max_examples=25, deadline=None)
@given(forest_params, st.integers(0, 10_000), st.sampled_from([16, 8]))
def test_quantized_engines_internally_consistent(fp, xseed, bits):
    """All engines must agree EXACTLY on a quantized forest (integer
    comparisons have no float slack)."""
    T, L, d, C, seed, full = fp
    forest = core.random_forest_ir(T, L, d, n_classes=C, seed=seed,
                                   full=full)
    qf = quantize_forest(forest, spec=QuantSpec(bits=bits))
    X = np.random.default_rng(xseed).normal(0, 2.0, size=(8, d))
    Xq = jnp.asarray(quantize_inputs(qf, X))
    qs = np.asarray(eval_batch(compile_qs(qf), Xq))
    rs = np.asarray(rs_eval(compile_rs(qf), Xq))
    nat = np.asarray(eval_native(compile_native(qf), Xq))
    np.testing.assert_array_equal(qs, rs)
    np.testing.assert_array_equal(qs, nat)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1000), st.floats(-100, 100),
       st.floats(0.01, 10.0))
def test_quantization_preserves_comparisons(seed, t, span):
    """Order consistency: if q(x) > q(t) then x > t (floor is monotone)."""
    rng = np.random.default_rng(seed)
    xs = t + rng.uniform(-span, span, size=64)
    s = 2.0 ** 15
    lo, hi = min(xs.min(), t), max(xs.max(), t)
    if hi - lo < 1e-9:
        return
    nx = (xs - lo) / (hi - lo)
    nt = (t - lo) / (hi - lo)
    qx, qt = np.floor(s * nx), np.floor(s * nt)
    # monotone: quantized comparison can only flip pairs within one grid cell
    flip = (qx > qt) != (xs > t)
    assert (np.abs(nx[flip] - nt) <= 1.0 / s + 1e-12).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.sampled_from([4, 8, 16]), st.integers(1, 8),
       st.integers(0, 99))
def test_merge_never_changes_predictions(T, L, d, seed):
    """Node merging is a pure re-indexing: predictions are identical even
    with artificially duplicated thresholds."""
    forest = core.random_forest_ir(T, L, d, seed=seed)
    # force duplicates: round thresholds to one decimal
    forest.threshold = np.round(forest.threshold, 1)
    X = np.random.default_rng(seed).normal(size=(16, d))
    qs = np.asarray(eval_batch(compile_qs(forest), jnp.asarray(X)))
    rs = np.asarray(rs_eval(compile_rs(forest), jnp.asarray(X)))
    np.testing.assert_array_equal(qs, rs)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 500))
def test_exit_leaf_is_reached_leaf(seed):
    """The QuickScorer exit leaf equals the leaf the plain traversal
    reaches, for every (instance, tree)."""
    from repro.core.quickscorer import exit_leaf, mask_reduce
    forest = core.random_forest_ir(3, 16, 5, seed=seed, full=False)
    X = np.random.default_rng(seed + 1).normal(size=(8, 5))
    qs = compile_qs(forest)
    Xj = jnp.asarray(X)
    cond = (Xj[:, qs.feat] > qs.thr[None]) & qs.valid[None]
    leafidx = mask_reduce(cond, qs.masks, qs.init_idx)
    leaves = np.asarray(exit_leaf(leafidx))            # (B, T)
    # numpy traversal per tree
    for t in range(forest.n_trees):
        for i in range(X.shape[0]):
            node = 0
            while True:
                f = forest.feature[t, node]
                nxt = (forest.left[t, node]
                       if X[i, f] <= forest.threshold[t, node]
                       else forest.right[t, node])
                if nxt < 0:
                    assert leaves[i, t] == -nxt - 1
                    break
                node = nxt
