"""Concurrent multi-tenant serving runtime (repro.inference.runtime):
threaded request loop, tenancy routing, SLO-aware adaptive batching,
shape warmup, manifest cold start — plus the bounded-stats and
monotonic-clock satellites in repro.inference.server."""
import threading
import time

import numpy as np
import pytest

from repro import core
from repro.inference import (AdaptiveBatchController, ForestServer,
                             Reservoir, ServingRuntime, SLOConfig)
from repro.inference.server import ServerStats


@pytest.fixture(scope="module")
def qpred_pair():
    """Two quantized forests + compiled predictors (distinct shapes so
    tenant routing mistakes can't alias)."""
    rng = np.random.default_rng(0)
    fa = core.random_forest_ir(n_trees=8, n_leaves=16, n_features=6,
                               n_classes=2, seed=0)
    fb = core.random_forest_ir(n_trees=12, n_leaves=16, n_features=6,
                               n_classes=3, seed=1)
    qa = core.quantize_forest(fa, rng.normal(size=(64, 6)))
    qb = core.quantize_forest(fb, rng.normal(size=(64, 6)))
    return (qa, core.compile_forest(qa, engine="bitvector"),
            qb, core.compile_forest(qb, engine="bitmm"))


# --------------------------------------------------------------------------- #
# Reservoir (bounded ServerStats satellite)
# --------------------------------------------------------------------------- #
def test_reservoir_exact_below_cap():
    r = Reservoir(cap=100)
    r.extend(float(i) for i in range(50))
    assert len(r) == 50 and r.n == 50
    assert list(r) == [float(i) for i in range(50)]
    assert r.mean() == pytest.approx(24.5)
    assert r.percentile(50) == pytest.approx(24.5)


def test_reservoir_bounded_memory_million_records():
    """A million-record run must not hold a million floats — retained
    storage is capped while count/sum stay exact."""
    r = Reservoir(cap=512)
    n = 1_000_000
    for i in range(n):
        r.append(1.0)
    assert r.n == n
    assert len(r) == 512                       # retained sample bounded
    assert len(r._sample) == 512               # the actual storage
    assert r.mean() == pytest.approx(1.0)
    assert r.percentile(99) == pytest.approx(1.0)


def test_reservoir_sample_is_plausible_and_deterministic():
    a, b = Reservoir(cap=64, seed=3), Reservoir(cap=64, seed=3)
    vals = list(np.linspace(0.0, 100.0, 10_000))
    a.extend(vals)
    b.extend(vals)
    assert list(a) == list(b)                  # seeded: deterministic
    # a uniform sample of a uniform ramp: median lands mid-range
    assert 20.0 < a.percentile(50) < 80.0


def test_reservoir_list_equality_and_empty():
    r = Reservoir()
    assert r == [] and not r
    assert ServerStats().batch_sizes == []
    r.append(2.0)
    assert r == [2.0] and bool(r)
    assert np.asarray(r).tolist() == [2.0]
    with pytest.raises(ValueError):
        Reservoir(cap=0)


def test_server_stats_summary_uses_exact_mean():
    st = ServerStats()
    st.n_batches = 0
    cap = st.batch_sizes.cap
    for i in range(cap + 100):                 # overflow the reservoir
        st.batch_sizes.append(4.0)
    assert st.summary()["mean_batch"] == pytest.approx(4.0)


# --------------------------------------------------------------------------- #
# Monotonic clock + block_until_ready satellites (ForestServer)
# --------------------------------------------------------------------------- #
def test_submit_default_clock_is_monotonic_not_wall(small_forest):
    pred = core.compile_forest(small_forest, engine="bitvector")
    srv = ForestServer(pred, max_batch=8, max_wait_ms=1.0)
    req = srv.submit(np.zeros(small_forest.n_features))
    # perf_counter timebase (process/boot origin), not the epoch wall
    # clock — an NTP step can no longer produce negative latencies
    assert abs(req.arrival_s - time.perf_counter()) < 5.0
    assert abs(req.arrival_s - time.time()) > 1e6


class _LazyScores:
    """Duck-typed 'device array still computing': block_until_ready
    sleeps, mimicking async dispatch that returned before finishing."""

    def __init__(self, arr, delay_s):
        self._arr = arr
        self.delay_s = delay_s
        self.blocked = False

    def block_until_ready(self):
        time.sleep(self.delay_s)
        self.blocked = True
        return self._arr

    def __iter__(self):
        return iter(self._arr)

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self._arr, dtype=dtype)


class _LazyPredictor:
    def __init__(self, delay_s=0.05, C=2):
        self.delay_s = delay_s
        self.C = C
        self.last = None

    def predict(self, X):
        self.last = _LazyScores(np.zeros((len(X), self.C)), self.delay_s)
        return self.last


def test_run_blocks_unfinished_scores_before_stamping_done(small_forest):
    """Regression (PR-6 class of bug): _run must block_until_ready the
    scores before stamping done_s, or async dispatch understates
    latency.  The lazy predictor 'finishes' 50 ms after predict()
    returns; the recorded latency must include that."""
    pred = _LazyPredictor(delay_s=0.05)
    srv = ForestServer(pred, max_batch=4, max_wait_ms=1.0)
    srv.submit(np.zeros(3), arrival_s=0.0)
    done = srv.flush(now_s=0.0)
    assert len(done) == 1
    assert pred.last.blocked                      # the sync happened
    assert done[0].latency_ms >= 50.0             # ...before done_s


# --------------------------------------------------------------------------- #
# Adaptive batching controller
# --------------------------------------------------------------------------- #
def test_controller_shrinks_on_violation_grows_on_headroom():
    slo = SLOConfig(target_p99_ms=10.0, window=8, min_batch=2,
                    max_batch=64, min_wait_ms=0.5, max_wait_ms=8.0)
    c = AdaptiveBatchController(slo, batch=64, wait_ms=8.0)
    for _ in range(8):
        c.observe(50.0)                            # way over budget
    assert c.decisions[-1]["action"] == "shrink"
    assert c.max_batch == 32 and c.max_wait_ms == 4.0
    for _ in range(5):                             # shrink to the floor
        for _ in range(8):
            c.observe(50.0)
    assert c.max_batch == 2 and c.max_wait_ms == 0.5   # clamped, bounded
    for _ in range(8):
        c.observe(1.0)                             # far under budget
    assert c.decisions[-1]["action"] == "grow"
    assert c.max_batch == 3 and c.max_wait_ms == pytest.approx(0.625)
    for _ in range(40):                            # grow to the ceiling
        for _ in range(8):
            c.observe(1.0)
    assert c.max_batch == 64 and c.max_wait_ms == 8.0  # clamped, bounded


def test_controller_holds_inside_band_and_is_deterministic():
    slo = SLOConfig(target_p99_ms=10.0, window=4, headroom=0.7,
                    max_batch=32, max_wait_ms=4.0)
    runs = []
    for _ in range(2):
        c = AdaptiveBatchController(slo, batch=16, wait_ms=2.0)
        trace = [8.0] * 4 + [20.0] * 4 + [1.0] * 4 + [9.0] * 4
        for v in trace:
            c.observe(v)
        runs.append([d["action"] for d in c.decisions])
    assert runs[0] == runs[1]                      # pure replay
    assert runs[0] == ["hold", "shrink", "grow", "hold"]


def test_controller_partial_window_no_decision_and_none_ignored():
    c = AdaptiveBatchController(SLOConfig(target_p99_ms=5.0, window=16),
                                batch=8, wait_ms=2.0)
    for _ in range(15):
        assert c.observe(3.0) is None
    assert c.observe(None) is None                 # incomplete latencies
    assert c.observe(3.0) is not None              # 16th closes the window


def test_controller_rejects_empty_bounds():
    with pytest.raises(ValueError, match="batch bounds"):
        AdaptiveBatchController(
            SLOConfig(target_p99_ms=5.0, min_batch=16, max_batch=8),
            batch=8, wait_ms=1.0)


def test_adaptive_runtime_virtual_clock_deterministic(qpred_pair):
    """The full pump path under a virtual clock: the controller's
    effective knobs change deterministically from observed (virtual)
    latencies, and stay within bounds."""
    qa, pa, *_ = qpred_pair
    slo = SLOConfig(target_p99_ms=0.5, window=4, min_batch=1,
                    max_batch=8, min_wait_ms=0.1, max_wait_ms=50.0)

    def run_once():
        rt = ServingRuntime(clock=lambda: 0.0)
        rt.add_model("m", pa, max_batch=8, max_wait_ms=50.0, slo=slo)
        X = np.zeros((32, qa.n_features))
        eff = []
        for i in range(32):
            # arrivals 10 ms apart; pump 60 ms later → every request
            # waits out the (virtual) deadline, so observed latency far
            # exceeds the 0.5 ms budget → the controller must shrink
            rt.submit("m", X[i], arrival_s=i * 0.01)
            rt.pump(now_s=i * 0.01 + 0.06)
            eff.append((rt.tenant("m").batcher.max_wait_ms,
                        rt.tenant("m").batcher.max_batch))
        rt.flush(now_s=10.0)
        return eff

    a, b = run_once(), run_once()
    assert a == b                                    # deterministic
    waits = [w for w, _ in a]
    assert waits[-1] < waits[0]                      # it shrank
    assert all(0.1 <= w <= 50.0 for w in waits)      # bounded
    assert all(1 <= mb <= 8 for _, mb in a)


# --------------------------------------------------------------------------- #
# Warmup
# --------------------------------------------------------------------------- #
def test_warmup_covers_ladder_and_freezes_trace_count(qpred_pair):
    """After warmup, serving any batch size adds zero new traces: the
    pad-to-bucket dispatch only ever presents warmed shapes."""
    qa, _, *_ = qpred_pair
    pred = core.compile_forest(qa, engine="bitvector")   # fresh jit cache
    rt = ServingRuntime()
    rt.add_model("m", pred, max_batch=13, max_wait_ms=1.0)
    warmed = rt.warmup()
    assert warmed == {"m": [1, 2, 4, 8, 16]}             # ladder to 2^ceil
    n_traces = pred._fn._cache_size()
    assert n_traces == 5
    X = np.random.default_rng(0).normal(size=(40, qa.n_features))
    for i in range(40):
        rt.submit("m", X[i], arrival_s=i * 1e-4)
        rt.pump(now_s=i * 1e-4)
    rt.flush(now_s=1.0)
    assert pred._fn._cache_size() == n_traces            # no cold shapes
    assert rt.summary("m")["n_requests"] == 40


def test_warmup_predictions_bit_identical(qpred_pair):
    qa, _, *_ = qpred_pair
    pred = core.compile_forest(qa, engine="rapidscorer")
    X = np.random.default_rng(1).normal(size=(9, qa.n_features))
    before = pred.predict(X)
    rt = ServingRuntime()
    rt.add_model("m", pred, max_batch=16)
    rt.warmup("m")
    np.testing.assert_array_equal(pred.predict(X), before)


def test_warmup_fused_cascade_resets_exit_stats(qpred_pair):
    from repro.cascade import CascadeSpec, MarginGate
    qa, *_ = qpred_pair
    fused = core.compile_forest(qa, engine="bitvector",
                                cascade=CascadeSpec(
                                    stages=(4, 8),
                                    policy=MarginGate(0.5), fused=True))
    rt = ServingRuntime()
    rt.add_model("casc", fused, max_batch=16)
    rt.warmup()
    # synthetic warmup rows must not pollute served exit accounting
    assert fused.exit_counts.sum() == 0
    n_traces = fused._jit_cache["prog"]._cache_size()
    assert n_traces >= 1
    X = np.random.default_rng(2).normal(size=(11, qa.n_features))
    for i in range(11):
        rt.submit("casc", X[i], arrival_s=i * 1e-4)
    rt.flush(now_s=1.0)
    # fused cascade buckets internally: the warmed shapes cover serving
    assert fused._jit_cache["prog"]._cache_size() == n_traces
    assert fused.exit_counts.sum() == 11


def test_warmup_respects_adaptive_upper_bound(qpred_pair):
    """Adaptive growth must never hit a cold shape: warmup pre-traces
    to the controller's max_batch bound, not the current effective."""
    qa, pa, *_ = qpred_pair
    rt = ServingRuntime()
    rt.add_model("m", pa, max_batch=4, max_wait_ms=1.0,
                 slo=SLOConfig(target_p99_ms=5.0, max_batch=32))
    assert rt.warmup() == {"m": [1, 2, 4, 8, 16, 32]}


# --------------------------------------------------------------------------- #
# Conformance: serving == synchronous predict, per engine
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("engine", ["bitvector", "bitmm", "native", "gemm"])
def test_served_scores_bit_identical_to_predict(qpred_pair, engine):
    """The padded/bucketed dispatch path must be bit-identical to the
    synchronous predictor.predict on quantized forests — including the
    odd batch tails that exercise the zero-padding."""
    qa, *_ = qpred_pair
    pred = core.compile_forest(qa, engine=engine)
    X = np.random.default_rng(3).normal(size=(23, qa.n_features))
    direct = pred.predict(X)
    rt = ServingRuntime()
    rt.add_model("m", pred, max_batch=5, max_wait_ms=1.0)   # odd batches
    reqs = [rt.submit("m", X[i], arrival_s=i * 1e-4) for i in range(23)]
    rt.flush(now_s=1.0)
    got = np.stack([r.result for r in reqs])
    np.testing.assert_array_equal(got, direct)


def test_served_cascade_exit_accounting_intact(qpred_pair):
    """Cascade tenants: scores match the synchronous path and the
    per-stage exit accounting reflects exactly the served rows."""
    from repro.cascade import CascadePredictor, CascadeSpec, MarginGate
    qa, *_ = qpred_pair
    spec = CascadeSpec(stages=(4, 8), policy=MarginGate(0.5))
    ref = CascadePredictor(qa, spec, engine="bitvector")
    served = CascadePredictor(qa, spec, engine="bitvector")
    X = np.random.default_rng(4).normal(size=(17, qa.n_features))
    direct = ref.predict(X)
    rt = ServingRuntime()
    rt.add_model("casc", served, max_batch=17, max_wait_ms=1.0)
    reqs = [rt.submit("casc", X[i], arrival_s=0.0) for i in range(17)]
    rt.flush(now_s=1.0)
    np.testing.assert_array_equal(np.stack([r.result for r in reqs]),
                                  direct)
    assert served.exit_counts.sum() == 17
    np.testing.assert_array_equal(served.exit_counts, ref.exit_counts)
    s = rt.summary("casc")
    assert "exit_fractions" in s and sum(s["exit_fractions"]) == \
        pytest.approx(1.0)


# --------------------------------------------------------------------------- #
# Concurrency: threaded loop, tenancy, shutdown
# --------------------------------------------------------------------------- #
def _hammer(rt, model_id, X, n_threads, per_thread):
    """n_threads × per_thread concurrent submissions; returns requests."""
    all_reqs, errs = [], []
    lock = threading.Lock()

    def worker(seed):
        rng = np.random.default_rng(seed)
        mine = []
        try:
            for _ in range(per_thread):
                i = int(rng.integers(0, len(X)))
                mine.append((i, rt.submit(model_id, X[i])))
        except Exception as e:                        # pragma: no cover
            errs.append(e)
        with lock:
            all_reqs.extend(mine)

    ts = [threading.Thread(target=worker, args=(s,))
          for s in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    return all_reqs


def test_threaded_exactly_once_single_tenant(qpred_pair):
    qa, pa, *_ = qpred_pair
    X = np.random.default_rng(5).normal(size=(32, qa.n_features))
    direct = pa.predict(X)
    rt = ServingRuntime()
    rt.add_model("m", pa, max_batch=16, max_wait_ms=0.5)
    with rt:
        reqs = _hammer(rt, "m", X, n_threads=8, per_thread=40)
        for _, r in reqs:
            r.wait(timeout=30)
    # exactly once: every request resolved, rids unique, totals add up
    assert len(reqs) == 320
    assert len({r.rid for _, r in reqs}) == 320
    for i, r in reqs:
        np.testing.assert_array_equal(r.result, direct[i])
        assert r.done_s is not None and r.latency_ms >= 0.0
    s = rt.summary("m")
    assert s["n_requests"] == 320
    assert rt.tenant("m").stats.batch_sizes.total == 320   # sum of sizes


def test_threaded_multi_tenant_routing(qpred_pair):
    qa, pa, qb, pb = qpred_pair
    X = np.random.default_rng(6).normal(size=(16, qa.n_features))
    da, db = pa.predict(X), pb.predict(X)
    assert da.shape[1] != db.shape[1]          # routing mistakes visible
    rt = ServingRuntime()
    rt.add_model("a", pa, max_batch=8, max_wait_ms=0.5)
    rt.add_model("b", pb, max_batch=8, max_wait_ms=0.5)
    rt.warmup()
    with rt:
        ra = _hammer(rt, "a", X, n_threads=4, per_thread=25)
        rb = _hammer(rt, "b", X, n_threads=4, per_thread=25)
        for _, r in ra + rb:
            r.wait(timeout=30)
    for i, r in ra:
        np.testing.assert_array_equal(r.result, da[i])
    for i, r in rb:
        np.testing.assert_array_equal(r.result, db[i])
    assert rt.summary("a")["n_requests"] == 100
    assert rt.summary("b")["n_requests"] == 100


def test_close_flushes_queued_requests_no_deadlock(qpred_pair):
    """Shutdown contract: whatever is still queued when close() is
    called completes exactly once; close joins within its timeout."""
    qa, pa, *_ = qpred_pair
    X = np.zeros((4, qa.n_features))
    rt = ServingRuntime()
    # deadline far away: requests sit in the queue until shutdown
    rt.add_model("m", pa, max_batch=64, max_wait_ms=60_000.0)
    rt.start()
    reqs = [rt.submit("m", X[i]) for i in range(4)]
    rt.close(timeout=30)
    for r in reqs:
        assert r.future.done()
        assert r.result is not None
    assert rt.summary("m")["n_requests"] == 4
    with pytest.raises(RuntimeError, match="closed"):
        rt.submit("m", X[0])
    rt.close()                                  # idempotent


def test_manual_close_flushes_without_thread(qpred_pair):
    qa, pa, *_ = qpred_pair
    rt = ServingRuntime(clock=lambda: 0.0)
    rt.add_model("m", pa, max_batch=64, max_wait_ms=60_000.0)
    r = rt.submit("m", np.zeros(qa.n_features))
    rt.close()
    assert r.future.done() and r.result is not None


def test_batch_exception_resolves_futures_and_worker_survives(qpred_pair):
    qa, pa, *_ = qpred_pair

    class Flaky:
        def __init__(self, inner):
            self.inner = inner
            self.fail_next = True

        def predict(self, X):
            if self.fail_next:
                self.fail_next = False
                raise RuntimeError("boom")
            return self.inner.predict(X)

        def host_forest(self):
            return self.inner.host_forest()

    rt = ServingRuntime()
    rt.add_model("m", Flaky(pa), max_batch=1, max_wait_ms=0.0)
    with rt:
        bad = rt.submit("m", np.zeros(qa.n_features))
        with pytest.raises(RuntimeError, match="boom"):
            bad.wait(timeout=30)
        good = rt.submit("m", np.zeros(qa.n_features))
        assert good.wait(timeout=30) is not None   # worker kept serving


def test_pump_and_flush_reject_while_threaded(qpred_pair):
    qa, pa, *_ = qpred_pair
    rt = ServingRuntime()
    rt.add_model("m", pa)
    with rt:
        with pytest.raises(RuntimeError, match="manual"):
            rt.pump()
        with pytest.raises(RuntimeError, match="manual"):
            rt.flush()


def test_unknown_tenant_and_duplicate_and_bad_id(qpred_pair):
    qa, pa, *_ = qpred_pair
    rt = ServingRuntime()
    rt.add_model("m", pa)
    with pytest.raises(ValueError, match="unknown model id"):
        rt.submit("nope", np.zeros(qa.n_features))
    with pytest.raises(ValueError, match="already serving"):
        rt.add_model("m", pa)
    with pytest.raises(ValueError, match="model id"):
        rt.add_model("bad/id", pa)


# --------------------------------------------------------------------------- #
# Manifest cold start
# --------------------------------------------------------------------------- #
def test_save_load_manifest_cold_start_bit_identical(qpred_pair, tmp_path):
    qa, _, qb, _ = qpred_pair
    rt = ServingRuntime()
    rt.add_model("alpha", core.compile_forest(qa, engine="bitvector"),
                 max_batch=16, max_wait_ms=3.0,
                 slo=SLOConfig(target_p99_ms=8.0, max_batch=64))
    rt.add_model("beta", core.compile_forest(qb, engine="bitmm"),
                 max_batch=8, max_wait_ms=1.5)
    X = np.random.default_rng(7).normal(size=(10, qa.n_features))
    da = rt.tenant("alpha").predictor.predict(X)
    db = rt.tenant("beta").predictor.predict(X)

    manifest = rt.save(tmp_path / "fleet")
    rt2 = ServingRuntime.load(manifest)
    assert set(rt2.model_ids) == {"alpha", "beta"}
    np.testing.assert_array_equal(rt2.tenant("alpha").predictor.predict(X),
                                  da)
    np.testing.assert_array_equal(rt2.tenant("beta").predictor.predict(X),
                                  db)
    # serving config + SLO round-trip
    ta, tb = rt2.tenant("alpha"), rt2.tenant("beta")
    assert ta.cfg_max_batch == 16 and ta.cfg_max_wait_ms == 3.0
    assert ta.controller is not None
    assert ta.controller.slo == SLOConfig(target_p99_ms=8.0, max_batch=64)
    assert tb.controller is None
    assert tb.cfg_max_batch == 8 and tb.cfg_max_wait_ms == 1.5
    # the loaded fleet actually serves, bit-identically
    reqs = [rt2.submit("alpha", X[i], arrival_s=0.0) for i in range(10)]
    rt2.flush(now_s=1.0)
    np.testing.assert_array_equal(np.stack([r.result for r in reqs]), da)
    # loading the directory (not the manifest file) works too
    rt3 = ServingRuntime.load(tmp_path / "fleet")
    assert set(rt3.model_ids) == {"alpha", "beta"}


def test_load_manifest_rejects_garbage(tmp_path):
    from repro.io import packed
    p = tmp_path / "manifest.json"
    p.write_text("not json {")
    with pytest.raises(ValueError, match="not a readable manifest"):
        packed.load_manifest(str(p))
    p.write_text('{"format": "something.else", "tenants": {}}')
    with pytest.raises(ValueError, match="unknown manifest format"):
        packed.load_manifest(str(p))
    p.write_text('{"format": "repro.tenants", "version": 99, '
                 '"tenants": {"m": {"artifact": "x.npz"}}}')
    with pytest.raises(ValueError, match="newer"):
        packed.load_manifest(str(p))
    p.write_text('{"format": "repro.tenants", "version": 1, '
                 '"tenants": {}}')
    with pytest.raises(ValueError, match="no tenants"):
        packed.load_manifest(str(p))
    with pytest.raises(ValueError, match="artifact"):
        packed.save_manifest(str(p), {"m": {"no_artifact": True}})


def test_from_forests_shares_autotune_cache(qpred_pair, tmp_path,
                                            monkeypatch):
    """N same-shaped tenants pay for ONE sweep: the second choose() is
    a cache hit (the runtime shares the process-wide autotune cache)."""
    from repro.core import engine_select
    qa, *_ = qpred_pair
    monkeypatch.setenv("REPRO_ENGINE_CACHE",
                       str(tmp_path / "cache.json"))
    engine_select.clear_cache()
    rt = ServingRuntime.from_forests(
        {"a": qa, "b": qa}, max_batch=8,
        engines=("qs", "native"), repeats=1)
    assert rt.tenant("a").engine_choice.from_cache is False
    assert rt.tenant("b").engine_choice.from_cache is True
    assert rt.tenant("a").engine_choice.engine == \
        rt.tenant("b").engine_choice.engine
    engine_select.clear_cache()
