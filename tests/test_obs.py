"""Observability layer: registry, exposition, tracing, retrace
detection, structured logging, and the instrumented serving runtime
(docs/OBSERVABILITY.md).

Conventions: every test builds its own ``MetricsRegistry`` (or swaps
the process default and restores it) so metric values are exact — the
process-wide default registry accumulates across tests by design,
exactly like a Prometheus process.
"""
import io
import json
import re
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import core
from repro.inference import ForestServer, ServingRuntime
from repro.obs import (METRIC_CATALOG, CompileWatch, MetricsRegistry,
                       MetricsServer, PHASES, ServingMetrics, Span,
                       TraceBuffer, fn_cache_size, get_registry,
                       json_snapshot, set_default_registry)
from repro.obs.log import StructLogger, effective_level, set_level
from repro.obs.trace import PHASES as TRACE_PHASES


def _forest(seed=0, trees=8, features=6):
    f = core.random_forest_ir(n_trees=trees, n_leaves=8,
                              n_features=features, n_classes=3, seed=seed)
    rng = np.random.default_rng(seed)
    return core.quantize_forest(f, rng.normal(size=(128, features)))


# --------------------------------------------------------------------------- #
# registry basics
# --------------------------------------------------------------------------- #
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("repro_t_total", "h")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1.0)                    # counters are monotone

    g = reg.gauge("repro_t_gauge", "h")
    g.set(7.0)
    g.dec(2.0)
    assert g.value == 5.0

    h = reg.histogram("repro_t_ms", "h")
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100
    assert h.sum == sum(range(100))
    assert h.percentile(50) == pytest.approx(49.5)


def test_labels_exact_schema_and_children():
    reg = MetricsRegistry()
    c = reg.counter("repro_l_total", "h", labels=("tenant",))
    c.labels(tenant="a").inc()
    c.labels(tenant="a").inc()
    c.labels(tenant="b").inc()
    assert c.labels(tenant="a").value == 2
    assert c.labels(tenant="b").value == 1
    with pytest.raises(ValueError):
        c.labels(wrong="a")            # wrong label name
    with pytest.raises(ValueError):
        c.labels()                     # missing label
    with pytest.raises(ValueError):
        c.inc()                        # label-free sugar on labeled family


def test_get_or_create_rejects_kind_and_schema_mismatch():
    reg = MetricsRegistry()
    reg.counter("repro_m_total", "h", labels=("tenant",))
    # same spec: same family object back
    again = reg.counter("repro_m_total", "h", labels=("tenant",))
    assert again is reg.get("repro_m_total")
    with pytest.raises(ValueError):
        reg.gauge("repro_m_total", "h")               # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("repro_m_total", "h", labels=("x",))  # label mismatch


def test_metric_name_validation():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("0bad", "h")
    with pytest.raises(ValueError):
        reg.counter("bad-name", "h")
    with pytest.raises(ValueError):
        reg.counter("ok_total", "h", labels=("bad-label",))


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("repro_d_total", "h")
    c.inc(5)
    h = reg.histogram("repro_d_ms", "h")
    h.observe(1.0)
    assert c.value == 0.0
    assert h.count == 0
    reg.enable(True)
    c.inc(5)
    assert c.value == 5.0


def test_default_registry_swap_restores():
    mine = MetricsRegistry()
    old = set_default_registry(mine)
    try:
        assert get_registry() is mine
    finally:
        set_default_registry(old)
    assert get_registry() is old


# --------------------------------------------------------------------------- #
# exposition formats
# --------------------------------------------------------------------------- #
def test_prometheus_text_line_by_line():
    reg = MetricsRegistry()
    c = reg.counter("repro_p_total", "requests", labels=("tenant",))
    c.labels(tenant="a b").inc(3)      # space → must be quoted+escaped
    c.labels(tenant='q"\\\n').inc()    # quote, backslash, newline
    g = reg.gauge("repro_p_gauge", "depth")
    g.set(2.5)
    h = reg.histogram("repro_p_ms", "latency")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)

    text = reg.prometheus()
    lines = text.splitlines()
    # every family emits HELP then TYPE
    assert "# HELP repro_p_total requests" in lines
    assert "# TYPE repro_p_total counter" in lines
    assert "# TYPE repro_p_gauge gauge" in lines
    assert "# TYPE repro_p_ms summary" in lines
    assert 'repro_p_total{tenant="a b"} 3' in lines
    # escaped label value round-trips the specials
    assert 'repro_p_total{tenant="q\\"\\\\\\n"} 1' in lines
    assert "repro_p_gauge 2.5" in lines
    assert 'repro_p_ms{quantile="0.5"} 2.5' in lines
    assert "repro_p_ms_sum 10" in lines
    assert "repro_p_ms_count 4" in lines
    # well-formedness: every sample line is name[{labels}] value
    sample_re = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? -?[0-9.einfa+-]+$')
    for ln in lines:
        if ln and not ln.startswith("#"):
            assert sample_re.match(ln), ln


def test_json_snapshot_round_trip():
    reg = MetricsRegistry()
    reg.counter("repro_j_total", "h", labels=("tenant",)) \
       .labels(tenant="x").inc(2)
    reg.histogram("repro_j_ms", "h").observe(4.0)
    snap = reg.snapshot()
    assert json.loads(json.dumps(snap)) == snap
    assert snap["repro_j_total"]["type"] == "counter"
    (sample,) = snap["repro_j_total"]["samples"]
    assert sample["labels"] == {"tenant": "x"}
    assert sample["value"] == 2
    (hs,) = snap["repro_j_ms"]["samples"]
    assert hs["count"] == 1 and hs["sum"] == 4.0
    # json_snapshot wraps it with optional extra stats
    full = json_snapshot(reg, extra=lambda: {"k": 1})
    assert full["stats"] == {"k": 1}
    assert full["metrics"].keys() == snap.keys()


# --------------------------------------------------------------------------- #
# thread-safety
# --------------------------------------------------------------------------- #
def test_thread_hammer_exact_totals_under_concurrent_scrapes():
    reg = MetricsRegistry()
    c = reg.counter("repro_h_total", "h", labels=("tenant",))
    h = reg.histogram("repro_h_ms", "h", labels=("tenant",))
    N_THREADS, N_OPS = 8, 500
    stop = threading.Event()
    scrapes = []

    def mutate(tid):
        child_c = c.labels(tenant=f"t{tid % 2}")
        child_h = h.labels(tenant=f"t{tid % 2}")
        for i in range(N_OPS):
            child_c.inc()
            child_h.observe(float(i))

    def scrape():
        while not stop.is_set():
            scrapes.append(reg.prometheus())
            reg.snapshot()

    scraper = threading.Thread(target=scrape)
    scraper.start()
    threads = [threading.Thread(target=mutate, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    scraper.join()

    total = sum(ch.value for ch in (c.labels(tenant="t0"),
                                    c.labels(tenant="t1")))
    assert total == N_THREADS * N_OPS            # no lost increments
    assert (h.labels(tenant="t0").count
            + h.labels(tenant="t1").count) == N_THREADS * N_OPS
    assert scrapes                               # scraper actually ran


# --------------------------------------------------------------------------- #
# tracing
# --------------------------------------------------------------------------- #
def test_trace_buffer_ring_bound_and_order():
    tb = TraceBuffer(cap=4)
    for i in range(10):
        tb.add(Span(rid=i, tenant="m", arrival_s=float(i)))
    assert len(tb) == 4
    assert tb.n_added == 10
    recent = tb.recent()
    assert [s["rid"] for s in recent] == [6, 7, 8, 9]   # oldest → newest
    assert [s["rid"] for s in tb.recent(2)] == [8, 9]
    parsed = json.loads(tb.to_json())
    assert parsed == recent
    tb.clear()
    assert len(tb) == 0
    with pytest.raises(ValueError):
        TraceBuffer(cap=0)


def test_span_to_dict_shape():
    s = Span(rid=3, tenant="m", arrival_s=1.0, batch_size=4, bucket=8,
             phases={"queue_ms": 1.0}, total_ms=2.5)
    d = s.to_dict()
    assert d["rid"] == 3 and d["bucket"] == 8 and d["ok"] is True
    assert "error" not in d                     # only present on failure
    assert json.loads(json.dumps(d)) == d
    assert set(PHASES) == set(TRACE_PHASES)


# --------------------------------------------------------------------------- #
# retrace detection
# --------------------------------------------------------------------------- #
def test_compile_watch_counts_growth_and_anomalies():
    class FakePred:
        def __init__(self):
            self.size = 0

        def trace_cache_size(self):
            return self.size

    p = FakePred()
    w = CompileWatch(p)
    assert w.observable
    assert w.poll() == (0, 0)
    p.size = 2                         # two traces before warmup
    assert w.poll() == (2, 0)
    assert w.compiles_total == 2 and w.anomalies_total == 0
    w.mark_warm()
    p.size = 3                         # post-warmup growth → anomaly
    assert w.poll() == (1, 1)
    assert w.anomalies_total == 1
    p.size = 0                         # deliberate cache reset
    assert w.poll() == (0, 0)
    p.size = 1                         # growth from the new baseline
    assert w.poll() == (1, 1)


def test_compile_watch_unobservable_predictor_is_noop():
    w = CompileWatch(object())
    assert not w.observable
    assert w.poll() == (0, 0)
    assert fn_cache_size(lambda x: x) is None


def test_real_predictor_trace_cache_observed():
    qf = _forest()
    pred = core.compile_forest(qf, engine="bitvector")
    w = CompileWatch(pred)
    assert w.observable
    pred.predict(np.zeros((4, qf.n_features_in)))
    compiles, anomalies = w.poll()
    assert compiles >= 1 and anomalies == 0
    w.mark_warm()
    # a brand-new shape after mark_warm is an anomaly
    pred.predict(np.zeros((32, qf.n_features_in)))
    compiles, anomalies = w.poll()
    assert compiles >= 1 and anomalies == compiles


def test_cascade_trace_cache_size_sums_stages():
    from repro.cascade import CascadeSpec, MarginGate
    qf = _forest(trees=8)
    spec = CascadeSpec(stages=(4, 8), policy=MarginGate(0.5))
    casc = core.compile_forest(qf, engine="bitvector", cascade=spec)
    before = casc.trace_cache_size()
    assert before is not None
    casc.predict(np.zeros((8, qf.n_features_in)))
    assert casc.trace_cache_size() > before

    fspec = CascadeSpec(stages=(4, 8), policy=MarginGate(0.5), fused=True)
    fused = core.compile_forest(qf, engine="bitvector", cascade=fspec)
    fused.predict(np.zeros((8, qf.n_features_in)))
    grown = fused.trace_cache_size()
    assert grown is not None and grown >= 1
    fused.set_policy(MarginGate(0.25))     # drops the fused jit cache
    w = CompileWatch(fused)
    assert w.poll() == (0, 0)              # shrink re-baselines, no count


# --------------------------------------------------------------------------- #
# structured logging
# --------------------------------------------------------------------------- #
def test_logger_line_format_and_quoting():
    buf = io.StringIO()
    lg = StructLogger("testcomp", stream=buf)
    lg.error("an_event", n=3, ms=1.23456789, msg="a b", eq="k=v")
    line = buf.getvalue().strip()
    parts = line.split(" ", 3)
    assert parts[1] == "ERROR"
    assert parts[2] == "testcomp"
    assert "an_event" in parts[3]
    assert "n=3" in line
    assert "ms=1.23457" in line            # floats at %.6g
    assert "msg='a b'" in line             # spaces quoted
    assert "eq='k=v'" in line              # '=' quoted


def test_logger_quiet_under_pytest_and_forced_level():
    # running under pytest: effective level is warning → info suppressed
    assert effective_level() == "warning"
    buf = io.StringIO()
    lg = StructLogger("t", stream=buf)
    lg.info("hidden")
    assert buf.getvalue() == ""
    lg.warning("shown")
    assert "shown" in buf.getvalue()
    set_level("debug")
    try:
        lg.debug("now_visible")
        assert "now_visible" in buf.getvalue()
    finally:
        set_level(None)
    with pytest.raises(ValueError):
        set_level("loud")


def test_logger_env_level(monkeypatch):
    monkeypatch.setenv("REPRO_LOG_LEVEL", "error")
    buf = io.StringIO()
    lg = StructLogger("t", stream=buf)
    lg.warning("hidden")
    assert buf.getvalue() == ""
    lg.error("shown")
    assert "shown" in buf.getvalue()


# --------------------------------------------------------------------------- #
# HTTP exposition
# --------------------------------------------------------------------------- #
def _get(url, timeout=10):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.status, resp.read().decode()


def test_metrics_server_endpoints():
    reg = MetricsRegistry()
    reg.counter("repro_e_total", "h").inc(4)
    tb = TraceBuffer(cap=8)
    tb.add(Span(rid=1, tenant="m", arrival_s=0.0))
    with MetricsServer(reg, traces=tb,
                       extra=lambda: {"up": True}) as srv:
        status, text = _get(srv.url + "/metrics")
        assert status == 200
        assert "repro_e_total 4" in text
        status, body = _get(srv.url + "/metrics.json")
        snap = json.loads(body)
        assert snap["metrics"]["repro_e_total"]["samples"][0]["value"] == 4
        assert snap["stats"] == {"up": True}
        _, body = _get(srv.url + "/traces?n=5")
        assert [s["rid"] for s in json.loads(body)] == [1]
        status, body = _get(srv.url + "/healthz")
        assert (status, body) == (200, "ok")
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/nope")
        assert ei.value.code == 404
    # idempotent close
    srv.close()


# --------------------------------------------------------------------------- #
# serving integration
# --------------------------------------------------------------------------- #
def test_serving_metrics_catalog_materialized():
    reg = MetricsRegistry()
    sm = ServingMetrics(reg)
    assert set(reg.names()) == set(METRIC_CATALOG)
    text = reg.prometheus()
    for name in METRIC_CATALOG:        # full catalog before any traffic
        assert f"# TYPE {name} " in text


def test_runtime_spans_stats_and_metrics_manual_clock():
    qf = _forest(seed=1)
    pred = core.compile_forest(qf, engine="bitvector")
    reg = MetricsRegistry()
    rt = ServingRuntime(obs=reg)
    rt.add_model("m", pred, max_batch=8, max_wait_ms=1.0)
    rt.warmup()
    X = np.random.default_rng(0).normal(size=(6, qf.n_features_in))
    reqs = [rt.submit("m", X[i], arrival_s=0.001 * i) for i in range(6)]
    rt.flush(now_s=1.0)

    # spans attached, phases complete, batch padded to the pow2 bucket
    for r in reqs:
        assert r.span is not None
        assert r.span.batch_size == 6 and r.span.bucket == 8
        assert set(r.span.phases) == set(PHASES)
        assert r.span.total_ms == pytest.approx(r.latency_ms)
    assert rt.obs.traces.n_added == 6

    # metrics: exact counts on the isolated registry
    snap = reg.snapshot()

    def value(name):
        return snap[name]["samples"][0]["value"]

    assert value("repro_requests_total") == 6
    assert value("repro_batches_total") == 1
    assert snap["repro_latency_ms"]["samples"][0]["count"] == 6
    qsamples = {tuple(sorted(s["labels"].items())): s
                for s in snap["repro_phase_ms"]["samples"]}
    assert qsamples[(("phase", "queue_ms"), ("tenant", "m"))]["count"] == 6
    assert qsamples[(("phase", "compute_ms"), ("tenant", "m"))]["count"] == 1

    # stats(): summary + queue depth + retrace watch state
    st = rt.stats("m")
    assert st["queue_depth"] == 0
    assert st["retrace_anomalies"] == 0
    assert st["compile_events"] == 0           # warmed: no live compile
    assert st["trace_cache_observable"]
    rt.close()


def test_runtime_retrace_anomaly_surfaces():
    qf = _forest(seed=2)
    pred = core.compile_forest(qf, engine="bitvector")
    reg = MetricsRegistry()
    rt = ServingRuntime(obs=reg)
    # hard_max_batch is 4 → warmup ladder stops at 4; a direct predict
    # on a bigger, never-warmed shape then leaks a post-warmup trace
    rt.add_model("m", pred, max_batch=4, max_wait_ms=1.0)
    rt.warmup()
    pred.predict(np.zeros((64, qf.n_features_in)))   # the leak
    X = np.zeros((2, qf.n_features_in))
    rt.submit("m", X[0], arrival_s=0.0)
    rt.flush(now_s=1.0)                # poll happens on the next batch
    st = rt.stats("m")
    assert st["retrace_anomalies"] >= 1
    assert st["compile_events"] >= 1
    (sample,) = reg.snapshot()["repro_retrace_anomalies_total"]["samples"]
    assert sample["value"] >= 1
    rt.close()


def test_runtime_controller_decisions_exported():
    from repro.inference import SLOConfig
    qf = _forest(seed=3)
    pred = core.compile_forest(qf, engine="bitvector")
    reg = MetricsRegistry()
    rt = ServingRuntime(obs=reg)
    rt.add_model("m", pred, max_batch=8, max_wait_ms=4.0,
                 slo=SLOConfig(target_p99_ms=1e9, window=4,
                               max_batch=8, max_wait_ms=4.0))
    rt.warmup()
    X = np.zeros((8, qf.n_features_in))
    for i in range(8):
        rt.submit("m", X[i], arrival_s=0.0)
    rt.flush(now_s=1.0)                # 8 observations → 2 windows
    st = rt.stats("m")
    assert st["controller"]["n_decisions"] == 2
    assert st["controller"]["actions"]["grow"] == 2   # huge target
    assert len(st["decisions"]) == 2
    assert st["decisions"][-1] == st["controller"]["last_decision"]
    snap = reg.snapshot()
    (d,) = snap["repro_controller_decisions_total"]["samples"]
    assert d["labels"] == {"tenant": "m", "action": "grow"}
    assert d["value"] == 2
    gauges = {s["labels"]["tenant"]: s["value"]
              for s in snap["repro_effective_max_batch"]["samples"]}
    assert gauges["m"] == st["effective_max_batch"]
    rt.close()


def test_runtime_error_path_counts_and_spans():
    class Boom:
        def predict(self, X):
            raise RuntimeError("boom")

        def host_forest(self):
            return None

    reg = MetricsRegistry()
    rt = ServingRuntime(obs=reg)
    rt.add_model("m", Boom(), max_batch=4, max_wait_ms=1.0)
    r = rt.submit("m", np.zeros(3), arrival_s=0.0)
    rt.flush(now_s=1.0)
    with pytest.raises(RuntimeError):
        r.wait(timeout=5)
    assert r.span is not None and r.span.ok is False
    assert "boom" in r.span.error
    snap = reg.snapshot()
    assert snap["repro_request_errors_total"]["samples"][0]["value"] == 1
    assert snap["repro_requests_total"]["samples"][0]["value"] == 1
    rt.close()


def test_threaded_runtime_with_concurrent_scrape():
    qf = _forest(seed=4)
    pred = core.compile_forest(qf, engine="bitvector")
    reg = MetricsRegistry()
    rt = ServingRuntime(obs=reg)
    rt.add_model("m", pred, max_batch=16, max_wait_ms=0.5)
    rt.warmup()
    X = np.random.default_rng(1).normal(size=(32, qf.n_features_in))
    n_req = 200
    with rt:
        url = rt.serve_metrics().url
        stop = threading.Event()
        errors = []

        def scraper():
            while not stop.is_set():
                try:
                    _get(url + "/metrics")
                    _get(url + "/metrics.json")
                except Exception as e:          # noqa: BLE001
                    errors.append(e)

        th = threading.Thread(target=scraper)
        th.start()
        reqs = [rt.submit("m", X[i % len(X)]) for i in range(n_req)]
        for r in reqs:
            r.wait(timeout=120)
        stop.set()
        th.join()
        status, text = _get(url + "/metrics")
    assert not errors
    assert status == 200
    c = reg.get("repro_requests_total").labels(tenant="m")
    assert c.value == n_req
    assert rt.stats("m")["retrace_anomalies"] == 0
    # endpoint stopped by close()
    with pytest.raises(Exception):
        _get(url + "/healthz", timeout=2)


def test_runtime_obs_disabled_has_no_instrumentation():
    qf = _forest(seed=5)
    pred = core.compile_forest(qf, engine="bitvector")
    rt = ServingRuntime(obs=False)
    rt.add_model("m", pred, max_batch=4, max_wait_ms=1.0)
    rt.warmup()
    r = rt.submit("m", np.zeros(qf.n_features_in), arrival_s=0.0)
    rt.flush(now_s=1.0)
    assert rt.obs is None
    assert r.span is None
    assert rt.tenant("m").watch is None
    with pytest.raises(RuntimeError):
        rt.serve_metrics()
    st = rt.stats("m")                 # stats() still works without obs
    assert st["queue_depth"] == 0 and "retrace_anomalies" not in st
    rt.close()


def test_forest_server_phase_stats_and_obs():
    qf = _forest(seed=6)
    pred = core.compile_forest(qf, engine="bitvector")
    reg = MetricsRegistry()
    srv = ForestServer(pred, max_batch=4, max_wait_ms=1.0, obs=reg,
                       obs_label="sync")
    X = np.random.default_rng(2).normal(size=(4, qf.n_features_in))
    for i in range(4):
        srv.submit(X[i], arrival_s=0.0)
    srv.flush(now_s=1.0)
    s = srv.stats.summary()
    assert s["compute_p50_ms"] >= 0.0
    assert s["sync_p50_ms"] >= 0.0
    snap = reg.snapshot()
    (c,) = snap["repro_requests_total"]["samples"]
    assert c["labels"] == {"tenant": "sync"} and c["value"] == 4


def test_autotune_metrics_hit_miss_and_sweep():
    from repro.core import engine_select
    qf = _forest(seed=7)
    mine = MetricsRegistry()
    old = set_default_registry(mine)
    try:
        engine_select.clear_cache()
        engines = ("qs", "native")
        engine_select.choose(qf, 8, engines=engines, cache_path=None,
                             repeats=1)
        snap = mine.snapshot()
        assert snap["repro_autotune_sweeps_total"]["samples"][0]["value"] \
            == 1
        (m,) = snap["repro_autotune_cache_misses_total"]["samples"]
        assert m["labels"] == {"reason": "cold"}
        assert snap["repro_autotune_candidates_benched_total"][
            "samples"][0]["value"] == len(engines)
        assert snap["repro_autotune_sweep_seconds"]["samples"][0][
            "count"] == 1
        # second call: memory-layer hit, no new sweep
        engine_select.choose(qf, 8, engines=engines, cache_path=None,
                             repeats=1)
        snap = mine.snapshot()
        (hit,) = snap["repro_autotune_cache_hits_total"]["samples"]
        assert hit["labels"] == {"layer": "memory"} and hit["value"] == 1
        assert snap["repro_autotune_sweeps_total"]["samples"][0][
            "value"] == 1
        # winner info gauge carries the decision in its labels
        (w,) = snap["repro_autotune_winner_info"]["samples"]
        assert w["value"] == 1.0 and w["labels"]["engine"] in engines
        # widening the candidate set forces a partial-coverage miss
        engine_select.choose(qf, 8, engines=("qs", "native", "qs-bitmm"),
                             cache_path=None, repeats=1)
        snap = mine.snapshot()
        reasons = {s["labels"]["reason"]: s["value"]
                   for s in snap["repro_autotune_cache_misses_total"][
                       "samples"]}
        assert reasons.get("partial") == 1
    finally:
        set_default_registry(old)
        engine_select.clear_cache()
