"""Bit-matmul engine validation: XLA path and Pallas kernel vs the
faithful scalar QuickScorer (Algorithm 1) and the traversal oracle, on
float32 and quantized (int16/int8) forests including the edge shapes the
packing has to survive: deep unbalanced trees (wide count fields), stumps,
multiclass, single-leaf trees, and multi-word leaf counts."""
import numpy as np
import pytest

from repro import core
from repro.core.quickscorer import (compile_qs, compile_qs_bitmm,
                                    eval_batch, eval_batch_bitmm,
                                    eval_scalar_numpy)
from repro.core.quantize import QuantSpec, quantize_forest, quantize_inputs
from repro.kernels.ops import pallas_bitmm_predictor

import jax.numpy as jnp

from conftest import rand_X

FOREST_SWEEP = [
    # (n_trees, n_leaves, n_features, n_classes, full, seed)
    (8, 16, 6, 1, True, 0),        # balanced
    (6, 64, 8, 1, False, 1),       # deep/unbalanced, multi-word counts
    (12, 32, 10, 3, False, 2),     # multiclass
    (10, 2, 4, 1, True, 3),        # stumps (single split per tree)
    (4, 128, 5, 2, False, 4),      # very deep, wide leaf axis
]


def _forest(T, L, d, C, full, seed):
    return core.random_forest_ir(T, L, d, n_classes=C, seed=seed, full=full)


@pytest.mark.parametrize("T,L,d,C,full,seed", FOREST_SWEEP)
def test_bitmm_matches_scalar_qs(T, L, d, C, full, seed):
    """eval_batch_bitmm ≡ Algorithm 1 (sorted features, early break)."""
    forest = _forest(T, L, d, C, full, seed)
    X = rand_X(forest, B=8, seed=seed + 100)
    scalar = eval_scalar_numpy(forest, X)
    got = np.asarray(eval_batch_bitmm(compile_qs_bitmm(forest),
                                      jnp.asarray(X)))
    np.testing.assert_allclose(got, scalar, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("T,L,d,C,full,seed", FOREST_SWEEP)
def test_pallas_bitmm_matches_scalar_qs(T, L, d, C, full, seed):
    forest = _forest(T, L, d, C, full, seed)
    X = rand_X(forest, B=8, seed=seed + 200)
    scalar = eval_scalar_numpy(forest, X)
    pred = pallas_bitmm_predictor(forest, block_b=8, block_t=4, block_n=16)
    np.testing.assert_allclose(pred.predict(X), scalar, rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("T,L,d,C,full,seed", FOREST_SWEEP)
def test_bitmm_matches_eval_batch_larger_batch(T, L, d, C, full, seed):
    """Against the seed XLA engine on a bigger batch (cheap oracle)."""
    forest = _forest(T, L, d, C, full, seed)
    X = jnp.asarray(rand_X(forest, B=96, seed=seed + 300))
    ref = np.asarray(eval_batch(compile_qs(forest), X))
    got = np.asarray(eval_batch_bitmm(compile_qs_bitmm(forest), X))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bits", [16, 8])
def test_bitmm_quantized_exact(bits, trained_rf, magic_ds):
    """Quantized forests: integer thresholds and leaves → bit-exact
    agreement with the scalar oracle (all arithmetic stays below 2^24)."""
    forest = core.from_random_forest(trained_rf)
    qf = quantize_forest(forest, magic_ds.X_train, spec=QuantSpec(bits=bits))
    X = magic_ds.X_test[:48]
    Xq = quantize_inputs(qf, X)
    scalar = eval_scalar_numpy(qf, Xq)
    got = core.compile_forest(qf, engine="bitmm").predict(X)
    np.testing.assert_array_equal(got, scalar)
    pal = pallas_bitmm_predictor(qf, block_b=16, block_t=8).predict(X)
    np.testing.assert_array_equal(pal, scalar)


def test_bitmm_single_leaf_tree():
    """Degenerate no-split trees must contribute their constant."""
    from repro.trees.cart import Tree, TreeNode
    stump = Tree(TreeNode(value=np.array([7.0])), 1, 0)
    l0, l1 = TreeNode(value=np.array([1.0])), TreeNode(value=np.array([2.0]))
    real = Tree(TreeNode(feature=0, threshold=0.0, left=l0, right=l1), 2, 1)
    f = core.from_trees([stump, real], n_features=1, n_classes=1)
    X = np.array([[-1.0], [1.0]])
    expect = np.array([[8.0], [9.0]])
    got = core.compile_forest(f, engine="bitmm").predict(X)
    np.testing.assert_allclose(got, expect, rtol=1e-6)
    pal = pallas_bitmm_predictor(f, block_b=8, block_t=2).predict(X)
    np.testing.assert_allclose(pal, expect, rtol=1e-6)


def test_bitmm_threshold_boundary_exact():
    """x == t must go LEFT (predicate is x > t for the clear matmul)."""
    from repro.trees.cart import Tree, TreeNode
    l0, l1 = TreeNode(value=np.array([1.0])), TreeNode(value=np.array([2.0]))
    root = TreeNode(feature=0, threshold=0.5, left=l0, right=l1)
    f = core.from_trees([Tree(root, 2, 1)], n_features=1, n_classes=1)
    X = np.array([[0.5], [0.5 + 1e-6]])
    got = core.compile_forest(f, engine="bitmm").predict(X)
    np.testing.assert_allclose(got[:, 0], [1.0, 2.0], rtol=1e-6)


def test_bitmm_tree_chunking_invariant(big_leaf_forest):
    """Scanned tree tiles must not change the result (and padded dummy
    trees must contribute exactly nothing)."""
    X = rand_X(big_leaf_forest, B=40, seed=9)
    ref = np.asarray(eval_batch(compile_qs(big_leaf_forest),
                                jnp.asarray(X)))
    for chunk in (1, 2, 4, big_leaf_forest.n_trees):
        bm = compile_qs_bitmm(big_leaf_forest, tree_chunk=chunk)
        got = np.asarray(eval_batch_bitmm(bm, jnp.asarray(X)))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"tree_chunk={chunk}")


def test_bitmm_field_width_adapts_to_depth():
    """Deep chains need wide count fields; balanced trees pack 8/word."""
    balanced = core.random_forest_ir(4, 64, 6, seed=0, full=True)
    deep = core.random_forest_ir(4, 64, 6, seed=1, full=False)
    bmb = compile_qs_bitmm(balanced)
    bmd = compile_qs_bitmm(deep)
    assert bmb.bits * bmb.npack <= 24 and bmd.bits * bmd.npack <= 24
    assert bmd.bits >= bmb.bits        # deeper → larger max clear count
