"""Pallas kernel validation (interpret mode): shape/dtype sweep, each cell
asserted allclose against the pure-jnp ref.py oracle AND the numpy
traversal oracle."""
import numpy as np
import pytest

from repro import core
from repro.core.quantize import QuantSpec, quantize_forest
from repro.kernels.ops import pallas_gemm_predictor, pallas_qs_predictor
from repro.kernels.ref import ref_gemm, ref_oracle, ref_qs

SHAPE_SWEEP = [
    # (n_trees, n_leaves, n_features, n_classes, batch)
    (4, 8, 4, 1, 16),
    (8, 16, 6, 1, 64),
    (12, 32, 10, 3, 96),
    (6, 64, 8, 2, 33),          # multi-word leafidx + ragged batch
    (16, 32, 784, 10, 40),      # wide features (mnist-like)
    (3, 16, 5, 1, 1),           # single instance
]


def _forest(T, L, d, C, seed=0):
    return core.random_forest_ir(T, L, d, n_classes=C, seed=seed,
                                 full=(seed % 2 == 0))


@pytest.mark.parametrize("T,L,d,C,B", SHAPE_SWEEP)
def test_pallas_qs_matches_ref(T, L, d, C, B):
    forest = _forest(T, L, d, C, seed=T)
    X = np.random.default_rng(B).normal(0, 1.3, size=(B, d))
    pred = pallas_qs_predictor(forest, block_b=32, block_t=4)
    got = pred.predict(X)
    np.testing.assert_allclose(got, ref_qs(forest, X), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got, ref_oracle(forest, X), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("T,L,d,C,B", SHAPE_SWEEP[:4])
def test_pallas_gemm_matches_ref(T, L, d, C, B):
    forest = _forest(T, L, d, C, seed=T + 1)
    X = np.random.default_rng(B + 1).normal(0, 1.3, size=(B, d))
    pred = pallas_gemm_predictor(forest, block_b=32, block_t=4)
    got = pred.predict(X)
    np.testing.assert_allclose(got, ref_gemm(forest, X), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(got, ref_oracle(forest, X), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("bits", [16, 8])
def test_pallas_qs_quantized(bits, trained_rf, magic_ds):
    forest = core.from_random_forest(trained_rf)
    qf = quantize_forest(forest, magic_ds.X_train, spec=QuantSpec(bits=bits))
    X = magic_ds.X_test[:64]
    pred = pallas_qs_predictor(qf, block_b=32, block_t=8)
    got = pred.predict(X)
    np.testing.assert_allclose(got, ref_oracle(qf, X), rtol=1e-5, atol=1e-6)


def test_pallas_block_shape_independence(small_forest):
    """Result must not depend on the BlockSpec tiling."""
    X = np.random.default_rng(7).normal(size=(70, small_forest.n_features))
    ref = ref_qs(small_forest, X)
    for bb, bt in [(8, 2), (32, 4), (128, 8)]:
        got = pallas_qs_predictor(small_forest, block_b=bb,
                                  block_t=bt).predict(X)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"block_b={bb} block_t={bt}")


def test_pallas_padding_batch_edge(small_forest):
    """Batch not a multiple of block_b: padded rows must not leak."""
    rng = np.random.default_rng(8)
    X = rng.normal(size=(5, small_forest.n_features))
    got = pallas_qs_predictor(small_forest, block_b=64).predict(X)
    assert got.shape == (5, 1)
    np.testing.assert_allclose(got, ref_qs(small_forest, X), rtol=1e-5,
                               atol=1e-6)


def test_pallas_tree_padding(class_forest):
    """Tree count not a multiple of block_t: zero-leaf padding trees must
    contribute exactly nothing."""
    X = np.random.default_rng(9).normal(size=(16, class_forest.n_features))
    got = pallas_qs_predictor(class_forest, block_b=16,
                              block_t=8).predict(X)   # 12 trees → pad to 16
    np.testing.assert_allclose(got, ref_qs(class_forest, X), rtol=1e-5,
                               atol=1e-6)
