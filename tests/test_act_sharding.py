"""Activation-sharding policy unit tests (single-device: constraints must
be transparent no-ops for numerics, and divisibility rules must hold)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import act_sharding as acts


@pytest.fixture(autouse=True)
def _clean_policy():
    acts.clear_policy()
    yield
    acts.clear_policy()


def test_noop_without_policy():
    x = jnp.ones((4, 8))
    y = acts.constrain_batch(x)
    assert y is x                       # literally untouched


def test_divisibility_skip():
    acts.set_policy(("data",), {"data": 16, "model": 16})
    x = jnp.ones((5, 8))                # 5 % 16 != 0
    assert acts.constrain_batch(x) is x


def test_fallback_to_inner_axis():
    acts.set_policy(("pod", "data"), {"pod": 2, "data": 16, "model": 16})
    assert acts._batch_axes_for(32) == ("pod", "data")
    assert acts._batch_axes_for(16) == ("data",)
    assert acts._batch_axes_for(7) is None


def test_model_axis_size():
    assert acts.model_axis_size() == 1
    acts.set_policy(("data",), {"data": 16, "model": 8})
    assert acts.model_axis_size() == 8


def test_constrain_spec_map_skips_indivisible():
    acts.set_policy(("data",), {"data": 4, "model": 4})
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        # under a real (1,1) mesh the constraint applies but sizes are 1;
        # here we only check the no-crash path + value preservation
        x = jnp.arange(32.0).reshape(4, 8)
        y = jax.jit(lambda a: acts.constrain(a, {0: "batch", 1: "model"}))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_policy_context_manager():
    with acts.policy(("data",), {"data": 2}):
        assert acts._batch_axes_for(4) == ("data",)
    assert acts._batch_axes_for(4) is None


def test_attn_shard_mode():
    from repro.models.attention import _attn_shard_mode
    acts.set_policy(("data",), {"data": 16, "model": 16})
    assert _attn_shard_mode(96) == "heads"      # command-r
    assert _attn_shard_mode(15) == "seq"        # smollm
    assert _attn_shard_mode(24) == "seq"        # starcoder2
    acts.clear_policy()
    assert _attn_shard_mode(15) == "none"


def test_model_numerics_invariant_under_policy():
    """Constraints must not change forward values (1-device mesh)."""
    from repro.configs import get_config
    from repro.models.model import Model
    cfg = get_config("smollm_360m").reduced()
    model = Model(cfg, compute_dtype=jnp.float32, q_chunk=16, ssd_chunk=8,
                  loss_chunk=16, remat=False)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, size=(2, 32)), jnp.int32)
    base = np.asarray(model.forward(params, toks))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    acts.policy_from_mesh(mesh)
    with mesh:
        got = np.asarray(jax.jit(model.forward)(params, toks))
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)
