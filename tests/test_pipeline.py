"""Pass pipeline (core/pipeline.py), engine registry (core/registry.py),
and the shared predictor surface (predict_proba)."""
import numpy as np
import pytest

from repro import core
from repro.core import engine_select, registry
from repro.core.pipeline import CompilePlan, PASSES, PIPELINE, compile_plan
from repro.core.registry import normalize_scores

from conftest import rand_X


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #
def test_registry_covers_all_engine_backends():
    assert set(registry.engines("jax")) == {"bitvector", "bitmm",
                                            "rapidscorer", "native",
                                            "unrolled", "gemm"}
    assert set(registry.engines("pallas")) == {"bitvector", "bitmm", "gemm"}
    assert registry.backends("bitvector") == ("jax", "pallas")


def test_registry_unknown_engine_lists_choices():
    with pytest.raises(ValueError, match="bitvector"):
        registry.get("nonesuch")
    with pytest.raises(ValueError, match="unknown engine"):
        core.compile_forest(core.random_forest_ir(2, 4, 3), engine="nope")


def test_tune_table_is_bijective_and_matches_engine_select():
    table = registry.tune_table()
    assert len(table) == len(registry.specs())        # no tune-name clash
    assert dict(engine_select.ENGINE_SPECS.items()) == table
    assert set(engine_select.default_engines(include_pallas=True)) \
        == set(table)


def test_engine_tables_support_mapping_idioms():
    assert engine_select.ENGINE_SPECS.get("qs") == ("bitvector", "jax")
    assert engine_select.ENGINE_SPECS.get("nope") is None
    assert engine_select.ENGINE_FACTORIES.get("nope") is None
    assert set(dict(engine_select.ENGINE_SPECS)) \
        == set(engine_select.ENGINE_FACTORIES.keys())


def test_register_engine_decorator_and_live_tables(small_forest):
    @registry.register_engine("_toy", tune_name="_toy")
    def build_toy(forest, **kw):
        return core.compile_forest(forest, engine="native")

    try:
        assert "_toy" in registry.engines("jax")
        # autotuner tables AND core.ENGINES are live registry views
        assert "_toy" in engine_select.ENGINE_SPECS
        assert "_toy" in core.ENGINES
        pred = engine_select.ENGINE_FACTORIES["_toy"](small_forest)
        X = rand_X(small_forest, B=8)
        np.testing.assert_allclose(pred.predict(X),
                                   small_forest.predict_oracle(X),
                                   rtol=1e-4, atol=1e-5)
    finally:
        del registry._REGISTRY[("_toy", "jax")]


# --------------------------------------------------------------------------- #
# pipeline passes
# --------------------------------------------------------------------------- #
def test_pipeline_declares_all_passes():
    assert PIPELINE == ("deserialize", "canonicalize", "quantize",
                        "optimize", "flint", "layout", "lower")
    assert all(name in PASSES for name in PIPELINE)


def test_compile_forest_records_plan(small_forest):
    pred = core.compile_forest(small_forest, engine="bitvector")
    assert [r.name for r in pred.plan.records] == list(PIPELINE)
    assert "qs" in pred.plan.describe()


def test_quantize_pass(small_forest):
    X = rand_X(small_forest, B=128)
    pred = compile_plan(small_forest, engine="bitvector",
                        quant=core.QuantSpec(bits=16), X_calib=X)
    qs = pred.compiled
    assert qs.thr.dtype == np.int16
    qrec = [r for r in pred.plan.records if r.name == "quantize"][0]
    assert "16b" in qrec.detail and "calib=data" in qrec.detail
    # ≡ the manual quantize-then-compile path
    manual = core.compile_forest(
        core.quantize_forest(small_forest, X), engine="bitvector")
    np.testing.assert_array_equal(pred.predict(X[:16]),
                                  manual.predict(X[:16]))


def test_quantize_pass_skips_already_quantized(small_forest):
    qf = core.quantize_forest(small_forest, rand_X(small_forest, B=64))
    pred = compile_plan(qf, engine="native", quant=core.QuantSpec(bits=16))
    qrec = [r for r in pred.plan.records if r.name == "quantize"][0]
    assert "already quantized" in qrec.detail


def test_layout_pass_sets_bitmm_tile_but_never_overrides(small_forest):
    auto = core.compile_forest(small_forest, engine="bitmm")
    assert auto.plan.engine_kw["tree_chunk"] == auto.compiled.tree_chunk
    forced = core.compile_forest(small_forest, engine="bitmm", tree_chunk=2)
    assert forced.compiled.tree_chunk == 2
    assert forced.plan.engine_kw["tree_chunk"] == 2


def test_bitmm_layout_defers_tiling_to_shard_wrapper(small_forest):
    """With n_devices>1 the layout pass must NOT pre-pick a global
    tree_chunk: the tile size has to divide the per-shard tree count,
    which only the shard wrapper (after device padding) can know."""
    from repro.core import pipeline
    plan = pipeline.CompilePlan(engine="bitmm", n_devices=2)
    pipeline.PASSES["layout"](small_forest, plan, {})
    assert "tree_chunk" not in plan.engine_kw
    assert "per-shard" in plan.records[-1].detail


def test_canonicalize_from_trainer(trained_rf, magic_ds):
    pred = compile_plan(trained_rf, engine="bitvector")
    crec = [r for r in pred.plan.records if r.name == "canonicalize"][0]
    assert "RandomForest" in crec.detail
    forest = core.from_random_forest(trained_rf)
    X = magic_ds.X_test[:32]
    np.testing.assert_allclose(pred.predict(X),
                               forest.predict_oracle(X),
                               rtol=1e-4, atol=1e-5)


def test_canonicalize_from_tree_list(small_forest):
    from repro.trees.cart import Tree, TreeNode
    l0, l1 = TreeNode(value=np.array([1.0])), TreeNode(value=np.array([2.0]))
    tree = Tree(TreeNode(feature=0, threshold=0.0, left=l0, right=l1), 2, 1)
    pred = compile_plan([tree], engine="gemm", n_features=1)
    np.testing.assert_allclose(pred.predict(np.array([[-1.0], [1.0]])),
                               [[1.0], [2.0]], rtol=1e-6)


def test_canonicalize_rejects_garbage():
    with pytest.raises(TypeError, match="canonicalize"):
        compile_plan(object(), engine="native")


def test_plan_kwargs_conflict_raises(small_forest):
    with pytest.raises(TypeError, match="not both"):
        compile_plan(small_forest, CompilePlan(), engine="gemm")


# --------------------------------------------------------------------------- #
# autotuner sweeps beyond the engine axis
# --------------------------------------------------------------------------- #
@pytest.fixture(autouse=True)
def _fresh_cache():
    engine_select.clear_cache()
    yield
    engine_select.clear_cache()


def test_autotuner_quantization_sweep(small_forest):
    c = engine_select.choose(small_forest, 32, engines=("qs", "native"),
                             quant_specs=(core.QuantSpec(bits=16),),
                             cache_path=None, repeats=1)
    assert set(c.timings) == {"qs", "native", "qs@q16", "native@q16"}
    assert c.engine == min(c.timings, key=c.timings.get)
    # the served predictor matches the variant named by the winner
    expect_int = c.engine.endswith("@q16")
    thr = c.predictor.compiled.forest.threshold
    assert np.issubdtype(thr.dtype, np.integer) == expect_int
    # second call is a pure cache hit over the same candidate set
    c2 = engine_select.choose(small_forest, 32, engines=("qs", "native"),
                              quant_specs=(core.QuantSpec(bits=16),),
                              cache_path=None, repeats=1)
    assert c2.from_cache and c2.engine == c.engine


def test_autotuner_layout_sweep(small_forest):
    c = engine_select.choose(
        small_forest, 32, engines=("qs-bitmm",),
        layout_specs={"qs-bitmm": ({"tree_chunk": 2}, {"tree_chunk": 4})},
        cache_path=None, repeats=1)
    assert set(c.timings) == {"qs-bitmm", "qs-bitmm@tree_chunk=2",
                              "qs-bitmm@tree_chunk=4"}
    assert c.engine == min(c.timings, key=c.timings.get)
    if "@tree_chunk=" in c.engine:
        chunk = int(c.engine.split("=")[-1])
        assert c.predictor.compiled.tree_chunk == chunk


def test_quant_variants_never_alias_in_cache(small_forest):
    """Distinct QuantSpecs must produce distinct candidate names: a
    leaves-only 16-bit sweep cannot be answered by the default-16-bit
    entry already in the cache."""
    c1 = engine_select.choose(small_forest, 32, engines=("native",),
                              quant_specs=(core.QuantSpec(bits=16),),
                              cache_path=None, repeats=1)
    c2 = engine_select.choose(
        small_forest, 32, engines=("native",),
        quant_specs=(core.QuantSpec(bits=16, quantize_splits=False),),
        cache_path=None, repeats=1)
    assert "native@q16" in c1.timings
    assert "native@q16-nosplits" in c2.timings
    assert not c2.from_cache          # different variant → no aliased hit


def test_default_engines_with_devices_drop_nonshardable(small_forest):
    """n_devices>1 with the *default* candidate set must silently drop
    non-shardable (pallas) engines instead of raising — this is the
    documented TPU serving path (a default sweep on >1 device can't run
    in-process on one CPU device, so the filter is asserted directly)."""
    from repro.core.engine_select import default_engines
    full = default_engines(include_pallas=True)
    shardable = tuple(e for e in full
                      if registry.by_tune_name(e).shardable)
    assert set(full) - set(shardable) == {"pallas-qs", "pallas-bitmm",
                                          "pallas-gemm"}
    # an explicit pallas request still errors loudly
    with pytest.raises(ValueError, match="cannot run tree-sharded"):
        engine_select.choose(small_forest, 16, engines=("pallas-qs",),
                             n_devices=2, cache_path=None, repeats=1)


def test_pipeline_rejects_pallas_sharding(small_forest):
    with pytest.raises(ValueError, match="jax backend only"):
        compile_plan(small_forest, engine="gemm", backend="pallas",
                     n_devices=2)


def test_quant_sweep_rejects_prequantized(small_forest):
    qf = core.quantize_forest(small_forest, rand_X(small_forest, B=64))
    with pytest.raises(ValueError, match="already quantized"):
        engine_select.choose(qf, 32, engines=("qs",),
                             quant_specs=(core.QuantSpec(bits=8),),
                             cache_path=None, repeats=1)


def test_layout_specs_unknown_key_raises(small_forest):
    with pytest.raises(ValueError, match="layout_specs keys"):
        engine_select.choose(
            small_forest, 32, engines=("qs-bitmm",),
            layout_specs={"bitmm": ({"tree_chunk": 2},)},   # canonical name
            cache_path=None, repeats=1)


# --------------------------------------------------------------------------- #
# predict_proba (shared predictor base, paper §4)
# --------------------------------------------------------------------------- #
def test_predict_proba_rows_normalized(class_forest):
    X = rand_X(class_forest, B=32)
    for engine in ("bitvector", "gemm"):
        proba = core.compile_forest(class_forest,
                                    engine=engine).predict_proba(X)
        assert proba.shape == (32, class_forest.n_classes)
        assert (proba >= 0).all()
        np.testing.assert_allclose(proba.sum(axis=1), 1.0, rtol=1e-6)


def test_predict_proba_argmax_matches_predict_class(class_forest):
    X = rand_X(class_forest, B=32)
    pred = core.compile_forest(class_forest, engine="bitvector")
    np.testing.assert_array_equal(pred.predict_proba(X).argmax(axis=1),
                                  pred.predict_class(X))


def test_predict_proba_mode_from_model_not_batch(class_forest):
    """class_forest has signed (logit-like) leaves → softmax, decided
    from the leaf table: one row's probabilities never depend on its
    batchmates (the data-inferred mode could flip per batch)."""
    pred = core.compile_forest(class_forest, engine="bitvector")
    X = rand_X(class_forest, B=16)
    expect = normalize_scores(pred.predict(X), votes=False)
    np.testing.assert_allclose(pred.predict_proba(X), expect, rtol=1e-7)
    for i in (0, 7):
        np.testing.assert_allclose(pred.predict_proba(X[i:i + 1])[0],
                                   expect[i], rtol=1e-7)


def test_predict_proba_rejects_regression(small_forest):
    pred = core.compile_forest(small_forest, engine="bitvector")
    with pytest.raises(ValueError, match="classification"):
        pred.predict_proba(rand_X(small_forest, B=4))


def test_normalize_scores_softmax_for_logit_scores():
    s = np.array([[2.0, -1.0], [-3.0, 0.5]])
    p = normalize_scores(s)
    np.testing.assert_allclose(p.sum(axis=1), 1.0)
    assert (p > 0).all() and p[0, 0] > p[0, 1] and p[1, 1] > p[1, 0]


def test_normalize_scores_zero_row_uniform():
    p = normalize_scores(np.array([[0.0, 0.0, 0.0], [3.0, 1.0, 0.0]]))
    np.testing.assert_allclose(p[0], [1 / 3] * 3)
    np.testing.assert_allclose(p[1], [0.75, 0.25, 0.0])


def test_server_exposes_predict_proba(class_forest):
    from repro.inference.server import ForestServer
    srv = ForestServer.from_forest(class_forest, max_batch=16,
                                   engines=("qs",), cache_path=None,
                                   repeats=1)
    X = rand_X(class_forest, B=8)
    np.testing.assert_allclose(srv.predict_proba(X).sum(axis=1), 1.0,
                               rtol=1e-6)
