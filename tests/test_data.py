"""Data substrate: dataset signatures, token pipeline determinism."""
import numpy as np
import pytest

from repro.data import datasets
from repro.data.tokens import SyntheticTokens, TokenPipelineConfig

SIGNATURES = {           # name → (n_features, n_classes)
    "magic": (10, 2),
    "adult": (108, 2),
    "eeg": (14, 2),
    "mnist": (784, 10),
    "fashion": (784, 10),
    "msn": (136, 1),
}


@pytest.mark.parametrize("name", list(SIGNATURES))
def test_dataset_signatures(name):
    d, C = SIGNATURES[name]
    ds = datasets.load(name, n=1000)
    assert ds.n_features == d
    assert ds.n_classes == C
    assert ds.X_train.shape[0] + ds.X_test.shape[0] == 1000
    if C > 1:
        assert set(np.unique(ds.y_train)) <= set(range(C))


def test_dataset_deterministic():
    a = datasets.REGISTRY["magic"](n=500)
    b = datasets.REGISTRY["magic"](n=500)
    np.testing.assert_array_equal(a.X_train, b.X_train)


def test_eeg_has_outliers():
    ds = datasets.load("eeg", n=3000)
    X = ds.X_train
    med = np.median(np.abs(X))
    # heavy tail by construction (artifact magnitude tuned to the paper's
    # EEG quantization regime, see datasets.make_eeg)
    assert np.abs(X).max() > 15 * med


def test_adult_mostly_binary():
    ds = datasets.load("adult", n=800)
    n_binary = sum(len(np.unique(ds.X_train[:, f])) <= 2
                   for f in range(ds.n_features))
    assert n_binary >= 90


# ----------------------------------------------------------------- tokens
def test_token_batch_deterministic():
    cfg = TokenPipelineConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    p1, p2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
    np.testing.assert_array_equal(p1.batch(17), p2.batch(17))
    assert not np.array_equal(p1.batch(17), p1.batch(18))


def test_token_range_and_dtype():
    cfg = TokenPipelineConfig(vocab=512, seq_len=32, global_batch=4)
    b = SyntheticTokens(cfg).batch(0)
    assert b.dtype == np.int32 and b.shape == (4, 32)
    assert b.min() >= 0 and b.max() < 512


def test_host_slice_partitions_global_batch():
    cfg = TokenPipelineConfig(vocab=100, seq_len=16, global_batch=8, seed=1)
    p = SyntheticTokens(cfg)
    full = p.batch(5)
    parts = [p.host_slice(5, i, 4) for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_tokens_have_bigram_structure():
    """The Markov mixing must make the corpus learnable: bigram entropy
    below unigram entropy."""
    cfg = TokenPipelineConfig(vocab=64, seq_len=256, global_batch=16, seed=0)
    b = SyntheticTokens(cfg).batch(0)
    uni = np.bincount(b.ravel(), minlength=64) + 1e-9
    uni = uni / uni.sum()
    h_uni = -(uni * np.log(uni)).sum()
    # conditional entropy H(next | prev state)
    prev = b[:, :-1].ravel() % 64
    nxt = b[:, 1:].ravel()
    h_cond = 0.0
    for s in range(64):
        sel = nxt[prev == s]
        if len(sel) < 10:
            continue
        p = np.bincount(sel, minlength=64) + 1e-9
        p = p / p.sum()
        h_cond += (len(sel) / len(nxt)) * -(p * np.log(p)).sum()
    assert h_cond < h_uni - 0.05
