"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real
single-CPU device (the 512-device trick is dryrun.py-only)."""
import numpy as np
import pytest

from repro import core
from repro.data import datasets


@pytest.fixture(scope="session")
def small_forest():
    """8 trees × 16 leaves × 6 features, scalar output."""
    return core.random_forest_ir(n_trees=8, n_leaves=16, n_features=6,
                                 n_classes=1, seed=0)


@pytest.fixture(scope="session")
def class_forest():
    """Multiclass forest (C=3), unbalanced trees."""
    return core.random_forest_ir(n_trees=12, n_leaves=32, n_features=10,
                                 n_classes=3, seed=1, full=False)


@pytest.fixture(scope="session")
def big_leaf_forest():
    """L=64 → 2 leafidx words (exercises multi-word exit-leaf search)."""
    return core.random_forest_ir(n_trees=6, n_leaves=64, n_features=8,
                                 n_classes=2, seed=2, full=False)


@pytest.fixture(scope="session")
def magic_ds():
    return datasets.load("magic", n=2000)


@pytest.fixture(scope="session")
def trained_rf(magic_ds):
    from repro.trees.random_forest import RandomForest, RandomForestConfig
    return RandomForest(RandomForestConfig(n_trees=32, max_leaves=16,
                                           seed=0)).fit(
        magic_ds.X_train, magic_ds.y_train)


def rand_X(forest, B=64, seed=3):
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1.2, size=(B, forest.n_features))
