"""Elastic-restart end-to-end: shrink DP, raise accumulation, restore —
the loss trajectory must continue as if nothing happened (global batch
invariant), which is the plan_elastic_restart contract."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.fault_tolerance import plan_elastic_restart
from repro.launch.train import Trainer


@pytest.fixture(scope="module")
def cfg():
    return get_config("smollm_360m").reduced()


def test_accum_matches_full_batch(cfg):
    """One step with accum=2 ≡ one step with accum=1 (same global batch).

    Tolerances: the accumulated path sums microbatch losses/grads in f32
    in a different order than the full-batch reduction, so step 0 agrees
    only to f32 rounding (measured ~5e-7 rel).  Adam amplifies that seed
    difference ~10× per step (eps/sqrt sensitivity near zero second
    moments), so later steps get a correspondingly looser bound.  A true
    averaging/dtype bug shows up orders of magnitude above these."""
    t1 = Trainer(cfg, batch=4, seq_len=32, accum_steps=1)
    t2 = Trainer(cfg, batch=4, seq_len=32, accum_steps=2)
    t1.init_state()
    t2.init_state()
    r1 = [t1.train_step() for _ in range(3)]
    r2 = [t2.train_step() for _ in range(3)]
    # step 0: same params, same data — only summation order differs
    assert r1[0]["loss"] == pytest.approx(r2[0]["loss"], rel=1e-5)
    assert r1[0]["grad_norm"] == pytest.approx(r2[0]["grad_norm"], rel=1e-5)
    for a, b in zip(r1[1:], r2[1:]):
        assert a["loss"] == pytest.approx(b["loss"], rel=1e-3)
        assert a["grad_norm"] == pytest.approx(b["grad_norm"], rel=1e-3)


def test_elastic_shrink_restart_continues_trajectory(cfg, tmp_path):
    """Simulated host loss: train on the 'big' config, checkpoint, replan
    with half the hosts (accum ×2), restore, continue — losses must match
    the uninterrupted run."""
    big = Trainer(cfg, batch=4, seq_len=32, accum_steps=1)
    big.init_state()
    for _ in range(2):
        big.train_step()
    big.save(str(tmp_path))
    ref = [big.train_step()["loss"] for _ in range(2)]

    plan = plan_elastic_restart(alive=[0], total_hosts=2, dp_size=2,
                                global_batch=4)
    assert plan.dp_size == 1 and plan.accum_steps == 2
    assert plan.global_batch == 4

    small = Trainer(cfg, batch=plan.global_batch, seq_len=32,
                    accum_steps=plan.accum_steps)
    got_step = small.restore(str(tmp_path))
    assert got_step == 2
    got = [small.train_step()["loss"] for _ in range(2)]
    np.testing.assert_allclose(got, ref, rtol=1e-4)
