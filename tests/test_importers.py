"""Golden-fixture importer tests (tests/fixtures/): checked-in model
dumps + hand-computed expected predictions, so importer regressions are
caught without sklearn/xgboost/lightgbm installed.  Also the packed
``.repro.npz`` container's error paths (version gate, kind mismatch,
garbage files)."""
import json
import os

import numpy as np
import pytest

from repro import core, io
from repro.core import registry

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")

with open(os.path.join(FIXTURES, "expected.json")) as f:
    EXPECTED = json.load(f)


def load_fixture(name: str):
    exp = EXPECTED[name]
    forest = io.load_model(os.path.join(FIXTURES, name + ".json"),
                           **exp["kw"])
    return forest, np.asarray(exp["X"]), np.asarray(exp["predict"])


# --------------------------------------------------------------------------- #
# importer → oracle golden checks
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_fixture_oracle_matches_expected(name):
    forest, X, expect = load_fixture(name)
    shape = EXPECTED[name]["shape"]
    assert forest.n_trees == shape["n_trees"]
    assert forest.n_classes == shape["n_classes"]
    assert forest.n_features == shape["n_features"]
    np.testing.assert_allclose(forest.predict_oracle(X), expect,
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_fixture_engines_match_expected(name):
    """Every registered XLA engine reproduces the golden predictions —
    the import→compile→predict chain, not just the IR."""
    forest, X, expect = load_fixture(name)
    for engine in registry.engines("jax"):
        got = core.compile_forest(forest, engine=engine).predict(X)
        np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{name}/{engine}")


def test_xgb_boundary_goes_right():
    """XGBoost's predicate is strict (< goes yes): x == split_condition
    must take the 'no' branch — the nextafter threshold mapping."""
    forest, _, _ = load_fixture("xgb_regression")
    # tree0 splits f0 < 0.5 (yes → 1.0); at exactly 0.5, no → 2.0
    got = forest.predict_oracle(np.array([[0.5, 5.0]]))   # t1: no → 30
    assert got[0, 0] == pytest.approx(32.0)


def test_lgbm_boundary_goes_left():
    """LightGBM's predicate is <= : x == threshold takes the left child."""
    forest, _, _ = load_fixture("lgbm_regression")
    got = forest.predict_oracle(np.array([[0.5, 0.0]]))
    assert got[0, 0] == pytest.approx(3.0 + -1.0)


def test_sklearn_object_and_json_shim_agree():
    """import_sklearn over the shim object ≡ load_model over its JSON —
    the duck-typed path a real fitted sklearn model takes."""
    path = os.path.join(FIXTURES, "sklearn_rf_classifier.json")
    with open(path) as f:
        shim = io.sklearn_shim_from_json(json.load(f))
    f1 = io.import_sklearn(shim)
    f2 = io.load_model(path)
    X = np.asarray(EXPECTED["sklearn_rf_classifier"]["X"])
    np.testing.assert_array_equal(f1.predict_oracle(X),
                                  f2.predict_oracle(X))


def test_importer_rejects_nan_threshold():
    dump = [{"nodeid": 0, "split": "f0", "split_condition": float("nan"),
             "yes": 1, "no": 2, "children": [
                 {"nodeid": 1, "leaf": 1.0}, {"nodeid": 2, "leaf": 2.0}]}]
    with pytest.raises(ValueError, match="NaN"):
        io.import_xgboost_json(dump)


def test_importer_rejects_categorical_lgbm():
    dump = {"tree_info": [{"tree_structure": {
        "split_feature": 0, "threshold": "1||2", "decision_type": "==",
        "left_child": {"leaf_value": 1.0},
        "right_child": {"leaf_value": 2.0}}}]}
    with pytest.raises(ValueError, match="decision_type"):
        io.import_lightgbm_json(dump)


def test_xgb_named_features_first_appearance_order():
    dump = [{"nodeid": 0, "split": "age", "split_condition": 10.0,
             "yes": 1, "no": 2, "children": [
                 {"nodeid": 1, "split": "income", "split_condition": 3.0,
                  "yes": 3, "no": 4, "children": [
                      {"nodeid": 3, "leaf": 1.0}, {"nodeid": 4, "leaf": 2.0}]},
                 {"nodeid": 2, "leaf": 5.0}]}]
    forest = io.import_xgboost_json(dump)
    assert forest.n_features == 2           # age → 0, income → 1
    got = forest.predict_oracle(np.array([[5.0, 1.0], [5.0, 4.0],
                                          [20.0, 0.0]]))
    np.testing.assert_allclose(got[:, 0], [1.0, 2.0, 5.0])


def test_sklearn_classifier_boosting_rejected():
    """GradientBoostingClassifier must be rejected loudly: multiclass
    grids must not be summed into one scalar, and the binary case hides
    its log-odds prior where the importer can't recover it."""
    with open(os.path.join(FIXTURES, "sklearn_gbr.json")) as f:
        d = json.load(f)
    for n_classes in (2, 3):
        d["n_classes"] = n_classes
        with pytest.raises(ValueError, match="classifiers"):
            io.import_sklearn(io.sklearn_shim_from_json(d))


def test_sklearn_boosting_init_without_constant():
    """An init_ lacking constant_ (e.g. a classifier prior object) means
    base 0, not an AttributeError."""
    with open(os.path.join(FIXTURES, "sklearn_gbr.json")) as f:
        d = json.load(f)
    shim = io.sklearn_shim_from_json(d)
    shim.init_ = object()                  # no constant_ attribute
    forest = io.import_sklearn(shim)
    got = forest.predict_oracle(np.array([[-1.0], [1.0]]))[:, 0]
    np.testing.assert_allclose(got, [-0.3, 0.3])   # lr-scaled, no base


def test_xgb_feature_names_fixes_column_order():
    """feature_names pins name → training-column mapping; without it,
    first-appearance order would permute the columns here."""
    dump = [{"nodeid": 0, "split": "income", "split_condition": 3.0,
             "yes": 1, "no": 2, "children": [
                 {"nodeid": 1, "leaf": 1.0}, {"nodeid": 2, "leaf": 2.0}]}]
    forest = io.import_xgboost_json(dump, feature_names=["age", "income"])
    assert forest.n_features == 2          # income → column 1
    got = forest.predict_oracle(np.array([[99.0, 1.0], [0.0, 9.0]]))
    np.testing.assert_allclose(got[:, 0], [1.0, 2.0])
    with pytest.raises(ValueError, match="missing from feature_names"):
        io.import_xgboost_json(dump, feature_names=["age"])


def test_xgb_feature_names_pin_fN_names_too():
    """With pinned feature_names, even fN-style split names resolve
    through the map (by the caller's column order, not by digit) and
    unknown fN names are rejected instead of silently clamped."""
    dump = [{"nodeid": 0, "split": "f1", "split_condition": 0.0,
             "yes": 1, "no": 2, "children": [
                 {"nodeid": 1, "leaf": 1.0}, {"nodeid": 2, "leaf": 2.0}]}]
    # permuted pinning: the column called "f1" is column 0
    forest = io.import_xgboost_json(dump, feature_names=["f1", "f0"])
    assert forest.n_features == 2
    got = forest.predict_oracle(np.array([[-1.0, 99.0], [1.0, -99.0]]))
    np.testing.assert_allclose(got[:, 0], [1.0, 2.0])
    with pytest.raises(ValueError, match="missing from feature_names"):
        io.import_xgboost_json(dump, feature_names=["colA", "colB"])


def test_load_model_filters_inapplicable_hints(tmp_path):
    """Hints reach only importers whose signatures accept them: n_classes
    with a LightGBM dump (self-describing num_class) must not TypeError."""
    forest = io.load_model(os.path.join(FIXTURES, "lgbm_regression.json"),
                           n_classes=3)
    assert forest.n_classes == 1           # the dump's num_class governs


def test_rapidscorer_server_cold_start_reaches_forest(small_forest,
                                                      tmp_path):
    """CompiledRS nests the IR under qs: host_forest() must reach it on
    a cold-started rapidscorer server (regression: compiled.forest)."""
    from repro.inference.server import ForestServer
    srv = ForestServer.from_forest(small_forest, max_batch=8,
                                   engines=("rapidscorer",),
                                   cache_path=None, repeats=1)
    p = str(tmp_path / "rs.npz")
    srv.save(p)
    loaded = ForestServer.load(p)
    f = loaded.predictor.host_forest()
    assert f is not None and f.n_features == small_forest.n_features
    X = np.random.default_rng(0).normal(size=(4, f.n_features))
    np.testing.assert_array_equal(loaded.predictor.predict(X),
                                  srv.predictor.predict(X))


def test_xgb_multiclass_base_score_applied():
    forest = io.load_model(os.path.join(FIXTURES, "xgb_multiclass.json"),
                           n_classes=3, base_score=0.5)
    got = forest.predict_oracle(np.array([[-1.0]]))
    np.testing.assert_allclose(got, [[1.5, 3.5, 5.5]])


def test_load_model_packed_ignores_importer_kwargs(tmp_path, small_forest):
    """The packed IR is self-describing: importer hints must not crash
    the npz path (regression: kw used to forward into load_forest)."""
    p = str(tmp_path / "f.repro.npz")
    io.save_forest(small_forest, p)
    loaded = io.load_model(p, n_classes=3)
    assert loaded.n_classes == small_forest.n_classes


def test_server_load_save_load_keeps_engine_choice(small_forest, tmp_path):
    """engine_choice (a name string after load) survives a save cycle."""
    from repro.inference.server import ForestServer
    srv = ForestServer.from_forest(small_forest, max_batch=8,
                                   engines=("native",), cache_path=None,
                                   repeats=1)
    p1, p2 = str(tmp_path / "a.npz"), str(tmp_path / "b.npz")
    srv.save(p1)
    srv2 = ForestServer.load(p1)
    srv2.save(p2)
    assert ForestServer.load(p2).engine_choice == "native"


def test_n_features_hint_below_referenced_index_rejected():
    """A too-small n_features would make engines gather a clamped column
    silently — all three importers must reject it loudly."""
    xgb = [{"nodeid": 0, "split": "f5", "split_condition": 0.0,
            "yes": 1, "no": 2, "children": [
                {"nodeid": 1, "leaf": 1.0}, {"nodeid": 2, "leaf": 2.0}]}]
    with pytest.raises(ValueError, match="too small"):
        io.import_xgboost_json(xgb, n_features=3)
    lgb = {"tree_info": [{"tree_structure": {
        "split_feature": 4, "threshold": 0.0, "decision_type": "<=",
        "left_child": {"leaf_value": 1.0},
        "right_child": {"leaf_value": 2.0}}}]}
    with pytest.raises(ValueError, match="too small"):
        io.import_lightgbm_json(lgb, n_features=2)
    with open(os.path.join(FIXTURES, "sklearn_rf_classifier.json")) as f:
        shim = io.sklearn_shim_from_json(json.load(f))
    with pytest.raises(ValueError, match="too small"):
        io.import_sklearn(shim, n_features=0)


def test_load_model_rejects_unknown_json(tmp_path):
    p = tmp_path / "mystery.json"
    p.write_text('{"weights": [1, 2, 3]}')
    with pytest.raises(ValueError, match="unrecognized model format"):
        io.load_model(str(p))


# --------------------------------------------------------------------------- #
# packed container error paths
# --------------------------------------------------------------------------- #
def test_packed_rejects_garbage_file(tmp_path):
    p = tmp_path / "junk.npz"
    p.write_bytes(b"this is not an npz archive")
    with pytest.raises(ValueError, match="not a readable"):
        io.load_forest(str(p))


def test_packed_rejects_missing_header(tmp_path):
    p = tmp_path / "noheader.npz"
    np.savez(str(p), x=np.zeros(3))
    with pytest.raises(ValueError, match="no header"):
        io.load_forest(str(p))


def test_packed_rejects_newer_version(tmp_path, small_forest):
    from repro.io import packed
    p = tmp_path / "future.npz"
    io.save_forest(small_forest, str(p))
    npz = dict(np.load(str(p), allow_pickle=False))
    hdr = json.loads(str(npz["header"]))
    hdr["version"] = packed.VERSION + 1
    npz["header"] = np.asarray(json.dumps(hdr))
    np.savez(str(p), **npz)
    with pytest.raises(ValueError, match="newer than this reader"):
        io.load_forest(str(p))


def test_packed_kind_mismatch(tmp_path, small_forest):
    fp = tmp_path / "forest.npz"
    io.save_forest(small_forest, str(fp))
    with pytest.raises(ValueError, match="not a predictor"):
        io.load_predictor(str(fp))
    pp = tmp_path / "pred.npz"
    io.save_predictor(core.compile_forest(small_forest, engine="native"),
                      str(pp))
    with pytest.raises(ValueError, match="not a forest"):
        io.load_forest(str(pp))


def test_save_predictor_requires_serializable_engine(small_forest):
    class NotAnEnginePredictor:
        _eval = None
    with pytest.raises(ValueError, match="cannot serialize"):
        io.save_predictor(NotAnEnginePredictor(), "/tmp/never-written.npz")
