"""Quickstart: train a Random Forest, convert to the QuickScorer IR,
quantize (paper §5), compile for every engine, and compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro import core
from repro.data import datasets
from repro.trees.random_forest import RandomForest, RandomForestConfig


def main() -> None:
    # 1. data + training (self-contained substrate: histogram CART)
    ds = datasets.load("magic", n=4000)
    rf = RandomForest(RandomForestConfig(n_trees=128, max_leaves=32,
                                         seed=0))
    rf.fit(ds.X_train, ds.y_train)
    print(f"trained RF: {len(rf.trees)} trees, "
          f"acc={(rf.predict(ds.X_test) == ds.y_test).mean():.4f}")

    # 2. canonical Forest IR (the paper's bitvector form)
    forest = core.from_random_forest(rf)
    print(f"forest IR: T={forest.n_trees} L={forest.n_leaves} "
          f"C={forest.n_classes} words={forest.n_words}")

    # 3. fixed-point quantization (paper §5: s = 2^15, int16)
    qforest = core.quantize_forest(forest, ds.X_train)
    print(f"quantized: splits {qforest.threshold.dtype}, "
          f"leaves {qforest.leaf_value.dtype}, scale {qforest.quant_scale}")

    # 4. every engine, float + quantized
    X = ds.X_test
    for f, tag in ((forest, " "), (qforest, "q")):
        for engine in core.ENGINES:
            pred = core.compile_forest(f, engine=engine)
            pred.predict(X[:8])                       # compile
            t0 = time.perf_counter()
            out = pred.predict(X)
            dt = (time.perf_counter() - t0) / len(X) * 1e6
            acc = (out.argmax(1) == ds.y_test).mean()
            print(f"  {tag}{engine:12s} acc={acc:.4f} {dt:7.2f} µs/inst")

    # 5. Pallas TPU kernel (interpret mode on CPU)
    pk = core.compile_forest(qforest, engine="bitvector", backend="pallas")
    out = pk.predict(X[:256])
    ref = core.compile_forest(qforest, engine="bitvector").predict(X[:256])
    print(f"pallas kernel max|Δ| vs XLA engine: "
          f"{np.abs(out - ref).max():.2e}")


if __name__ == "__main__":
    main()
