"""End-to-end LM training driver: a ~100M-param smollm-family model for a
few hundred steps on CPU, with checkpointing and fault-tolerance hooks —
the same Trainer class the production mesh uses.

    PYTHONPATH=src python examples/train_lm.py --steps 300

By default this runs a width-reduced smollm (~14M params) so a few hundred
steps finish on CPU in minutes; pass --full-100m for the real ~100M
variant if you have the patience (or a TPU).
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.launch.train import Trainer, run_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    cfg = get_config("smollm_360m")
    if args.full_100m:
        # ~100M params: keep smollm's shape, trim depth
        cfg = dataclasses.replace(cfg, n_layers=8, name="smollm-100m")
    else:
        cfg = dataclasses.replace(
            cfg.reduced(), n_layers=4, d_model=256, n_heads=4, n_kv=2,
            head_dim=64, d_ff=1024, vocab=8192, name="smollm-14m")

    n = cfg.param_count()
    print(f"[example] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq_len}")

    trainer = Trainer(cfg, batch=args.batch, seq_len=args.seq_len,
                      lr=args.lr, remat=False)
    t0 = time.time()
    records = run_loop(trainer, steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=100, log_every=20,
                       hb_dir=args.ckpt_dir + "/hb")
    dt = time.time() - t0
    first = sum(r["loss"] for r in records[:10]) / max(len(records[:10]), 1)
    last = sum(r["loss"] for r in records[-10:]) / max(len(records[-10:]), 1)
    print(f"[example] done in {dt:.0f}s — loss {first:.3f} → {last:.3f} "
          f"(must decrease); ckpt at {args.ckpt_dir}")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
