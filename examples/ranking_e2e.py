"""Learning-to-rank pipeline (paper §6.1): gradient-boosted trees on the
MSN-shaped ranking data, scored with every traversal engine, reproducing
the Table-2 protocol end-to-end at laptop scale.

    PYTHONPATH=src python examples/ranking_e2e.py
"""
import time

import numpy as np

from repro import core
from repro.data import datasets
from repro.trees.gradient_boosting import (GradientBoosting,
                                           GradientBoostingConfig)


def ndcg_at_k(scores, labels, k=10, n_queries=50):
    """Group the test set into synthetic queries, compute mean NDCG@k."""
    n = len(scores) // n_queries
    total = 0.0
    for q in range(n_queries):
        s = scores[q * n:(q + 1) * n]
        l = labels[q * n:(q + 1) * n]
        order = np.argsort(-s)[:k]
        dcg = np.sum((2 ** l[order] - 1) / np.log2(np.arange(2, k + 2)))
        ideal = np.sort(l)[::-1][:k]
        idcg = np.sum((2 ** ideal - 1) / np.log2(np.arange(2, k + 2)))
        total += dcg / max(idcg, 1e-9)
    return total / n_queries


def main() -> None:
    ds = datasets.load("msn", n=6000)
    gb = GradientBoosting(GradientBoostingConfig(
        n_trees=300, max_leaves=32, objective="l2", learning_rate=0.15,
        seed=0))
    t0 = time.time()
    gb.fit(ds.X_train, ds.y_train)
    print(f"trained GBT: {len(gb.trees)} trees in {time.time()-t0:.1f}s")

    forest = core.from_gradient_boosting(gb)
    base = ndcg_at_k(gb.predict(ds.X_test), ds.y_test)
    print(f"NDCG@10 = {base:.4f} (direct trainer predict)")

    X = ds.X_test
    for engine in core.ENGINES:
        pred = core.compile_forest(forest, engine=engine)
        pred.predict(X[:8])
        t0 = time.perf_counter()
        scores = pred.predict(X)[:, 0]
        us = (time.perf_counter() - t0) / len(X) * 1e6
        nd = ndcg_at_k(scores, ds.y_test)
        print(f"  {engine:12s} NDCG@10={nd:.4f} ({us:6.2f} µs/inst)")
        assert abs(nd - base) < 1e-6, "engine changed ranking order!"

    qforest = core.quantize_forest(forest, ds.X_train)
    qpred = core.compile_forest(qforest, engine="rapidscorer")
    nd = ndcg_at_k(qpred.predict(X)[:, 0], ds.y_test)
    print(f"  int16-quantized rapidscorer NDCG@10={nd:.4f} "
          f"(Δ={nd-base:+.4f})")


if __name__ == "__main__":
    main()
