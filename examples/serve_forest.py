"""Batched tree-ensemble serving: Poisson request stream through the
micro-batcher into the autotuned fastest engine for this forest — the
paper's IoT workload as a service, with its "best implementation depends
on the forest and the device" conclusion applied automatically
(docs/ENGINES.md).

    PYTHONPATH=src python examples/serve_forest.py
"""
import numpy as np

from repro import core
from repro.data import datasets
from repro.inference.server import ForestServer
from repro.trees.random_forest import RandomForest, RandomForestConfig


def main() -> None:
    ds = datasets.load("mnist", n=3000)
    rf = RandomForest(RandomForestConfig(n_trees=128, max_leaves=64,
                                         seed=0)).fit(ds.X_train, ds.y_train)
    forest = core.quantize_forest(core.from_random_forest(rf), ds.X_train)

    # autotune: microbenchmark the engine matrix at the dispatch batch
    # size, cache the winner (JSON on disk — restarts skip the sweep)
    server = ForestServer.from_forest(forest, max_batch=128, max_wait_ms=2.0)
    print(f"autotuned engine: {server.engine_choice.engine} "
          f"(cached: {server.engine_choice.from_cache})")
    pred = server.predictor

    # warm the jit cache for the batch shapes the server will see, so
    # latency percentiles measure serving, not compilation
    for b in (1, 128):
        pred.predict(ds.X_test[:b])
    rng = np.random.default_rng(0)
    n_requests = 2000
    arrivals = np.cumsum(rng.exponential(1 / 5000.0, size=n_requests))
    rows = rng.integers(0, ds.X_test.shape[0], size=n_requests)

    correct = total = 0
    for at, row in zip(arrivals, rows):
        req = server.submit(ds.X_test[row], arrival_s=at)
        req.label = int(ds.y_test[row])
        for done in server.poll(now_s=at):
            total += 1
            correct += int(np.argmax(done.result)) == done.label
    for done in server.flush(now_s=float(arrivals[-1])):
        total += 1
        correct += int(np.argmax(done.result)) == done.label

    s = server.stats.summary()
    print(f"served {s['n_requests']} requests in {s['n_batches']} batches "
          f"(mean batch {s['mean_batch']:.1f})")
    print(f"latency p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms")
    print(f"accuracy {correct/total:.4f}")


if __name__ == "__main__":
    main()
