"""Fault-tolerance demo: train → lose half the hosts → elastic restart
with gradient accumulation → identical loss trajectory.

    PYTHONPATH=src python examples/elastic_restart.py

Simulates the production flow on CPU: the "big" job (DP=2 in spirit)
checkpoints; a failure survey finds one host dead; plan_elastic_restart
shrinks DP and doubles accumulation; the "small" job restores and
continues — the loss curve is bit-close to the uninterrupted run because
the global batch and the (seed, step)-keyed data stream are invariant.
"""
import tempfile

import numpy as np

from repro.configs import get_config
from repro.distributed.fault_tolerance import (Heartbeat,
                                               plan_elastic_restart)
from repro.launch.train import Trainer


def main() -> None:
    cfg = get_config("smollm_360m").reduced()
    ckpt_dir = tempfile.mkdtemp(prefix="repro_elastic_")
    hb_dir = ckpt_dir + "/hb"

    # --- phase 1: the "2-host" job runs 3 steps and checkpoints -------- #
    big = Trainer(cfg, batch=4, seq_len=64, accum_steps=1)
    big.init_state()
    for i in range(3):
        rec = big.train_step()
        for host in (0, 1):
            Heartbeat(hb_dir, host).beat(rec["step"])
        print(f"[big]   step {rec['step']} loss {rec['loss']:.4f}")
    big.save(ckpt_dir)

    # reference: what the uninterrupted job would do next
    ref = [big.train_step()["loss"] for _ in range(3)]

    # --- phase 2: host 1 dies; survey + plan --------------------------- #
    Heartbeat(hb_dir, 0).beat(3)                      # host 0 still alive
    survey = Heartbeat.survey(hb_dir, timeout_s=1e9)
    survey[1]["alive"] = False                        # simulated failure
    alive = [h for h, rec in survey.items() if rec["alive"]]
    plan = plan_elastic_restart(alive, total_hosts=2, dp_size=2,
                                global_batch=4)
    print(f"[plan]  survivors={alive} → dp={plan.dp_size} "
          f"accum={plan.accum_steps} global_batch={plan.global_batch} "
          f"dropped={plan.dropped_hosts}")

    # --- phase 3: shrunken job restores and continues ------------------ #
    small = Trainer(cfg, batch=plan.global_batch, seq_len=64,
                    accum_steps=plan.accum_steps)
    step = small.restore(ckpt_dir)
    print(f"[small] restored at step {step}")
    got = []
    for _ in range(3):
        rec = small.train_step()
        got.append(rec["loss"])
        print(f"[small] step {rec['step']} loss {rec['loss']:.4f}")

    err = max(abs(a - b) / abs(a) for a, b in zip(ref, got))
    print(f"[check] max relative deviation from uninterrupted run: "
          f"{err:.2e} (must be ≈ float tolerance)")
    assert err < 1e-3
    print("[check] elastic restart preserved the loss trajectory ✓")


if __name__ == "__main__":
    main()
