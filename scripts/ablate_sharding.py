"""Reproducible ablation for EXPERIMENTS.md §Perf iterations 1–5: lower a
dry-run cell with the activation-sharding constraint system DISABLED
(REPRO_NO_ACT_SHARDING=1) vs enabled, and print the roofline/memory delta.

    PYTHONPATH=src python scripts/ablate_sharding.py \
        [--arch smollm_360m] [--shape train_4k]

Each variant runs in a subprocess (jax device state + the env hook are
process-global).
"""
import argparse
import json
import os
import subprocess
import sys

CELL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from repro.launch.dryrun import build_cell, PEAK_FLOPS, HBM_BW, ICI_BW
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.configs import get_config, SHAPES

arch, shape_name = sys.argv[1], sys.argv[2]
cfg = get_config(arch); shape = SHAPES[shape_name]
mesh = make_production_mesh()
with mesh:
    fn, args, extra = build_cell(cfg, shape, mesh)
    compiled = fn.lower(*args).compile()
hc = analyze(compiled.as_text())
mem = compiled.memory_analysis()
print(json.dumps({
    "flops": hc.flops, "bytes": hc.bytes_hbm,
    "coll_link": hc.collectives.link_bytes,
    "temp_bytes": int(mem.temp_size_in_bytes),
    "compute_s": hc.flops / PEAK_FLOPS,
    "memory_s": hc.bytes_hbm / HBM_BW,
    "collective_s": hc.collectives.link_bytes / ICI_BW,
}))
"""


def run_variant(arch: str, shape: str, disabled: bool) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    if disabled:
        env["REPRO_NO_ACT_SHARDING"] = "1"
    else:
        env.pop("REPRO_NO_ACT_SHARDING", None)
    out = subprocess.run(
        [sys.executable, "-c", CELL_SCRIPT, arch, shape],
        env=env, capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    print(f"[ablate] {args.arch} {args.shape}: constraints OFF ...",
          flush=True)
    off = run_variant(args.arch, args.shape, disabled=True)
    print(f"[ablate] {args.arch} {args.shape}: constraints ON ...",
          flush=True)
    on = run_variant(args.arch, args.shape, disabled=False)

    def row(k, scale=1e9, unit="GB"):
        o, n = off[k] / scale, on[k] / scale
        return (f"  {k:14s} {o:12.2f} → {n:12.2f} {unit}   "
                f"({o / max(n, 1e-12):5.1f}× reduction)")

    print("\nconstraints OFF → ON (per chip):")
    print(row("temp_bytes"))
    print(row("bytes"))
    print(row("coll_link"))
    print(f"  {'flops':14s} {off['flops']/1e12:12.2f} → "
          f"{on['flops']/1e12:12.2f} TFLOP  "
          f"({off['flops']/max(on['flops'],1e-9):5.1f}× reduction)")
    print("\nroofline terms (s):")
    for k in ("compute_s", "memory_s", "collective_s"):
        print(f"  {k:14s} {off[k]:10.3f} → {on[k]:10.3f}")


if __name__ == "__main__":
    main()
