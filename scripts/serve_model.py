"""Serve an externally trained model file end to end.

    PYTHONPATH=src python scripts/serve_model.py --model model.json
    PYTHONPATH=src python scripts/serve_model.py --model forest.repro.npz \
        --save server.pred.npz
    PYTHONPATH=src python scripts/serve_model.py --model server.pred.npz

``--model`` accepts any format ``repro.io`` understands: an XGBoost JSON
dump, a LightGBM ``dump_model`` JSON, a sklearn-shim JSON, a packed
``.repro.npz`` forest — or a packed *predictor/server* artifact, which
cold-starts without autotuning or recompiling (docs/FORMATS.md).
``--save`` writes the autotuned compiled artifact so the next start takes
the cold path.  ``--explain`` prints the served predictor's
``plan.describe()`` — every pipeline pass including the optimizer
middle-end's per-pass stats (docs/OPTIM.md) — so a served artifact can
say how it was compiled; ``--opt 2`` adds ``@O2`` optimizer candidates
to the autotune sweep.
"""
import argparse
import sys
import time

import numpy as np


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--model", required=True,
                    help="model file (XGB/LGBM/shim JSON or .repro.npz)")
    ap.add_argument("--engine", default=None,
                    help="pin one autotuner engine (default: sweep)")
    ap.add_argument("--batch", type=int, default=64,
                    help="serving max_batch (autotune batch bucket)")
    ap.add_argument("--n-classes", type=int, default=1,
                    help="multiclass round-robin width for XGBoost dumps")
    ap.add_argument("--save", default=None,
                    help="write the compiled server artifact here")
    ap.add_argument("--n-requests", type=int, default=256,
                    help="synthetic requests to stream through the server")
    ap.add_argument("--opt", default=None,
                    help="optimizer level for the autotune sweep "
                         "(e.g. 2 → adds @O2 candidates; docs/OPTIM.md)")
    ap.add_argument("--explain", action="store_true",
                    help="print the served predictor's compile plan "
                         "(pipeline passes incl. optimizer stats)")
    args = ap.parse_args(argv)

    from repro import io
    from repro.inference.server import ForestServer

    t0 = time.perf_counter()
    header_kind = None
    if args.model.endswith(".npz"):
        header_kind = io.peek(args.model).get("kind")
    if header_kind == "predictor":
        srv = ForestServer.load(args.model)
        # host_forest, not compiled.forest: rapidscorer nests the IR
        forest = srv.predictor.host_forest()
        print(f"[serve] cold start from compiled artifact "
              f"(engine_choice={srv.engine_choice})")
    else:
        kw = {"n_classes": args.n_classes} if args.n_classes > 1 else {}
        forest = io.load_model(args.model, **kw)
        print(f"[serve] imported forest: T={forest.n_trees} "
              f"L={forest.n_leaves} C={forest.n_classes} "
              f"d={forest.n_features}")
        engines = (args.engine,) if args.engine else None
        opt_levels = (args.opt,) if args.opt is not None else None
        srv = ForestServer.from_forest(forest, max_batch=args.batch,
                                       engines=engines,
                                       opt_levels=opt_levels, repeats=1)
        print(f"[serve] autotuned engine: {srv.engine_choice.engine} "
              f"(cached: {srv.engine_choice.from_cache})")
    # n_features_in, not n_features: an optimizer feat_map keeps the
    # serving interface full-width even after dropped columns
    d = getattr(forest, "n_features_in", forest.n_features)
    if args.explain:
        plan = getattr(srv.predictor, "plan", None)
        print("[serve] compile plan: "
              + (plan.describe() if plan is not None
                 else "unavailable (predictor built outside the pipeline)"))
    X1 = np.zeros((1, d))
    srv.predictor.predict(X1)                      # first prediction
    print(f"[serve] load-to-first-prediction: "
          f"{time.perf_counter() - t0:.3f}s")

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1e-4, size=args.n_requests))
    for at in arrivals:
        srv.submit(rng.normal(size=d), arrival_s=float(at))
        srv.poll(now_s=float(at))
    srv.flush(now_s=float(arrivals[-1]))
    s = srv.stats.summary()
    print(f"[serve] {s['n_requests']} requests in {s['n_batches']} batches "
          f"(mean batch {s['mean_batch']:.1f}) "
          f"p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms")

    if args.save:
        srv.save(args.save)
        print(f"[serve] compiled server artifact → {args.save}")


if __name__ == "__main__":
    sys.exit(main())
