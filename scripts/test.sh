#!/usr/bin/env bash
# Tier-1 verify: run the full test suite with the src layout on the path.
#   scripts/test.sh              # whole suite
#   scripts/test.sh tests/test_bitmm.py -k quantized
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
