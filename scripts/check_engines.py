"""Dev sanity check: all registered engines vs the traversal oracle.

    PYTHONPATH=src python scripts/check_engines.py             # engine matrix
    PYTHONPATH=src python scripts/check_engines.py --cascade   # + cascade e2e
    PYTHONPATH=src python scripts/check_engines.py --cascade-fused  # + fused
    PYTHONPATH=src python scripts/check_engines.py --optimize  # + -O2 == -O0
    PYTHONPATH=src python scripts/check_engines.py --serving   # + runtime
    PYTHONPATH=src python scripts/check_engines.py --int       # + int/FLInt
    PYTHONPATH=src python scripts/check_engines.py --obs       # + metrics
    PYTHONPATH=src python scripts/check_engines.py --os        # + -Os

The engine list comes from ``core.registry`` — a newly registered engine
shows up here (and in the benchmarks and the agreement tests) with no
edits to this file.  ``--cascade`` additionally exercises the staged-
evaluation subsystem end-to-end on one engine: gate-off bit-exactness,
a calibrated gate under the accuracy floor, and the exit-fraction
accounting (the CI smoke path).  ``--cascade-fused`` checks fused
single-computation execution (docs/CASCADE.md §Fused execution) against
the staged loop: bit-exact scores and identical per-stage exit counts
on the quantized forest, for every jax engine and for the single-kernel
Pallas tier in interpret mode.  ``--optimize`` checks the optimizer
middle-end (docs/OPTIM.md): every registered engine compiled at ``-O2``
must agree with its ``-O0`` compile — bit-exactly on the quantized
forest, within float tolerance on the float one.  ``--serving`` checks
the concurrent runtime (docs/SERVING.md): shape warmup leaves
predictions bit-identical, served scores equal the synchronous
``predictor.predict`` for every jax engine and for a cascade tenant
(exit accounting intact), and the adaptive controller never leaves its
configured bounds under adversarial latency streams.  ``--int`` checks
the integer end-to-end paths (docs/QUANT.md): int-accum engines
bit-exact vs the quantized oracle (every jax engine + the Pallas tier in
interpret mode), FLInt engines equal to the float engines exactly, and
the int-gate cascade class-exact with the full forest.  ``--obs`` checks
the observability layer (docs/OBSERVABILITY.md): served scores stay
bit-exact with full instrumentation on (plain + fused-cascade tenants,
threaded runtime, live scrape endpoint), the Prometheus scrape exposes
every catalog metric as well-formed text, ``/metrics.json`` parses and
carries the runtime stats, and the warmed fleet serves with **zero**
retrace anomalies.  ``--os`` checks zero-shot compilation
(docs/AUTOTUNE.md): a cost model trained from measured sweeps must hand
back a plan bit-exact with compiling that plan directly, the
low-confidence fallback's narrow sweep must agree with the restricted
full sweep, and an ``-Os`` fleet cold-start must survive a manifest
save/load round trip bit-identically.

Exit status is non-zero on any FAIL line, so CI can gate on it.
"""
import argparse
import os
import sys

import numpy as np

from repro import core
from repro.core import registry
from repro.data import load
from repro.trees import RandomForest, RandomForestConfig

FAILED = []


def _check(label: str, err: float, tol: float) -> None:
    ok = err < tol
    print(f"{label:24s} max_err={err:.2e} {'OK' if ok else 'FAIL'}")
    if not ok:
        FAILED.append(label)


def check_engines(ds, forest, qf, X):
    oracle = forest.predict_oracle(X)
    for engine in registry.engines("jax"):
        pred = core.compile_forest(forest, engine=engine)
        _check(engine, np.abs(pred.predict(X) - oracle).max(), 1e-5)

    # scalar faithful QS (Algorithm 1 with early break)
    sc = core.eval_scalar_numpy(forest, X[:8])
    _check("scalar-QS", np.abs(sc - oracle[:8]).max(), 1e-5)

    # quantized
    oq = qf.predict_oracle(core.quantize_inputs(qf, X)) / core.leaf_scale(qf)
    for engine in registry.engines("jax"):
        pred = core.compile_forest(qf, engine=engine)
        _check(f"q-{engine}", np.abs(pred.predict(X) - oq).max(), 1e-4)

    acc_f = (core.compile_forest(forest).predict_class(ds.X_test)
             == ds.y_test).mean()
    acc_q = (core.compile_forest(qf).predict_class(ds.X_test)
             == ds.y_test).mean()
    print(f"accuracy float={acc_f:.4f} quant={acc_q:.4f}")


def check_cascade(ds, qf, X, engine="bitvector"):
    """Cascade smoke: one engine end-to-end through the staged path."""
    from repro.cascade import calibrate, CascadeSpec, MarginGate
    base = core.compile_forest(qf, engine=engine)
    stages = (max(qf.n_trees // 4, 1), qf.n_trees)

    # gate disabled → bit-exact with the base engine on the quantized IR
    off = core.compile_forest(qf, engine=engine, cascade=CascadeSpec(
        stages=stages, policy=MarginGate(np.inf)))
    err = float(np.abs(off.predict(X) - base.predict(X)).max())
    _check(f"cascade-off-{engine}", err, 1e-12)

    # calibrated gate: accuracy within the floor, some rows exit early
    casc = core.compile_forest(qf, engine=engine,
                               cascade=CascadeSpec(stages=stages))
    n_cal = len(ds.X_test) // 2
    cal = calibrate(casc, ds.X_test[:n_cal], ds.y_test[:n_cal],
                    floor_pp=0.5)
    casc.set_policy(cal.policy)
    casc.reset_exit_stats()
    acc_full = (base.predict_class(ds.X_test[n_cal:])
                == ds.y_test[n_cal:]).mean()
    acc_casc = (casc.predict_class(ds.X_test[n_cal:])
                == ds.y_test[n_cal:]).mean()
    fr = casc.exit_fractions
    print(f"cascade {engine} plan: {casc.plan.describe()}")
    print(f"cascade policy={casc.policy.tag()} "
          f"exit_fractions={np.round(fr, 3).tolist()} "
          f"mean_trees={casc.mean_trees_evaluated:.1f}/{qf.n_trees}")
    print(f"cascade accuracy full={acc_full:.4f} gated={acc_casc:.4f}")
    drop_pp = (acc_full - acc_casc) * 100.0
    _check(f"cascade-acc-{engine}", max(drop_pp, 0.0), 1.0)
    if abs(float(fr.sum()) - 1.0) > 1e-9:
        print(f"cascade-exit-accounting FAIL: fractions sum to {fr.sum()}")
        FAILED.append("cascade-exit-accounting")


def check_cascade_fused(ds, qf, X):
    """Fused-execution smoke: fused must be bit-exact with the staged
    loop (scores AND per-stage exit counts) on the quantized forest —
    every jax engine, plus the single-kernel Pallas tier (interpret
    mode, a few rows: interpret is slow)."""
    from repro.cascade import (CascadePredictor, CascadeSpec,
                               FusedCascadePredictor, MarginGate)
    spec = CascadeSpec(stages=(max(qf.n_trees // 4, 1), qf.n_trees),
                       policy=MarginGate(0.5))
    fspec = CascadeSpec(stages=spec.stages, policy=spec.policy,
                        fused=True)
    for engine in registry.engines("jax"):
        staged = CascadePredictor(qf, spec, engine=engine)
        fused = core.compile_forest(qf, engine=engine, cascade=fspec)
        assert isinstance(fused, FusedCascadePredictor)
        err = float(np.abs(fused.predict(X) - staged.predict(X)).max())
        if not np.array_equal(fused.last_exit_counts,
                              staged.last_exit_counts):
            err = np.inf         # exit-count drift is a hard FAIL too
        _check(f"fused-{engine}", err, 1e-12)
    staged = CascadePredictor(qf, spec, engine="bitvector")
    fused = FusedCascadePredictor(qf, fspec, engine="bitvector",
                                  backend="pallas",
                                  engine_kw={"interpret": True})
    err = float(np.abs(fused.predict(X[:8]) - staged.predict(X[:8])).max())
    if not np.array_equal(fused.last_exit_counts, staged.last_exit_counts):
        err = np.inf
    _check("fused-pallas-kernel", err, 1e-12)
    print(f"fused host_syncs={fused.host_syncs} "
          f"(staged: {staged.host_syncs})")


def check_optimize(forest, qf, X):
    """Optimizer smoke: every registered engine × -O2 agrees with -O0
    (the acceptance invariant of the optimizer middle-end)."""
    from repro import optim
    res = optim.optimize(qf, 2)
    print(f"optimizer -O2 on quantized forest: {res.describe()}")
    for engine in registry.engines("jax"):
        o0 = core.compile_forest(forest, engine=engine)
        o2 = core.compile_forest(forest, engine=engine, opt=2)
        _check(f"O2-float-{engine}",
               float(np.abs(o2.predict(X) - o0.predict(X)).max()), 1e-4)
        q0 = core.compile_forest(qf, engine=engine)
        q2 = core.compile_forest(qf, engine=engine, opt=2)
        _check(f"O2-quant-{engine}",          # bit-exact: integer sums
               float(np.abs(q2.predict(X) - q0.predict(X)).max()), 1e-12)
    # Pallas backends in interpret mode, a few rows (interpret is slow)
    for spec in registry.specs("pallas"):
        p0 = core.compile_forest(qf, engine=spec.name, backend="pallas",
                                 interpret=True)
        p2 = core.compile_forest(qf, engine=spec.name, backend="pallas",
                                 interpret=True, opt=2)
        _check(f"O2-{spec.tune_name}",
               float(np.abs(p2.predict(X[:8]) - p0.predict(X[:8])).max()),
               1e-12)


def check_int(ds, forest, X):
    """Integer end-to-end smoke (docs/QUANT.md): int-accum bit-exactness
    vs the quantized oracle, FLInt == float engines, int-gate cascade
    class-exact."""
    from repro.cascade import CascadeSpec, ScoreBoundGate
    from repro.core.pipeline import CompilePlan, compile_plan
    from repro.core.quantize import QuantSpec, accum_bits

    qi = core.quantize_forest(forest, ds.X_train,
                              spec=QuantSpec(int_accum=True))
    print(f"int-accum: acc_bits={accum_bits(qi)} "
          f"err_bound={qi.leaf_err_bound:g}")
    oracle = (qi.predict_oracle(core.quantize_inputs(qi, X))
              / core.leaf_scale(qi)).astype(np.float32)
    for engine in registry.engines("jax"):
        pred = core.compile_forest(qi, engine=engine)
        err = 0.0 if np.array_equal(pred.predict(X), oracle) else np.inf
        _check(f"int-{engine}", err, 1e-12)
    for spec in registry.specs("pallas"):
        pred = core.compile_forest(qi, engine=spec.name, backend="pallas",
                                   interpret=True)
        err = 0.0 if np.array_equal(pred.predict(X[:8]), oracle[:8]) \
            else np.inf
        _check(f"int-{spec.tune_name}", err, 1e-12)

    # FLInt: integer compares must reproduce the float engines exactly
    for engine in registry.engines("jax"):
        ref = core.compile_forest(forest, engine=engine).predict(X)
        fl = compile_plan(forest, CompilePlan(engine=engine, flint=True))
        err = 0.0 if np.array_equal(fl.predict(X), ref) else np.inf
        _check(f"flint-{engine}", err, 1e-12)

    # int-gate cascade: exact integer suffix bounds, class-exact at slack 0
    base = core.compile_forest(qi, engine="bitvector")
    casc = core.compile_forest(qi, engine="bitvector", cascade=CascadeSpec(
        stages=(max(qi.n_trees // 4, 1), qi.n_trees),
        policy=ScoreBoundGate()))
    same = np.array_equal(casc.predict_class(ds.X_test),
                          base.predict_class(ds.X_test))
    _check("int-cascade-gate", 0.0 if same else np.inf, 1e-12)


def check_serving(ds, qf, X):
    """Serving-runtime smoke (docs/SERVING.md acceptance invariants):
    warmup bit-identity, served == synchronous predict per engine and
    for a cascade tenant, controller bounds under adversarial input."""
    from repro.cascade import CascadePredictor, CascadeSpec, MarginGate
    from repro.inference import (AdaptiveBatchController, ServingRuntime,
                                 SLOConfig)

    # 1. warmup leaves predictions bit-identical (zeros never leak)
    for engine in registry.engines("jax"):
        pred = core.compile_forest(qf, engine=engine)
        before = pred.predict(X)
        rt = ServingRuntime()
        rt.add_model("m", pred, max_batch=32)
        rt.warmup()
        err = float(np.abs(pred.predict(X) - before).max())
        _check(f"serve-warm-{engine}", err, 1e-12)

    # 2. served scores == synchronous predict (odd batches → padding)
    for engine in registry.engines("jax"):
        pred = core.compile_forest(qf, engine=engine)
        direct = pred.predict(X)
        rt = ServingRuntime()
        rt.add_model("m", pred, max_batch=7, max_wait_ms=1.0)
        rt.warmup()
        reqs = [rt.submit("m", X[i], arrival_s=i * 1e-4)
                for i in range(len(X))]
        rt.flush(now_s=1.0)
        got = np.stack([r.result for r in reqs])
        _check(f"serve-{engine}", float(np.abs(got - direct).max()), 1e-12)

    # 3. cascade tenant: scores + exit accounting intact through serving
    spec = CascadeSpec(stages=(max(qf.n_trees // 4, 1), qf.n_trees),
                       policy=MarginGate(0.5))
    ref = CascadePredictor(qf, spec, engine="bitvector")
    served = CascadePredictor(qf, spec, engine="bitvector")
    direct = ref.predict(X)
    rt = ServingRuntime()
    rt.add_model("casc", served, max_batch=len(X), max_wait_ms=1.0)
    rt.warmup()
    reqs = [rt.submit("casc", X[i], arrival_s=0.0) for i in range(len(X))]
    rt.flush(now_s=1.0)
    got = np.stack([r.result for r in reqs])
    err = float(np.abs(got - direct).max())
    if served.exit_counts.sum() != len(X) or \
            not np.array_equal(served.exit_counts, ref.exit_counts):
        err = np.inf             # accounting drift is a hard FAIL too
    _check("serve-cascade-exits", err, 1e-12)

    # 4. controller bounds under adversarial latency streams
    slo = SLOConfig(target_p99_ms=5.0, window=4, min_batch=2,
                    max_batch=128, min_wait_ms=0.25, max_wait_ms=16.0)
    c = AdaptiveBatchController(slo, batch=64, wait_ms=8.0)
    rng = np.random.default_rng(0)
    streams = [np.full(400, 1e6), np.full(400, 0.0),
               rng.exponential(5.0, size=400),
               np.tile([0.0, 1e6], 200)]           # oscillation attack
    worst = 0.0
    for s in streams:
        for v in s:
            c.observe(float(v))
            worst = max(worst,
                        slo.min_batch - c.max_batch,
                        c.max_batch - slo.max_batch,
                        slo.min_wait_ms - c.max_wait_ms,
                        c.max_wait_ms - slo.max_wait_ms)
    _check("serve-slo-bounds", worst, 1e-12)


def check_obs(ds, qf, X):
    """Observability smoke (docs/OBSERVABILITY.md acceptance): bit-exact
    serving with full instrumentation on, a live scrape covering the
    whole metric catalog, parseable JSON, zero retrace anomalies."""
    import json
    import re
    import urllib.request

    from repro.cascade import CascadePredictor, CascadeSpec, MarginGate
    from repro.inference import ServingRuntime
    from repro.obs import METRIC_CATALOG, MetricsRegistry

    pred = core.compile_forest(qf, engine="bitvector")
    direct = pred.predict(X)
    spec = CascadeSpec(stages=(max(qf.n_trees // 4, 1), qf.n_trees),
                       policy=MarginGate(0.5), fused=True)
    casc = core.compile_forest(qf, engine="bitvector", cascade=spec)
    casc_direct = CascadePredictor(
        qf, CascadeSpec(stages=spec.stages, policy=spec.policy),
        engine="bitvector").predict(X)

    rt = ServingRuntime(obs=MetricsRegistry())   # isolated registry
    rt.add_model("m", pred, max_batch=7, max_wait_ms=0.5)
    rt.add_model("casc", casc, max_batch=len(X), max_wait_ms=0.5)
    rt.warmup()
    with rt:
        url = rt.serve_metrics().url
        reqs = [rt.submit("m", X[i]) for i in range(len(X))]
        creqs = [rt.submit("casc", X[i]) for i in range(len(X))]
        for r in reqs + creqs:
            r.wait(timeout=120)
        got = np.stack([r.result for r in reqs])
        cgot = np.stack([r.result for r in creqs])
        with urllib.request.urlopen(url + "/metrics", timeout=10) as resp:
            text = resp.read().decode()
        with urllib.request.urlopen(url + "/metrics.json",
                                    timeout=10) as resp:
            snap = json.loads(resp.read().decode())
        with urllib.request.urlopen(url + "/traces?n=8",
                                    timeout=10) as resp:
            traces = json.loads(resp.read().decode())

    # served == synchronous, bit-exact, with everything instrumented
    _check("obs-serve-bitexact", float(np.abs(got - direct).max()), 1e-12)
    _check("obs-serve-cascade", float(np.abs(cgot - casc_direct).max()),
           1e-12)

    # the scrape must expose every catalog metric, every line well-formed
    missing = [n for n in METRIC_CATALOG if f"# TYPE {n} " not in text]
    _check("obs-scrape-catalog", float(len(missing)), 1)
    line_re = re.compile(
        r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? -?[0-9.einfa+-]+$")
    bad = [ln for ln in text.splitlines()
           if ln and not ln.startswith("#") and not line_re.match(ln)]
    _check("obs-scrape-wellformed", float(len(bad)), 1)

    stats = snap.get("stats", {})
    ok_json = "metrics" in snap and "m" in stats and "casc" in stats
    _check("obs-json-snapshot", 0.0 if ok_json else np.inf, 1e-12)

    # the warmup contract, live: no post-warmup trace on either tenant
    anomalies = sum(s.get("retrace_anomalies", 0) for s in stats.values())
    _check("obs-zero-retrace", float(anomalies), 1e-12)
    ok_traces = len(traces) == 8 and all("phases" in t for t in traces)
    _check("obs-traces", 0.0 if ok_traces else np.inf, 1e-12)
    compiles = {tid: s.get("compile_events") for tid, s in stats.items()}
    n_series = sum(1 for ln in text.splitlines()
                   if ln and not ln.startswith("#"))
    print(f"obs: {len(METRIC_CATALOG)} catalog metrics / {n_series} "
          f"series scraped, compile_events={compiles}, "
          f"retrace_anomalies={anomalies}")


def check_os(ds, qf, X):
    """Zero-shot compilation smoke (docs/AUTOTUNE.md acceptance): train
    a cost model from a few measured sweeps, then (1) the predict path
    returns a plan bit-exact with compiling that plan directly, (2) the
    low-confidence fallback's narrow sweep agrees with the full sweep
    restricted to its top-k set, (3) a fleet cold-starts under ``-Os``
    and survives a manifest save/load round trip bit-identically."""
    import tempfile

    from repro import tune
    from repro.core import engine_select
    from repro.inference import ServingRuntime

    engines = ("qs", "qs-bitmm", "native")
    with tempfile.TemporaryDirectory() as td:
        cache = os.path.join(td, "cache.json")
        engine_select.clear_cache()
        shapes = [(8, 16, 6), (16, 16, 8), (24, 32, 10), (12, 8, 6)]
        for i, (T, L, d) in enumerate(shapes):
            f = core.random_forest_ir(T, L, d, n_classes=1, seed=i)
            engine_select.choose(f, 64, engines=engines,
                                 cache_path=cache, repeats=1)
        model_path = os.path.join(td, "model.json")
        model = tune.train_from_cache(cache, save_to=model_path)
        print(f"-Os cost model: {model.n_rows} rows, "
              f"sigma={model.resid_sigma:.3f}")
        engine_select.clear_cache()

        # 1. predict path: zero-shot plan, bit-exact vs direct compile
        held = core.random_forest_ir(10, 16, 7, n_classes=1, seed=99)
        Xh = np.random.default_rng(0).normal(size=(64, held.n_features))
        c = engine_select.choose(held, 64, engines=engines,
                                 cache_path=cache, mode="predict",
                                 cost_model=model_path,
                                 confidence_threshold=0.0, repeats=1)
        direct = engine_select._candidate_factories(
            held, engines, None, None, 1)[c.engine]()
        err = float(np.abs(c.predictor.predict(Xh)
                           - direct.predict(Xh)).max())
        if not c.predicted:
            err = np.inf
        print(f"-Os predict: winner={c.engine} "
              f"confidence={c.confidence:.3f}")
        _check("os-predict-bitexact", err, 1e-12)

        # 2. fallback path: narrow top-k sweep == restricted full sweep
        engine_select.clear_cache()
        fb_cache = os.path.join(td, "fb.json")
        fb = engine_select.choose(held, 64, engines=engines,
                                  cache_path=fb_cache, mode="predict",
                                  cost_model=model_path,
                                  confidence_threshold=1.01, top_k=2,
                                  repeats=1)
        full = engine_select.choose(held, 64, engines=engines,
                                    cache_path=fb_cache, repeats=1)
        restricted = {n: full.timings[n] for n in fb.timings}
        ok = (not fb.predicted and len(fb.timings) == 2
              and fb.engine == min(restricted, key=restricted.get))
        print(f"-Os fallback: swept {sorted(fb.timings)} → {fb.engine}")
        _check("os-fallback-topk", 0.0 if ok else np.inf, 1e-12)

        # 3. fleet cold-start under -Os + manifest round trip
        engine_select.clear_cache()
        # shapes disjoint from the training sweeps: a cache hit would
        # (correctly) bypass the model, which isn't what we're checking
        forests = {f"t{i}": core.random_forest_ir(
            9 + 2 * i, 16, 6 + i % 3, n_classes=1, seed=50 + i)
            for i in range(4)}
        rt = ServingRuntime.from_forests(
            forests, max_batch=64, tune="predict", engines=engines,
            cost_model=model_path, confidence_threshold=0.0,
            cache_path=cache, repeats=1)
        n_pred = sum(1 for tid in forests
                     if rt.tenant(tid).engine_choice.predicted)
        print(f"-Os fleet: {n_pred}/{len(forests)} tenants zero-shot")
        _check("os-fleet-zeroshot", float(len(forests) - n_pred), 1e-12)
        manifest = rt.save(os.path.join(td, "fleet"))
        rt2 = ServingRuntime.load(manifest)
        worst = 0.0
        for tid, f in forests.items():
            Xt = np.random.default_rng(7).normal(size=(16, f.n_features))
            a = rt.tenant(tid).predictor.predict(Xt)
            b = rt2.tenant(tid).predictor.predict(Xt)
            worst = max(worst, float(np.abs(a - b).max()))
        _check("os-manifest-roundtrip", worst, 1e-12)
        engine_select.clear_cache()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cascade", action="store_true",
                    help="also smoke the cascade subsystem end-to-end")
    ap.add_argument("--cascade-fused", action="store_true",
                    help="also check fused execution against the "
                         "staged loop (scores + exit counts)")
    ap.add_argument("--optimize", action="store_true",
                    help="also check every engine × -O2 against -O0")
    ap.add_argument("--serving", action="store_true",
                    help="also check the concurrent serving runtime")
    ap.add_argument("--int", action="store_true", dest="int_paths",
                    help="also check int-accum / FLInt bit-exactness "
                         "and the exact-integer cascade gate")
    ap.add_argument("--obs", action="store_true",
                    help="also check the observability layer (bit-exact "
                         "instrumented serving, live scrape, zero "
                         "retrace anomalies)")
    ap.add_argument("--os", action="store_true", dest="os_mode",
                    help="also check zero-shot compilation: cost-model "
                         "predict path, low-confidence fallback, and "
                         "-Os fleet cold-start + manifest round trip")
    args = ap.parse_args(argv)

    ds = load("magic", n=2000)
    rf = RandomForest(RandomForestConfig(
        n_trees=24, max_leaves=32, max_samples=512)).fit(ds.X_train,
                                                         ds.y_train)
    forest = core.from_random_forest(rf)
    qf = core.quantize_forest(forest, ds.X_train)
    X = ds.X_test[:64]

    check_engines(ds, forest, qf, X)
    if args.cascade:
        check_cascade(ds, qf, X)
    if args.cascade_fused:
        check_cascade_fused(ds, qf, X)
    if args.optimize:
        check_optimize(forest, qf, X)
    if args.serving:
        check_serving(ds, qf, X)
    if args.int_paths:
        check_int(ds, forest, X)
    if args.obs:
        check_obs(ds, qf, X)
    if args.os_mode:
        check_os(ds, qf, X)
    if FAILED:
        print(f"\nFAILED: {FAILED}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
