"""Dev sanity check: all registered engines vs the traversal oracle.

The engine list comes from ``core.registry`` — a newly registered engine
shows up here (and in the benchmarks and the agreement tests) with no
edits to this file.
"""
import numpy as np

from repro import core
from repro.core import registry
from repro.data import load
from repro.trees import RandomForest, RandomForestConfig

ds = load("magic", n=2000)
rf = RandomForest(RandomForestConfig(n_trees=24, max_leaves=32,
                                     max_samples=512)).fit(ds.X_train, ds.y_train)
forest = core.from_random_forest(rf)
X = ds.X_test[:64]
oracle = forest.predict_oracle(X)

for engine in registry.engines("jax"):
    pred = core.compile_forest(forest, engine=engine)
    got = pred.predict(X)
    err = np.abs(got - oracle).max()
    print(f"{engine:12s} max_err={err:.2e} {'OK' if err < 1e-5 else 'FAIL'}")

# scalar faithful QS (Algorithm 1 with early break)
sc = core.eval_scalar_numpy(forest, X[:8])
print(f"{'scalar-QS':12s} max_err={np.abs(sc - oracle[:8]).max():.2e}")

# quantized
qf = core.quantize_forest(forest, ds.X_train)
oq = qf.predict_oracle(core.quantize_inputs(qf, X)) / core.leaf_scale(qf)
for engine in registry.engines("jax"):
    pred = core.compile_forest(qf, engine=engine)
    got = pred.predict(X)
    err = np.abs(got - oq).max()
    print(f"q-{engine:10s} max_err={err:.2e} {'OK' if err < 1e-4 else 'FAIL'}")

acc_f = (core.compile_forest(forest).predict_class(ds.X_test) == ds.y_test).mean()
acc_q = (core.compile_forest(qf).predict_class(ds.X_test) == ds.y_test).mean()
print(f"accuracy float={acc_f:.4f} quant={acc_q:.4f}")
