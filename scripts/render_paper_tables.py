"""Render the §Table2-5/§Fig1 sections of EXPERIMENTS.md from the CSVs in
experiments/bench/ (run after `python -m benchmarks.run`). Prints markdown;
`--insert` replaces the `<!-- PAPER_TABLES -->` marker in EXPERIMENTS.md.
"""
import csv
import json
import os
import sys

BENCH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "bench")


def md_table(path):
    with open(path) as f:
        rows = list(csv.reader(f))
    out = ["| " + " | ".join(rows[0]) + " |",
           "|" + "---|" * len(rows[0])]
    for r in rows[1:]:
        out.append("| " + " | ".join(r) + " |")
    return "\n".join(out)


def render() -> str:
    s = []
    s.append("""
## Paper reproduction tables

CPU wall-clock on this container is a *relative* comparison between
XLA-compiled traversal programs (the paper's absolute numbers are
ARM-specific); the engine names map QS/VQS→bitvector, RS→rapidscorer,
NA→native, IE→unrolled per DESIGN.md §2. Forest training uses the
framework's own histogram-CART substrate on the offline dataset stand-ins
(DESIGN.md §5), so accuracy *deltas* are the reproduced quantity, not
absolute accuracies.

### §Table2 — ranking traversal runtime (µs/instance, GBT on MSN-shaped data)
""")
    s.append(md_table(os.path.join(BENCH, "table2_ranking.csv")))
    s.append("\nquantized (int16) variants:\n")
    s.append(md_table(os.path.join(BENCH, "table2_ranking_quant.csv")))
    s.append("""
trained-GBT vs synthetic-forest timing anchor (identical (T, L, d) —
NATIVE's gap is depth: leaf-wise trained trees are deeper than balanced
synthetic ones, and NATIVE cost ∝ depth):
""")
    s.append(md_table(os.path.join(BENCH, "table2_trained_anchor.csv")))
    s.append("""
Findings vs the paper: on ARM the bitvector engines beat NATIVE (paper
Table 2: RS up to 5.8×); on CPU-executed XLA the ranking *inverts* —
NATIVE/IF-ELSE win, and the gap widens with leaf count (L=64 doubles the
bitvector word count W, so predication does O(T·N·W) work vs NATIVE's
O(T·depth) gathers; compare the 32- vs 64-leaf rows). Predication only
approaches NATIVE where trees are deep (trained leaf-wise forests — the
anchor table: QS 30 µs vs NA 51 µs at depth 18) or forests are small
at large batch (REPRO_BENCH_SCALE=quick). `unrolled` (IF-ELSE) beyond
1000 trees is compile-bound — the paper's IF-ELSE codegen-scaling wall,
reproduced in XLA. The device-dependence of the winner IS the paper's
headline conclusion, re-confirmed on a third device class. The TPU-target
numbers (the point of this framework) are in §Perf: tiled-bitvector
wins by 240×.

### §Table3 — quantization accuracy (paper Table 3)
""")
    s.append(md_table(os.path.join(BENCH, "table3_quant_accuracy.csv")))
    s.append("""
Reproduces the paper's claim structurally: quantization is accuracy-free
everywhere except EEG-like heavy-tailed features, where *split*
quantization compresses the physiological bulk onto ~20 fixed-point
levels. On the synthetic stand-in the accuracy cost shows at small
ensemble capacity (64 trees: −3.7pp, paper: −4.1pp at 1024 trees;
REPRO_BENCH_SCALE=quick) and washes out as trees are added — synthetic
clusters stay separable on a coarse grid where real EEG does not. The
*mechanism* — unique-threshold collapse — reproduces at every scale
(§Table4 below: 9.0% → 2.2% unique nodes under quantization at T=128),
and leaf quantization is free at every scale, both as the paper claims.

### §Table4 — unique nodes kept after RapidScorer merging (paper Table 4)
""")
    s.append(md_table(os.path.join(BENCH, "table4_merging.csv")))
    s.append("""
Reproduces both of the paper's effects: (a) merging rates fall with tree
count; (b) float≡quant everywhere except EEG, where quantization collapses
unique thresholds (paper: 19.4%→8.4% at T=1024; here 9.0%→2.2% at
T=128 and 5.0%→1.1% at T=256) — the mechanism behind the Table-3
accuracy effect. Adult's extreme merging rate (paper: 12.1% at T=128;
here 6.5%) also reproduces: one-hot features admit few distinct
thresholds.

### §Table5 — classification traversal runtime (µs/instance, RF)
""")
    s.append(md_table(os.path.join(BENCH, "table5_classification_us.csv")))
    s.append("\nspeedups vs float NATIVE (paper's convention):\n")
    s.append(md_table(os.path.join(BENCH,
                                   "table5_classification_speedup.csv")))
    s.append("""
### §Fig1 — speedup vs tree count (avg over leaf counts)
""")
    s.append(md_table(os.path.join(BENCH, "fig1_speedup.csv")))

    rf = os.path.join(BENCH, "roofline_forest.json")
    if os.path.exists(rf):
        rows = json.load(open(rf))
        s.append("""
### Forest-engine TPU roofline (see §Perf for analysis)

| config | engine | dominant | ns/inst or µs/batch |
|---|---|---|---|""")
        for r in rows:
            metr = r.get("ns_per_instance_roofline")
            metr = (f"{metr} ns/inst" if metr is not None
                    else f"{r.get('us_batch_latency_roofline')} µs/batch")
            s.append(f"| {r['config']} | {r['engine']} "
                     f"| {r['dominant'].replace('_s','')} | {metr} |")
    return "\n".join(s)


def main():
    text = render()
    if "--insert" in sys.argv:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "EXPERIMENTS.md")
        content = open(path).read()
        assert "<!-- PAPER_TABLES -->" in content, "marker missing"
        open(path, "w").write(
            content.replace("<!-- PAPER_TABLES -->", text))
        print("inserted into EXPERIMENTS.md")
    else:
        print(text)


if __name__ == "__main__":
    main()
