"""Concurrent multi-tenant serving runtime — the production front door.

``ForestServer`` is a synchronous submit/poll loop around one predictor:
correct, deterministic, and exactly what benchmarks and tests want — but
a real deployment has concurrent callers, several models hot at once,
a latency SLO, and no tolerance for a first request that eats an XLA
compile.  ``ServingRuntime`` turns the existing parts (``MicroBatcher``,
``ServerStats``, packed artifacts, the autotuner) into that front door:

  * **Threaded request loop** — ``submit(model_id, x)`` is thread-safe
    and returns a future-backed ``ServedRequest``; a single worker
    thread drains the lock-guarded per-tenant queues into batches when
    the dispatch rule fires.  Every request is completed exactly once —
    including on shutdown, where ``close()`` flushes all queues before
    the worker exits (no request is ever dropped or double-resolved).
  * **Multi-model tenancy** — N forests hot in one process, routed by
    model id.  Tenants share the process-wide engine/autotune cache
    (``from_forests`` sweeps through ``core.engine_select.choose``) and
    cold-start from packed ``.repro.npz`` artifacts via a JSON manifest
    (``save``/``load``, ``io.packed.save_manifest``).
  * **SLO-aware adaptive batching** — ``SLOConfig(target_p99_ms=...)``
    attaches an ``AdaptiveBatchController`` per tenant: the observed
    p99 over a sliding window grows or shrinks the *effective*
    ``max_batch``/``max_wait_ms`` multiplicatively, always clamped to
    the configured bounds.  The controller is a pure function of the
    observed latency sequence — no internal clock — so it is
    deterministic under the virtual-clock test contract.
  * **Shape warmup** — ``warmup()`` pre-traces every power-of-two batch
    bucket a tenant can be served at (``core.engine_select
    .bucket_ladder``), including the fused cascade's internally-bucketed
    shapes, so no live request ever pays a trace/compile.  Dispatch pads
    plain-engine batches to the same buckets (row-independent engines:
    the padded rows change nothing — conformance-tested bit-exact), so
    the warmed shapes are the *only* shapes the engines ever see.

Two execution modes share all of the above:

  * ``start()``/``close()`` — the background worker thread on the real
    (monotonic) clock; production and the load benchmark.
  * ``pump(now_s)``/``flush(now_s)`` — manual dispatch on a caller
    clock; deterministic tests drive virtual time through the same
    batching, stats, and controller code the thread runs.

See docs/SERVING.md for the architecture and the warmup contract.
"""
from __future__ import annotations

import dataclasses
import os
import re
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from ..core.engine_select import bucket_batch, bucket_ladder
from ..obs.log import get_logger
from ..obs.metrics import MetricsRegistry, get_registry
from ..obs.retrace import CompileWatch
from ..obs.serving import ServingMetrics
from ..obs.trace import Span
from .server import MicroBatcher, Request, ServerStats

_LOG = get_logger("serving")


# --------------------------------------------------------------------------- #
# SLO-aware adaptive batching
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class SLOConfig:
    """Latency budget + controller bounds for one tenant.

    ``target_p99_ms`` is the budget; the controller keeps the effective
    ``max_batch``/``max_wait_ms`` inside ``[min_batch, max_batch]`` ×
    ``[min_wait_ms, max_wait_ms]`` (``None`` bounds default to the
    tenant's configured values at attach time).  ``window`` completed
    requests feed one control decision; ``headroom`` is the fraction of
    the budget below which the controller grows (between ``headroom *
    target`` and ``target`` it holds, avoiding oscillation around the
    budget)."""
    target_p99_ms: float
    window: int = 64
    min_batch: int = 1
    max_batch: Optional[int] = None
    min_wait_ms: float = 0.0
    max_wait_ms: Optional[float] = None
    grow: float = 1.25
    shrink: float = 0.5
    headroom: float = 0.7

    def to_header(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_header(cls, d: dict) -> "SLOConfig":
        return cls(**d)


class AdaptiveBatchController:
    """Bounded grow/shrink controller over (max_batch, max_wait_ms).

    Feed it every completed request's latency via ``observe``; each full
    window of ``slo.window`` observations closes with one decision:

      * window p99 > target           → **shrink** both knobs (×
        ``slo.shrink``, clamped to the lower bounds) — the batcher
        dispatches sooner and smaller, trading throughput for latency;
      * window p99 < headroom·target  → **grow** both knobs (×
        ``slo.grow``, clamped to the upper bounds) — spare budget is
        spent on bigger batches;
      * otherwise                     → **hold**.

    The decision is a pure function of the observed latencies (no
    clock, no randomness), so a virtual-clock test replaying a latency
    trace gets bit-identical decisions.  The effective values can never
    leave the configured bounds — ``scripts/check_engines.py --serving``
    hammers this with adversarial latency streams."""

    #: decisions retained for inspection (bounded, like the stats)
    HISTORY = 256

    def __init__(self, slo: SLOConfig, batch: int, wait_ms: float):
        self.slo = slo
        self.min_batch = max(1, int(slo.min_batch))
        self.max_batch_bound = int(slo.max_batch if slo.max_batch
                                   is not None else batch)
        self.min_wait_ms = float(slo.min_wait_ms)
        self.max_wait_ms_bound = float(slo.max_wait_ms if slo.max_wait_ms
                                       is not None else wait_ms)
        if self.max_batch_bound < self.min_batch:
            raise ValueError(f"SLO batch bounds empty: "
                             f"[{self.min_batch}, {self.max_batch_bound}]")
        if self.max_wait_ms_bound < self.min_wait_ms:
            raise ValueError(
                f"SLO wait bounds empty: "
                f"[{self.min_wait_ms}, {self.max_wait_ms_bound}]")
        self.max_batch = self._clamp_batch(batch)
        self.max_wait_ms = self._clamp_wait(wait_ms)
        self._window: list[float] = []
        self.decisions: list[dict] = []

    def _clamp_batch(self, b) -> int:
        return int(min(max(int(b), self.min_batch), self.max_batch_bound))

    def _clamp_wait(self, w) -> float:
        return float(min(max(float(w), self.min_wait_ms),
                         self.max_wait_ms_bound))

    def observe(self, latency_ms: Optional[float]) -> Optional[dict]:
        """Record one completed latency; returns the decision record when
        this observation closes a window, else ``None``."""
        if latency_ms is None:
            return None
        self._window.append(float(latency_ms))
        if len(self._window) < self.slo.window:
            return None
        p99 = float(np.percentile(self._window, 99))
        self._window = []
        target = self.slo.target_p99_ms
        if p99 > target:
            action = "shrink"
            self.max_batch = self._clamp_batch(
                self.max_batch * self.slo.shrink)
            self.max_wait_ms = self._clamp_wait(
                self.max_wait_ms * self.slo.shrink)
        elif p99 < self.slo.headroom * target:
            action = "grow"
            # a zero wait can't grow multiplicatively — seed it with the
            # smaller of half a millisecond and the upper bound
            grown = self.max_wait_ms * self.slo.grow \
                if self.max_wait_ms > 0 \
                else min(0.5, self.max_wait_ms_bound)
            self.max_batch = self._clamp_batch(
                max(self.max_batch + 1, self.max_batch * self.slo.grow))
            self.max_wait_ms = self._clamp_wait(grown)
        else:
            action = "hold"
        rec = {"p99_ms": p99, "target_ms": target, "action": action,
               "max_batch": self.max_batch,
               "max_wait_ms": self.max_wait_ms}
        self.decisions.append(rec)
        del self.decisions[:-self.HISTORY]
        return rec


# --------------------------------------------------------------------------- #
# Requests / tenants
# --------------------------------------------------------------------------- #
@dataclass
class ServedRequest(Request):
    """A ``Request`` routed to a tenant, with a thread-safe future the
    submitting thread can block on (``wait``).  When observability is on
    the worker attaches a ``repro.obs.trace.Span`` (phase breakdown)
    before resolving the future."""
    tenant: str = ""
    future: Future = field(default_factory=Future)
    span: Optional[Span] = None

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until the worker resolves this request; returns the
        score row (or re-raises the batch's exception)."""
        return self.future.result(timeout)


_MODEL_ID = re.compile(r"^[A-Za-z0-9._-]+$")


def _pads_to_bucket(pred) -> bool:
    """Whether dispatch may zero-pad this predictor's batches up to the
    power-of-two bucket.  Padding is safe exactly when the predictor is
    row-independent *and* does not account per-row statistics: cascade
    predictors count per-row exits (a padded row would pollute
    ``exit_fractions``) and bucket internally anyway; Pallas predictors
    (``block_b``) bucket internally too.  Everything else — the plain
    ``BasePredictor`` engines and the tree-sharded wrapper — retraces
    per batch shape, so padding is what makes warmup's bucket ladder
    cover every live shape."""
    if hasattr(pred, "last_exit_counts"):     # cascade: exit accounting
        return False
    if hasattr(pred, "block_b"):              # Pallas: internal bucketing
        return False
    return True


class _Tenant:
    """One hot model: predictor + batcher + stats (+ controller)."""

    def __init__(self, model_id: str, predictor, max_batch: int,
                 max_wait_ms: float, slo: Optional[SLOConfig]):
        self.model_id = model_id
        self.predictor = predictor
        self.cfg_max_batch = int(max_batch)       # configured (manifest)
        self.cfg_max_wait_ms = float(max_wait_ms)
        self.batcher = MicroBatcher(max_batch, max_wait_ms)
        self.stats = ServerStats()
        self.controller = AdaptiveBatchController(slo, max_batch,
                                                  max_wait_ms) \
            if slo is not None else None
        if self.controller is not None:
            # start at the controller's clamped effective values
            self.batcher.max_batch = self.controller.max_batch
            self.batcher.max_wait_ms = self.controller.max_wait_ms
        self.pad_buckets = _pads_to_bucket(predictor)
        self.warmed: tuple = ()
        self.engine_choice = None                 # set by from_forests()
        self.watch: Optional[CompileWatch] = None  # set by add_model()

    @property
    def hard_max_batch(self) -> int:
        """The largest batch dispatch can ever emit — the controller's
        upper bound when adaptive (growth must never hit a cold shape),
        the configured cap otherwise.  Warmup pre-traces up to this."""
        if self.controller is not None:
            return self.controller.max_batch_bound
        return self.batcher.max_batch

    def summary(self) -> dict:
        out = self.stats.summary()
        out["effective_max_batch"] = self.batcher.max_batch
        out["effective_max_wait_ms"] = self.batcher.max_wait_ms
        out["adaptive"] = self.controller is not None
        out["warmed_buckets"] = list(self.warmed)
        if self.controller is not None:
            c = self.controller
            actions = {"grow": 0, "shrink": 0, "hold": 0}
            for rec in c.decisions:
                actions[rec["action"]] = actions.get(rec["action"], 0) + 1
            out["controller"] = {
                "target_p99_ms": c.slo.target_p99_ms,
                "n_decisions": len(c.decisions),
                "actions": actions,
                "last_decision": c.decisions[-1] if c.decisions else None,
                "batch_bounds": [c.min_batch, c.max_batch_bound],
                "wait_ms_bounds": [c.min_wait_ms, c.max_wait_ms_bound],
            }
        if self.watch is not None:
            out["compile_events"] = self.watch.compiles_total
            out["retrace_anomalies"] = self.watch.anomalies_total
        return out


# --------------------------------------------------------------------------- #
# The runtime
# --------------------------------------------------------------------------- #
class ServingRuntime:
    """Concurrent multi-tenant serving front door (module docstring).

    ``clock`` injects the timebase for *default* timestamps (submission
    arrivals, manual ``pump``/``flush``); it defaults to the monotonic
    ``time.perf_counter``.  Explicit ``arrival_s``/``now_s`` arguments
    always win, which is the virtual-clock test contract shared with
    ``ForestServer``.

    ``obs`` wires the observability layer (docs/OBSERVABILITY.md):
    ``True`` (default) instruments against the process-wide default
    registry; a ``MetricsRegistry`` or ``ServingMetrics`` instance
    instruments against that (isolated registries in tests);
    ``False``/``None`` disables instrumentation entirely.  Phase spans
    use the same timestamps the runtime already stamps, so virtual-clock
    runs stay deterministic with observability on."""

    def __init__(self, *, clock: Optional[Callable[[], float]] = None,
                 obs=True, trace_cap: int = 256):
        self._clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._tenants: dict[str, _Tenant] = {}
        self._rid = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        if obs is True:
            self._obs: Optional[ServingMetrics] = ServingMetrics(
                get_registry(), trace_cap=trace_cap)
        elif isinstance(obs, ServingMetrics):
            self._obs = obs
        elif isinstance(obs, MetricsRegistry):
            self._obs = ServingMetrics(obs, trace_cap=trace_cap)
        else:
            self._obs = None
        self._metrics_server = None

    @property
    def obs(self) -> Optional[ServingMetrics]:
        """The instrumentation bundle, or ``None`` when disabled."""
        return self._obs

    # ---------------------------------------------------------- tenancy
    def add_model(self, model_id: str, predictor, *, max_batch: int = 256,
                  max_wait_ms: float = 2.0,
                  slo: Optional[SLOConfig] = None) -> None:
        """Register a hot model under ``model_id`` (any compiled
        predictor: plain engine, sharded, cascade — the ``Predictor``
        protocol).  ``slo`` attaches the adaptive batching controller."""
        if not _MODEL_ID.match(model_id):
            raise ValueError(
                f"model id {model_id!r} must match {_MODEL_ID.pattern} "
                "(it names the packed artifact on save())")
        with self._lock:
            if model_id in self._tenants:
                raise ValueError(f"model id {model_id!r} already serving")
            t = _Tenant(model_id, predictor, max_batch, max_wait_ms, slo)
            if self._obs is not None:
                t.watch = CompileWatch(predictor)
            self._tenants[model_id] = t

    @property
    def model_ids(self) -> tuple:
        return tuple(self._tenants)

    def tenant(self, model_id: str) -> _Tenant:
        try:
            return self._tenants[model_id]
        except KeyError:
            raise ValueError(f"unknown model id {model_id!r}; serving "
                             f"{sorted(self._tenants)}") from None

    @classmethod
    def from_forests(cls, forests: dict, *, max_batch: int = 256,
                     max_wait_ms: float = 2.0,
                     slo: Optional[SLOConfig] = None,
                     clock: Optional[Callable[[], float]] = None,
                     obs=True, tune: Optional[str] = None,
                     **choose_kw) -> "ServingRuntime":
        """Autotune-and-serve N forests: each tenant's engine comes from
        ``core.engine_select.choose`` — all tenants share the
        process-wide sweep cache (memory + disk), so a fleet of
        same-shaped models pays for one sweep, not N.

        ``tune="predict"`` (alias ``"-Os"``) is the fleet cold-start
        fast path (docs/AUTOTUNE.md): each tenant's plan comes from the
        learned cost model — one compile per tenant instead of a full
        sweep — falling back to a narrow top-k sweep per shape whose
        confidence is low.  Extra ``choose_kw`` (``cost_model=``,
        ``confidence_threshold=``, ...) pass through."""
        from ..core import engine_select
        if tune is not None:
            choose_kw.setdefault("mode", tune)
        rt = cls(clock=clock, obs=obs)
        for tid, forest in forests.items():
            choice = engine_select.choose(forest, max_batch, **choose_kw)
            rt.add_model(tid, choice.predictor, max_batch=max_batch,
                         max_wait_ms=max_wait_ms, slo=slo)
            rt.tenant(tid).engine_choice = choice
        return rt

    # ------------------------------------------------------- persistence
    def save(self, directory) -> str:
        """Persist every tenant as a packed artifact plus a JSON
        manifest (``io.packed.save_manifest``) — ``load()`` cold-starts
        the whole fleet with no sweep and no recompile, predictions
        bit-identical.  Returns the manifest path."""
        from .. import io
        from ..io import packed
        directory = os.fspath(directory)
        os.makedirs(directory, exist_ok=True)
        entries = {}
        for tid, t in self._tenants.items():
            fname = f"{tid}.repro.npz"
            io.save_predictor(t.predictor, os.path.join(directory, fname))
            entries[tid] = {
                "artifact": fname,
                "max_batch": t.cfg_max_batch,
                "max_wait_ms": t.cfg_max_wait_ms,
                "slo": t.controller.slo.to_header()
                if t.controller is not None else None,
            }
        return packed.save_manifest(
            os.path.join(directory, "manifest.json"), entries)

    @classmethod
    def load(cls, path, *,
             clock: Optional[Callable[[], float]] = None,
             obs=True) -> "ServingRuntime":
        """Cold-start a fleet from a ``save()`` manifest (or the
        directory holding one): every tenant's compiled arrays upload
        as-saved — no autotune sweep, no recompilation — and serving
        results are bit-identical to the saved predictors'."""
        from .. import io
        from ..io import packed
        rt = cls(clock=clock, obs=obs)
        for tid, e in packed.load_manifest(path).items():
            pred = io.load_predictor(e["artifact"])
            slo = SLOConfig.from_header(e["slo"]) if e.get("slo") else None
            rt.add_model(tid, pred, max_batch=int(e.get("max_batch", 256)),
                         max_wait_ms=float(e.get("max_wait_ms", 2.0)),
                         slo=slo)
        return rt

    # ------------------------------------------------------------ warmup
    def warmup(self, model_id: Optional[str] = None) -> dict:
        """Pre-trace every batch bucket each tenant can be served at.

        For each tenant, runs one prediction per ``bucket_ladder``
        entry up to ``hard_max_batch`` (the adaptive controller's upper
        bound — growth must never hit a cold shape).  Because dispatch
        pads plain-engine batches to those same buckets, and the fused
        cascade / Pallas predictors bucket internally, a warmed tenant
        never pays a trace/compile on a live request (the PR-6
        follow-on: the fused cascade's XLA tier re-traced per bucket).
        Warmup inputs are zeros — predictions afterwards are
        bit-identical (``check_engines.py --serving`` pins this) — and
        cascade exit statistics are reset so synthetic warmup rows never
        pollute served exit accounting.  Returns {model_id: [buckets]}."""
        ids = [model_id] if model_id is not None else list(self._tenants)
        out = {}
        for tid in ids:
            t = self.tenant(tid)
            pred = t.predictor
            forest = getattr(pred, "host_forest", lambda: None)()
            if forest is None:
                raise ValueError(
                    f"cannot warm {tid!r}: predictor exposes no "
                    "host_forest() to derive the input width from")
            d = int(getattr(forest, "n_features_in", forest.n_features))
            ladder = bucket_ladder(t.hard_max_batch)
            X = np.zeros((ladder[-1], max(d, 1)), dtype=np.float64)
            for b in ladder:
                jax.block_until_ready(pred.predict(X[:b]))
            getattr(pred, "reset_exit_stats", lambda: None)()
            t.warmed = tuple(ladder)
            if t.watch is not None:
                # warmup traces were deliberate; from here on any new
                # trace is a retrace anomaly (docs/OBSERVABILITY.md)
                t.watch.mark_warm()
            out[tid] = list(ladder)
        return out

    # ------------------------------------------------------- submission
    def submit(self, model_id: str, features,
               arrival_s: Optional[float] = None) -> ServedRequest:
        """Thread-safe enqueue; returns a future-backed request the
        caller can ``wait()`` on.  Wakes the worker thread."""
        payload = np.asarray(features)
        with self._cv:
            if self._stop:
                raise RuntimeError("runtime is closed")
            t = self.tenant(model_id)
            self._rid += 1
            req = ServedRequest(self._rid, payload,
                                arrival_s if arrival_s is not None
                                else self._clock(), tenant=model_id)
            t.batcher.add(req)
            depth = len(t.batcher.queue)
            self._cv.notify()
        o = self._obs
        if o is not None and o.enabled:
            o.queue_depth.labels(tenant=model_id).set(float(depth))
        return req

    # ------------------------------------------------------ dispatching
    def _run_batch(self, t: _Tenant, reqs: list, now_s: float) -> list:
        """Evaluate one drained batch and resolve its futures — the
        ``ForestServer._run`` contract (monotonic compute timing, block
        before stamping ``done_s``, stats + exit accounting) plus
        bucket padding, the adaptive controller, and — when
        observability is on — the phase span / metric / retrace hooks.
        ``done_s`` semantics are unchanged: the instrumentation reuses
        the timestamps the dispatch path already takes."""
        if not reqs:
            return []
        o = self._obs if (self._obs is not None
                          and self._obs.enabled) else None
        t_form = time.perf_counter()
        X = np.stack([r.payload for r in reqs])
        n = len(reqs)
        bucket = n
        t0 = time.perf_counter()
        try:
            if t.pad_buckets:
                bucket = bucket_batch(n)
                if bucket > n:
                    # zero rows: row-independent traversal, sliced off
                    # before anything observable (conformance-tested)
                    Xp = np.zeros((bucket,) + X.shape[1:], dtype=X.dtype)
                    Xp[:n] = X
                    X = Xp
            t_pad = time.perf_counter()
            scores = t.predictor.predict(X)
            t_compute = time.perf_counter()
            jax.block_until_ready(scores)        # async dispatch honesty
            scores = np.asarray(scores)[:n]
            t_sync = time.perf_counter()
        except Exception as e:                   # noqa: BLE001 — resolve,
            err_done = now_s + (time.perf_counter() - t0)
            for r in reqs:                       # don't kill the worker
                r.done_s = err_done
            if o is not None:                    # spans before futures:
                self._observe_error(o, t, reqs, now_s, bucket, e)
            for r in reqs:
                r.future.set_exception(e)
            return reqs
        done_s = now_s + (t_sync - t0)
        for r, s in zip(reqs, scores):
            r.result = s
            r.done_s = done_s
        phases = {
            "form_ms": (t0 - t_form) * 1e3,
            "pad_ms": (t_pad - t0) * 1e3,
            "compute_ms": (t_compute - t_pad) * 1e3,
            "sync_ms": (t_sync - t_compute) * 1e3,
        }
        t.stats.record_batch(reqs)
        t.stats.record_phases(phases["compute_ms"], phases["sync_ms"])
        exits = getattr(t.predictor, "last_exit_counts", None)
        t.stats.record_exits(exits)
        decisions: list[dict] = []
        if t.controller is not None:
            for r in reqs:
                rec = t.controller.observe(r.latency_ms)
                if rec is not None:
                    decisions.append(rec)
            if decisions:
                t.batcher.max_batch = t.controller.max_batch
                t.batcher.max_wait_ms = t.controller.max_wait_ms
        if o is not None:
            self._observe_batch(o, t, reqs, now_s, bucket, phases,
                                exits, decisions)
        # resolve futures last: a caller woken by wait() observes the
        # fully-stamped request and consistent stats
        for r in reqs:
            r.future.set_result(r.result)
        return reqs

    # -------------------------------------------------- observability
    def _observe_batch(self, o: ServingMetrics, t: _Tenant, reqs: list,
                       now_s: float, bucket: int, phases: dict,
                       exits, decisions: list) -> None:
        """Feed one successful batch into the metrics + trace layer.
        Only called when observability is on; every op here is a cheap
        in-process counter/reservoir update (bench_serving measures the
        total overhead and BENCH_serving.json reports it)."""
        tid = t.model_id
        n = len(reqs)
        o.batches_total.labels(tenant=tid).inc()
        o.batch_size.labels(tenant=tid).observe(float(n))
        req_ctr = o.requests_total.labels(tenant=tid)
        lat_hist = o.latency_ms.labels(tenant=tid)
        for p, v in phases.items():
            o.phase_ms.labels(tenant=tid, phase=p).observe(v)
        queue_hist = o.phase_ms.labels(tenant=tid, phase="queue_ms")
        for r in reqs:
            queue_ms = max((now_s - r.arrival_s) * 1e3, 0.0)
            req_ctr.inc()
            queue_hist.observe(queue_ms)
            lat = r.latency_ms
            if lat is not None:
                lat_hist.observe(lat)
            span = Span(rid=r.rid, tenant=tid, arrival_s=r.arrival_s,
                        batch_size=n, bucket=bucket,
                        phases={"queue_ms": queue_ms, **phases},
                        total_ms=lat)
            r.span = span
            o.traces.add(span)
        o.queue_depth.labels(tenant=tid).set(float(len(t.batcher.queue)))
        o.effective_max_batch.labels(tenant=tid).set(
            float(t.batcher.max_batch))
        o.effective_max_wait_ms.labels(tenant=tid).set(
            float(t.batcher.max_wait_ms))
        for rec in decisions:
            o.controller_decisions_total.labels(
                tenant=tid, action=rec["action"]).inc()
        if exits is not None:
            for stage, count in enumerate(exits):
                if count:
                    o.cascade_stage_exits_total.labels(
                        tenant=tid, stage=str(stage)).inc(float(count))
        if t.watch is not None:
            compiles, anomalies = t.watch.poll()
            if compiles:
                o.compile_events_total.labels(tenant=tid).inc(compiles)
            if anomalies:
                o.retrace_anomalies_total.labels(tenant=tid).inc(anomalies)
                _LOG.warning("retrace_anomaly", tenant=tid,
                             new_traces=anomalies, batch=n, bucket=bucket)

    def _observe_error(self, o: ServingMetrics, t: _Tenant, reqs: list,
                       now_s: float, bucket: int, err: Exception) -> None:
        """The failed-batch twin of ``_observe_batch``: errored requests
        still count as completed (their futures resolve) and additionally
        increment ``repro_request_errors_total``; their spans carry
        ``ok=false`` and the exception repr."""
        tid = t.model_id
        n = len(reqs)
        o.batches_total.labels(tenant=tid).inc()
        o.batch_size.labels(tenant=tid).observe(float(n))
        for r in reqs:
            o.requests_total.labels(tenant=tid).inc()
            o.request_errors_total.labels(tenant=tid).inc()
            queue_ms = max((now_s - r.arrival_s) * 1e3, 0.0)
            span = Span(rid=r.rid, tenant=tid, arrival_s=r.arrival_s,
                        batch_size=n, bucket=bucket,
                        phases={"queue_ms": queue_ms},
                        total_ms=r.latency_ms, ok=False, error=repr(err))
            r.span = span
            o.traces.add(span)
        o.queue_depth.labels(tenant=tid).set(float(len(t.batcher.queue)))
        _LOG.error("batch_failed", tenant=tid, batch=n, error=repr(err))

    def _next_deadline(self, now: float) -> Optional[float]:
        """Seconds until the earliest queued request's wait expires."""
        deadlines = [t.batcher.queue[0].arrival_s
                     + t.batcher.max_wait_ms * 1e-3
                     for t in self._tenants.values() if t.batcher.queue]
        if not deadlines:
            return None
        return max(min(deadlines) - now, 1e-4)

    def _loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    if self._stop:
                        break
                    now = self._clock()
                    ready = [t for t in self._tenants.values()
                             if t.batcher.ready(now)]
                    if ready:
                        break
                    self._cv.wait(self._next_deadline(now))
                now = self._clock()
                if self._stop:
                    # shutdown flush: drain EVERYTHING under the lock —
                    # submit() already rejects, so after this the queues
                    # are empty forever and every request resolves once
                    batches = []
                    for t in self._tenants.values():
                        while t.batcher.queue:
                            batches.append((t, t.batcher.drain()))
                else:
                    batches = [(t, t.batcher.drain())
                               for t in self._tenants.values()
                               if t.batcher.ready(now)]
            for t, reqs in batches:
                self._run_batch(t, reqs, now)
            if self._stop:
                return

    # ---------------------------------------------------------- control
    def start(self) -> "ServingRuntime":
        """Launch the background worker (idempotent)."""
        with self._lock:
            if self._stop:
                raise RuntimeError("runtime is closed")
            if self._thread is not None and self._thread.is_alive():
                return self
            self._thread = threading.Thread(
                target=self._loop, name="repro-serving", daemon=True)
            self._thread.start()
        return self

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Stop accepting work, flush every queue, join the worker.
        Safe to call twice; never deadlocks — the worker's shutdown
        drain happens under the same lock that gates ``submit``."""
        with self._cv:
            already = self._stop
            self._stop = True
            self._cv.notify_all()
        thread = self._thread
        if thread is not None:
            thread.join(timeout)
            if thread.is_alive():
                raise RuntimeError("serving worker failed to stop "
                                   f"within {timeout}s")
        elif not already:
            # manual-mode close: complete queued work synchronously
            self._flush_locked(self._clock())
        if self._metrics_server is not None:
            self._metrics_server.close()
            self._metrics_server = None

    def __enter__(self) -> "ServingRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------- manual (virtual) loop
    def pump(self, now_s: Optional[float] = None) -> list:
        """Manual dispatch: run every tenant whose rule fires at
        ``now_s`` — the deterministic single-threaded twin of the worker
        loop (virtual-clock tests drive this).  Returns completed
        requests."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("pump() is the manual loop; the worker "
                               "thread is already running")
        now = now_s if now_s is not None else self._clock()
        with self._lock:
            batches = [(t, t.batcher.drain())
                       for t in self._tenants.values()
                       if t.batcher.ready(now)]
        done = []
        for t, reqs in batches:
            done.extend(self._run_batch(t, reqs, now))
        return done

    def _flush_locked(self, now: float) -> list:
        with self._lock:
            batches = []
            for t in self._tenants.values():
                while t.batcher.queue:
                    batches.append((t, t.batcher.drain()))
        done = []
        for t, reqs in batches:
            done.extend(self._run_batch(t, reqs, now))
        return done

    def flush(self, now_s: Optional[float] = None) -> list:
        """Unconditionally drain every tenant (manual mode only)."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("flush() is the manual loop; close() "
                               "flushes the threaded runtime")
        return self._flush_locked(now_s if now_s is not None
                                  else self._clock())

    # ------------------------------------------------------------- stats
    def summary(self, model_id: Optional[str] = None) -> dict:
        """Per-tenant ``ServerStats.summary()`` + effective batching
        knobs; one tenant's dict, or {model_id: dict} for the fleet."""
        if model_id is not None:
            return self.tenant(model_id).summary()
        return {tid: t.summary() for tid, t in self._tenants.items()}

    def stats(self, model_id: Optional[str] = None) -> dict:
        """``summary()`` plus the operational state an operator wants
        live: current queue depth, the controller's full (bounded)
        decision history, and the retrace watch counters.  This is the
        ``stats`` section of the metrics endpoint's ``/metrics.json``."""
        if model_id is None:
            return {tid: self.stats(tid) for tid in self._tenants}
        t = self.tenant(model_id)
        out = t.summary()
        out["queue_depth"] = len(t.batcher.queue)
        if t.controller is not None:
            out["decisions"] = list(t.controller.decisions)
        if t.watch is not None:
            out["trace_cache_observable"] = t.watch.observable
        return out

    # ------------------------------------------------------- exposition
    def serve_metrics(self, port: int = 0,
                      host: str = "127.0.0.1"):
        """Start (idempotently) the scrape endpoint over this runtime's
        registry: Prometheus text at ``/metrics``, JSON at
        ``/metrics.json`` (including ``stats()``), recent spans at
        ``/traces``.  Owned by the runtime — ``close()`` stops it.
        Returns the ``repro.obs.expo.MetricsServer`` (``.url``)."""
        if self._obs is None:
            raise RuntimeError("observability is disabled (obs=False); "
                               "no metrics to serve")
        if self._metrics_server is None:
            from ..obs.expo import MetricsServer
            self._metrics_server = MetricsServer(
                self._obs.registry, traces=self._obs.traces,
                extra=self.stats, host=host, port=port).start()
            _LOG.info("metrics_endpoint", url=self._metrics_server.url)
        return self._metrics_server
