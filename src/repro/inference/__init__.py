from .runtime import (AdaptiveBatchController, ServedRequest,
                      ServingRuntime, SLOConfig)
from .server import (ForestServer, LMServer, MicroBatcher, Request,
                     Reservoir, ServerStats)

__all__ = ["ForestServer", "LMServer", "MicroBatcher", "Request",
           "Reservoir", "ServerStats",
           "ServingRuntime", "ServedRequest", "SLOConfig",
           "AdaptiveBatchController"]
