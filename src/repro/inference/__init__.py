from .server import (ForestServer, LMServer, MicroBatcher, Request,
                     ServerStats)

__all__ = ["ForestServer", "LMServer", "MicroBatcher", "Request",
           "ServerStats"]
