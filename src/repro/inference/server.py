"""Batched inference serving for both engines of the framework.

The paper's deployment target is continuous streams of measurements on IoT
devices; the framework generalises that to a server abstraction:

  * ``MicroBatcher`` — groups incoming requests into engine-shaped batches
    under a max-latency budget (classic dynamic batching: dispatch when
    ``max_batch`` is reached OR the oldest request exceeds ``max_wait_ms``).
  * ``ForestServer`` — tree-ensemble scoring behind a micro-batcher, any
    core engine (bitvector / rapidscorer / gemm / native / pallas).
  * ``LMServer`` — prefill + KV-cache decode for the LM model zoo
    (CPU-reduced configs in tests; the same class drives the production
    mesh on real hardware).

Requests are processed in arrival order; the batcher is deterministic given
arrival timestamps, so tests can assert exact batching decisions.

Timestamps: all *default* clocks here are ``time.perf_counter()`` —
monotonic, so a latency can never go negative because NTP stepped the
wall clock mid-request.  Callers that pass explicit ``arrival_s`` /
``now_s`` values (virtual clocks — the deterministic-test contract, and
``repro.launch.serve``'s replayed arrival traces) are untouched: the
server only ever *subtracts* timestamps, so any consistent timebase
works.

The concurrent multi-tenant front door (threaded request loop, adaptive
batching, shape warmup) lives in ``repro.inference.runtime`` and is
built out of these parts — see docs/SERVING.md.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# Requests / stats
# --------------------------------------------------------------------------- #
@dataclass
class Request:
    rid: int
    payload: Any                      # (d,) features | (S,) prompt tokens
    arrival_s: float
    done_s: Optional[float] = None
    result: Any = None

    @property
    def latency_ms(self) -> Optional[float]:
        if self.done_s is None:
            return None
        return (self.done_s - self.arrival_s) * 1e3


class Reservoir:
    """Bounded sample of a value stream: exact below ``cap``, a uniform
    random sample above (Vitter's Algorithm R, deterministic seed), with
    the running count and sum kept exactly so ``mean()`` is always exact
    while percentiles come from the retained sample.

    This replaces the unbounded ``ServerStats`` lists: a server under
    sustained traffic holds O(cap) floats no matter how many requests it
    has completed, and ``summary()`` percentiles stay O(cap) work.
    Below the cap the sample IS the full stream, so short runs (every
    test, every benchmark window) lose nothing.
    """

    __slots__ = ("cap", "n", "total", "_sample", "_rng")

    def __init__(self, cap: int = 4096, seed: int = 0):
        if cap < 1:
            raise ValueError(f"reservoir cap must be >= 1, got {cap}")
        self.cap = cap
        self.n = 0                       # values ever observed (exact)
        self.total = 0.0                 # running sum (exact mean)
        self._sample: list[float] = []
        self._rng = random.Random(seed)

    def append(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.total += v
        if len(self._sample) < self.cap:
            self._sample.append(v)
        else:
            # Algorithm R: keep each of the n values with prob cap/n
            j = self._rng.randrange(self.n)
            if j < self.cap:
                self._sample[j] = v

    def extend(self, it) -> None:
        for v in it:
            self.append(v)

    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q) -> float:
        if not self._sample:
            raise ValueError("percentile of an empty reservoir")
        return float(np.percentile(self._sample, q))

    # list-compatible surface: existing callers iterate, truth-test,
    # np.asarray, and compare against plain lists
    def __len__(self) -> int:
        return len(self._sample)

    def __iter__(self):
        return iter(self._sample)

    def __bool__(self) -> bool:
        return self.n > 0

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self._sample, dtype=dtype)

    def __eq__(self, other):
        if isinstance(other, Reservoir):
            return self._sample == other._sample and self.n == other.n
        if isinstance(other, (list, tuple)):
            return self._sample == list(other)
        return NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        return (f"Reservoir(n={self.n}, cap={self.cap}, "
                f"retained={len(self._sample)})")


@dataclass
class ServerStats:
    n_requests: int = 0
    n_batches: int = 0
    batch_sizes: Reservoir = field(default_factory=Reservoir)
    latencies_ms: Reservoir = field(default_factory=Reservoir)
    # per-batch phase breakdown: device compute (predict dispatch) vs
    # host sync (block_until_ready + copy-out) — docs/OBSERVABILITY.md
    compute_ms: Reservoir = field(default_factory=Reservoir)
    sync_ms: Reservoir = field(default_factory=Reservoir)
    # cascade serving: cumulative per-stage exit counts (empty unless the
    # predictor reports them — see ForestServer._run / docs/CASCADE.md)
    stage_exit_counts: list = field(default_factory=list)

    def record_batch(self, reqs: list[Request]) -> None:
        if not reqs:                   # zero-request batch: stats unchanged
            return
        self.n_batches += 1
        self.n_requests += len(reqs)
        self.batch_sizes.append(len(reqs))
        self.latencies_ms.extend(
            r.latency_ms for r in reqs if r.latency_ms is not None)

    def record_phases(self, compute_ms: float, sync_ms: float) -> None:
        """Record one batch's device-compute / host-sync split."""
        self.compute_ms.append(compute_ms)
        self.sync_ms.append(sync_ms)

    def record_exits(self, counts) -> None:
        """Accumulate a cascade predictor's per-stage exit counts for the
        batch just served (``counts`` is its ``last_exit_counts``)."""
        if counts is None:
            return
        counts = [int(c) for c in counts]
        if len(self.stage_exit_counts) < len(counts):
            self.stage_exit_counts.extend(
                [0] * (len(counts) - len(self.stage_exit_counts)))
        for i, c in enumerate(counts):
            self.stage_exit_counts[i] += c

    def summary(self) -> dict:
        # no completed request → no latency distribution: report null,
        # not the 0.0 percentiles of a zeros(1) placeholder (a dashboard
        # reading p99=0.0 would conclude the server is infinitely fast)
        lat = self.latencies_ms if self.latencies_ms else None
        out = {
            "n_requests": self.n_requests,
            "n_batches": self.n_batches,
            "mean_batch": self.batch_sizes.mean(),
            "p50_ms": lat.percentile(50) if lat is not None else None,
            "p99_ms": lat.percentile(99) if lat is not None else None,
        }
        if self.compute_ms:
            out["compute_p50_ms"] = self.compute_ms.percentile(50)
            out["sync_p50_ms"] = self.sync_ms.percentile(50) \
                if self.sync_ms else None
        if self.stage_exit_counts:
            tot = sum(self.stage_exit_counts)
            out["exit_fractions"] = [c / max(tot, 1)
                                     for c in self.stage_exit_counts]
        return out


# --------------------------------------------------------------------------- #
# Micro-batcher
# --------------------------------------------------------------------------- #
class MicroBatcher:
    """Dispatch rule: flush when ``len(queue) >= max_batch`` or when
    ``now - oldest.arrival_s >= max_wait_ms``. Pure decision logic —
    unit-testable without a clock."""

    def __init__(self, max_batch: int = 64, max_wait_ms: float = 5.0):
        if max_batch < 1:
            # drain() would emit empty batches forever (flush() spins)
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.queue: list[Request] = []

    def add(self, req: Request) -> None:
        self.queue.append(req)

    def ready(self, now_s: float) -> bool:
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        return (now_s - self.queue[0].arrival_s) * 1e3 >= self.max_wait_ms

    def drain(self) -> list[Request]:
        batch, self.queue = (self.queue[:self.max_batch],
                             self.queue[self.max_batch:])
        return batch


# --------------------------------------------------------------------------- #
# Forest serving
# --------------------------------------------------------------------------- #
class ForestServer:
    def __init__(self, predictor, max_batch: int = 256,
                 max_wait_ms: float = 2.0, *, obs=None,
                 obs_label: str = "forest"):
        self.predictor = predictor
        self.batcher = MicroBatcher(max_batch, max_wait_ms)
        self.stats = ServerStats()
        self.engine_choice = None          # set by from_forest()
        self._rid = 0
        # optional catalog instrumentation (docs/OBSERVABILITY.md):
        # obs=True → the process default registry; a MetricsRegistry /
        # ServingMetrics instance → that.  The synchronous server stays
        # uninstrumented by default — ServingRuntime is the production
        # front door and defaults the other way.
        self.obs_label = obs_label
        if obs is None or obs is False:
            self._obs = None
        else:
            from ..obs.metrics import MetricsRegistry, get_registry
            from ..obs.serving import ServingMetrics
            if obs is True:
                obs = ServingMetrics(get_registry())
            elif isinstance(obs, MetricsRegistry):
                obs = ServingMetrics(obs)
            self._obs = obs

    _CACHE_UNSET = object()       # distinguish "not given" from None

    @classmethod
    def from_forest(cls, forest, *, max_batch: int = 256,
                    max_wait_ms: float = 2.0, engines=None,
                    n_devices: int = 1,
                    cache_path=_CACHE_UNSET, **choose_kw) -> "ForestServer":
        """Build a server on the autotuned fastest engine for this forest.

        The dispatch batch cap is the autotune batch: the winner is picked
        for the batch shape the micro-batcher will actually emit.  The
        decision comes from ``core.engine_select``'s cache when one exists
        (in-memory or the JSON file), so restarts skip the sweep.
        ``n_devices > 1`` serves the winner tree-sharded across the device
        mesh (``core.shard``); the autotune cache key includes the device
        count, so single- and multi-device decisions never alias.
        ``cascade_specs=`` (forwarded to ``choose``) adds confidence-gated
        staged candidates — a cascade winner serves through the same
        micro-batcher, with per-stage exit fractions reported in
        ``ServerStats.summary()``; ``opt_levels=`` (also forwarded) adds
        optimizer middle-end variants (``qs@O2``, docs/OPTIM.md) whose
        serving interface is unchanged (full-width rows).  ``cache_path=None`` disables the disk
        layer (as in ``choose``); omitting it uses the default cache
        file."""
        from ..core import engine_select
        kw = dict(choose_kw)
        if cache_path is not cls._CACHE_UNSET:
            kw["cache_path"] = cache_path
        choice = engine_select.choose(forest, max_batch, engines=engines,
                                      n_devices=n_devices, **kw)
        srv = cls(choice.predictor, max_batch=max_batch,
                  max_wait_ms=max_wait_ms)
        srv.engine_choice = choice
        return srv

    def save(self, path) -> None:
        """Persist the compiled serving artifact (docs/FORMATS.md): the
        engine's device arrays + the serving config, so a cold restart
        skips both the autotune sweep and recompilation.  The predictor
        must come from a serializable engine (``EngineSpec.serial_arrays``
        — tree-sharded and Pallas predictors are not; keep the forest and
        rebuild those).  Cascade predictors persist as kind=cascade
        artifacts: every stage's arrays plus the gate thresholds."""
        from .. import io
        # engine_choice is an EngineChoice after from_forest() but a bare
        # name string after load() — persist the name through both, so a
        # load → save cycle keeps it
        extra = {"server": {"max_batch": self.batcher.max_batch,
                            "max_wait_ms": self.batcher.max_wait_ms,
                            "engine_choice": getattr(self.engine_choice,
                                                     "engine",
                                                     self.engine_choice)}}
        io.save_predictor(self.predictor, path, extra=extra)

    @classmethod
    def load(cls, path) -> "ForestServer":
        """Cold-start a server from a ``save()`` artifact: predictions are
        bit-identical to the saved predictor's, no sweep, no recompile.
        ``engine_choice`` on the restored server is the winning engine's
        *name* (the timings/predictor of the original ``EngineChoice``
        were not persisted)."""
        from .. import io
        pred, header = io.load_predictor(path, return_header=True)
        scfg = header.get("server") or {}
        srv = cls(pred, max_batch=int(scfg.get("max_batch", 256)),
                  max_wait_ms=float(scfg.get("max_wait_ms", 2.0)))
        srv.engine_choice = scfg.get("engine_choice")
        return srv

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        """Normalized class scores (paper §4) from the serving engine —
        synchronous path, bypasses the micro-batcher."""
        return self.predictor.predict_proba(X)

    def submit(self, features: np.ndarray,
               arrival_s: Optional[float] = None) -> Request:
        # default timestamps are monotonic (perf_counter): latency is a
        # timestamp difference, and the wall clock can step backwards
        # (NTP) mid-request — virtual-clock callers pass arrival_s
        self._rid += 1
        req = Request(self._rid, np.asarray(features),
                      arrival_s if arrival_s is not None
                      else time.perf_counter())
        self.batcher.add(req)
        return req

    def poll(self, now_s: Optional[float] = None) -> list[Request]:
        """Flush if the dispatch rule fires; returns completed requests."""
        now = now_s if now_s is not None else time.perf_counter()
        if not self.batcher.ready(now):
            return []
        return self._run(self.batcher.drain(), now)

    def flush(self, now_s: Optional[float] = None) -> list[Request]:
        """Unconditional drain (shutdown path)."""
        done = []
        now = now_s if now_s is not None else time.perf_counter()
        while self.batcher.queue:
            done.extend(self._run(self.batcher.drain(), now))
        return done

    def _run(self, reqs: list[Request], now_s: float) -> list[Request]:
        if not reqs:                   # empty flush/drain: no-op, no stats
            return []
        X = np.stack([r.payload for r in reqs])
        t0 = time.perf_counter()
        scores = self.predictor.predict(X)
        t_compute = time.perf_counter()
        # async dispatch: a predictor returning device arrays has only
        # *launched* the work when predict returns — block before
        # stamping done_s or the recorded latency understates reality
        # (the same bug PR 6 fixed in the bench loops)
        jax.block_until_ready(scores)
        t_sync = time.perf_counter()
        # completion on the caller's clock: virtual arrival time + real
        # compute time (keeps latency stats consistent under virtual clocks)
        done_s = (now_s if now_s is not None else t0) + (t_sync - t0)
        for r, s in zip(reqs, scores):
            r.result = s
            r.done_s = done_s
        compute_ms = (t_compute - t0) * 1e3
        sync_ms = (t_sync - t_compute) * 1e3
        self.stats.record_batch(reqs)
        self.stats.record_phases(compute_ms, sync_ms)
        # cascade predictors report which stage each row exited at; the
        # stats aggregate them so ServerStats.summary() can show the
        # per-stage exit fractions of the served traffic
        exits = getattr(self.predictor, "last_exit_counts", None)
        self.stats.record_exits(exits)
        o = self._obs
        if o is not None and o.enabled:
            tid = self.obs_label
            o.batches_total.labels(tenant=tid).inc()
            o.batch_size.labels(tenant=tid).observe(float(len(reqs)))
            o.phase_ms.labels(tenant=tid, phase="compute_ms").observe(
                compute_ms)
            o.phase_ms.labels(tenant=tid, phase="sync_ms").observe(sync_ms)
            req_ctr = o.requests_total.labels(tenant=tid)
            lat_hist = o.latency_ms.labels(tenant=tid)
            queue_hist = o.phase_ms.labels(tenant=tid, phase="queue_ms")
            for r in reqs:
                req_ctr.inc()
                queue_hist.observe(max((now_s - r.arrival_s) * 1e3, 0.0))
                if r.latency_ms is not None:
                    lat_hist.observe(r.latency_ms)
            if exits is not None:
                for stage, count in enumerate(exits):
                    if count:
                        o.cascade_stage_exits_total.labels(
                            tenant=tid, stage=str(stage)).inc(float(count))
        return reqs


# --------------------------------------------------------------------------- #
# LM serving (prefill + decode)
# --------------------------------------------------------------------------- #
class LMServer:
    """Batch LM text completion over the framework's Model. Greedy decode.

    The decode loop is jit'd once per (batch, max_len); state threads the KV
    cache exactly like the dry-run decode cells, so what the tests exercise
    on CPU is the same program the production mesh lowers.
    """

    def __init__(self, model, params, *, batch: int, max_len: int,
                 kv_quant: bool = False):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.kv_quant = kv_quant          # int8 KV cache (paper §5 → decode)
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def _prefill_fn(self, params, state, tokens):
        """Sequential prefill via decode steps (teacher-forcing the prompt);
        simple and cache-correct for the CPU path."""
        def body(carry, tok):
            st, _ = carry
            logits, st = self.model.decode_step(params, st, tok[:, None])
            return (st, logits.astype(jnp.float32)), None

        (state, logits), _ = jax.lax.scan(body,
                                          (state, jnp.zeros(
                                              (tokens.shape[0],
                                               self.model.cfg.vocab),
                                              jnp.float32)),
                                          tokens.T)
        return state, logits

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts (B, S) int32 → (B, S + n_new) completed greedily."""
        B, S = prompts.shape
        assert B == self.batch and S + n_new <= self.max_len
        state = self.model.init_decode_state(B, self.max_len,
                                             params=self.params,
                                             kv_quant=self.kv_quant)
        state, logits = self._prefill(self.params, state,
                                      jnp.asarray(prompts))
        out = [np.asarray(prompts)]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(n_new):
            out.append(np.asarray(tok)[:, None])
            logits, state = self._decode(self.params, state, tok[:, None])
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.concatenate(out, axis=1)
