"""Mamba2 SSD (state-space duality) block — chunked parallel form for
training/prefill, O(1) recurrent form for decode.

Recurrence (per head h, state N, head-dim P):
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t ⊗ x_t        h ∈ R^{P×N}
    y_t = C_t · h_t + D x_t

Chunked form (chunk Q): within a chunk, with running log-decay
``cum_i = Σ_{k≤i} dt_k A``:
    y_intra_i = Σ_{j≤i} exp(cum_i − cum_j) dt_j (C_i·B_j) x_j
    y_inter_i = exp(cum_i) (C_i · h_in)
    h_out     = exp(cum_Q) h_in + Σ_j exp(cum_Q − cum_j) dt_j B_j ⊗ x_j
All exponents are ≤ 0 (A < 0) → numerically stable. Inter-chunk states are
threaded with ``lax.scan`` (sequential over S/Q chunks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .act_sharding import constrain_batch
from .config import ArchConfig


def make_ssm_params(mk, cfg: ArchConfig, extra_axes: tuple = ()) -> dict:
    D = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = cfg.ssm_ngroups
    cw = cfg.conv_width
    ea = tuple(extra_axes)
    pre = ("layers",) * len(ea)
    return {
        "in_z": mk(ea + (D, di), pre + ("embed", "ssm_inner")),
        "in_x": mk(ea + (D, di), pre + ("embed", "ssm_inner")),
        "in_B": mk(ea + (D, G, N), pre + ("embed", "ssm_group", "ssm_state")),
        "in_C": mk(ea + (D, G, N), pre + ("embed", "ssm_group", "ssm_state")),
        "in_dt": mk(ea + (D, H), pre + ("embed", "ssm_heads")),
        "dt_bias": mk(ea + (H,), pre + ("ssm_heads",), init="zeros"),
        "A_log": mk(ea + (H,), pre + ("ssm_heads",), init="zeros"),
        "Dskip": mk(ea + (H,), pre + ("ssm_heads",), init="ones"),
        "conv_x": mk(ea + (cw, di), pre + ("conv", "ssm_inner"), init="zeros"),
        "out": mk(ea + (di, D), pre + ("ssm_inner", "embed")),
        "norm": mk(ea + (di,), pre + ("ssm_inner",), init="ones"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x (B, S, F), w (cw, F)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, h0=None):
    """xh (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,G,N) →
    (y (B,S,H,P), h_final (B,H,P,N)).

    The whole per-chunk computation (including the Q×Q intra-chunk matrix)
    lives inside the state ``lax.scan`` so peak memory is one chunk's
    quadratic term, not nc of them."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[-2:]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    f32 = jnp.float32
    # chunk-major for scan: (nc, B, Q, ...)
    xh = xh.astype(f32).reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dt = dt.astype(f32).reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
    Bm = Bm.astype(f32).reshape(B, nc, Q, G, N).transpose(1, 0, 2, 3, 4)
    Cm = Cm.astype(f32).reshape(B, nc, Q, G, N).transpose(1, 0, 2, 3, 4)
    iu = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h, inp):
        h = constrain_batch(h)                      # loop-carry re-pin
        xq, dtq, Bq, Cq = inp                       # (B,Q,H,P) (B,Q,H) (B,Q,G,N)
        Bh = jnp.repeat(Bq, rep, axis=2)            # (B,Q,H,N)
        Ch = jnp.repeat(Cq, rep, axis=2)
        dA = dtq * A[None, None, :]                 # ≤ 0
        cum = jnp.cumsum(dA, axis=1)                # (B,Q,H)
        Ldec = jnp.where(iu[None, :, :, None],
                         jnp.exp(cum[:, :, None, :] - cum[:, None, :, :]), 0.0)
        CB = jnp.einsum("bihn,bjhn->bijh", Ch, Bh)
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", CB * Ldec, dtq, xq)
        y_inter = jnp.einsum("bihn,bhpn->bihp",
                             Ch * jnp.exp(cum)[..., None], h)
        st = jnp.einsum("bjh,bjh,bjhn,bjhp->bhpn",
                        jnp.exp(cum[:, -1:, :] - cum), dtq, Bh, xq)
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + st
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), f32)
    h0 = constrain_batch(h0)
    xh, dt = constrain_batch(xh, dim=1), constrain_batch(dt, dim=1)
    Bm, Cm = constrain_batch(Bm, dim=1), constrain_batch(Cm, dim=1)
    h_final, ys = jax.lax.scan(body, h0, (xh, dt, Bm, Cm))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, h_final


def ssm_forward(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                chunk: int = 128) -> jnp.ndarray:
    """Full-sequence Mamba2 block. x (B, S, D) → (B, S, D)."""
    B, S, D = x.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    Bm = jnp.einsum("bsd,dgn->bsgn", x, p["in_B"])
    Cm = jnp.einsum("bsd,dgn->bsgn", x, p["in_C"])
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, p["in_dt"])
                         + p["dt_bias"])
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"]))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, _ = ssd_chunked(xs.reshape(B, S, H, P), dt, A, Bm, Cm, chunk)
    y = y + xs.reshape(B, S, H, P).astype(jnp.float32) * p["Dskip"][None, None, :, None]
    y = y.reshape(B, S, H * P).astype(x.dtype)
    y = y * jax.nn.silu(z)
    # grouped RMSNorm (Mamba2 uses norm before out-proj)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) \
        * p["norm"]
    return jnp.einsum("bse,ed->bsd", y, p["out"])


# --------------------------------------------------------------------- decode
def init_ssm_state(cfg: ArchConfig, batch: int, n_ssm_layers: int,
                   dtype=jnp.float32) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "h": jnp.zeros((n_ssm_layers, batch, H, P, N), dtype),
        "conv": jnp.zeros((n_ssm_layers, batch, cfg.conv_width - 1,
                           cfg.d_inner), dtype),
    }


def ssm_decode_step(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                    h: jnp.ndarray, conv_buf: jnp.ndarray):
    """One-token recurrent step. x (B, 1, D); h (B,H,P,N);
    conv_buf (B, cw-1, di). Returns (out (B,1,D), h', conv_buf')."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xt = x[:, 0]                                              # (B, D)
    z = xt @ p["in_z"]
    xs = xt @ p["in_x"]
    Bm = jnp.einsum("bd,dgn->bgn", xt, p["in_B"])
    Cm = jnp.einsum("bd,dgn->bgn", xt, p["in_C"])
    dt = jax.nn.softplus(xt @ p["in_dt"] + p["dt_bias"])      # (B, H)

    # causal conv over ring buffer
    win = jnp.concatenate([conv_buf, xs[:, None, :]], axis=1)  # (B, cw, di)
    xs = jax.nn.silu(jnp.einsum("bcf,cf->bf", win, p["conv_x"]))
    conv_buf = win[:, 1:]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                              # (B, H)
    rep = H // cfg.ssm_ngroups
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)       # (B, H, N)
    Chh = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    h = h * dA[:, :, None, None] \
        + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, xh)
    y = jnp.einsum("bhn,bhpn->bhp", Chh, h) \
        + xh * p["Dskip"][None, :, None]
    y = y.reshape(B, H * P).astype(x.dtype) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) \
        * p["norm"]
    return (y @ p["out"])[:, None, :], h, conv_buf