"""Architecture configuration for the assigned model zoo."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    mlp: str = "swiglu"         # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_period: int = 1         # every Nth layer is MoE (moe/hybrid families)
    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    conv_width: int = 4
    # --- hybrid (Jamba): 1 attention layer per `attn_period` layers ---
    attn_period: int = 0
    attn_offset: int = 4
    # --- enc-dec ---
    enc_layers: int = 0
    frontend_stub: Optional[str] = None   # "audio" | "vlm" (see DESIGN.md §4)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/hybrid only)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def is_attn_layer(self, l: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return l % self.attn_period == self.attn_offset
        return True

    def is_moe_layer(self, l: int) -> bool:
        if self.n_experts == 0:
            return False
        return (l % self.moe_period) == self.moe_period - 1

    # ------------------------------------------------------------------ size
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, K, hd = self.n_heads, self.n_kv, self.head_dim
        attn = D * (H * hd) + 2 * D * (K * hd) + (H * hd) * D
        mlp = 3 * D * F if self.mlp == "swiglu" else 2 * D * F
        moe = self.n_experts * mlp + D * self.n_experts
        if self.family == "ssm":
            di, N, G = self.d_inner, self.ssm_state, self.ssm_ngroups
            ssm = D * (2 * di + 2 * G * N + self.ssm_heads) \
                + self.conv_width * (di + 2 * G * N) \
                + di * D + 2 * self.ssm_heads
        else:
            di, N, G = self.d_inner, max(self.ssm_state, 16), self.ssm_ngroups
            ssm = D * (2 * di + 2 * G * N + self.ssm_heads) \
                + self.conv_width * (di + 2 * G * N) + di * D

        total = 0
        n_dec = self.n_layers
        for l in range(n_dec):
            if self.family == "ssm" or (self.family == "hybrid"
                                        and not self.is_attn_layer(l)):
                total += ssm
            else:
                total += attn
            if self.family == "ssm":
                pass  # mamba block has no separate mlp
            elif self.is_moe_layer(l):
                total += moe
            else:
                total += mlp
            total += 2 * D
        if self.family == "encdec":
            for _ in range(self.enc_layers):
                total += attn + mlp + 2 * D          # encoder self + ff
            # decoder cross-attn is full MHA (K = H, models/attention.py)
            cross = 4 * D * (H * hd)
            total += n_dec * (cross + D)
        total += V * D * 2 + D                       # embed + head + norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full_mlp = 3 * self.d_model * self.d_ff if self.mlp == "swiglu" \
            else 2 * self.d_model * self.d_ff
        dead = 0
        for l in range(self.n_layers):
            if self.is_moe_layer(l):
                dead += (self.n_experts - self.top_k) * full_mlp
        return self.param_count() - dead

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        n_layers = {"hybrid": max(self.attn_period, 2)}.get(self.family, 2)
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv=2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.family in ("ssm", "hybrid") else self.ssm_headdim,
            enc_layers=2 if self.enc_layers else 0,
            attn_period=min(self.attn_period, 2) or 0,
            attn_offset=1 if self.family == "hybrid" else self.attn_offset,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("pure full-attention arch: 512k dense-KV decode is "
                       "quadratic with no sub-quadratic path (DESIGN.md §4)")
    return True, ""
