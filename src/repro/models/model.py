"""Model assembly for all 10 assigned architectures.

A model is a stack of *units* scanned with ``lax.scan``; a unit is the
smallest repeating layer group:

  dense/moe : 1 layer  (attn mixer + mlp|moe ffn)
  ssm       : 1 mamba block (no separate ffn — mamba2 style)
  hybrid    : ``attn_period`` layers (jamba: 7 mamba + 1 attn, alternating moe)
  encdec    : decoder unit (self-attn + cross-attn + mlp); encoder is a
              separate scanned stack of (attn + mlp) units

Params are nested dicts; every block leaf carries a leading ``n_units`` axis
for the scan. ``SpecMaker`` builds an identical tree of logical-axis tuples
consumed by distributed/sharding.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba, moe
from .act_sharding import constrain, constrain_batch
from .config import ArchConfig
from .layers import (RealMaker, SpecMaker, make_embed_params,
                     make_mlp_params, rmsnorm)


@dataclass
class UnitPos:
    mixer: str              # "attn" | "ssm"
    ffn: Optional[str]      # "mlp" | "moe" | None
    cross: bool = False


def unit_layout(cfg: ArchConfig) -> list[UnitPos]:
    """Per-position descriptors of one scan unit."""
    if cfg.family == "ssm":
        return [UnitPos("ssm", None)]
    if cfg.family == "hybrid":
        out = []
        for i in range(cfg.attn_period):
            mixer = "attn" if cfg.is_attn_layer(i) else "ssm"
            ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
            out.append(UnitPos(mixer, ffn))
        return out
    ffn0 = "moe" if (cfg.n_experts and cfg.moe_period == 1) else None
    if cfg.family == "moe" and ffn0 is None:
        # period-based MoE for dense-ish moe configs
        return [UnitPos("attn", "moe" if cfg.is_moe_layer(i) else "mlp")
                for i in range(cfg.moe_period)]
    return [UnitPos("attn", ffn0 or "mlp", cross=(cfg.family == "encdec"))]


def n_units(cfg: ArchConfig) -> int:
    lay = unit_layout(cfg)
    assert cfg.n_layers % len(lay) == 0, (cfg.name, cfg.n_layers, len(lay))
    return cfg.n_layers // len(lay)


# --------------------------------------------------------------------------- #
# Parameter construction (shared between RealMaker and SpecMaker)
# --------------------------------------------------------------------------- #
def _make_unit_params(mk, cfg: ArchConfig, layout: list[UnitPos],
                      U: int) -> dict:
    blocks: dict[str, Any] = {}
    ea = (U,)
    for i, pos in enumerate(layout):
        p: dict[str, Any] = {
            "ln1": mk(ea + (cfg.d_model,), ("layers", "embed"), init="ones"),
        }
        if pos.mixer == "attn":
            p["attn"] = attn.make_attn_params(mk, cfg, extra_axes=ea)
        else:
            p["ssm"] = mamba.make_ssm_params(mk, cfg, extra_axes=ea)
        if pos.ffn:
            p["ln2"] = mk(ea + (cfg.d_model,), ("layers", "embed"),
                          init="ones")
        if pos.ffn == "mlp":
            p["mlp"] = make_mlp_params(mk, cfg.d_model, cfg.d_ff, cfg.mlp,
                                       extra_axes=ea)
        elif pos.ffn == "moe":
            p["moe"] = moe.make_moe_params(mk, cfg, extra_axes=ea)
        if pos.cross:
            p["ln_cross"] = mk(ea + (cfg.d_model,), ("layers", "embed"),
                               init="ones")
            p["cross"] = attn.make_attn_params(mk, cfg, cross=True,
                                               extra_axes=ea)
        blocks[f"pos{i}"] = p
    return blocks


def make_params(cfg: ArchConfig, mk) -> dict:
    layout = unit_layout(cfg)
    U = n_units(cfg)
    params = {
        "embed": make_embed_params(mk, cfg.vocab, cfg.d_model),
        "blocks": _make_unit_params(mk, cfg, layout, U),
    }
    if cfg.family == "encdec":
        enc_layout = [UnitPos("attn", "mlp")]
        params["enc_blocks"] = _make_unit_params(
            mk, cfg, enc_layout, cfg.enc_layers)
        params["enc_norm"] = mk((cfg.d_model,), ("embed",), init="ones")
    return params


# --------------------------------------------------------------------------- #
# Model
# --------------------------------------------------------------------------- #
class Model:
    def __init__(self, cfg: ArchConfig, compute_dtype=jnp.bfloat16,
                 q_chunk: int = 1024, ssd_chunk: int = 128,
                 loss_chunk: int = 1024, remat: bool = True):
        self.cfg = cfg
        self.layout = unit_layout(cfg)
        self.n_units = n_units(cfg)
        self.compute_dtype = compute_dtype
        self.q_chunk = q_chunk
        self.ssd_chunk = ssd_chunk
        self.loss_chunk = loss_chunk
        self.remat = remat

    # ------------------------------------------------------------- params
    def init_params(self, rng: jax.Array, dtype=jnp.float32) -> dict:
        return make_params(self.cfg, RealMaker(rng, dtype))

    def param_logical_specs(self) -> dict:
        return make_params(self.cfg, SpecMaker())

    # ------------------------------------------------------------- blocks
    def _apply_unit(self, up: dict, x: jnp.ndarray, positions: jnp.ndarray,
                    causal: bool, memory: Optional[jnp.ndarray],
                    layout: list[UnitPos]) -> tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        # constraining each sub-block OUTPUT to the residual spec makes the
        # SPMD dot handler emit reduce-scatter for the TP output projection
        # (contracting dim sharded + output S-sharded) instead of
        # all-reduce + slice — §Perf iter 5. XLA's reduce-scatter-creator
        # pass would do this on TPU; the CPU pipeline lacks it, so we ask
        # the partitioner directly.
        res_spec = {0: "batch", 1: "model"}
        for i, pos in enumerate(layout):
            p = up[f"pos{i}"]
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            if pos.mixer == "attn":
                h = attn.attn_forward(p["attn"], h, cfg, positions,
                                      causal=causal, q_chunk=self.q_chunk)
            else:
                h = mamba.ssm_forward(p["ssm"], h, cfg, chunk=self.ssd_chunk)
            x = x + constrain(h, res_spec)
            if pos.cross and memory is not None:
                h = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
                h = attn.attn_forward(p["cross"], h, cfg, positions,
                                      causal=False, memory=memory,
                                      q_chunk=self.q_chunk)
                x = x + constrain(h, res_spec)
            if pos.ffn:
                h = rmsnorm(x, p["ln2"], cfg.norm_eps)
                if pos.ffn == "moe":
                    h, a = moe.moe_forward(p["moe"], h, cfg)
                    aux = aux + a
                else:
                    from .layers import mlp_forward
                    h = mlp_forward(p["mlp"], h, cfg.mlp)
                x = x + constrain(h, res_spec)
        return x, aux

    def _run_stack(self, blocks: dict, x: jnp.ndarray,
                   positions: jnp.ndarray, causal: bool,
                   memory: Optional[jnp.ndarray],
                   layout: list[UnitPos]) -> tuple[jnp.ndarray, jnp.ndarray]:
        # residual-stream sharding (§Perf iterations 1+3): batch over the
        # data axes AND sequence over the model axis (Korthikanti-style
        # sequence parallelism). The stored per-layer carries — the bulk of
        # remat-training HBM — shrink by the TP degree; XLA inserts the
        # all-gather at attn/mlp entry and reduce-scatter at exit. Decode
        # (S=1) skips the seq constraint automatically (divisibility).
        res_spec = {0: "batch", 1: "model"}

        def unit_fn(carry, up):
            # re-pin the scan carry: XLA's propagation through `while`
            # resolves unannotated carries to REPLICATED (788 GB/device
            # temps before §Perf iteration 1)
            carry = constrain(carry, res_spec)
            y, aux = self._apply_unit(up, carry, positions, causal, memory,
                                      layout)
            y = constrain(y, res_spec)
            return y, aux

        fn = jax.checkpoint(unit_fn) if self.remat else unit_fn
        x, auxs = jax.lax.scan(fn, x, blocks)
        return x, auxs.sum()

    def _cast(self, params: dict) -> dict:
        """Cast f32 master params to the compute dtype (bf16) at entry."""
        dt = self.compute_dtype
        return jax.tree.map(
            lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, params)

    # ------------------------------------------------------------ forward
    def trunk(self, params: dict, tokens: jnp.ndarray,
              enc_embeds: Optional[jnp.ndarray] = None):
        """Embed + all blocks + final norm → (hidden (B,S,D), aux)."""
        cfg = self.cfg
        dt = self.compute_dtype
        params = self._cast(params)
        x = jnp.take(params["embed"]["embedding"], tokens, axis=0).astype(dt)
        x = constrain(x, {0: "batch", 1: "model"})
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        memory = None
        if cfg.family == "encdec":
            assert enc_embeds is not None, "encdec needs encoder embeddings"
            memory = self.encode(params, enc_embeds)
        x, aux = self._run_stack(params["blocks"], x, positions, True,
                                 memory, self.layout)
        x = rmsnorm(x, params["embed"]["final_norm"], cfg.norm_eps)
        return x, aux

    def encode(self, params: dict, enc_embeds: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        params = self._cast(params)
        x = enc_embeds.astype(self.compute_dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, _ = self._run_stack(params["enc_blocks"], x, positions, False,
                               None, [UnitPos("attn", "mlp")])
        return rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    def logits(self, params: dict, hidden: jnp.ndarray) -> jnp.ndarray:
        return jnp.einsum("bsd,dv->bsv", hidden,
                          params["embed"]["lm_head"].astype(hidden.dtype))

    def forward(self, params, tokens, enc_embeds=None):
        h, _ = self.trunk(params, tokens, enc_embeds)
        return self.logits(params, h)

    # --------------------------------------------------------------- loss
    def loss_fn(self, params: dict, tokens: jnp.ndarray,
                enc_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Next-token CE, computed in sequence chunks so the (B,S,V) logits
        tensor is never materialised (vocab up to 256k)."""
        h, aux = self.trunk(params, tokens, enc_embeds)
        B, S, D = h.shape
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
        mask = jnp.concatenate(
            [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)],
            axis=1)
        ck = min(self.loss_chunk, S)
        nc = S // ck
        hc = constrain_batch(h.reshape(B, nc, ck, D).transpose(1, 0, 2, 3),
                             dim=1)
        lc = constrain_batch(labels.reshape(B, nc, ck).transpose(1, 0, 2),
                             dim=1)
        mc = constrain_batch(mask.reshape(B, nc, ck).transpose(1, 0, 2),
                             dim=1)
        head = params["embed"]["lm_head"]

        def chunk_loss(carry, inp):
            hh, ll, mm = inp
            lg = jnp.einsum("bsd,dv->bsv", hh, head.astype(hh.dtype))
            lg = lg.astype(jnp.float32)
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, ll[..., None], axis=-1)[..., 0]
            ce = ((lse - gold) * mm).sum()
            return carry + ce, None

        fn = jax.checkpoint(chunk_loss) if self.remat else chunk_loss
        total, _ = jax.lax.scan(fn, jnp.zeros((), jnp.float32), (hc, lc, mc))
        ntok = jnp.maximum(mask.sum(), 1.0)
        return total / ntok + 0.01 * aux

    # ------------------------------------------------------------- decode
    def _cache_shapes(self):
        cfg = self.cfg
        n_attn_per_unit = sum(1 for p in self.layout if p.mixer == "attn")
        n_ssm_per_unit = sum(1 for p in self.layout if p.mixer == "ssm")
        return n_attn_per_unit, n_ssm_per_unit

    def init_decode_state(self, batch: int, max_len: int,
                          params: Optional[dict] = None,
                          enc_embeds: Optional[jnp.ndarray] = None,
                          dtype=jnp.bfloat16,
                          kv_quant: bool = False) -> dict:
        cfg = self.cfg
        U = self.n_units
        na, ns = self._cache_shapes()
        state: dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
        if na:
            K, hd = cfg.n_kv, cfg.head_dim
            kv_dtype = jnp.int8 if kv_quant else dtype
            state["k"] = jnp.zeros((U, na, batch, max_len, K, hd), kv_dtype)
            state["v"] = jnp.zeros((U, na, batch, max_len, K, hd), kv_dtype)
            if kv_quant:
                # int8 KV (paper §5 → decode roofline): per-(pos, head)
                # scales, ~2 bytes/elem → 1.03
                state["k_scale"] = jnp.zeros((U, na, batch, max_len, K),
                                             jnp.float32)
                state["v_scale"] = jnp.zeros((U, na, batch, max_len, K),
                                             jnp.float32)
        if ns:
            H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
            state["ssm_h"] = jnp.zeros((U, ns, batch, H, P, N), jnp.float32)
            state["conv"] = jnp.zeros(
                (U, ns, batch, cfg.conv_width - 1, cfg.d_inner), dtype)
        if cfg.family == "encdec":
            assert params is not None and enc_embeds is not None
            memory = self.encode(params, enc_embeds)
            ks, vs = [], []
            # cross K/V per unit (layout has one position for encdec)
            def per_unit(up):
                return attn.cross_memory_kv(up["pos0"]["cross"], memory, dtype)
            kv = jax.vmap(per_unit)(params["blocks"])
            state["cross_k"], state["cross_v"] = kv
        return state

    def decode_step(self, params: dict, state: dict,
                    tokens: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
        """tokens (B, 1) → (logits (B, vocab), new state)."""
        cfg = self.cfg
        dt = self.compute_dtype
        params = self._cast(params)
        x = jnp.take(params["embed"]["embedding"], tokens, axis=0).astype(dt)
        index = state["index"]
        na, ns = self._cache_shapes()

        kv_quant = "k_scale" in state

        xs: list[Any] = [params["blocks"]]
        if na:
            xs += [state["k"], state["v"]]
            if kv_quant:
                xs += [state["k_scale"], state["v_scale"]]
        if ns:
            xs += [state["ssm_h"], state["conv"]]
        if cfg.family == "encdec":
            xs += [state["cross_k"], state["cross_v"]]

        def unit_fn(carry, inp):
            x = carry
            it = iter(inp)
            up = next(it)
            kc = vc = hc = cc = xk = xv = ksc = vsc = None
            if na:
                kc, vc = next(it), next(it)
                if kv_quant:
                    ksc, vsc = next(it), next(it)
            if ns:
                hc, cc = next(it), next(it)
            if cfg.family == "encdec":
                xk, xv = next(it), next(it)
            ai = si = 0
            new_k, new_v, new_h, new_c = [], [], [], []
            new_ks, new_vs = [], []
            for i, pos in enumerate(self.layout):
                p = up[f"pos{i}"]
                h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                if pos.mixer == "attn":
                    if kv_quant:
                        h, k2, v2, ks2, vs2 = attn.attn_decode_step(
                            p["attn"], h, cfg, kc[ai], vc[ai], index,
                            k_scale=ksc[ai], v_scale=vsc[ai])
                        new_ks.append(ks2)
                        new_vs.append(vs2)
                    else:
                        h, k2, v2 = attn.attn_decode_step(
                            p["attn"], h, cfg, kc[ai], vc[ai], index)
                    new_k.append(k2)
                    new_v.append(v2)
                    ai += 1
                else:
                    h, h2, c2 = mamba.ssm_decode_step(
                        p["ssm"], h, cfg, hc[si], cc[si])
                    new_h.append(h2)
                    new_c.append(c2)
                    si += 1
                x = x + h
                if pos.cross:
                    h = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
                    h = attn.cross_attn_decode(p["cross"], h, cfg, xk, xv)
                    x = x + h
                if pos.ffn:
                    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
                    if pos.ffn == "moe":
                        h, _ = moe.moe_forward(p["moe"], h, cfg)
                    else:
                        from .layers import mlp_forward
                        h = mlp_forward(p["mlp"], h, cfg.mlp)
                    x = x + h
            ys = []
            if na:
                ys += [jnp.stack(new_k), jnp.stack(new_v)]
                if kv_quant:
                    ys += [jnp.stack(new_ks), jnp.stack(new_vs)]
            if ns:
                ys += [jnp.stack(new_h), jnp.stack(new_c)]
            return x, tuple(ys)

        x, ys = jax.lax.scan(unit_fn, x, tuple(xs))
        x = rmsnorm(x, params["embed"]["final_norm"], cfg.norm_eps)
        logits = self.logits(params, x)[:, 0]
        new_state = dict(state)
        yi = iter(ys)
        if na:
            new_state["k"], new_state["v"] = next(yi), next(yi)
            if kv_quant:
                new_state["k_scale"] = next(yi)
                new_state["v_scale"] = next(yi)
        if ns:
            new_state["ssm_h"], new_state["conv"] = next(yi), next(yi)
        new_state["index"] = index + 1
        return logits, new_state

    # ------------------------------------------------------------ prefill
    def prefill(self, params: dict, tokens: jnp.ndarray,
                enc_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Inference prefill: forward trunk, return last-token logits.
        (Cache filling for the serve path is exercised by decode cells; the
        prefill dry-run cell measures the forward cost, MaxText-style.)"""
        h, _ = self.trunk(params, tokens, enc_embeds)
        return self.logits(params, h[:, -1:, :])[:, 0]
