"""GQA attention: chunked-flash training path + KV-cache decode path."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .act_sharding import constrain, constrain_batch, model_axis_size
from .config import ArchConfig
from .layers import apply_rope

NEG_INF = -1e30


def make_attn_params(mk, cfg: ArchConfig, cross: bool = False,
                     extra_axes: tuple = ()) -> dict:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    if cross:
        K = cfg.n_heads  # cross-attention: full MHA
    ea = tuple(extra_axes)
    pre = ("layers",) * len(ea)
    return {
        "wq": mk(ea + (D, H, hd), pre + ("embed", "heads", "head_dim")),
        "wk": mk(ea + (D, K, hd), pre + ("embed", "kv", "head_dim")),
        "wv": mk(ea + (D, K, hd), pre + ("embed", "kv", "head_dim")),
        "wo": mk(ea + (H, hd, D), pre + ("heads", "head_dim", "embed")),
    }


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, K, hd) → (B, S, K*n_rep, hd)."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)) \
        .reshape(b, s, kh * n_rep, hd)


def _attn_shard_mode(n_heads: int) -> str:
    """How attention compute splits over the "model" axis (§Perf iter 2):
      "heads" — classic Megatron head parallelism (H % model == 0);
      "seq"   — sequence-parallel q (context-parallel-lite) when the head
                count doesn't divide (smollm 15H, starcoder2 24H on 16):
                q/output shard the q-sequence; K/V are fully replicated
                per device (cheap under GQA — kv streams are small).
    """
    ms = model_axis_size()
    if ms == 1:
        return "none"
    return "heads" if n_heads % ms == 0 else "seq"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, q_chunk: int = 1024,
                    k_chunk: int = 1024,
                    q_offset: int = 0,
                    shard_mode: str = "none",
                    n_rep: int = 1) -> jnp.ndarray:
    """Memory-bounded softmax attention (pure-JAX flash): scan over KV chunks
    with running (max, sum, acc). q (B,Sq,H,hd), k/v (B,Sk,K,hd) with
    H = K·n_rep (GQA kept UN-repeated in the streams — §Perf iter 4: the
    repeated K/V would be streamed/all-gathered at H heads; the repeat
    happens per chunk inside the loop, post-sharding, so each device only
    expands its own head slice).

    Streams stay in the input dtype (bf16); scores/accumulators are f32
    via ``preferred_element_type`` — MXU semantics, half the stream bytes.

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0)."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert K * n_rep == H, (K, n_rep, H)
    scale = hd ** -0.5
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // k_chunk
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0

    qc = q.reshape(B, nq, q_chunk, H, hd)
    kc = k.reshape(B, nk, k_chunk, K, hd)
    vc = v.reshape(B, nk, k_chunk, K, hd)

    q_pos = (q_offset + jnp.arange(Sq)).reshape(nq, q_chunk)
    k_pos = jnp.arange(Sk).reshape(nk, k_chunk)

    # sharding specs for the flash loop state and chunk streams. Dims:
    # carry m/l (B, H, qc); acc (B, H, qc, hd); q chunks (nq, B, qc, H, hd);
    # kv streams (nk, B, kc, K, hd); expanded kv chunk (B, kc, H, hd).
    if shard_mode == "heads":
        c_ml = {0: "batch", 1: "model"}
        c_q = {1: "batch", 3: "model"}
        c_kv = {1: "batch", 3: "model"}          # no-op unless K % ms == 0
        c_exp = {0: "batch", 2: "model"}
    elif shard_mode == "seq":
        c_ml = {0: "batch", 2: "model"}          # shard the q positions
        c_q = {1: "batch", 2: "model"}
        c_kv = {1: "batch"}                      # K/V replicated on model
        c_exp = {0: "batch"}
    else:
        c_ml = {0: "batch"}
        c_q = {1: "batch"}
        c_kv = {1: "batch"}
        c_exp = {0: "batch"}

    def one_q_chunk(qi, q_blk):
        # q_blk (B, qc, H, hd)
        def kv_step(carry, inputs):
            m, l, acc = carry
            # re-pin loop-carry sharding (see act_sharding docstring)
            m = constrain(m, c_ml)
            l = constrain(l, c_ml)
            acc = constrain(acc, c_ml)
            k_blk, v_blk, bias = inputs
            if n_rep > 1:
                # GQA expand on the chunk only (each device expands just
                # its sharded head slice)
                k_blk = constrain(_repeat_kv(k_blk, n_rep), c_exp)
                v_blk = constrain(_repeat_kv(v_blk, n_rep), c_exp)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                # additive f32 (qc, kc) bias instead of a pred mask:
                # `where` on a broadcast pred gets hoisted out of the loop
                # as a (nk, B, H, qc, kc) tensor by XLA (≈ TB-scale);
                # the f32 bias stack is nk·qc·kc·4 bytes (MBs).
                s = s + bias[None, None, :, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] \
                + jnp.einsum("bhqk,bkhd->bhqd",
                             p.astype(v_blk.dtype), v_blk,
                             preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        biases = jnp.where(
            q_pos[qi][None, :, None] >= k_pos[:, None, :],
            0.0, NEG_INF).astype(jnp.float32)          # (nk, qc, kc)
        m0 = constrain(jnp.full((B, H, q_chunk), NEG_INF, jnp.float32),
                       c_ml)
        l0 = constrain(jnp.zeros((B, H, q_chunk), jnp.float32), c_ml)
        a0 = constrain(jnp.zeros((B, H, q_chunk, hd), jnp.float32), c_ml)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (constrain(kc.transpose(1, 0, 2, 3, 4), c_kv),
             constrain(vc.transpose(1, 0, 2, 3, 4), c_kv), biases))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3)                       # (B, qc, H, hd)

    out = jax.lax.map(lambda args: one_q_chunk(*args),
                      (jnp.arange(nq),
                       constrain(qc.transpose(1, 0, 2, 3, 4), c_q)))
    return constrain(out, c_q).transpose(1, 0, 2, 3, 4) \
        .reshape(B, Sq, H, hd).astype(q.dtype)


def attn_forward(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                 positions: jnp.ndarray, causal: bool = True,
                 memory: Optional[jnp.ndarray] = None,
                 q_chunk: int = 1024) -> jnp.ndarray:
    """Training/prefill attention. ``memory`` (B, Sm, D) switches to
    cross-attention (no RoPE on memory side, no causal mask)."""
    src = x if memory is None else memory
    mode = _attn_shard_mode(cfg.n_heads)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    n_rep = 1
    if memory is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        n_rep = cfg.n_heads // cfg.n_kv      # GQA expand happens per-chunk
    # pin the attention compute layout before the flash loops (heads over
    # "model" when divisible, else q-sequence — §Perf iter 2)
    if mode == "heads":
        q = constrain(q, {0: "batch", 2: "model"})
        k = constrain(k, {0: "batch", 2: "model"})   # no-op unless K | ms
        v = constrain(v, {0: "batch", 2: "model"})
    elif mode == "seq":
        q = constrain(q, {0: "batch", 1: "model"})
        k = constrain(k, {0: "batch"})
        v = constrain(v, {0: "batch"})
    out = flash_attention(q, k, v, causal=causal and memory is None,
                          q_chunk=q_chunk, shard_mode=mode, n_rep=n_rep)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ----------------------------------------------------------------------- KV
def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  n_attn_layers: int, dtype=jnp.bfloat16) -> dict:
    K, hd = cfg.n_kv, cfg.head_dim
    shape = (n_attn_layers, batch, max_len, K, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "index": jnp.zeros((), jnp.int32)}


# ------------------------------------------------------------ int8 KV cache
# The paper's fixed-point quantization (§5) applied to the decode roofline
# bottleneck: at 32k context the per-token KV read IS the decode memory
# term (§Roofline), so int8 storage halves it vs bf16. Scales are
# per (batch, position, kv-head) — they factor out of the head_dim
# contraction, so dequantization is exact up to the rounding itself:
#   s  = (q · k̂) · scale_k           (k̂ int8, scale per position/head)
#   out = (w ⊙ scale_v) · v̂
def quantize_kv_token(x: jnp.ndarray):
    """x (B, 1, K, hd) → (int8 values, f32 scale (B, 1, K))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attn_decode_step(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     index: jnp.ndarray,
                     k_scale: jnp.ndarray = None,
                     v_scale: jnp.ndarray = None):
    """One-token GQA self-attention decode. x (B, 1, D);
    k_cache/v_cache (B, Smax, K, hd) stay in cache dtype (bf16, or int8
    with per-(position, head) scales — see quantize_kv_token) — scores are
    accumulated in f32 inside the dots, never materialising an H-head or f32
    copy of the cache. Returns (out (B,1,D), new caches [, new scales])."""
    B = x.shape[0]
    K, hd = cfg.n_kv, cfg.head_dim
    R = cfg.n_heads // K
    quant = k_scale is not None
    pos = jnp.full((B, 1), index, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k_new = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["wk"]),
                       pos, cfg.rope_theta)
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if quant:
        k_q, k_s = quantize_kv_token(k_new)
        v_q, v_s = quantize_kv_token(v_new)
        k_scale = jax.lax.dynamic_update_slice_in_dim(
            k_scale, k_s, index, axis=1)
        v_scale = jax.lax.dynamic_update_slice_in_dim(
            v_scale, v_s, index, axis=1)
        k_new, v_new = k_q, v_q
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), index, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), index, axis=1)

    qg = q.reshape(B, K, R, hd)                                  # grouped q
    kc = k_cache.astype(jnp.bfloat16) if quant else k_cache
    s = jnp.einsum("bkrh,bskh->bkrs", qg, kc,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if quant:
        s = s * k_scale.transpose(0, 2, 1)[:, :, None, :]        # (B,K,1,S)
    Smax = k_cache.shape[1]
    valid = (jnp.arange(Smax) <= index)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    if quant:
        w = (w * v_scale.transpose(0, 2, 1)[:, :, None, :]) \
            .astype(jnp.bfloat16)
        vc = v_cache.astype(jnp.bfloat16)
    else:
        w = w.astype(x.dtype)
        vc = v_cache
    out = jnp.einsum("bkrs,bskh->bkrh", w, vc,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, cfg.n_heads, hd).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if quant:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


def cross_attn_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                      mem_k: jnp.ndarray, mem_v: jnp.ndarray) -> jnp.ndarray:
    """Cross-attention against precomputed encoder K/V (B, Sm, H, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    s = jnp.einsum("bqhk,bshk->bhqs", q, mem_k,
                   preferred_element_type=jnp.float32) * (cfg.head_dim ** -0.5)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", w.astype(x.dtype), mem_v,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_memory_kv(p: dict, memory: jnp.ndarray, dtype=jnp.bfloat16):
    """Precompute cross-attention K/V from encoder output (done once)."""
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"]).astype(dtype)
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"]).astype(dtype)
    return k, v
