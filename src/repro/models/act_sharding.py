"""Activation-sharding constraints (MaxText-style).

XLA's SPMD sharding propagation is weak through ``while`` loops: scan
carries (layer stack, flash-attention m/l/acc state, SSD chunk state) have
no user annotation, and the partitioner frequently resolves them to
REPLICATED — silently un-sharding the batch dimension of every activation
and inflating per-chip memory/compute by the DP degree (observed: smollm
train_4k at 788 GB temp/device before constraints; §Perf iteration 1).

``constrain_batch(x, dim)`` pins the batch dimension of an activation to
the data axes of the ambient mesh. The policy is process-global and set by
the launcher (dryrun/train/serve); the default (None) makes every
constraint a no-op so CPU unit tests and single-device runs are untouched.

Constraints are applied at loop-carry boundaries — the places propagation
actually loses information — not on every intermediate (XLA propagates
fine within straight-line blocks).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_POLICY: dict = {"batch_axes": None, "axis_sizes": {}}


def set_policy(batch_axes: Optional[Sequence[str]],
               axis_sizes: Optional[dict] = None) -> None:
    """batch_axes: mesh axes the batch dim is sharded over (e.g. ("data",)
    or ("pod", "data")); None disables all constraints.
    axis_sizes: mesh axis→size, used for divisibility checks."""
    _POLICY["batch_axes"] = tuple(batch_axes) if batch_axes else None
    _POLICY["axis_sizes"] = dict(axis_sizes or {})


def policy_from_mesh(mesh) -> None:
    import os
    if os.environ.get("REPRO_NO_ACT_SHARDING") == "1":
        # ablation hook: reproduce the §Perf iteration-1/2 baselines
        # (scripts/ablate_sharding.py)
        set_policy(None)
        return
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    set_policy(axes or None, {a: mesh.shape[a] for a in mesh.axis_names})


def clear_policy() -> None:
    set_policy(None)


@contextmanager
def policy(batch_axes, axis_sizes=None):
    old = dict(_POLICY)
    set_policy(batch_axes, axis_sizes)
    try:
        yield
    finally:
        _POLICY.update(old)


def _batch_axes_for(n: int):
    """Largest prefix/suffix of the configured axes that divides n."""
    axes = _POLICY["batch_axes"]
    if not axes:
        return None
    sizes = _POLICY["axis_sizes"]
    total = 1
    for a in axes:
        total *= sizes.get(a, 1)
    if total > 1 and n % total == 0:
        return axes
    # fall back to the innermost axis alone (e.g. global_batch 32 on a
    # 2×16 pod×data factorisation)
    last = axes[-1]
    if sizes.get(last, 1) > 1 and n % sizes[last] == 0:
        return (last,)
    return None


def constrain_batch(x: jax.Array, dim: int = 0) -> jax.Array:
    """Pin x's ``dim`` to the data axes; all other dims unconstrained
    (propagation fills them in). No-op when no policy or not divisible."""
    axes = _batch_axes_for(x.shape[dim])
    if axes is None:
        return x
    spec = [None] * x.ndim
    spec[dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def model_axis_size() -> int:
    if _POLICY["batch_axes"] is None:
        return 1
    return _POLICY["axis_sizes"].get("model", 1)


def constrain(x: jax.Array, spec_map: dict) -> jax.Array:
    """General constraint: {dim: "model"} pins dims to the model axis,
    {dim: "batch"} to the data axes. Dims that don't divide are skipped."""
    if _POLICY["batch_axes"] is None:
        return x
    sizes = _POLICY["axis_sizes"]
    spec = [None] * x.ndim
    any_set = False
    for dim, kind in spec_map.items():
        if kind == "batch":
            axes = _batch_axes_for(x.shape[dim])
            if axes is not None:
                spec[dim] = axes if len(axes) > 1 else axes[0]
                any_set = True
        elif kind == "model":
            ms = sizes.get("model", 1)
            if ms > 1 and x.shape[dim] % ms == 0:
                spec[dim] = "model"
                any_set = True
    if not any_set:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_tree_batch(tree, dim: int = 0):
    return jax.tree.map(lambda a: constrain_batch(a, dim) if a.ndim > dim
                        else a, tree)
