"""Top-k Mixture-of-Experts (GShard-style einsum dispatch with capacity).

Expert weights carry the "experts" logical axis → expert-parallel over the
mesh "model" axis when the expert count divides it (phi3.5-moe/jamba: 16
experts; grok-1: 8 experts → falls back to tensor-parallel d_ff sharding,
see distributed/sharding.py resolution rules).

Dispatch is the dense one-hot einsum formulation: tokens are processed in
groups (sequence chunks) so the dispatch tensor (G, S_g, E, C) stays
bounded; capacity C = ceil(top_k * S_g / E * capacity_factor). Overflowing
tokens are dropped (standard GShard semantics) — their combine weight is 0
and the residual stream passes them through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import make_mlp_params


def make_moe_params(mk, cfg: ArchConfig, extra_axes: tuple = ()) -> dict:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    ea = tuple(extra_axes)
    pre = ("layers",) * len(ea)
    p = {"router": mk(ea + (D, E), pre + ("embed", "experts"))}
    if cfg.mlp == "swiglu":
        p["w_gate"] = mk(ea + (E, D, F), pre + ("experts", "embed", "ff"))
        p["w_up"] = mk(ea + (E, D, F), pre + ("experts", "embed", "ff"))
        p["w_down"] = mk(ea + (E, F, D), pre + ("experts", "ff", "embed"))
    else:
        p["w_up"] = mk(ea + (E, D, F), pre + ("experts", "embed", "ff"))
        p["w_down"] = mk(ea + (E, F, D), pre + ("experts", "ff", "embed"))
    return p


def moe_forward(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                group_size: int = 1024) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) → (out (B, S, D), aux_loss ()). Top-k routing with
    capacity; aux = load-balancing loss (Switch §2.2)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    tokens = x.reshape(B * S, D)
    n_tok = B * S
    g = max(1, n_tok // group_size) if n_tok >= group_size else 1
    sg = n_tok // g
    xt = tokens[: g * sg].reshape(g, sg, D)

    logits = jnp.einsum("gsd,de->gse", xt, p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    # capacity: GShard formula for large groups; lossless (cap = group
    # size) for small groups — decode steps route a handful of tokens and
    # must produce the same result as the teacher-forced forward pass
    # (tests/test_models_smoke.py::test_decode_matches_forward).
    if sg <= 256:
        cap = sg
    else:
        cap = max(1, int(k * sg / E * cfg.capacity_factor))

    # top-k gating with per-expert capacity via cumulative position
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # (g, sg, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)      # (g, sg, k, E)
    # position of each (token, choice) in its expert's queue
    flat = onehot.reshape(g, sg * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (g, sg*k, E)
    pos = pos.reshape(g, sg, k, E)
    within = (pos < cap) & (onehot > 0)
    slot = (pos * onehot).sum(-1).astype(jnp.int32)              # (g, sg, k)
    keep = within.any(-1)                                        # (g, sg, k)

    # dispatch (g, sg, E, cap) / combine with gate weights
    slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32)       # (g, sg, k, cap)
    disp = jnp.einsum("gske,gskc->gsec", onehot * keep[..., None], slot_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec", onehot * keep[..., None],
                      slot_oh, gate_vals)

    xe = jnp.einsum("gsec,gsd->gecd", disp, xt)                  # (g,E,cap,D)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["w_up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    out = jnp.einsum("gsec,gecd->gsd", comb, ye)

    out = out.reshape(g * sg, D)
    if g * sg < n_tok:                                           # ragged tail
        out = jnp.concatenate(
            [out, jnp.zeros((n_tok - g * sg, D), out.dtype)], axis=0)
    out = out.reshape(B, S, D).astype(x.dtype)

    # load-balance aux loss: E * Σ_e f_e · p_e
    f = onehot.mean(axis=(1, 2))                                 # (g, E) frac
    pm = probs.mean(axis=1)                                      # (g, E)
    aux = (E * (f * pm).sum(-1)).mean()
    return out, aux
