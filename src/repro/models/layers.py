"""Shared layers: param factory with logical sharding axes, norms, RoPE,
MLPs, embeddings. Pure JAX (no flax) — params are nested dicts of arrays,
and an identically-structured tree of *logical axis* tuples is built by the
same code (``SpecMaker``), so sharding rules live in one place
(distributed/sharding.py)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# Param factory
# --------------------------------------------------------------------------- #
class RealMaker:
    """Creates initialized arrays. fan_in init: normal(0, 1/sqrt(fan_in))."""

    def __init__(self, rng: jax.Array, dtype=jnp.float32):
        self.rng = rng
        self.dtype = dtype

    def _next(self):
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def __call__(self, shape: Sequence[int], logical: Sequence[str],
                 init: str = "fan_in") -> jnp.ndarray:
        shape = tuple(shape)
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "embed":
            scale = 1.0
        elif init == "fan_in":
            # fan-in = product of all dims except the last
            fan = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            scale = fan ** -0.5
        else:
            raise ValueError(init)
        return jax.random.normal(self._next(), shape, self.dtype) * scale


class SpecMaker:
    """Returns the logical-axis tuple instead of an array (same call sites)."""

    def __call__(self, shape, logical, init="fan_in"):
        assert len(shape) == len(logical), (shape, logical)
        return tuple(logical)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


# --------------------------------------------------------------------------- #
# Rotary position embedding
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x (..., S, H, hd), positions (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                               # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                     # (...,S,1,hd/2)
    sin = sin[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
def make_mlp_params(mk, d_model: int, d_ff: int, kind: str,
                    extra_axes: tuple = ()) -> dict:
    ea = tuple(extra_axes)
    pre = ("layers",) * len(ea)
    if kind == "swiglu":
        return {
            "w_gate": mk(ea + (d_model, d_ff), pre + ("embed", "ff")),
            "w_up": mk(ea + (d_model, d_ff), pre + ("embed", "ff")),
            "w_down": mk(ea + (d_ff, d_model), pre + ("ff", "embed")),
        }
    return {
        "w_up": mk(ea + (d_model, d_ff), pre + ("embed", "ff")),
        "w_down": mk(ea + (d_ff, d_model), pre + ("ff", "embed")),
    }


def mlp_forward(p: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_up"]))
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #
def make_embed_params(mk, vocab: int, d_model: int) -> dict:
    return {
        "embedding": mk((vocab, d_model), ("vocab", "embed"), init="embed"),
        "lm_head": mk((d_model, vocab), ("embed", "vocab")),
        "final_norm": mk((d_model,), ("embed",), init="ones"),
    }
