"""Forest optimizer middle-end: registered, recorded, verifiable IR→IR
passes running between ``quantize`` and ``layout`` in the compile
pipeline (``core/pipeline.py``).

The paper's Table 4 observation — equivalent-node merging and threshold
collapse (especially after quantization) shrink the work every traversal
does — lives here as compiler passes visible to *every* engine, instead
of inside RapidScorer's compile step.  Five passes ship:

  * ``dedup_thresholds``       — per-feature threshold canonicalization:
    ``-0.0`` → ``+0.0`` (bit-identical thresholds merge in RapidScorer's
    unique table) and dominated-split elimination — a node whose
    per-feature reachable interval already decides its predicate is
    replaced by the taken subtree.  Quantization collapses distinct float
    thresholds onto one grid point, so collapsed forests are where this
    pass bites hardest (the paper's "threshold collapse").
  * ``merge_equivalent_leaves`` — generalizes RapidScorer's equivalent-
    node merging to the IR: a split whose two children are leaves with
    bit-identical values becomes that leaf (applied bottom-up, so whole
    constant subtrees fold).
  * ``compact``                — strip dead padding: rebuild every tree
    (dropping nodes unreachable from the root), shrink the ensemble
    padding width ``L`` to the real per-tree maximum, drop all-zero
    constant trees (they add exactly 0 to every score), and recompute
    ``max_depth``.  Smaller ``L`` directly shrinks every engine's node
    and leaf tables (QuickScorer masks are (T, L-1, W)).
  * ``drop_unused_features``   — remap the feature axis to the columns
    the forest actually reads, recording the remap in
    ``Forest.feat_map`` so ``transform_inputs`` still accepts full-width
    rows (callers never change).
  * ``reorder_trees``          — discriminative-first tree ordering
    (Daghero et al.: ordering determines early-exit efficiency): trees
    whose scores vary most across a validation set (``X_calib``; leaf-
    value spread as the data-free fallback) come first, so cascade
    prefixes decide more rows earlier (``repro.cascade``).

Equivalence contract (docs/OPTIM.md): every pass preserves
``predict_oracle`` over all finite inputs — bit-exactly when the leaf
table is integer (quantized forests: sums reassociate losslessly), and
up to float summation reassociation otherwise (only ``reorder_trees``
even moves the sum order).  ``optimize`` *always* runs the oracle-
equivalence check after the pass list; a pass that breaks it raises
``OptimizationError`` at compile time instead of serving wrong scores.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional, Sequence, Union

import numpy as np

from ..core.forest import Forest
from ..core.quantize import quantize_inputs
from .analysis import n_unique_splits
from .rewrite import Node, count_leaves, extract_tree, leaf, rebuild_forest


class OptimizationError(RuntimeError):
    """An optimizer pass failed its oracle-equivalence check."""


# --------------------------------------------------------------------------- #
# Pass registry (mirrors core/registry.py's engine registry)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class OptPass:
    name: str
    fn: Callable                  # (forest, ctx) -> Forest
    doc: str = ""


OPT_PASSES: dict[str, OptPass] = {}


def register_pass(name: str, *, doc: str = ""):
    """Decorator: register an IR→IR optimizer pass under ``name``.

    The callable takes ``(forest, ctx)`` and returns a Forest computing
    the same function (the equivalence contract above); ``ctx`` may carry
    ``X_calib`` (original-coordinate validation rows)."""
    def deco(fn):
        OPT_PASSES[name] = OptPass(name=name, fn=fn, doc=doc)
        return fn
    return deco


def opt_passes() -> tuple[str, ...]:
    """Registered pass names, in registration order."""
    return tuple(OPT_PASSES)


# optimization levels: O1 = structural shrink, O2 = + interface remap and
# cascade-aware ordering (the passes that change how callers' rows are
# consumed or how stages split, still behavior-preserving end to end)
OPT_LEVELS: dict[int, tuple[str, ...]] = {
    0: (),
    1: ("dedup_thresholds", "merge_equivalent_leaves", "compact"),
    2: ("dedup_thresholds", "merge_equivalent_leaves", "compact",
        "drop_unused_features", "reorder_trees"),
}

OptLike = Union[None, int, str, Sequence[str]]


def resolve_opt(opt: OptLike) -> tuple[tuple[str, ...], str]:
    """Normalize an ``opt=`` request → (pass names, candidate tag).

    Accepts a level (``2``, ``"O2"``, ``"-O2"``) or an explicit sequence
    of registered pass names; ``None`` means O0 (no passes)."""
    if opt is None:
        return (), "O0"
    if isinstance(opt, str):
        s = opt.lstrip("-")
        if s[:1] in ("O", "o"):
            s = s[1:]
        try:
            opt = int(s)
        except ValueError:
            raise ValueError(
                f"unknown opt level {opt!r} (use 0/1/2, 'O2', or a "
                f"sequence of pass names from {opt_passes()})") from None
    if isinstance(opt, (int, np.integer)):
        try:
            return OPT_LEVELS[int(opt)], f"O{int(opt)}"
        except KeyError:
            raise ValueError(f"unknown opt level {opt} "
                             f"(levels: {sorted(OPT_LEVELS)})") from None
    names = tuple(opt)
    unknown = [n for n in names if n not in OPT_PASSES]
    if unknown:
        raise ValueError(f"unknown optimizer pass(es) {unknown}; "
                         f"registered: {opt_passes()}")
    return names, "+".join(names)


# --------------------------------------------------------------------------- #
# Per-pass stats
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ForestStats:
    n_trees: int
    n_nodes: int                   # real internal nodes over the ensemble
    n_unique_splits: int           # unique (feature, threshold) pairs
    n_leaves: int                  # padded width L
    n_features: int
    max_depth: int

    @classmethod
    def of(cls, forest: Forest) -> "ForestStats":
        return cls(n_trees=forest.n_trees,
                   n_nodes=int(forest.n_nodes.sum()),
                   n_unique_splits=n_unique_splits(forest),
                   n_leaves=forest.n_leaves,
                   n_features=forest.n_features,
                   max_depth=forest.max_depth)


@dataclass(frozen=True)
class PassStats:
    """Before/after snapshot of one optimizer pass (plan-record payload)."""
    name: str
    before: ForestStats
    after: ForestStats

    def detail(self) -> str:
        b, a = self.before, self.after
        parts = [f"nodes {b.n_nodes}→{a.n_nodes}",
                 f"thr {b.n_unique_splits}→{a.n_unique_splits}"]
        if b.n_trees != a.n_trees:
            parts.append(f"T {b.n_trees}→{a.n_trees}")
        if b.n_leaves != a.n_leaves:
            parts.append(f"L {b.n_leaves}→{a.n_leaves}")
        if b.n_features != a.n_features:
            parts.append(f"d {b.n_features}→{a.n_features}")
        if b.max_depth != a.max_depth:
            parts.append(f"depth {b.max_depth}→{a.max_depth}")
        return ", ".join(parts)


@dataclass
class OptResult:
    forest: Forest
    stats: list = field(default_factory=list)   # [PassStats]
    tag: str = "O0"
    verified: Optional[str] = None   # "bit-exact" | "allclose" | None

    def describe(self) -> str:
        b = self.stats[0].before if self.stats else None
        a = self.stats[-1].after if self.stats else None
        if b is None:
            return f"{self.tag}: no passes"
        return (f"{self.tag}: {len(self.stats)} passes, "
                f"nodes {b.n_nodes}→{a.n_nodes}, "
                f"thr {b.n_unique_splits}→{a.n_unique_splits}, "
                f"verified {self.verified or 'off'}")


# --------------------------------------------------------------------------- #
# The five passes
# --------------------------------------------------------------------------- #
def _canon_threshold(t, is_float: bool):
    # -0.0 and +0.0 compare equal in every predicate but differ bitwise,
    # so they'd stay two entries in RapidScorer's unique-split table
    if is_float and t == 0:
        return type(t)(0.0)
    return t


@register_pass("dedup_thresholds",
               doc="canonicalize thresholds (-0.0→+0.0) and remove "
                   "dominated splits via per-feature interval reasoning")
def dedup_thresholds(forest: Forest, ctx: dict) -> Forest:
    is_float = np.issubdtype(forest.threshold.dtype, np.floating)

    def walk(nd: Node, bounds: dict) -> Node:
        if nd.is_leaf:
            return nd
        f = nd.feature
        t = _canon_threshold(nd.threshold, is_float)
        lo, hi = bounds.get(f, (-np.inf, np.inf))
        # reachable inputs satisfy lo < x[f] <= hi (finite inputs):
        # the predicate x <= t is decided when t covers the interval
        if t >= hi:
            return walk(nd.left, bounds)
        if t <= lo:
            return walk(nd.right, bounds)
        l = walk(nd.left, {**bounds, f: (lo, t)})
        r = walk(nd.right, {**bounds, f: (t, hi)})
        return Node(feature=f, threshold=t, left=l, right=r)

    roots = [walk(extract_tree(forest, t), {})
             for t in range(forest.n_trees)]
    return rebuild_forest(forest, roots)


@register_pass("merge_equivalent_leaves",
               doc="fold splits whose subtrees are bit-identical "
                   "constants into a single leaf (RapidScorer Table 4, "
                   "generalized to the IR)")
def merge_equivalent_leaves(forest: Forest, ctx: dict) -> Forest:
    def walk(nd: Node) -> Node:
        if nd.is_leaf:
            return nd
        l, r = walk(nd.left), walk(nd.right)
        if l.is_leaf and r.is_leaf and \
                l.value.tobytes() == r.value.tobytes():
            return l           # bit-identical either way → exact merge
        return Node(feature=nd.feature, threshold=nd.threshold,
                    left=l, right=r)

    roots = [walk(extract_tree(forest, t)) for t in range(forest.n_trees)]
    return rebuild_forest(forest, roots)


@register_pass("compact",
               doc="strip dead padding: drop unreachable nodes and "
                   "all-zero constant trees, shrink L to the real "
                   "maximum, recompute max_depth")
def compact(forest: Forest, ctx: dict) -> Forest:
    roots, kept = [], []
    for t in range(forest.n_trees):
        root = extract_tree(forest, t)
        if root.is_leaf and not root.value.any():
            continue           # contributes exactly 0 to every score
        roots.append(root)
        kept.append(t)
    if not roots:               # keep the forest well-formed (T >= 1)
        roots = [leaf(np.zeros(forest.n_classes,
                               dtype=forest.leaf_value.dtype))]
    return rebuild_forest(forest, roots,
                          n_leaves=max(count_leaves(r) for r in roots))


@register_pass("drop_unused_features",
               doc="remap the feature axis to the referenced columns; "
                   "Forest.feat_map keeps transform_inputs full-width")
def drop_unused_features(forest: Forest, ctx: dict) -> Forest:
    valid = forest.feature >= 0
    used = np.unique(forest.feature[valid]).astype(np.int64)
    if used.size == forest.n_features:
        return forest           # every column referenced — nothing to drop
    remap = np.full(forest.n_features, -1, dtype=forest.feature.dtype)
    remap[used] = np.arange(used.size, dtype=forest.feature.dtype)
    feature = np.where(valid, remap[np.maximum(forest.feature, 0)],
                       forest.feature.dtype.type(-1))
    # compose with an existing remap so feat_map always indexes the
    # caller's original row layout; the caller-side width is preserved
    # through compositions (n_features_in resolves the existing map's)
    feat_map = used if forest.feat_map is None \
        else np.asarray(forest.feat_map, dtype=np.int64)[used]
    return replace(
        forest, n_features=int(used.size), feature=feature,
        feat_map=feat_map, n_features_src=forest.n_features_in,
        feat_lo=None if forest.feat_lo is None else forest.feat_lo[used],
        feat_hi=None if forest.feat_hi is None else forest.feat_hi[used])


def per_tree_scores(forest: Forest, X: np.ndarray) -> np.ndarray:
    """(T, B, C) float64 per-tree oracle scores on IR-coordinate inputs
    (the ``reorder_trees`` cost model; also handy in tests)."""
    B = X.shape[0]
    out = np.zeros((forest.n_trees, B, forest.n_classes), dtype=np.float64)
    for t in range(forest.n_trees):
        if forest.n_nodes[t] == 0:
            out[t] = forest.leaf_value[t, 0]
            continue
        node = np.zeros(B, dtype=np.int32)
        done = np.zeros(B, dtype=bool)
        lf = np.zeros(B, dtype=np.int32)
        for _ in range(forest.max_depth + 1):
            f = forest.feature[t, node]
            go_left = X[np.arange(B), np.maximum(f, 0)] \
                <= forest.threshold[t, node]
            nxt = np.where(go_left, forest.left[t, node],
                           forest.right[t, node])
            is_leaf = nxt < 0
            lf = np.where(~done & is_leaf, -nxt - 1, lf)
            done |= is_leaf
            node = np.where(is_leaf, node, nxt)
            if done.all():
                break
        out[t] = forest.leaf_value[t, lf]
    return out


_REORDER_MAX_ROWS = 256            # cost-model rows (cheap, stable ranking)


@register_pass("reorder_trees",
               doc="discriminative-first tree order (validation-set "
                   "score variance; leaf-value spread fallback) so "
                   "cascade prefixes decide rows earlier")
def reorder_trees(forest: Forest, ctx: dict) -> Forest:
    X_val = (ctx or {}).get("X_calib")
    if X_val is not None and np.asarray(X_val).size:
        Xe = quantize_inputs(forest,
                             np.asarray(X_val)[:_REORDER_MAX_ROWS])
        S = per_tree_scores(forest, Xe)                     # (T, B, C)
        disc = ((S - S.mean(axis=1, keepdims=True)) ** 2).mean(axis=(1, 2))
    else:
        # data-free fallback: a tree's score can move a row by at most
        # its leaf-value spread — order by that bound
        lv = forest.leaf_value.astype(np.float64)
        real = np.arange(forest.n_leaves)[None, :] \
            < forest.n_leaves_per_tree[:, None]
        hi = np.where(real[..., None], lv, -np.inf).max(axis=1)
        lo = np.where(real[..., None], lv, np.inf).min(axis=1)
        disc = (hi - lo).sum(axis=1)
    order = np.argsort(-disc, kind="stable")
    if (order == np.arange(forest.n_trees)).all():
        return forest
    return replace(
        forest,
        feature=forest.feature[order], threshold=forest.threshold[order],
        left=forest.left[order], right=forest.right[order],
        leaf_lo=forest.leaf_lo[order], leaf_mid=forest.leaf_mid[order],
        leaf_hi=forest.leaf_hi[order], leaf_value=forest.leaf_value[order],
        n_nodes=forest.n_nodes[order],
        n_leaves_per_tree=forest.n_leaves_per_tree[order])


# --------------------------------------------------------------------------- #
# Oracle-equivalence verification (mandatory on every optimize() run)
# --------------------------------------------------------------------------- #
def _relative_map(before: Forest, after: Forest):
    """Column map from ``before``'s IR coordinates to ``after``'s (the
    delta the pass list added on top of any pre-existing feat_map)."""
    if after.feat_map is None:
        return None
    if before.feat_map is None:
        return np.asarray(after.feat_map, dtype=np.int64)
    pos = {int(c): i for i, c in enumerate(before.feat_map)}
    return np.array([pos[int(c)] for c in after.feat_map], dtype=np.int64)


def _check_inputs(forest: Forest, n_check: int, seed: int) -> np.ndarray:
    """Adversarial IR-coordinate inputs: random rows over the threshold
    range plus rows pinned exactly on each (finite) threshold — boundary
    rows are where a broken rewrite shows first."""
    rng = np.random.default_rng(seed)
    d = forest.n_features
    valid = forest.feature >= 0
    thr = forest.threshold[valid].astype(np.float64)
    thr = thr[np.isfinite(thr)]
    lo = float(thr.min()) - 2.0 if thr.size else -2.0
    hi = float(thr.max()) + 2.0 if thr.size else 2.0
    if np.issubdtype(forest.threshold.dtype, np.integer):
        X = rng.integers(int(np.floor(lo)), int(np.ceil(hi)) + 1,
                         size=(n_check, d)).astype(np.int64)
    else:
        X = rng.uniform(lo, hi, size=(n_check, d))
    if d:
        feats = np.maximum(forest.feature, 0)[valid]
        fin = np.isfinite(forest.threshold[valid].astype(np.float64))
        for i, (f, t) in enumerate(zip(feats[fin][:n_check],
                                       forest.threshold[valid][fin])):
            X[i, int(f)] = t
    return X


def verify_equivalence(before: Forest, after: Forest, *,
                       n_check: int = 64, seed: int = 0) -> str:
    """Check ``after`` computes the same scores as ``before`` — bit-exact
    when the leaf table is integer, within float-reassociation tolerance
    otherwise.  Raises ``OptimizationError`` on any divergence; returns
    the mode that held ("bit-exact" / "allclose")."""
    X = _check_inputs(before, n_check, seed)
    rel = _relative_map(before, after)
    Xa = X if rel is None else X[:, rel]
    got = after.predict_oracle(Xa)
    expect = before.predict_oracle(X)
    if np.issubdtype(before.leaf_value.dtype, np.integer):
        if not np.array_equal(got, expect):
            row = int(np.abs(got - expect).max(axis=1).argmax())
            raise OptimizationError(
                f"optimized forest diverges from the source oracle "
                f"(bit-exact contract, quantized leaves): row {row}, "
                f"{got[row]} vs {expect[row]}")
        return "bit-exact"
    if not np.allclose(got, expect, rtol=1e-5, atol=1e-7):
        err = float(np.abs(got - expect).max())
        raise OptimizationError(
            f"optimized forest diverges from the source oracle "
            f"(max |err| = {err:.3e} over {n_check} rows)")
    return "allclose"


# --------------------------------------------------------------------------- #
# Driver
# --------------------------------------------------------------------------- #
def optimize(forest: Forest, opt: OptLike = 1, *,
             ctx: Optional[dict] = None, verify: bool = True,
             n_check: int = 64, seed: int = 0) -> OptResult:
    """Run an optimization level (or explicit pass list) on ``forest``.

    Returns an ``OptResult`` carrying the optimized forest, per-pass
    before/after ``PassStats``, and the verification mode.  The compile
    pipeline's ``optimize`` pass (``compile_forest(..., opt=...)``) calls
    this and turns each ``PassStats`` into a ``CompilePlan`` record.

    ``verify=False`` skips the oracle check — for timing experiments
    only; the pipeline always verifies."""
    names, tag = resolve_opt(opt)
    ctx = ctx or {}
    out = forest
    stats: list[PassStats] = []
    before = ForestStats.of(forest) if names else None
    for name in names:
        out = OPT_PASSES[name].fn(out, ctx)
        after = ForestStats.of(out)   # carried forward: one scan per pass
        stats.append(PassStats(name, before, after))
        before = after
    mode = None
    if verify and names:
        mode = verify_equivalence(forest, out, n_check=n_check, seed=seed)
    return OptResult(forest=out, stats=stats, tag=tag, verified=mode)
