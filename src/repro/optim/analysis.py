"""Forest IR analysis shared by the optimizer, RapidScorer, and Table 4.

``unique_splits`` is the generalized form of RapidScorer's equivalent-node
merging (Ye et al. 2018): the ensemble-wide table of unique
(feature, threshold) pairs plus the node → unique-id inverse map.  It
started life inside ``core/rapidscorer.py`` as that engine's private
compile step; the optimizer pass framework (``repro.optim``) needs the
same statistic to measure what ``dedup_thresholds`` achieves, and
``benchmarks/table4_merging.py`` needs it to check the paper's
quantization-collapse claim against the optimizer — so it lives here and
``rapidscorer.merge_nodes`` delegates.

IMPORT HYGIENE: this module deliberately imports nothing from
``repro.core`` — ``core/rapidscorer.py`` (imported by ``repro.core``'s
package init) resolves ``unique_splits`` from here, so an import in the
other direction would deadlock the package inits.  Forests are
duck-typed (only ``feature`` / ``threshold`` / ``n_nodes`` are read).
"""
from __future__ import annotations

import numpy as np


def unique_splits(forest):
    """Unique (feature, threshold) table + inverse map over the ensemble.

    Returns ``(u_feat (U,) int32, u_thr (U,), inv (T, N) int32,
    n_unique)``.  Padding nodes map to unique id 0 but are masked out by
    ``valid`` downstream; the key is bit-exact (float thresholds compared
    by bit pattern, so ``-0.0`` and ``+0.0`` count as distinct — the
    ``dedup_thresholds`` optimizer pass canonicalizes them)."""
    T, N = forest.feature.shape
    valid = (forest.feature >= 0).ravel()
    feat = np.maximum(forest.feature, 0).ravel()
    thr = forest.threshold.ravel()
    key = np.stack([feat.astype(np.int64),
                    thr.astype(np.float64).view(np.int64)], axis=1)
    key[~valid] = np.array([-1, 0])
    uniq, inv = np.unique(key, axis=0, return_inverse=True)
    n_pad = int((uniq[:, 0] == -1).any())
    u_feat = np.maximum(uniq[:, 0], 0).astype(np.int32)
    u_thr = uniq[:, 1].view(np.float64).astype(forest.threshold.dtype)
    return u_feat, u_thr, inv.reshape(T, N).astype(np.int32), len(uniq) - n_pad


def n_unique_splits(forest) -> int:
    """Just the unique-(feature, threshold) count (optimizer pass stats)."""
    *_, n = unique_splits(forest)
    return n


def unique_fraction(forest) -> float:
    """Fraction of unique nodes kept after merging (paper Table 4)."""
    total = int(forest.n_nodes.sum())
    return n_unique_splits(forest) / max(total, 1)
