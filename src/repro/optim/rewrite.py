"""IR ↔ tree rewriting for structural optimizer passes.

The SoA Forest IR is ideal for evaluation but awkward for structural
surgery (collapsing dominated splits, merging equal-leaf subtrees): those
passes want a pointer tree.  This module round-trips one tree at a time:

  * ``extract_tree`` — IR tree ``t`` → a lightweight ``Node`` tree
    (leaf values keep the IR's dtype; thresholds keep their numpy scalar
    type, so a quantized forest survives the round trip bit-exactly);
  * ``rebuild_forest`` — a list of ``Node`` roots → a fresh Forest with
    the *same* dtypes and quantization metadata as the source forest
    (``core.forest.from_trees`` always emits float32, which would wreck
    an int16-threshold quantized forest).

Rebuilding re-derives the canonical invariants (preorder nodes, in-order
leaves, interval spans, real ``max_depth``) — so any pass that rebuilds
automatically drops nodes unreachable from the root.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.forest import Forest


class Node:
    """One tree node: a leaf (``value`` set) or a split (children set)."""
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self, feature=-1, threshold=None, left=None, right=None,
                 value=None):
        self.feature = feature
        self.threshold = threshold
        self.left = left
        self.right = right
        self.value = value

    @property
    def is_leaf(self) -> bool:
        return self.value is not None


def leaf(value: np.ndarray) -> Node:
    return Node(value=np.asarray(value))


def split(feature: int, threshold, left: Node, right: Node) -> Node:
    return Node(feature=feature, threshold=threshold, left=left, right=right)


def extract_tree(forest: Forest, t: int) -> Node:
    """IR tree ``t`` → ``Node`` tree (root is IR node 0; single-leaf
    trees come back as a bare leaf)."""
    if int(forest.n_nodes[t]) == 0:
        return leaf(forest.leaf_value[t, 0].copy())

    def walk(code: int) -> Node:
        if code < 0:
            return leaf(forest.leaf_value[t, -code - 1].copy())
        return split(int(forest.feature[t, code]),
                     forest.threshold[t, code],
                     walk(int(forest.left[t, code])),
                     walk(int(forest.right[t, code])))

    return walk(0)


def count_leaves(root: Node) -> int:
    return 1 if root.is_leaf else (count_leaves(root.left)
                                   + count_leaves(root.right))


def rebuild_forest(forest: Forest, roots: list[Node],
                   n_leaves: Optional[int] = None) -> Forest:
    """Canonicalise ``roots`` into a Forest with ``forest``'s dtypes and
    metadata.  ``n_leaves=None`` keeps the source padding width (so a
    single pass's effect stays observable); pass the real maximum (or
    anything >= it) to shrink — ``compact`` does."""
    T = len(roots)
    L = forest.n_leaves if n_leaves is None else max(int(n_leaves), 2)
    C = forest.n_classes
    feature = np.full((T, L - 1), -1, dtype=forest.feature.dtype)
    threshold = np.zeros((T, L - 1), dtype=forest.threshold.dtype)
    left = np.zeros((T, L - 1), dtype=forest.left.dtype)
    right = np.zeros((T, L - 1), dtype=forest.right.dtype)
    leaf_lo = np.zeros((T, L - 1), dtype=forest.leaf_lo.dtype)
    leaf_mid = np.zeros((T, L - 1), dtype=forest.leaf_mid.dtype)
    leaf_hi = np.zeros((T, L - 1), dtype=forest.leaf_hi.dtype)
    leaf_value = np.zeros((T, L, C), dtype=forest.leaf_value.dtype)
    n_nodes = np.zeros(T, dtype=forest.n_nodes.dtype)
    n_leaves_per_tree = np.zeros(T, dtype=forest.n_leaves_per_tree.dtype)
    max_depth = 1

    for t, root in enumerate(roots):
        nodes: list[Node] = []
        spans: dict[int, tuple[int, int, int]] = {}
        leaf_ctr = 0

        def walk(nd: Node, depth: int) -> tuple[int, int]:
            nonlocal leaf_ctr, max_depth
            max_depth = max(max_depth, depth)
            if nd.is_leaf:
                j = leaf_ctr
                leaf_ctr += 1
                leaf_value[t, j, :] = nd.value
                return j, j + 1
            nodes.append(nd)
            lo, mid = walk(nd.left, depth + 1)
            _, hi = walk(nd.right, depth + 1)
            spans[id(nd)] = (lo, mid, hi)
            return lo, hi

        walk(root, 1)
        index = {id(nd): i for i, nd in enumerate(nodes)}
        leaf_ctr2 = 0

        def walk2(nd: Node) -> int:
            nonlocal leaf_ctr2
            if nd.is_leaf:
                j = leaf_ctr2
                leaf_ctr2 += 1
                return -(j + 1)
            i = index[id(nd)]
            lcode = walk2(nd.left)
            rcode = walk2(nd.right)
            feature[t, i] = nd.feature
            threshold[t, i] = nd.threshold
            left[t, i] = lcode
            right[t, i] = rcode
            leaf_lo[t, i], leaf_mid[t, i], leaf_hi[t, i] = spans[id(nd)]
            return i

        walk2(root)
        n_nodes[t] = len(nodes)
        n_leaves_per_tree[t] = leaf_ctr

    return Forest(
        n_trees=T, n_leaves=L, n_classes=C, n_features=forest.n_features,
        feature=feature, threshold=threshold, left=left, right=right,
        leaf_lo=leaf_lo, leaf_mid=leaf_mid, leaf_hi=leaf_hi,
        leaf_value=leaf_value, n_nodes=n_nodes,
        n_leaves_per_tree=n_leaves_per_tree, max_depth=max_depth,
        quant_scale=forest.quant_scale, quant_bits=forest.quant_bits,
        leaf_scale=forest.leaf_scale, feat_lo=forest.feat_lo,
        feat_hi=forest.feat_hi, feat_map=forest.feat_map,
        n_features_src=forest.n_features_src)
