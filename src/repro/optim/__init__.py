"""repro.optim — the forest optimizer middle-end (docs/OPTIM.md).

IR→IR passes running between ``quantize`` and ``layout`` in the compile
pipeline.  Typical use is through the pipeline::

    pred = core.compile_forest(forest, engine="bitvector", opt=2)
    print(pred.plan.describe())       # per-pass before/after stats

or standalone::

    from repro import optim
    res = optim.optimize(forest, 2)   # OptResult: forest + stats,
    res.forest                        # oracle-equivalence verified

Passes register through ``register_pass`` (mirroring the engine
registry); ``OPT_LEVELS`` groups them into -O0/-O1/-O2.  The autotuner
sweeps levels as ``<engine>@O2`` candidates
(``engine_select.choose(..., opt_levels=(1, 2))``).
"""
# .analysis first: it must stay import-light (numpy only) because
# core/rapidscorer.py resolves unique_splits from it during
# `import repro.core` — see the note in analysis.py
from .analysis import n_unique_splits, unique_fraction, unique_splits
from .rewrite import Node, extract_tree, rebuild_forest
from .passes import (OPT_LEVELS, OPT_PASSES, ForestStats, OptimizationError,
                     OptPass, OptResult, PassStats, opt_passes, optimize,
                     per_tree_scores, register_pass, resolve_opt,
                     verify_equivalence)

__all__ = [
    "unique_splits", "n_unique_splits", "unique_fraction",
    "Node", "extract_tree", "rebuild_forest",
    "OPT_LEVELS", "OPT_PASSES", "OptPass", "OptResult", "PassStats",
    "ForestStats", "OptimizationError", "opt_passes", "optimize",
    "per_tree_scores", "register_pass", "resolve_opt",
    "verify_equivalence",
]
