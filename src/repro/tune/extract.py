"""Autotuner cache → cost-model training rows (docs/AUTOTUNE.md).

Every schema-v2 cache entry already carries everything a training row
needs: ``meta`` (the ``engine_select.shape_meta`` feature view — forest
shape, batch bucket, backend, device fingerprint) and per-candidate
``bench_us`` labels (steady-state microseconds per instance) with
``compile_s`` alongside.  This module flattens that into rows and parses
candidate names back into their per-axis tags — the inverse of
``engine_select._candidate_factories``'s ``cname``.

v1 entries (pre-fingerprint, no ``meta``/``bench_us``) are skipped: they
predate the feature/label contract and their keys can no longer be hit
anyway.
"""
from __future__ import annotations

import os
import re

AXES = ("engine", "quant", "opt", "layout", "cascade", "flint")

_QUANT = re.compile(r"q\d+")


def parse_candidate(name: str) -> dict:
    """Candidate name → per-axis tags.

    Names are ``engine[@qTAG][@flint][@OPT][@kw=v,...][@cascade...=...]``
    (see ``_candidate_factories``).  The segments are self-describing, so
    parsing is order-insensitive: ``flint`` literal, ``cascade...``
    prefix, ``q<bits>...`` quant tags, anything with ``=`` is a layout
    kw set, and the remainder (``O2``, ``dedup_thresholds+compact``) is
    the optimizer tag.  Absent axes parse to ``""`` (``False`` for
    flint) — the cost model one-hots these as their own category."""
    parts = name.split("@")
    axes = {"engine": parts[0], "quant": "", "opt": "", "layout": "",
            "cascade": "", "flint": False}
    for p in parts[1:]:
        if p == "flint":
            axes["flint"] = True
        elif p.startswith("cascade"):
            axes["cascade"] = p
        elif _QUANT.match(p) and not axes["quant"]:
            axes["quant"] = p
        elif "=" in p:
            axes["layout"] = p
        else:
            axes["opt"] = p
    return axes


def rows_from_entries(entries: dict) -> list:
    """Flatten cache entries (``key → entry``, the on-disk layout) into
    training rows: ``{"key", "candidate", "axes", "meta", "us",
    "compile_s"}`` — one row per (shape key, candidate) measurement."""
    rows = []
    for key, entry in entries.items():
        if not isinstance(entry, dict):
            continue
        meta = entry.get("meta")
        bench_us = entry.get("bench_us")
        if not isinstance(meta, dict) or not isinstance(bench_us, dict) \
                or not bench_us:
            continue                  # v1 entry: no feature/label contract
        compile_s = entry.get("compile_s") or {}
        for cand, us in bench_us.items():
            if not isinstance(us, (int, float)) or isinstance(us, bool) \
                    or us <= 0:
                continue
            rows.append({
                "key": key, "candidate": cand,
                "axes": parse_candidate(cand), "meta": meta,
                "us": float(us),
                "compile_s": float(compile_s.get(cand) or 0.0),
            })
    return rows


def extract_rows(paths=None) -> list:
    """Training rows from one or more autotuner cache files.  ``paths``
    may be a single path, a sequence, or ``None`` for the process default
    (``engine_select.default_cache_path()``).  Unreadable or malformed
    files contribute nothing — same degrade-to-resweep posture as the
    cache itself."""
    from ..core import engine_select
    if paths is None:
        paths = [engine_select.default_cache_path()]
    elif isinstance(paths, (str, os.PathLike)):
        paths = [paths]
    rows = []
    for p in paths:
        rows.extend(rows_from_entries(engine_select._load_disk(os.fspath(p))))
    return rows
