"""Learned autotuner cost model — numpy-only ridge ranker + confidence.

The ``-Os`` predictor (docs/AUTOTUNE.md): a regularized linear model on
engineered features of (forest shape × candidate axes × device), fit to
``log(us/instance)`` labels from the autotuner cache.  Log space makes
the model a *ranker* — a constant multiplicative error on every
candidate cancels out of the comparison — and makes the residual spread
directly interpretable as a relative-error band.

Features per (shape, candidate) row:

* numerics — log2(n_trees), log2(n_leaves), max_depth, log2(n_features),
  n_classes, log2(batch), n_devices, flint;
* one-hots — engine, quant tag, opt tag, layout tag, cascade tag,
  backend, device kind, device fingerprint, dtype (vocabulary fixed at
  fit time; an unseen value at predict time marks the candidate
  *unknown*);
* interactions — engine one-hot × every numeric, so each engine gets its
  own shape-scaling slopes (this is what lets the model flip the winner
  between e.g. ``qs-bitmm`` and ``unrolled`` as L grows — the paper's
  shape-dependence finding, learned).

Confidence is the Gaussian probability that the predicted top-1 really
beats the runner-up: with ``gap`` the predicted log-us margin and
``sigma`` the training residual std (floored at ``SIGMA_FLOOR`` so small
training sets cannot claim certainty), two independent errors give
``conf = Phi(gap / (sqrt(2) * sigma))``.  A candidate with
out-of-vocabulary tags cannot be ranked at all: confidence is reported
as ``-1.0``, below any threshold.

No sklearn, no scipy — closed-form ridge via ``np.linalg.solve`` and
``math.erf``.  Persisted as a versioned JSON artifact through
``repro.io.packed.save_cost_model``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .extract import parse_candidate

NUMERIC = ("log_trees", "log_leaves", "depth", "log_features",
           "n_classes", "log_batch", "n_devices", "flint")
GROUPS = ("engine", "quant", "opt", "layout", "cascade", "backend",
          "device_kind", "fingerprint", "dtype")
SIGMA_FLOOR = 0.05        # log-units ≈ 5% relative error: the calibration
#                           floor that keeps tiny training sets honest


def _numeric(meta: dict, axes: dict) -> np.ndarray:
    return np.array([
        math.log2(max(float(meta.get("n_trees", 1)), 1.0)),
        math.log2(max(float(meta.get("n_leaves", 1)), 1.0)),
        float(meta.get("max_depth", 0)),
        math.log2(max(float(meta.get("n_features", 1)), 1.0)),
        float(meta.get("n_classes", 1)),
        math.log2(max(float(meta.get("batch", 1)), 1.0)),
        float(meta.get("n_devices", 1)),
        1.0 if axes.get("flint") else 0.0,
    ])


def _cat(meta: dict, axes: dict, group: str) -> str:
    src = axes if group in ("engine", "quant", "opt", "layout",
                            "cascade") else meta
    return str(src.get(group, ""))


def featurize(vocab: dict, meta: dict, axes: dict) -> tuple:
    """One (shape, candidate) pair → ``(feature vector, known)``.
    ``known`` is False when any categorical value falls outside the fit
    vocabulary — the model has never seen a row like this and its score
    for it is extrapolation, not prediction."""
    num = _numeric(meta, axes)
    parts = [num]
    known = True
    for g in GROUPS:
        vals = vocab.get(g, [])
        oh = np.zeros(len(vals))
        v = _cat(meta, axes, g)
        try:
            oh[vals.index(v)] = 1.0
        except ValueError:
            known = False
        parts.append(oh)
    engines = vocab.get("engine", [])
    inter = np.zeros((len(engines), num.size))
    e = _cat(meta, axes, "engine")
    if e in engines:
        inter[engines.index(e)] = num
    parts.append(inter.ravel())
    return np.concatenate(parts), known


@dataclass
class CostModel:
    """A fitted ranker: ``assess`` scores candidate names for a shape
    (via ``engine_select.shape_meta``); ``save``/``load`` round-trip the
    versioned JSON artifact."""
    weights: np.ndarray               # (D + 1,), trailing bias term
    mu: np.ndarray                    # (D,) feature standardization
    sd: np.ndarray
    resid_sigma: float                # training residual std, log-units
    vocab: dict                       # group → sorted value list
    n_rows: int = 0
    info: dict = field(default_factory=dict)

    def predict_log_us(self, meta: dict,
                       candidates: Sequence[str]) -> tuple:
        """Predicted ``log(us/instance)`` per candidate plus the
        per-candidate known mask."""
        X, known = [], []
        for c in candidates:
            x, k = featurize(self.vocab, meta, parse_candidate(c))
            X.append(x)
            known.append(k)
        Xs = (np.stack(X) - self.mu) / self.sd
        Xs = np.concatenate([Xs, np.ones((Xs.shape[0], 1))], axis=1)
        return Xs @ self.weights, np.array(known, dtype=bool)

    def assess(self, meta: dict, candidates: Sequence[str]) -> dict:
        """Rank ``candidates`` for the shape described by ``meta``.

        Returns ``{"us", "known", "order", "confidence"}``: predicted
        us/instance per candidate, the known mask, candidate indices
        sorted fastest-first (unknowns last), and the top-1 confidence —
        ``-1.0`` when the top pick itself is out-of-vocabulary (never
        trust it), otherwise ``Phi(gap / (sqrt(2)·sigma))`` against the
        best-ranked runner-up."""
        y, known = self.predict_log_us(meta, candidates)
        rank = np.where(known, y, np.inf)
        order = np.argsort(rank, kind="stable")
        i0 = int(order[0])
        if not known[i0]:
            conf = -1.0
        elif len(candidates) == 1:
            conf = 1.0
        else:
            i1 = int(order[1])
            if not known[i1]:
                conf = -1.0
            else:
                sigma = max(float(self.resid_sigma), SIGMA_FLOOR)
                z = float(y[i1] - y[i0]) / (math.sqrt(2.0) * sigma)
                conf = 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))
        return {"us": np.exp(y), "known": known, "order": order,
                "confidence": float(conf)}

    def save(self, path) -> str:
        from ..io import packed
        return packed.save_cost_model(path, {
            "numeric": list(NUMERIC), "groups": list(GROUPS),
            "weights": [float(w) for w in self.weights],
            "mu": [float(v) for v in self.mu],
            "sd": [float(v) for v in self.sd],
            "resid_sigma": float(self.resid_sigma),
            "vocab": {g: list(v) for g, v in self.vocab.items()},
            "n_rows": int(self.n_rows), "info": dict(self.info),
        })

    @classmethod
    def load(cls, path) -> "CostModel":
        from ..io import packed
        doc = packed.load_cost_model(path)
        if tuple(doc.get("numeric", ())) != NUMERIC or \
                tuple(doc.get("groups", ())) != GROUPS:
            raise ValueError(
                f"{path!r} was fit with a different feature layout "
                f"than this build understands — retrain "
                f"(repro.tune.train_from_cache)")
        try:
            return cls(weights=np.asarray(doc["weights"], dtype=float),
                       mu=np.asarray(doc["mu"], dtype=float),
                       sd=np.asarray(doc["sd"], dtype=float),
                       resid_sigma=float(doc["resid_sigma"]),
                       vocab={g: list(v)
                              for g, v in doc["vocab"].items()},
                       n_rows=int(doc.get("n_rows", 0)),
                       info=dict(doc.get("info") or {}))
        except (KeyError, TypeError) as e:
            raise ValueError(f"{path!r}: malformed cost model: {e}") from e


def fit_cost_model(rows: list, l2: float = 1e-3) -> CostModel:
    """Closed-form ridge fit of ``log(us/instance)`` on the extracted
    rows (``repro.tune.extract_rows``).  ``l2`` regularizes everything
    but the bias; the residual std becomes the confidence scale."""
    if len(rows) < 2:
        raise ValueError(
            f"need at least 2 training rows, got {len(rows)} — run some "
            "measured sweeps first (the cache is the training set)")
    vocab = {g: sorted({_cat(r["meta"], r["axes"], g) for r in rows})
             for g in GROUPS}
    X = np.stack([featurize(vocab, r["meta"], r["axes"])[0]
                  for r in rows])
    y = np.log(np.maximum(np.array([r["us"] for r in rows]), 1e-9))
    mu = X.mean(axis=0)
    sd = X.std(axis=0)
    sd[sd == 0.0] = 1.0
    Xs = np.concatenate([(X - mu) / sd, np.ones((X.shape[0], 1))], axis=1)
    A = Xs.T @ Xs + l2 * np.eye(Xs.shape[1])
    A[-1, -1] -= l2                  # unpenalized bias
    w = np.linalg.solve(A, Xs.T @ y)
    resid = y - Xs @ w
    sigma = float(np.sqrt(np.mean(resid ** 2)))
    return CostModel(weights=w, mu=mu, sd=sd,
                     resid_sigma=max(sigma, SIGMA_FLOOR), vocab=vocab,
                     n_rows=len(rows),
                     info={"l2": float(l2),
                           "label": "log_us_per_instance"})


def train_from_cache(cache_path=None, save_to=None,
                     l2: float = 1e-3) -> CostModel:
    """One-call training loop: extract rows from the autotuner cache
    (default: ``engine_select.default_cache_path()``), fit, and — when
    ``save_to`` is given — persist the artifact where
    ``choose(mode="predict")`` will find it (pass
    ``engine_select.default_model_path()`` for the default)."""
    from .extract import extract_rows
    model = fit_cost_model(extract_rows(cache_path), l2=l2)
    if save_to:
        model.save(save_to)
    return model
