"""repro.tune — learned cost model over the autotuner cache (``-Os``).

The measured autotuner (``core.engine_select.choose``) is ground truth
but O(product) compiles per new shape; this package turns its
accumulated cache history into a zero-shot predictor (ROADMAP item 3,
docs/AUTOTUNE.md)::

    from repro import tune
    from repro.core import engine_select

    # after some measured sweeps have populated the cache:
    model = tune.train_from_cache(
        save_to=engine_select.default_model_path())

    # new shapes now compile once, not O(product) times:
    choice = engine_select.choose(forest, 256, mode="predict")

``extract_rows`` flattens schema-v2 cache entries into feature rows,
``fit_cost_model`` is the numpy-only ridge ranker with a calibrated
confidence score, and ``CostModel.save``/``load`` round-trip the
versioned JSON artifact (``repro.io.packed``).
"""
from .extract import AXES, extract_rows, parse_candidate, rows_from_entries
from .model import (GROUPS, NUMERIC, SIGMA_FLOOR, CostModel, featurize,
                    fit_cost_model, train_from_cache)

__all__ = [
    "AXES", "GROUPS", "NUMERIC", "SIGMA_FLOOR",
    "CostModel", "featurize", "fit_cost_model", "train_from_cache",
    "extract_rows", "parse_candidate", "rows_from_entries",
]
