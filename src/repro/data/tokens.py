"""Deterministic LM token pipeline.

The container is offline, so the pipeline synthesises a corpus with a
Zipfian unigram distribution + Markov bigram structure (so the loss has
learnable signal and a well-defined floor). Deterministic in
(seed, step, shard) — a restarted/elastically-resized job regenerates the
exact same global batch for a given step, which is what makes the
checkpoint-restart tests bit-reproducible.

Multi-host note: each process materialises only its addressable slice of
the global batch (`host_slice`); the global batch is defined by (seed,
step) alone, not by the number of hosts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_markov_states: int = 256      # bigram structure strength


class SyntheticTokens:
    """step → (global_batch, seq_len) int32 tokens, deterministically."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        V, K = cfg.vocab, min(cfg.n_markov_states, cfg.vocab)
        # Zipf unigram over the vocab
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / (1.0 / ranks).sum()
        # Markov state machine: state → biased token subset
        self._state_shift = base.integers(0, V, size=K)
        self._K = K

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
        u = rng.random(size=(B, S))
        # inverse-CDF sample of the Zipf unigram
        cdf = np.cumsum(self._unigram)
        toks = np.searchsorted(cdf, u).astype(np.int64)
        # bigram structure: token t+1 is shifted by a state keyed on token t
        state = toks[:, :-1] % self._K
        mix = rng.random(size=(B, S - 1)) < 0.5
        toks[:, 1:] = np.where(
            mix, (toks[:, 1:] + self._state_shift[state]) % V, toks[:, 1:])
        return toks.astype(np.int32)

    def host_slice(self, step: int, proc_index: int,
                   proc_count: int) -> np.ndarray:
        """Per-host shard of the global batch (contiguous rows)."""
        g = self.batch(step)
        B = g.shape[0]
        assert B % proc_count == 0, (B, proc_count)
        per = B // proc_count
        return g[proc_index * per:(proc_index + 1) * per]

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
