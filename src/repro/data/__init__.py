from .datasets import Dataset, REGISTRY, load

__all__ = ["Dataset", "REGISTRY", "load"]
