"""Synthetic stand-ins for the paper's datasets.

The container is offline, so Magic/Adult/EEG/MNIST/Fashion/MSN cannot be
downloaded. Each generator is deterministic and matches its dataset's
*signature* — (n_features, n_classes, scale, feature character) — so that the
paper's measured quantities (traversal throughput, which depends only on
forest/feature shapes, and quantization *deltas*, which depend on threshold
geometry) are reproducible:

  * ``adult``   — predominantly one-hot/binary features (108 dims), like the
                  categorical-encoded census set → extreme node-merging rates
                  (paper Table 4: 6% unique nodes).
  * ``eeg``     — 14 continuous channels with heavy-tailed outliers: min-max
                  scaling compresses the bulk of thresholds into a narrow
                  band, reproducing the paper's EEG quantization collapse
                  (Table 4: unique nodes halve; Table 3: accuracy drops).
  * ``magic``   — 10 smooth continuous features.
  * ``mnist``/``fashion`` — 784 bounded pixel-like dims, class templates.
  * ``msn``     — 136-dim learning-to-rank regression targets (0..4).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Dataset:
    name: str
    X_train: np.ndarray
    y_train: np.ndarray
    X_test: np.ndarray
    y_test: np.ndarray
    n_classes: int          # 1 → regression/ranking

    @property
    def n_features(self) -> int:
        return self.X_train.shape[1]


def _cluster_classify(rng, n, d, n_classes, d_informative, sep=2.0,
                      clusters_per_class=2):
    means = rng.normal(0, sep, size=(n_classes, clusters_per_class, d_informative))
    y = rng.integers(0, n_classes, size=n)
    cl = rng.integers(0, clusters_per_class, size=n)
    Xi = means[y, cl] + rng.normal(0, 1.0, size=(n, d_informative))
    if d > d_informative:
        Xn = rng.normal(0, 1.0, size=(n, d - d_informative))
        X = np.concatenate([Xi, Xn], axis=1)
    else:
        X = Xi
    perm = rng.permutation(d)
    return X[:, perm], y


def _split(X, y, test_frac, rng):
    n = X.shape[0]
    idx = rng.permutation(n)
    nt = int(n * test_frac)
    te, tr = idx[:nt], idx[nt:]
    return X[tr], y[tr], X[te], y[te]


def make_magic(n=6000, seed=101) -> Dataset:
    rng = np.random.default_rng(seed)
    X, y = _cluster_classify(rng, n, d=10, n_classes=2, d_informative=8, sep=1.6)
    X = X * rng.uniform(0.5, 50.0, size=(1, 10))      # heterogeneous scales
    return Dataset("magic", *_split(X, y, 0.2, rng), 2)


def make_adult(n=6000, seed=102) -> Dataset:
    rng = np.random.default_rng(seed)
    d_cont, d_bin = 8, 100
    Xc, y = _cluster_classify(rng, n, d=d_cont, n_classes=2, d_informative=6, sep=1.4)
    # one-hot style binary block, weakly class-correlated
    logits = rng.normal(0, 1.0, size=(2, d_bin))
    p = 1 / (1 + np.exp(-logits[y]))
    Xb = (rng.uniform(size=(n, d_bin)) < p).astype(np.float64)
    X = np.concatenate([Xc, Xb], axis=1)
    return Dataset("adult", *_split(X, y, 0.2, rng), 2)


def make_eeg(n=6000, seed=103) -> Dataset:
    rng = np.random.default_rng(seed)
    X, y = _cluster_classify(rng, n, d=14, n_classes=2, d_informative=10, sep=1.2)
    X = X * 0.02 + 4.3                                # tight physiological band
    out = rng.uniform(size=X.shape) < 0.002           # rare huge artifacts
    # artifact magnitude tuned so min-max scaling leaves the physiological
    # bulk ~20 fixed-point levels — the paper's EEG regime: split
    # quantization costs points (Table 3) and collapses unique thresholds
    # (Table 4) while leaf quantization stays free
    X = np.where(out, X * rng.uniform(30, 90, size=X.shape), X)
    return Dataset("eeg", *_split(X, y, 0.2, rng), 2)


def _make_image_like(name, n, seed, n_classes=10, d=784) -> Dataset:
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(d))
    templates = np.zeros((n_classes, side, side))
    for c in range(n_classes):
        for _ in range(6):                            # blobs per class
            cx, cy = rng.uniform(4, side - 4, size=2)
            sx, sy = rng.uniform(1.5, 4.0, size=2)
            gx = np.exp(-((np.arange(side) - cx) ** 2) / (2 * sx ** 2))
            gy = np.exp(-((np.arange(side) - cy) ** 2) / (2 * sy ** 2))
            templates[c] += np.outer(gy, gx)
    templates = templates.reshape(n_classes, d)
    templates /= templates.max(axis=1, keepdims=True) + 1e-9
    y = rng.integers(0, n_classes, size=n)
    X = templates[y] * rng.uniform(0.6, 1.0, size=(n, 1)) \
        + rng.normal(0, 0.18, size=(n, d))
    X = np.clip(X, 0.0, 1.0)
    nt = int(n * 0.2)
    return Dataset(name, X[nt:], y[nt:], X[:nt], y[:nt], n_classes)


def make_mnist(n=8000, seed=104) -> Dataset:
    return _make_image_like("mnist", n, seed)


def make_fashion(n=8000, seed=105) -> Dataset:
    return _make_image_like("fashion", n, seed, n_classes=10)


def make_msn(n=8000, seed=106) -> Dataset:
    """Learning-to-rank stand-in: 136 features, graded relevance 0..4,
    regression target (the paper's Table 2 measures traversal runtime)."""
    rng = np.random.default_rng(seed)
    d = 136
    X = rng.normal(0, 1, size=(n, d))
    w = rng.normal(0, 1, size=d) * (rng.uniform(size=d) < 0.3)
    score = X @ w + 0.5 * np.sin(X[:, 0] * 2) * X[:, 1]
    qs = np.quantile(score, [0.5, 0.75, 0.9, 0.97])
    y = np.digitize(score, qs).astype(np.float64)
    nt = int(n * 0.2)
    return Dataset("msn", X[nt:], y[nt:], X[:nt], y[:nt], 1)


REGISTRY = {
    "magic": make_magic,
    "adult": make_adult,
    "eeg": make_eeg,
    "mnist": make_mnist,
    "fashion": make_fashion,
    "msn": make_msn,
}

_CACHE: dict = {}


def load(name: str, **kw) -> Dataset:
    key = (name, tuple(sorted(kw.items())))
    if key not in _CACHE:
        _CACHE[key] = REGISTRY[name](**kw)
    return _CACHE[key]
