"""Serving driver: tree-ensemble scoring or LM generation.

    # forest serving (the paper's workload)
    PYTHONPATH=src python -m repro.launch.serve --mode forest \
        --engine rapidscorer --quantize --n-requests 2000

    # LM generation (reduced config on CPU)
    PYTHONPATH=src python -m repro.launch.serve --mode lm \
        --arch smollm_360m --reduced --n-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import core
from ..configs import get_config
from ..data import datasets
from ..inference.server import ForestServer, LMServer
from ..models.model import Model
from ..trees.random_forest import RandomForest, RandomForestConfig


def _cascade_spec(args):
    """--cascade "16,64" [--cascade-policy margin|proba|bound
    --cascade-threshold t] → CascadeSpec (None when --cascade unset)."""
    if not args.cascade:
        return None
    from ..cascade import CascadeSpec, MarginGate, ProbaGate, ScoreBoundGate
    stages = tuple(int(s) for s in args.cascade.split(","))
    t = args.cascade_threshold
    policy = {"margin": lambda: MarginGate(t if t is not None else 0.9),
              "proba": lambda: ProbaGate(t if t is not None else 0.95),
              "bound": lambda: ScoreBoundGate(t if t is not None else 0.0),
              }[args.cascade_policy]()
    return CascadeSpec(stages=stages, policy=policy)


def serve_forest(args) -> dict:
    ds = datasets.load(args.dataset)
    rf = RandomForest(RandomForestConfig(
        n_trees=args.n_trees, max_leaves=args.n_leaves,
        seed=args.seed)).fit(ds.X_train, ds.y_train)
    forest = core.from_random_forest(rf)
    if args.quantize:
        forest = core.quantize_forest(forest, ds.X_train)
    pred = core.compile_forest(forest, engine=args.engine,
                               backend=args.backend,
                               cascade=_cascade_spec(args))

    server = ForestServer(pred, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms)
    rng = np.random.default_rng(args.seed)
    rows = rng.integers(0, ds.X_test.shape[0], size=args.n_requests)

    # Poisson arrivals; virtual clock so results are deterministic
    inter = rng.exponential(1.0 / args.rate, size=args.n_requests)
    arrivals = np.cumsum(inter)
    t_start = time.time()
    done = 0
    correct = 0
    for i, (row, at) in enumerate(zip(rows, arrivals)):
        req = server.submit(ds.X_test[row], arrival_s=t_start + at)
        req.label = ds.y_test[row]
        for r in server.poll(now_s=t_start + at):
            done += 1
            if int(np.argmax(r.result)) == int(r.label):
                correct += 1
    for r in server.flush():
        done += 1
        if int(np.argmax(r.result)) == int(r.label):
            correct += 1
    out = server.stats.summary()
    out.update({"engine": args.engine, "backend": args.backend,
                "quantized": bool(args.quantize),
                "accuracy": correct / max(done, 1),
                "wall_s": round(time.time() - t_start, 2)})
    if args.cascade:
        out["cascade"] = pred.describe()
        out["mean_trees_evaluated"] = pred.mean_trees_evaluated
    return out


def serve_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, q_chunk=64, ssd_chunk=32, loss_chunk=64, remat=False)
    params = model.init_params(jax.random.PRNGKey(args.seed), jnp.float32)
    B, S = args.batch, args.prompt_len
    server = LMServer(model, params, batch=B, max_len=S + args.n_new + 1)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.n_new)
    dt = time.time() - t0
    return {"arch": cfg.name, "batch": B, "prompt_len": S,
            "n_new": args.n_new, "out_shape": list(out.shape),
            "tokens_per_s": round(B * args.n_new / dt, 2),
            "wall_s": round(dt, 2)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="forest", choices=["forest", "lm"])
    # forest args
    ap.add_argument("--dataset", default="magic")
    ap.add_argument("--engine", default="bitvector",
                    choices=list(core.ENGINES))
    ap.add_argument("--backend", default="jax", choices=["jax", "pallas"])
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--cascade", type=str, default=None,
                    help="comma-separated stage boundaries (tree prefixes),"
                         " e.g. '16,64' — serve a confidence-gated cascade")
    ap.add_argument("--cascade-policy", default="margin",
                    choices=["margin", "proba", "bound"])
    ap.add_argument("--cascade-threshold", type=float, default=None,
                    help="gate threshold (margin/proba) or slack (bound); "
                         "default per policy")
    ap.add_argument("--n-trees", type=int, default=128)
    ap.add_argument("--n-leaves", type=int, default=32)
    ap.add_argument("--n-requests", type=int, default=1000)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="arrival rate (req/s, virtual clock)")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    # lm args
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out = serve_forest(args) if args.mode == "forest" else serve_lm(args)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
