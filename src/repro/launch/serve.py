"""Serving driver: tree-ensemble scoring or LM generation.

    # forest serving (the paper's workload)
    PYTHONPATH=src python -m repro.launch.serve --mode forest \
        --engine rapidscorer --quantize --n-requests 2000

    # concurrent multi-tenant runtime (threaded, adaptive batching)
    PYTHONPATH=src python -m repro.launch.serve --mode runtime \
        --tenants 2 --quantize --slo-p99-ms 10 --n-requests 2000

    # LM generation (reduced config on CPU)
    PYTHONPATH=src python -m repro.launch.serve --mode lm \
        --arch smollm_360m --reduced --n-new 16

``--mode runtime`` drives ``repro.inference.runtime.ServingRuntime``
(docs/SERVING.md): N tenants hot in one process, shape-warmed, served by
the worker thread under open-loop Poisson arrivals; ``--slo-p99-ms``
attaches the adaptive batching controller, ``--save-fleet``/
``--load-fleet`` round-trip the whole fleet through packed artifacts.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import core
from ..configs import get_config
from ..data import datasets
from ..inference.server import ForestServer, LMServer
from ..models.model import Model
from ..obs.log import get_logger
from ..trees.random_forest import RandomForest, RandomForestConfig

log = get_logger("serve")


def _cascade_spec(args):
    """--cascade "16,64" [--cascade-policy margin|proba|bound
    --cascade-threshold t] → CascadeSpec (None when --cascade unset)."""
    if not args.cascade:
        return None
    from ..cascade import CascadeSpec, MarginGate, ProbaGate, ScoreBoundGate
    stages = tuple(int(s) for s in args.cascade.split(","))
    t = args.cascade_threshold
    policy = {"margin": lambda: MarginGate(t if t is not None else 0.9),
              "proba": lambda: ProbaGate(t if t is not None else 0.95),
              "bound": lambda: ScoreBoundGate(t if t is not None else 0.0),
              }[args.cascade_policy]()
    return CascadeSpec(stages=stages, policy=policy)


def serve_forest(args) -> dict:
    ds = datasets.load(args.dataset)
    rf = RandomForest(RandomForestConfig(
        n_trees=args.n_trees, max_leaves=args.n_leaves,
        seed=args.seed)).fit(ds.X_train, ds.y_train)
    forest = core.from_random_forest(rf)
    if args.quantize:
        forest = core.quantize_forest(forest, ds.X_train)
    pred = core.compile_forest(forest, engine=args.engine,
                               backend=args.backend,
                               cascade=_cascade_spec(args))

    mserver = None
    if args.metrics_port is not None:
        from ..obs.expo import MetricsServer
        from ..obs.metrics import get_registry
        server = ForestServer(pred, max_batch=args.max_batch,
                              max_wait_ms=args.max_wait_ms, obs=True)
        mserver = MetricsServer(get_registry(),
                                extra=server.stats.summary,
                                port=args.metrics_port).start()
        log.info("metrics_endpoint", url=mserver.url)
    else:
        server = ForestServer(pred, max_batch=args.max_batch,
                              max_wait_ms=args.max_wait_ms)
    rng = np.random.default_rng(args.seed)
    rows = rng.integers(0, ds.X_test.shape[0], size=args.n_requests)

    # Poisson arrivals; virtual clock so results are deterministic
    inter = rng.exponential(1.0 / args.rate, size=args.n_requests)
    arrivals = np.cumsum(inter)
    t_start = time.time()
    done = 0
    correct = 0
    for i, (row, at) in enumerate(zip(rows, arrivals)):
        req = server.submit(ds.X_test[row], arrival_s=t_start + at)
        req.label = ds.y_test[row]
        for r in server.poll(now_s=t_start + at):
            done += 1
            if int(np.argmax(r.result)) == int(r.label):
                correct += 1
    for r in server.flush():
        done += 1
        if int(np.argmax(r.result)) == int(r.label):
            correct += 1
    out = server.stats.summary()
    out.update({"engine": args.engine, "backend": args.backend,
                "quantized": bool(args.quantize),
                "accuracy": correct / max(done, 1),
                "wall_s": round(time.time() - t_start, 2)})
    if mserver is not None:
        out["metrics_url"] = mserver.url
        mserver.close()
    if args.cascade:
        out["cascade"] = pred.describe()
        out["mean_trees_evaluated"] = pred.mean_trees_evaluated
    return out


def serve_runtime(args) -> dict:
    """Concurrent multi-tenant serving: threaded runtime, real clock."""
    from ..inference import ServingRuntime, SLOConfig

    slo = SLOConfig(target_p99_ms=args.slo_p99_ms) \
        if args.slo_p99_ms is not None else None
    if args.load_fleet:
        rt = ServingRuntime.load(args.load_fleet)
    else:
        ds = datasets.load(args.dataset)
        rt = ServingRuntime()
        for i in range(args.tenants):
            rf = RandomForest(RandomForestConfig(
                n_trees=args.n_trees, max_leaves=args.n_leaves,
                seed=args.seed + i)).fit(ds.X_train, ds.y_train)
            forest = core.from_random_forest(rf)
            if args.quantize:
                forest = core.quantize_forest(forest, ds.X_train)
            rt.add_model(f"t{i}", core.compile_forest(
                forest, engine=args.engine, backend=args.backend,
                cascade=_cascade_spec(args)),
                max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
                slo=slo)
        if args.save_fleet:
            log.info("fleet_saved", manifest=rt.save(args.save_fleet))
    metrics_url = None
    if args.metrics_port is not None:
        metrics_url = rt.serve_metrics(port=args.metrics_port).url
    warmed = rt.warmup() if args.warmup else {}

    ds = datasets.load(args.dataset)
    rng = np.random.default_rng(args.seed)
    rows = rng.integers(0, ds.X_test.shape[0], size=args.n_requests)
    tids = rng.choice(list(rt.model_ids), size=args.n_requests)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                         size=args.n_requests))
    t_wall = time.time()
    base = time.perf_counter() + 0.005
    reqs = []
    with rt:
        for row, tid, at in zip(rows, tids, arrivals):
            target = base + at
            while time.perf_counter() < target:
                time.sleep(min(max(target - time.perf_counter(), 0.0),
                               5e-4))
            reqs.append(rt.submit(tid, ds.X_test[row], arrival_s=target))
        for r in reqs:
            r.wait(timeout=120)
    lats = np.array([r.latency_ms for r in reqs])
    correct = sum(int(np.argmax(r.result)) == int(ds.y_test[row])
                  for row, r in zip(rows, reqs))
    return {
        "tenants": {tid: rt.stats(tid) for tid in rt.model_ids},
        "warmed": warmed,
        "metrics_url": metrics_url,
        "adaptive": slo is not None,
        "n_requests": len(reqs),
        "rate": args.rate,
        "p50_ms": float(np.percentile(lats, 50)),
        "p99_ms": float(np.percentile(lats, 99)),
        "accuracy": correct / max(len(reqs), 1),
        "wall_s": round(time.time() - t_wall, 2),
    }


def serve_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, q_chunk=64, ssd_chunk=32, loss_chunk=64, remat=False)
    params = model.init_params(jax.random.PRNGKey(args.seed), jnp.float32)
    B, S = args.batch, args.prompt_len
    server = LMServer(model, params, batch=B, max_len=S + args.n_new + 1)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.n_new)
    dt = time.time() - t0
    return {"arch": cfg.name, "batch": B, "prompt_len": S,
            "n_new": args.n_new, "out_shape": list(out.shape),
            "tokens_per_s": round(B * args.n_new / dt, 2),
            "wall_s": round(dt, 2)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="forest",
                    choices=["forest", "runtime", "lm"])
    # forest args
    ap.add_argument("--dataset", default="magic")
    ap.add_argument("--engine", default="bitvector",
                    choices=list(core.ENGINES))
    ap.add_argument("--backend", default="jax", choices=["jax", "pallas"])
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--cascade", type=str, default=None,
                    help="comma-separated stage boundaries (tree prefixes),"
                         " e.g. '16,64' — serve a confidence-gated cascade")
    ap.add_argument("--cascade-policy", default="margin",
                    choices=["margin", "proba", "bound"])
    ap.add_argument("--cascade-threshold", type=float, default=None,
                    help="gate threshold (margin/proba) or slack (bound); "
                         "default per policy")
    ap.add_argument("--n-trees", type=int, default=128)
    ap.add_argument("--n-leaves", type=int, default=32)
    ap.add_argument("--n-requests", type=int, default=1000)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="arrival rate (req/s, virtual clock)")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    # runtime args
    ap.add_argument("--tenants", type=int, default=2,
                    help="runtime mode: number of hot models")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="attach the adaptive batching controller with "
                         "this p99 latency budget")
    ap.add_argument("--no-warmup", dest="warmup", action="store_false",
                    help="skip shape warmup (first requests pay compiles)")
    ap.add_argument("--save-fleet", type=str, default=None,
                    help="persist the fleet as packed artifacts + manifest")
    ap.add_argument("--load-fleet", type=str, default=None,
                    help="cold-start the fleet from a saved manifest")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the observability scrape endpoint "
                         "(/metrics Prometheus text, /metrics.json, "
                         "/traces — docs/OBSERVABILITY.md) on this "
                         "port; 0 picks an ephemeral port")
    # lm args
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out = {"forest": serve_forest, "runtime": serve_runtime,
           "lm": serve_lm}[args.mode](args)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
