import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from ..distributed.optimizer import Adam, AdamConfig
from ..distributed.sharding import (data_spec, decode_state_specs,
                                    tree_shardings)
from ..models.config import ArchConfig, ShapeConfig
from ..models.model import Model
from ..obs.log import get_logger
from .hlo_analysis import analyze, normalize_cost_analysis
from .mesh import make_production_mesh
from .specs import input_specs

log = get_logger("dryrun")

# TPU v5e constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per chip, one direction)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _accum_steps(cfg: ArchConfig, shape: ShapeConfig, mesh) -> int:
    """Microbatches per step: keep per-chip live activations inside HBM.
    Rough model: stored residual carries + remat working set ≈
    B/chip/accum × S × d_model × 2B × (n_layers + C). Target ≤ ~6 GB,
    leaving room for weight shards + grads + optimizer state."""
    import numpy as np
    n_data = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                          if a in ("pod", "data")]))
    n_model = mesh.shape.get("model", 1)
    b_chip = max(shape.global_batch // n_data, 1)
    seq_shard = shape.seq_len // n_model if shape.seq_len % n_model == 0 \
        else shape.seq_len
    act_bytes = (b_chip * seq_shard * cfg.d_model * 2
                 * (cfg.n_layers + 24))
    accum = 1
    while act_bytes / accum > 6e9 and accum < b_chip:
        accum *= 2
    return accum


def _analytic_memory(cfg: ArchConfig, shape: ShapeConfig, mesh,
                     accum: int, opt_state_dtype: str) -> dict:
    """Per-chip HBM residency model. The CPU backend's memory_analysis
    lacks TPU's while-loop double buffering (it keeps every layer's
    gathered weights live), so the fit criterion uses this analytic model;
    the measured number is recorded as a pessimistic bound.

    Terms (bytes/chip):
      params      — f32 master (train) / bf16 (serve), fully sharded
      optimizer   — Adam m+v (f32: 8 B/param; int8: ~2.06 B/param)
      grads       — f32, sharded like params (train only)
      act_carries — stored residual stream per layer (bf16, seq-sharded)
      working     — 2× double-buffered per-layer gathered weights (bf16)
                    + flash attention live window
      kv_cache    — decode cells: bf16 cache as sharded by state specs
    """
    import numpy as np
    n_chips = int(np.prod(list(mesh.shape.values())))
    n_model = mesh.shape.get("model", 1)
    n_data = n_chips // n_model
    P = cfg.param_count()
    train = shape.kind == "train"
    b_chip = max(shape.global_batch // n_data, 1)
    seq_shard = (shape.seq_len // n_model
                 if shape.seq_len % n_model == 0 else shape.seq_len)

    out = {}
    out["params"] = P * (4 if train else 2) / n_chips
    out["optimizer"] = (P * (2.06 if opt_state_dtype == "int8" else 8)
                        / n_chips) if train else 0.0
    out["grads"] = P * 4 / n_chips if train else 0.0
    if shape.kind == "decode":
        na = sum(1 for l in range(cfg.n_layers) if cfg.is_attn_layer(l))
        kv = (2 * na * shape.global_batch * shape.seq_len
              * cfg.n_kv * cfg.head_dim * 2)
        ssm_layers = cfg.n_layers - na
        ssm = (ssm_layers * shape.global_batch
               * cfg.d_inner * max(cfg.ssm_state, 1) * 4) if ssm_layers else 0
        # cache sharding mirrors distributed.sharding.decode_state_specs:
        # batch over data axes; kv-heads over "model" when divisible, else
        # head_dim (both divide for every assigned arch)
        b_div = n_data if shape.global_batch % n_data == 0 else 1
        m_div = n_model if (cfg.n_kv % n_model == 0
                            or cfg.head_dim % n_model == 0) else 1
        out["kv_cache"] = kv / (b_div * m_div) + ssm / b_div
        out["act_carries"] = 0.0
    else:
        mb = max(b_chip // accum, 1)
        out["kv_cache"] = 0.0
        out["act_carries"] = mb * seq_shard * cfg.d_model * 2 * cfg.n_layers
        qc = min(1024, shape.seq_len)
        flash = 3 * b_chip / max(accum, 1) * max(cfg.n_heads // n_model, 1) \
            * qc * qc * 4
        out["working"] = 2 * (P / max(cfg.n_layers, 1) / n_model) * 2 + flash
    out.setdefault("working", 2 * (P / max(cfg.n_layers, 1) / n_model) * 2)
    out["total"] = float(sum(out.values()))
    out["fits_16gb"] = bool(out["total"] < 16e9)
    return {k: (round(v, 1) if isinstance(v, float) else v)
            for k, v in out.items()}


def _model_for(cfg: ArchConfig, shape: ShapeConfig) -> Model:
    q_chunk = 1024 if shape.seq_len >= 1024 else shape.seq_len
    return Model(cfg, q_chunk=q_chunk, ssd_chunk=128,
                 loss_chunk=min(1024, shape.seq_len), remat=True)


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               opt_state_dtype: str = "auto", grad_dtype: str = "f32",
               kv_quant: bool = False):
    """Returns (jitted fn, example args tree of ShapeDtypeStruct)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..models.act_sharding import policy_from_mesh
    policy_from_mesh(mesh)

    model = _model_for(cfg, shape)
    logical = model.param_logical_specs()
    rng = jax.random.PRNGKey(0)

    param_dtype = jnp.float32 if shape.kind == "train" else jnp.bfloat16
    p_shapes = jax.eval_shape(lambda k: model.init_params(k, param_dtype), rng)
    p_shards = tree_shardings(p_shapes, logical, mesh)

    ins = input_specs(cfg, shape)
    tok_shard = NamedSharding(
        mesh, data_spec(mesh, 2, shape.global_batch))
    enc_shard = None
    if "enc_embeds" in ins:
        enc_shard = NamedSharding(
            mesh, data_spec(mesh, 3, shape.global_batch))

    if shape.kind == "train":
        if opt_state_dtype == "auto":
            opt_state_dtype = "int8" if cfg.param_count() > 40e9 else "f32"
        opt = Adam(AdamConfig(state_dtype=opt_state_dtype))
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_logical = opt.state_logical_specs(logical)
        o_shards = tree_shardings(o_shapes, o_logical, mesh)

        # gradient accumulation (§Perf iter 6): per-chip activation temps
        # scale with the microbatch, so large models microbatch until the
        # remat window fits HBM. Heuristic: ~1 microbatch row per chip for
        # ≥100B params. Also the mechanism behind elastic DP-shrink
        # restarts (fault_tolerance.plan_elastic_restart).
        accum = _accum_steps(cfg, shape, mesh)

        def cast_grads(grads):
            if grad_dtype == "f32":
                return grads
            return jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)

        B = shape.global_batch

        def accum_grads(params, tokens, enc=None):
            if accum == 1:
                args = (tokens,) if enc is None else (tokens, enc)
                return jax.value_and_grad(model.loss_fn)(params, *args)
            S = tokens.shape[1]
            tb = tokens.reshape(accum, B // accum, S)
            eb = None
            if enc is not None:
                eb = enc.reshape(accum, B // accum, *enc.shape[1:])
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def micro(carry, inp):
                closs, cg = carry
                if enc is None:
                    l, g = jax.value_and_grad(model.loss_fn)(params, inp)
                else:
                    l, g = jax.value_and_grad(model.loss_fn)(params, *inp)
                cg = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                  cg, g)
                return (closs + l, cg), None

            xs = tb if enc is None else (tb, eb)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), g0), xs)
            inv = 1.0 / accum
            return loss * inv, jax.tree.map(lambda g: g * inv, grads)

        if "enc_embeds" in ins:
            def step(params, opt_state, tokens, enc):
                loss, grads = accum_grads(params, tokens, enc)
                p2, s2 = opt.update(cast_grads(grads), opt_state, params)
                return p2, s2, loss
            args = (p_shapes, o_shapes, ins["tokens"], ins["enc_embeds"])
            in_sh = (p_shards, o_shards, tok_shard, enc_shard)
        else:
            def step(params, opt_state, tokens):
                loss, grads = accum_grads(params, tokens)
                p2, s2 = opt.update(cast_grads(grads), opt_state, params)
                return p2, s2, loss
            args = (p_shapes, o_shapes, ins["tokens"])
            in_sh = (p_shards, o_shards, tok_shard)
        fn = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(p_shards, o_shards, NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        return fn, args, {"opt_state_dtype": opt_state_dtype,
                          "accum_steps": accum}

    if shape.kind == "prefill":
        if "enc_embeds" in ins:
            def step(params, tokens, enc):
                return model.prefill(params, tokens, enc)
            args = (p_shapes, ins["tokens"], ins["enc_embeds"])
            in_sh = (p_shards, tok_shard, enc_shard)
        else:
            def step(params, tokens):
                return model.prefill(params, tokens)
            args = (p_shapes, ins["tokens"])
            in_sh = (p_shards, tok_shard)
        out_sh = NamedSharding(mesh, data_spec(mesh, 2, shape.global_batch))
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        return fn, args, {}

    # decode: one new token against a seq_len-deep cache
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        enc = jax.ShapeDtypeStruct(
            (B, min(S // 4, 8192), cfg.d_model), jnp.float32)
        s_shapes = jax.eval_shape(
            lambda p, e: model.init_decode_state(B, S, params=p,
                                                 enc_embeds=e,
                                                 kv_quant=kv_quant),
            p_shapes, enc)
    else:
        s_shapes = jax.eval_shape(
            lambda: model.init_decode_state(B, S, kv_quant=kv_quant))
    s_shards = decode_state_specs(cfg, s_shapes, mesh)

    def step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    args = (p_shapes, s_shapes, ins["tokens"])
    from jax.sharding import NamedSharding as NS
    logits_sh = NS(mesh, data_spec(mesh, 2, B))
    fn = jax.jit(step, in_shardings=(p_shards, s_shards, tok_shard),
                 out_shardings=(logits_sh, s_shards),
                 donate_argnums=(1,))
    return fn, args, {}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save: bool = True, kv_quant: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "timestamp": time.time(),
    }
    if kv_quant:
        rec["kv_quant"] = True
        rec["mesh"] = mesh_kind + "__kvq8"   # separate artifact name
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        t0 = time.time()
        with mesh:
            fn, args, extra = build_cell(cfg, shape, mesh,
                                         kv_quant=kv_quant)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = normalize_cost_analysis(compiled.cost_analysis())
        hlo = compiled.as_text()
        # trip-count-aware accounting (XLA's cost_analysis counts while
        # bodies once — see hlo_analysis module docstring)
        hc = analyze(hlo)
        coll = hc.collectives
        flops = hc.flops
        bytes_hbm = hc.bytes_hbm
        rec.update({
            "status": "ok",
            "n_chips": n_chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            "analytic_memory": _analytic_memory(
                cfg, shape, mesh, extra.get("accum_steps", 1),
                extra.get("opt_state_dtype", "f32")),
            "hlo_flops": flops,
            "hlo_bytes": bytes_hbm,
            "xla_cost_analysis": {          # reference only: while bodies ×1
                k: float(v) for k, v in cost.items()
                if k in ("flops", "bytes accessed", "transcendentals")},
            "collectives": {
                "total_bytes": coll.total_bytes,
                "link_bytes": coll.link_bytes,
                "per_op": dict(coll.per_op),
                "counts": dict(coll.counts),
            },
            **extra,
        })
        # roofline terms (per chip — cost analysis is for the partitioned
        # per-device program)
        rec["roofline"] = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_hbm / HBM_BW,
            "collective_s": coll.link_bytes / ICI_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["roofline"]["dominant"] = dom
        model_flops = _model_flops(cfg, shape)
        rec["model_flops_global"] = model_flops
        rec["model_flops_per_chip"] = model_flops / n_chips
        if flops > 0:
            rec["useful_flop_ratio"] = (model_flops / n_chips) / flops
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if save:
        _save(rec)
    return rec


def _model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6·N_active·D for train (fwd+bwd), 2·N_active·D for
    inference. D = processed tokens."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape.global_batch   # decode: 1 token / seq


def _save(rec: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    fn = os.path.join(
        RESULTS_DIR, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode cells (paper §5 → "
                         "decode roofline)")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                out = os.path.join(
                    RESULTS_DIR, f"{arch}__{shape}__{mk}.json")
                if args.skip_existing and os.path.exists(out):
                    log.info("skip_existing", arch=arch, shape=shape,
                             mesh=mk)
                    continue
                t0 = time.time()
                rec = run_cell(arch, shape, mk, kv_quant=args.kv_quant)
                status = rec["status"]
                fields = dict(arch=arch, shape=shape, mesh=mk,
                              wall_s=time.time() - t0)
                if status == "ok":
                    r = rec["roofline"]
                    fields.update(dominant=r["dominant"],
                                  compute_ms=r["compute_s"] * 1e3,
                                  memory_ms=r["memory_s"] * 1e3,
                                  collective_ms=r["collective_s"] * 1e3)
                    log.info("cell_ok", **fields)
                elif status == "error":
                    log.error("cell_error", error=rec["error"][:120],
                              **fields)
                else:
                    log.info(f"cell_{status}", **fields)


if __name__ == "__main__":
    main()
