"""Production mesh builders (functions — importing this module never touches
jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds the 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many local devices exist (CPU tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
