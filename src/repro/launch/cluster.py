"""Multi-host cluster bring-up helpers.

On a real TPU pod each host runs the same command; JAX discovers peers via
the TPU runtime (or explicit coordinator env for CPU/GPU clusters):

    # per-host (v5e-256: 64 hosts × 4 chips)
    python -m repro.launch.train --arch grok_1_314b --mesh production \
        --ckpt-dir gs://bucket/run1 --hb-dir gs://bucket/run1/hb

``initialize_from_env()`` is called by the drivers when REPRO_MULTIHOST=1;
it is a thin wrapper over ``jax.distributed.initialize`` with the standard
environment conventions, kept separate so the CPU container never touches
distributed state.

Failure/restart protocol (scripts/launch_pod.sh wraps this):
  1. every host heartbeats (fault_tolerance.Heartbeat) each step;
  2. the job runner (GKE/xmanager/slurm) restarts dead hosts; on restart
     the driver resumes from the newest complete checkpoint (atomic
     rename ⇒ never a torn read);
  3. if the replacement capacity is smaller, ``plan_elastic_restart``
     shrinks the DP axis to the largest pow2 ≤ survivors and raises
     ``--accum-steps`` so the global batch (and loss trajectory) is
     unchanged — verified bit-close in tests/test_elastic.py.
"""
from __future__ import annotations

import os
from typing import Optional


def multihost_requested() -> bool:
    return os.environ.get("REPRO_MULTIHOST", "0") == "1"


def initialize_from_env(coordinator: Optional[str] = None,
                        num_processes: Optional[int] = None,
                        process_id: Optional[int] = None) -> None:
    """jax.distributed.initialize with env fallbacks:
    REPRO_COORDINATOR (host:port), REPRO_NUM_PROCESSES, REPRO_PROCESS_ID.
    On TPU pods all three are discovered automatically and may be None."""
    import jax
    kw = {}
    coordinator = coordinator or os.environ.get("REPRO_COORDINATOR")
    if coordinator:
        kw["coordinator_address"] = coordinator
    np_ = num_processes or os.environ.get("REPRO_NUM_PROCESSES")
    if np_:
        kw["num_processes"] = int(np_)
    pid = process_id if process_id is not None \
        else os.environ.get("REPRO_PROCESS_ID")
    if pid is not None:
        kw["process_id"] = int(pid)
    jax.distributed.initialize(**kw)


def host_info() -> dict:
    import jax
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": len(jax.local_devices()),
        "global_devices": len(jax.devices()),
    }
