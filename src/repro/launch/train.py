"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --reduced \
        --steps 200 --batch 8 --seq-len 256 --ckpt-dir /tmp/ckpt

Features (DESIGN.md §7):
  * checkpoint/restart — atomic sharded checkpoints, resume from LATEST,
    elastic re-sharding onto a different mesh;
  * straggler watchdog — trailing-median step deadline, per-host heartbeat
    files, offender logging;
  * preemption — SIGTERM/SIGINT triggers checkpoint-then-exit;
  * gradient compression — int8 error-feedback codec around the DP
    all-reduce (--compress-grads);
  * optimizer-state quantization — Adam m/v in int8 (--opt-state int8);
  * deterministic data — (seed, step)-keyed synthetic batches, so restarts
    replay the exact token stream.

On the CPU container this runs reduced configs on a debug mesh; on a real
cluster the same script runs the full config on the production mesh
(--mesh production) under ``jax.distributed.initialize``.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config
from ..data.tokens import SyntheticTokens, TokenPipelineConfig
from ..distributed import checkpoint as ckpt
from ..distributed.compression import compress_tree, init_residuals
from ..distributed.fault_tolerance import (Heartbeat, PreemptionFlag,
                                           StragglerDetector)
from ..distributed.optimizer import Adam, AdamConfig
from ..distributed.sharding import data_spec, tree_shardings
from ..models.model import Model
from ..obs.log import get_logger
from .mesh import make_debug_mesh, make_production_mesh

log = get_logger("train")


# --------------------------------------------------------------------------- #
# Trainer
# --------------------------------------------------------------------------- #
class Trainer:
    """Owns the jitted step, the state tree, and the fault-tolerance hooks.
    Exposed as a class so tests can drive the loop step-by-step."""

    def __init__(self, cfg, *, batch: int, seq_len: int, mesh=None,
                 lr: float = 3e-4, opt_state: str = "f32",
                 compress_grads: bool = False, remat: bool = True,
                 seed: int = 0, param_dtype=jnp.float32,
                 accum_steps: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.mesh = mesh or make_debug_mesh(1, 1)
        from ..models.act_sharding import policy_from_mesh
        policy_from_mesh(self.mesh)
        self.compress_grads = compress_grads
        # gradient accumulation: global batch is invariant in accum_steps
        # (elastic restarts shrink DP and raise accum — same loss
        # trajectory, lower throughput; fault_tolerance.plan_elastic_restart)
        assert batch % accum_steps == 0, (batch, accum_steps)
        self.accum_steps = accum_steps
        self.model = Model(cfg, q_chunk=min(512, seq_len),
                           ssd_chunk=min(128, seq_len), remat=remat,
                           loss_chunk=min(512, seq_len))
        self.opt = Adam(AdamConfig(lr=lr, state_dtype=opt_state))
        self.pipeline = SyntheticTokens(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=seq_len, global_batch=batch, seed=seed))

        logical = self.model.param_logical_specs()
        rng = jax.random.PRNGKey(seed)
        p_shapes = jax.eval_shape(
            lambda k: self.model.init_params(k, param_dtype), rng)
        self.p_shards = tree_shardings(p_shapes, logical, self.mesh)
        o_shapes = jax.eval_shape(self.opt.init, p_shapes)
        self.o_shards = tree_shardings(
            o_shapes, self.opt.state_logical_specs(logical), self.mesh)
        self.tok_shard = NamedSharding(
            self.mesh, data_spec(self.mesh, 2, batch))

        self._needs_enc = cfg.family == "encdec"
        self._step_fn = self._build_step()
        self.params = None
        self.opt_state = None
        self.residuals = None
        self.step = 0

    # ------------------------------------------------------------- build
    def _build_step(self):
        model, opt = self.model, self.opt
        compress = self.compress_grads
        accum = self.accum_steps

        def grad_fn(params, tokens, enc=None):
            if accum == 1:
                args = (tokens,) if enc is None else (tokens, enc)
                return jax.value_and_grad(model.loss_fn)(params, *args)
            B, S = tokens.shape
            tb = tokens.reshape(accum, B // accum, S)
            eb = (None if enc is None else
                  enc.reshape(accum, B // accum, *enc.shape[1:]))
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def micro(carry, inp):
                closs, cg = carry
                if enc is None:
                    l, g = jax.value_and_grad(model.loss_fn)(params, inp)
                else:
                    l, g = jax.value_and_grad(model.loss_fn)(params, *inp)
                return (closs + l,
                        jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     cg, g)), None

            xs = tb if enc is None else (tb, eb)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), g0), xs)
            inv = 1.0 / accum
            return loss * inv, jax.tree.map(lambda g: g * inv, grads)

        def step_fn(params, opt_state, residuals, tokens, enc=None):
            loss, grads = grad_fn(params, tokens, enc)
            if compress:
                grads, residuals = compress_tree(grads, residuals)
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, residuals, loss, gnorm

        donate = (0, 1, 2)
        with self.mesh:
            return jax.jit(step_fn, donate_argnums=donate)

    def init_state(self, seed: int = 0) -> None:
        rng = jax.random.PRNGKey(seed)
        with self.mesh:
            self.params = jax.jit(
                lambda k: self.model.init_params(k, jnp.float32),
                out_shardings=self.p_shards)(rng)
            self.opt_state = jax.jit(
                self.opt.init, out_shardings=self.o_shards)(self.params)
        if self.compress_grads:
            self.residuals = init_residuals(self.params)
        else:
            self.residuals = jax.tree.map(lambda _: jnp.zeros(()),
                                          self.params)
        self.step = 0

    # --------------------------------------------------------- checkpoint
    def state_tree(self) -> dict:
        return {"params": self.params, "opt": self.opt_state,
                "residuals": self.residuals}

    def save(self, path: str) -> str:
        return ckpt.save(path, self.step, self.state_tree())

    def restore(self, path: str, step: Optional[int] = None) -> int:
        """Elastic restore: target shapes from *this* trainer's mesh, data
        re-sharded from the global checkpoint arrays."""
        shardings = {"params": self.p_shards, "opt": self.o_shards,
                     "residuals": jax.tree.map(lambda _: None,
                                               self.residuals or {})}
        if self.params is None:
            self.init_state()
        tree, got = ckpt.restore(path, self.state_tree(), step=step,
                                 shardings=None)
        with self.mesh:
            self.params = jax.device_put(tree["params"], self.p_shards)
            self.opt_state = jax.device_put(tree["opt"], self.o_shards)
            self.residuals = tree["residuals"]
        self.step = got
        return got

    # --------------------------------------------------------------- step
    def train_step(self) -> dict:
        tokens = jnp.asarray(self.pipeline.batch(self.step))
        args = [self.params, self.opt_state, self.residuals, tokens]
        if self._needs_enc:
            rng = np.random.default_rng((17, self.step))
            from .specs import enc_len
            Se = enc_len(self.cfg, self.seq_len)
            enc = jnp.asarray(rng.normal(
                0, 1, size=(self.batch, Se, self.cfg.d_model)
            ).astype(np.float32))
            args.append(enc)
        with self.mesh:
            out = self._step_fn(*args)
        self.params, self.opt_state, self.residuals, loss, gnorm = out
        self.step += 1
        return {"step": self.step, "loss": float(loss),
                "grad_norm": float(gnorm)}


# --------------------------------------------------------------------------- #
# CLI loop with fault-tolerance hooks
# --------------------------------------------------------------------------- #
def run_loop(trainer: Trainer, *, steps: int, ckpt_dir: Optional[str],
             ckpt_every: int = 50, log_path: Optional[str] = None,
             resume: bool = True, keep: int = 3,
             hb_dir: Optional[str] = None,
             log_every: int = 10) -> list[dict]:
    flag = PreemptionFlag()
    signal.signal(signal.SIGTERM, flag.set)
    watchdog = StragglerDetector()
    hb = Heartbeat(hb_dir, jax.process_index()) if hb_dir else None

    if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
        got = trainer.restore(ckpt_dir)
        log.info("resumed", step=got)
    elif trainer.params is None:
        trainer.init_state()

    logf = open(log_path, "a") if log_path else None
    records = []
    t_tokens = trainer.batch * trainer.seq_len
    try:
        while trainer.step < steps:
            t0 = time.time()
            rec = trainer.train_step()
            dt = time.time() - t0
            rec["step_time_s"] = round(dt, 4)
            rec["tokens_per_s"] = round(t_tokens / dt, 1)
            if watchdog.observe(dt):
                rec["straggler"] = True
                log.warning("straggler", step=rec["step"], step_s=dt,
                            median_s=watchdog.median)
            records.append(rec)
            if hb:
                hb.beat(rec["step"])
            if logf:
                logf.write(json.dumps(rec) + "\n")
                logf.flush()
            if rec["step"] % log_every == 0 or rec["step"] == 1:
                log.info("step", step=rec["step"], loss=rec["loss"],
                         gnorm=rec["grad_norm"],
                         tok_per_s=rec["tokens_per_s"])
            if ckpt_dir and rec["step"] % ckpt_every == 0:
                trainer.save(ckpt_dir)
                ckpt.cleanup(ckpt_dir, keep=keep)
            if flag:
                log.warning("preempted", step=trainer.step,
                            checkpointing=bool(ckpt_dir))
                if ckpt_dir:
                    trainer.save(ckpt_dir)
                break
    finally:
        if logf:
            logf.close()
    if ckpt_dir and trainer.step and (not records
                                      or trainer.step % ckpt_every != 0):
        trainer.save(ckpt_dir)
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "production"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--log", default=None)
    ap.add_argument("--hb-dir", default=None)
    ap.add_argument("--opt-state", default="f32", choices=["f32", "int8"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from .cluster import initialize_from_env, multihost_requested
    if multihost_requested():
        initialize_from_env()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.mesh == "production"
            else make_debug_mesh(1, 1))
    trainer = Trainer(cfg, batch=args.batch, seq_len=args.seq_len, mesh=mesh,
                      lr=args.lr, opt_state=args.opt_state,
                      compress_grads=args.compress_grads, seed=args.seed,
                      accum_steps=args.accum_steps)
    t0 = time.time()
    records = run_loop(trainer, steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, log_path=args.log,
                       resume=not args.no_resume, hb_dir=args.hb_dir)
    if records:
        first, last = records[0], records[-1]
        log.info("done", steps=len(records), wall_s=time.time() - t0,
                 loss_first=first["loss"], loss_last=last["loss"])


if __name__ == "__main__":
    main()
