"""Trip-count-aware roofline accounting over optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE (trip counts
are invisible to it), which undercounts scanned-layer models by the scan
length. This module re-derives the three roofline numerators from the HLO
module itself:

  * FLOPs        — every ``dot`` (2 × |result| × contraction), scaled by the
                   product of enclosing loop trip counts. Elementwise and
                   transcendental FLOPs are ignored (≪ dot FLOPs for these
                   models; documented in EXPERIMENTS.md §Roofline).
  * HBM bytes    — Σ over *executed* top-level instructions of
                   (operand bytes + result bytes) × trip multiplier, i.e.
                   XLA's own per-instruction "bytes accessed" convention
                   applied at fusion boundaries. Fusion-internal traffic is
                   excluded (it lives in registers/VMEM); cache reuse across
                   instructions is not modelled (upper bound).
  * collectives  — result bytes of every all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute × trip
                   multiplier, with ring-algorithm link weights.

Computation multipliers: ENTRY = 1; a ``while`` body/condition inherits
parent × trip (trip from ``backend_config known_trip_count``, else the
largest constant in the condition — XLA's counted-loop pattern, else 1);
``fusion``/``call``/``to_apply`` children inherit parent × 1. Multipliers
accumulate over call sites (fixed-point propagation over the computation
DAG).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_LINK_WEIGHT = {"all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

# `%name (params) -> result {` — params may nest parens (tuple types)
_COMP_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# one instruction: `%name = TYPE opcode(...)`, TYPE = `dtype[dims]{...}` or
# a tuple `(T1, T2, ...)`
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota")


def _shape_list(type_str: str):
    """'f32[8,16]{1,0}' or '(f32[8], s32[])' → [(dtype, [dims...]), ...]."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dtype, shape))
    return out


def _nbytes(shapes) -> float:
    total = 0.0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    type_str: str       # result type text (before the opcode)
    operands: list      # operand instruction names
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)    # name -> result shapes


@dataclass
class CollectiveStats:
    per_op: dict = field(default_factory=lambda: defaultdict(float))
    total_bytes: float = 0.0
    link_bytes: float = 0.0
    counts: dict = field(default_factory=lambda: defaultdict(int))


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    collectives: CollectiveStats = field(default_factory=CollectiveStats)
    mult: dict = field(default_factory=dict)    # computation → multiplier


# --------------------------------------------------------------------------- #
# parsing
# --------------------------------------------------------------------------- #
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OPCODE_RE = re.compile(r"(?<=\s)([a-zA-Z][\w\-]*)\(")


def _parse_opcode(after_eq: str) -> tuple[str, str, str]:
    """'f32[8]{0} dot(%a, %b), attrs' → ('dot', 'f32[8]{0} ', rest).

    Robust to tuple result types with `/*index=N*/` comments and layout
    tiling annotations (`{1,0:T(8,128)}` — the `T(` is not preceded by
    whitespace, so the opcode search skips it)."""
    s = _COMMENT_RE.sub("", after_eq)
    m = _OPCODE_RE.search(s)
    if not m:
        return "", after_eq, ""
    return m.group(1), s[:m.start()], s[m.start():]


def parse_computations(hlo: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
        else:
            if line.strip() == "}":
                cur = None
                continue
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, rest = im.group(1), im.group(2)
            opcode, type_str, tail = _parse_opcode(rest)
            if not opcode:
                continue
            # operands: %names inside the balanced paren group after opcode
            p0 = len(opcode)
            depth, j = 0, p0
            while j < len(tail):
                if tail[j] == "(":
                    depth += 1
                elif tail[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
                j += 1
            operands = _OPERANDS_RE.findall(tail[p0:j + 1])
            ins = Instr(name, opcode, type_str, operands, line)
            cur.instrs.append(ins)
            cur.defs[name] = _shape_list(type_str)
    return comps, entry


def _multipliers(comps: dict, entry: str) -> dict:
    """Fixed-point propagation of execution counts over the computation
    DAG. while bodies/conds get × trip; fusion/call/reduce children × 1."""
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for cname, comp in comps.items():
        for ins in comp.instrs:
            wm = _WHILE_RE.search(ins.line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = 1
                    if cond in comps:
                        consts = [int(c) for c in _CONST_RE.findall(
                            "\n".join(i.line for i in comps[cond].instrs))]
                        if consts:
                            trip = max(consts)
                edges[cname].append((body, float(trip)))
                edges[cname].append((cond, float(trip) + 1.0))
                continue
            for child in _CALLS_RE.findall(ins.line):
                if child in comps:
                    edges[cname].append((child, 1.0))

    # fixed-point recompute over the (acyclic) computation graph: each
    # sweep recomputes every node's multiplier from the previous sweep's
    # parents, so shared children accumulate over all call sites without
    # order sensitivity. Converges in ≤ depth sweeps.
    mult: dict[str, float] = {entry: 1.0}
    for _ in range(len(comps) + 1):
        nxt: dict[str, float] = defaultdict(float)
        nxt[entry] = 1.0
        for parent, kids in edges.items():
            for child, w in kids:
                nxt[child] += mult.get(parent, 0.0) * w
        nxt = dict(nxt)
        if nxt == mult:
            break
        mult = nxt
    return mult


# --------------------------------------------------------------------------- #
# cost model
# --------------------------------------------------------------------------- #
def analyze(hlo: str) -> HloCost:
    comps, entry = parse_computations(hlo)
    mult = _multipliers(comps, entry)

    # executed computations for byte accounting: entry + while bodies/conds
    # (reached via while edges); fusion internals are excluded.
    executed = {entry}
    for cname, comp in comps.items():
        for ins in comp.instrs:
            wm = _WHILE_RE.search(ins.line)
            if wm:
                executed.add(wm.group(2))
                executed.add(wm.group(1))

    cost = HloCost(mult=mult)
    coll = cost.collectives
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        in_exec = cname in executed
        for ins in comp.instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            # ---- FLOPs: dots anywhere ---------------------------------- #
            if op == "dot":
                res = comp.defs.get(ins.name, [])
                n_res = 1
                for _, dims in res:
                    for d in dims:
                        n_res *= d
                cdims = _DOT_DIMS_RE.search(ins.line)
                csize = 1
                if cdims and ins.operands:
                    lhs = comp.defs.get(ins.operands[0])
                    if lhs:
                        _, ldims = lhs[0]
                        for ci in (int(x) for x in
                                   cdims.group(1).split(",") if x):
                            if ci < len(ldims):
                                csize *= ldims[ci]
                cost.flops += 2.0 * n_res * csize * m
            # ---- collectives ------------------------------------------- #
            if base in COLLECTIVES and not op.endswith("-done"):
                shapes = _shape_list(ins.type_str)
                if op.endswith("-start") and len(shapes) > 1:
                    # async tuple (operand alias, result): use the result
                    shapes = shapes[len(shapes) // 2:]
                nbytes = _nbytes(shapes) * m
                coll.per_op[base] += nbytes
                coll.total_bytes += nbytes
                coll.link_bytes += nbytes * _LINK_WEIGHT[base]
                coll.counts[base] += 1
            # ---- HBM bytes (fusion-boundary accounting) ---------------- #
            if in_exec and op not in _SKIP_BYTES_OPS \
                    and not op.endswith("-done"):
                cost.bytes_hbm += _instr_bytes(ins, comp, comps) * m
    return cost


_SLICE_OPS = ("dynamic-slice", "slice", "gather")


def _instr_bytes(ins: Instr, comp: Computation, comps: dict) -> float:
    """Operand + result bytes of one top-level instruction, with
    slice-aware accounting: a (dynamic-)slice/gather reads only its result
    extent, and a dynamic-update-slice writes only the update region —
    charging the full operand would make chunked scans look quadratic in
    sequence length. Fusion parameters consumed exclusively by slice-type
    ops inside the fused computation are charged at the slice size too."""
    op = ins.opcode
    res = _nbytes(comp.defs.get(ins.name, []))
    if op in _SLICE_OPS:
        return 2.0 * res                       # read extent + write result
    if op == "dynamic-update-slice":
        upd = (_nbytes(comp.defs.get(ins.operands[1], []))
               if len(ins.operands) > 1 else res)
        return 2.0 * upd
    total = res
    fused = None
    if op == "fusion":
        import re as _re
        cm = _re.search(r"calls=%?([\w\.\-]+)", ins.line)
        if cm and cm.group(1) in comps:
            fused = comps[cm.group(1)]
    for i, oname in enumerate(ins.operands):
        ob = _nbytes(comp.defs.get(oname, []))
        if fused is not None and ob > 0:
            sliced = _fusion_param_slice_bytes(fused, i)
            if sliced is not None:
                ob = min(ob, sliced)
        total += ob
    return total


def _fusion_param_slice_bytes(fused: Computation, idx: int):
    """If fusion parameter ``idx`` is consumed only by slice-type ops,
    return the summed slice-result bytes (else None)."""
    pname = None
    for i2 in fused.instrs:
        if i2.opcode == "parameter" and f"parameter({idx})" in i2.line:
            pname = i2.name
            break
    if pname is None:
        return None
    consumed = [i2 for i2 in fused.instrs if pname in i2.operands]
    if not consumed:
        return None
    if all(i2.opcode in _SLICE_OPS for i2 in consumed):
        return sum(_nbytes(fused.defs.get(i2.name, [])) for i2 in consumed)
    return None


def collective_bytes(hlo: str) -> CollectiveStats:
    """Back-compat entry point (dryrun.py, tests)."""
    return analyze(hlo).collectives


def normalize_cost_analysis(cost) -> dict:
    """``compiled.cost_analysis()`` → plain dict across JAX versions.

    Older JAX returns a dict; newer versions return a list with one dict
    per partition (usually length 1).  Multi-entry lists are merged by
    summing numeric values; None/empty → {}."""
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    out: dict = {}
    for part in cost:
        if not isinstance(part, dict):
            continue
        for k, v in part.items():
            if isinstance(v, (int, float)) and k in out:
                out[k] += v
            else:
                out[k] = v
    return out
