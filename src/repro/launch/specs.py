"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input of
every (arch × shape) cell. No device allocation; weak-type-correct;
shardable. The modality frontends of [audio]/[vlm] archs are stubs: the
encoder consumes precomputed frame embeddings (DESIGN.md §4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig, ShapeConfig


def enc_len(cfg: ArchConfig, seq_len: int) -> int:
    """Stub audio frontend: ~4× downsampled frames, capped."""
    return min(max(seq_len // 4, 16), 8192)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Inputs for the *step function* of the cell:

    train   : {tokens (B, S) i32 [, enc_embeds (B, Se, D) f32]}
    prefill : same as train
    decode  : {tokens (B, 1) i32}  (the KV/SSM cache is threaded state, see
              launch.dryrun.build_cell)
    """
    B, S = shape.global_batch, shape.seq_len
    specs: dict = {}
    if shape.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, enc_len(cfg, S), cfg.d_model), jnp.float32)
    return specs
