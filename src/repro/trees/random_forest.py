"""Random Forest trainer (paper §6.2/§6.3: 1024 trees, {32, 64} leaves).

Bagging + feature subsampling over histogram CART trees. Leaf payloads are
class-probability vectors already scaled by ``w_i = 1/M`` (paper §2: weights
are folded into the leaves during preprocessing so the ensemble vote is a
plain sum).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

from .cart import Binner, CartConfig, Tree, grow_tree


@dataclass
class RandomForestConfig:
    n_trees: int = 128
    max_leaves: int = 32
    max_depth: int = 24
    min_samples_leaf: int = 1
    n_bins: int = 64
    max_features: Optional[float] = None   # None → sqrt(d)/d heuristic
    max_samples: Optional[int] = None      # bootstrap subsample cap
    seed: int = 0


class RandomForest:
    def __init__(self, cfg: RandomForestConfig):
        self.cfg = cfg
        self.trees: list[Tree] = []
        self.binner: Optional[Binner] = None
        self.n_classes = 0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        cfg = self.cfg
        n, d = X.shape
        self.n_classes = int(y.max()) + 1
        self.binner = Binner.fit(X, cfg.n_bins)
        Xb = self.binner.transform(X)
        max_features = cfg.max_features
        if max_features is None:
            max_features = min(1.0, np.sqrt(d) / d) if d > 32 else 1.0
        tree_cfg = CartConfig(
            max_leaves=cfg.max_leaves, max_depth=cfg.max_depth,
            min_samples_leaf=cfg.min_samples_leaf, n_bins=cfg.n_bins,
            max_features=max_features, criterion="gini")
        rng = np.random.default_rng(cfg.seed)
        n_boot = min(n, cfg.max_samples) if cfg.max_samples else n
        self.trees = []
        for _ in range(cfg.n_trees):
            idx = rng.integers(0, n, size=n_boot)
            t = grow_tree(Xb[idx], self.binner, tree_cfg, rng,
                          y=y[idx], n_classes=self.n_classes)
            # fold 1/M into the leaves (paper §2)
            _scale_leaves(t.root, 1.0 / cfg.n_trees)
            self.trees.append(t)
        return self

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        out = np.zeros((X.shape[0], self.n_classes))
        for t in self.trees:
            out += t.predict(X)
        return out

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.predict_proba(X).argmax(axis=1)


def _scale_leaves(node, s: float) -> None:
    if node.is_leaf:
        node.value = node.value * s
    else:
        _scale_leaves(node.left, s)
        _scale_leaves(node.right, s)
