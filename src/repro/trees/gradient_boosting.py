"""Gradient-boosted trees (paper §6.1: XGBoost GBTs on the MSN ranking set).

Second-order boosting on histogram CART trees:
  * ``objective="l2"``       — squared error (ranking-by-regression, as the
                               paper's throughput experiment requires: it
                               measures traversal speed, not NDCG).
  * ``objective="logistic"`` — binary log-loss.
  * ``objective="softmax"``  — multiclass: one scalar tree per class per
                               round, embedded as C-dim leaves downstream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .cart import Binner, CartConfig, Tree, grow_tree


@dataclass
class GradientBoostingConfig:
    n_trees: int = 100                 # total trees (softmax: rounds = n/C)
    max_leaves: int = 32
    max_depth: int = 24
    min_samples_leaf: int = 1
    n_bins: int = 64
    learning_rate: float = 0.1
    objective: str = "l2"
    reg_lambda: float = 1.0
    subsample: Optional[int] = None
    seed: int = 0


class GradientBoosting:
    def __init__(self, cfg: GradientBoostingConfig):
        self.cfg = cfg
        self.trees: list[Tree] = []
        self.tree_class: list[int] = []    # which class each tree scores (-1 = scalar)
        self.binner: Optional[Binner] = None
        self.n_classes = 1
        self.base_score = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GradientBoosting":
        cfg = self.cfg
        n = X.shape[0]
        self.binner = Binner.fit(X, cfg.n_bins)
        Xb = self.binner.transform(X)
        rng = np.random.default_rng(cfg.seed)
        tree_cfg = CartConfig(
            max_leaves=cfg.max_leaves, max_depth=cfg.max_depth,
            min_samples_leaf=cfg.min_samples_leaf, n_bins=cfg.n_bins,
            criterion="mse", reg_lambda=cfg.reg_lambda,
            leaf_lr=cfg.learning_rate)

        if cfg.objective == "softmax":
            self.n_classes = int(y.max()) + 1
            F = np.zeros((n, self.n_classes))
            rounds = max(1, cfg.n_trees // self.n_classes)
            for _ in range(rounds):
                p = _softmax(F)
                for c in range(self.n_classes):
                    g = p[:, c] - (y == c)
                    h = np.maximum(p[:, c] * (1 - p[:, c]), 1e-6)
                    t = self._fit_one(Xb, tree_cfg, rng, g, h)
                    self.trees.append(t)
                    self.tree_class.append(c)
                    F[:, c] += t.predict(self._raw(Xb))[:, 0]
            return self

        y = y.astype(np.float64)
        if cfg.objective == "logistic":
            self.base_score = 0.0
            F = np.zeros(n)
            for _ in range(cfg.n_trees):
                p = 1.0 / (1.0 + np.exp(-F))
                g, h = p - y, np.maximum(p * (1 - p), 1e-6)
                t = self._fit_one(Xb, tree_cfg, rng, g, h)
                self.trees.append(t)
                self.tree_class.append(-1)
                F += t.predict(self._raw(Xb))[:, 0]
        else:  # l2
            self.base_score = float(y.mean())
            F = np.full(n, self.base_score)
            for _ in range(cfg.n_trees):
                g = F - y
                t = self._fit_one(Xb, tree_cfg, rng, g, np.ones(n))
                self.trees.append(t)
                self.tree_class.append(-1)
                F += t.predict(self._raw(Xb))[:, 0]
        return self

    def _fit_one(self, Xb, tree_cfg, rng, g, h) -> Tree:
        n = Xb.shape[0]
        if self.cfg.subsample and self.cfg.subsample < n:
            idx = rng.choice(n, size=self.cfg.subsample, replace=False)
            return grow_tree(Xb[idx], self.binner, tree_cfg, rng,
                             grad=g[idx], hess=h[idx])
        return grow_tree(Xb, self.binner, tree_cfg, rng, grad=g, hess=h)

    def _raw(self, Xb: np.ndarray) -> np.ndarray:
        """Trees store float thresholds; re-inflate binned X to floats that
        land on the same side of every edge (use the edge value itself)."""
        out = np.empty(Xb.shape)
        for f, e in enumerate(self.binner.edges):
            ext = np.concatenate([e, [e[-1] + 1.0 if len(e) else 1.0]])
            out[:, f] = ext[np.minimum(Xb[:, f], len(ext) - 1)]
        return out

    def predict(self, X: np.ndarray) -> np.ndarray:
        if self.cfg.objective == "softmax":
            out = np.zeros((X.shape[0], self.n_classes))
            for t, c in zip(self.trees, self.tree_class):
                out[:, c] += t.predict(X)[:, 0]
            return out
        out = np.full(X.shape[0], self.base_score)
        for t in self.trees:
            out += t.predict(X)[:, 0]
        return out


def _softmax(F: np.ndarray) -> np.ndarray:
    z = F - F.max(axis=1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=1, keepdims=True)
