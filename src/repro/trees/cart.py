"""Histogram-based CART decision-tree trainer (numpy).

sklearn/xgboost are unavailable in the offline container, so the framework
builds its own training substrate. Trees are grown *leaf-wise* (best-first,
LightGBM style) so ``max_leaves`` — the paper's controlling knob {32, 64} —
is respected exactly.

Two split criteria:
  * ``"gini"``  — multiclass Gini impurity over class-count histograms
                  (Random Forests, paper §6.2/§6.3).
  * ``"mse"``   — variance reduction over gradient/hessian histograms
                  (gradient boosting, paper §6.1 ranking experiment).

Features are pre-binned into ``n_bins`` quantile bins once per dataset
(`Binner`); split search scans cumulative histograms, exactly like
LightGBM/XGBoost-hist.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


# --------------------------------------------------------------------------- #
# Binning
# --------------------------------------------------------------------------- #
@dataclass
class Binner:
    """Per-feature quantile binning. ``edges[f][b]`` is the upper threshold of
    bin ``b``; a sample falls in bin ``b`` iff ``x <= edges[f][b]`` and
    ``x > edges[f][b-1]``."""

    edges: list  # list of (n_edges_f,) float arrays, ascending

    @staticmethod
    def fit(X: np.ndarray, n_bins: int = 64) -> "Binner":
        edges = []
        qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
        for f in range(X.shape[1]):
            e = np.unique(np.quantile(X[:, f], qs))
            edges.append(e.astype(np.float64))
        return Binner(edges)

    def transform(self, X: np.ndarray) -> np.ndarray:
        out = np.empty(X.shape, dtype=np.int16)
        for f, e in enumerate(self.edges):
            out[:, f] = np.searchsorted(e, X[:, f], side="left")
        return out

    def threshold(self, f: int, b: int) -> float:
        """Float threshold realising a split 'bin <= b' as 'x <= t'."""
        return float(self.edges[f][b])

    def n_bins(self, f: int) -> int:
        return len(self.edges[f]) + 1


# --------------------------------------------------------------------------- #
# Tree structure (builder form; converted to Forest IR by core.forest)
# --------------------------------------------------------------------------- #
@dataclass
class TreeNode:
    feature: int = -1
    threshold: float = 0.0
    left: Optional["TreeNode"] = None
    right: Optional["TreeNode"] = None
    value: Optional[np.ndarray] = None  # (C,) leaf payload

    @property
    def is_leaf(self) -> bool:
        return self.left is None


@dataclass
class FlatTree:
    """Array form for vectorized traversal. Leaves have feature == -1 and
    left == right == self-index (traversal is a fixed-point after depth
    steps)."""
    feature: np.ndarray    # (n_nodes,) int32
    threshold: np.ndarray  # (n_nodes,) float64
    left: np.ndarray       # (n_nodes,) int32
    right: np.ndarray      # (n_nodes,) int32
    value: np.ndarray      # (n_nodes, C) float64 (zeros at internal nodes)
    depth: int

    def predict(self, X: np.ndarray) -> np.ndarray:
        node = np.zeros(X.shape[0], dtype=np.int32)
        for _ in range(self.depth):
            f = self.feature[node]
            go_left = X[np.arange(X.shape[0]), np.maximum(f, 0)] \
                <= self.threshold[node]
            node = np.where(f < 0, node,
                            np.where(go_left, self.left[node], self.right[node]))
        return self.value[node]


@dataclass
class Tree:
    root: TreeNode
    n_leaves: int
    max_depth_seen: int
    _flat: Optional[FlatTree] = None

    def flat(self) -> FlatTree:
        if self._flat is None:
            self._flat = flatten_tree(self)
        return self._flat

    def predict(self, X: np.ndarray) -> np.ndarray:
        """Ground-truth traversal (vectorized; split rule: left iff x <= t)."""
        return self.flat().predict(X)

    def predict_slow(self, X: np.ndarray) -> np.ndarray:
        """Per-sample pointer-chasing oracle (used by tests to cross-check
        the vectorized path)."""
        out = np.empty((X.shape[0], len(_first_leaf(self.root).value)))
        for i, x in enumerate(X):
            node = self.root
            while not node.is_leaf:
                node = node.left if x[node.feature] <= node.threshold else node.right
            out[i] = node.value
        return out


def flatten_tree(tree: "Tree") -> FlatTree:
    nodes: list[TreeNode] = []

    def collect(nd: TreeNode):
        nodes.append(nd)
        if not nd.is_leaf:
            collect(nd.left)
            collect(nd.right)

    collect(tree.root)
    index = {id(nd): i for i, nd in enumerate(nodes)}
    n = len(nodes)
    C = len(_first_leaf(tree.root).value)
    feature = np.full(n, -1, dtype=np.int32)
    threshold = np.zeros(n)
    left = np.arange(n, dtype=np.int32)
    right = np.arange(n, dtype=np.int32)
    value = np.zeros((n, C))
    for i, nd in enumerate(nodes):
        if nd.is_leaf:
            value[i] = nd.value
        else:
            feature[i] = nd.feature
            threshold[i] = nd.threshold
            left[i] = index[id(nd.left)]
            right[i] = index[id(nd.right)]
    return FlatTree(feature, threshold, left, right, value,
                    depth=max(tree.max_depth_seen, 1))


def _first_leaf(node: TreeNode) -> TreeNode:
    while not node.is_leaf:
        node = node.left
    return node


# --------------------------------------------------------------------------- #
# Histogram accumulation
# --------------------------------------------------------------------------- #
def _class_hist(Xb: np.ndarray, y: np.ndarray, idx: np.ndarray, feats: np.ndarray,
                n_bins: int, n_classes: int) -> np.ndarray:
    """Class-count histogram (len(feats), n_bins, C) for samples ``idx``."""
    sub = Xb[np.ix_(idx, feats)].astype(np.int64)               # (n, F)
    codes = (np.arange(len(feats))[None, :] * n_bins + sub) * n_classes \
        + y[idx][:, None]
    h = np.bincount(codes.ravel(), minlength=len(feats) * n_bins * n_classes)
    return h.reshape(len(feats), n_bins, n_classes).astype(np.float64)


def _grad_hist(Xb: np.ndarray, g: np.ndarray, h: np.ndarray, idx: np.ndarray,
               feats: np.ndarray, n_bins: int) -> np.ndarray:
    """Gradient/hessian/count histogram (len(feats), n_bins, 3)."""
    sub = Xb[np.ix_(idx, feats)].astype(np.int64)
    codes = np.arange(len(feats))[None, :] * n_bins + sub
    flat = codes.ravel()
    size = len(feats) * n_bins
    gs = np.bincount(flat, weights=np.repeat(g[idx], len(feats)), minlength=size)
    hs = np.bincount(flat, weights=np.repeat(h[idx], len(feats)), minlength=size)
    cs = np.bincount(flat, minlength=size)
    return np.stack([gs, hs, cs], axis=-1).reshape(len(feats), n_bins, 3)


# --------------------------------------------------------------------------- #
# Split search
# --------------------------------------------------------------------------- #
def _best_split_gini(hist: np.ndarray, min_leaf: int):
    """hist: (F, B, C) class counts. Returns (gain, f_local, bin) or None."""
    total = hist.sum(axis=1)                                    # (F, C)
    n = total.sum(axis=1)                                       # (F,)
    left = np.cumsum(hist, axis=1)[:, :-1, :]                   # (F, B-1, C)
    nl = left.sum(axis=2)
    nr = n[:, None] - nl
    right = total[:, None, :] - left
    with np.errstate(divide="ignore", invalid="ignore"):
        gini_l = 1.0 - (left ** 2).sum(2) / np.maximum(nl, 1) ** 2
        gini_r = 1.0 - (right ** 2).sum(2) / np.maximum(nr, 1) ** 2
        gini_p = 1.0 - (total ** 2).sum(1) / np.maximum(n, 1) ** 2
    impurity = (nl * gini_l + nr * gini_r) / np.maximum(n[:, None], 1)
    gain = gini_p[:, None] - impurity
    gain[(nl < min_leaf) | (nr < min_leaf)] = -np.inf
    f, b = np.unravel_index(np.argmax(gain), gain.shape)
    g = gain[f, b]
    if not np.isfinite(g) or g <= 1e-12:
        return None
    return float(g), int(f), int(b)


def _best_split_mse(hist: np.ndarray, min_leaf: int, lam: float = 1.0):
    """hist: (F, B, 3) [grad, hess, count]. XGBoost-style gain."""
    gl = np.cumsum(hist[..., 0], axis=1)[:, :-1]
    hl = np.cumsum(hist[..., 1], axis=1)[:, :-1]
    cl = np.cumsum(hist[..., 2], axis=1)[:, :-1]
    gt, ht, ct = hist[..., 0].sum(1), hist[..., 1].sum(1), hist[..., 2].sum(1)
    gr, hr, cr = gt[:, None] - gl, ht[:, None] - hl, ct[:, None] - cl
    gain = gl ** 2 / (hl + lam) + gr ** 2 / (hr + lam) \
        - (gt ** 2 / (ht + lam))[:, None]
    gain[(cl < min_leaf) | (cr < min_leaf)] = -np.inf
    f, b = np.unravel_index(np.argmax(gain), gain.shape)
    g = gain[f, b]
    if not np.isfinite(g) or g <= 1e-12:
        return None
    return float(g), int(f), int(b)


# --------------------------------------------------------------------------- #
# Leaf-wise tree growth
# --------------------------------------------------------------------------- #
@dataclass
class CartConfig:
    max_leaves: int = 32
    max_depth: int = 24
    min_samples_leaf: int = 1
    n_bins: int = 64
    max_features: Optional[float] = None   # fraction; None = all
    criterion: str = "gini"                # "gini" | "mse"
    reg_lambda: float = 1.0
    leaf_lr: float = 1.0                   # shrinkage applied to mse leaves


_COUNTER = 0  # heap tiebreaker


def grow_tree(Xb: np.ndarray, binner: Binner, cfg: CartConfig,
              rng: np.random.Generator,
              y: Optional[np.ndarray] = None,        # int labels (gini)
              n_classes: int = 2,
              grad: Optional[np.ndarray] = None,     # (n,) or (n, C) (mse)
              hess: Optional[np.ndarray] = None) -> Tree:
    global _COUNTER
    n, d = Xb.shape
    n_bins = cfg.n_bins + 1  # searchsorted can emit bin == n_edges
    if cfg.max_features is None:
        n_feats = d
    else:
        n_feats = max(1, int(round(cfg.max_features * d)))

    multi_grad = grad is not None and grad.ndim == 2
    if grad is not None and hess is None:
        hess = np.ones(n)

    def leaf_value(idx: np.ndarray) -> np.ndarray:
        if cfg.criterion == "gini":
            cnt = np.bincount(y[idx], minlength=n_classes).astype(np.float64)
            return cnt / max(cnt.sum(), 1.0)
        if multi_grad:
            gs = grad[idx].sum(0)
            hs = hess[idx].sum() + cfg.reg_lambda
            return cfg.leaf_lr * (-gs / hs)
        gs, hs = grad[idx].sum(), hess[idx].sum() + cfg.reg_lambda
        return np.array([cfg.leaf_lr * (-gs / hs)])

    def find_split(idx: np.ndarray):
        feats = (rng.choice(d, size=n_feats, replace=False)
                 if n_feats < d else np.arange(d))
        if cfg.criterion == "gini":
            hist = _class_hist(Xb, y, idx, feats, n_bins, n_classes)
            res = _best_split_gini(hist, cfg.min_samples_leaf)
        else:
            g1 = grad.sum(axis=1) if multi_grad else grad
            hist = _grad_hist(Xb, g1, hess, idx, feats, n_bins)
            res = _best_split_mse(hist, cfg.min_samples_leaf, cfg.reg_lambda)
        if res is None:
            return None
        gain, f_local, b = res
        f = int(feats[f_local])
        if b >= len(binner.edges[f]):   # split beyond last edge → useless
            return None
        return gain, f, b

    root = TreeNode(value=leaf_value(np.arange(n)))
    heap = []
    depth_of = {id(root): 0}

    def push(node: TreeNode, idx: np.ndarray):
        global _COUNTER
        if len(idx) < 2 * cfg.min_samples_leaf or depth_of[id(node)] >= cfg.max_depth:
            return
        s = find_split(idx)
        if s is None:
            return
        gain, f, b = s
        _COUNTER += 1
        heapq.heappush(heap, (-gain, _COUNTER, node, idx, f, b))

    push(root, np.arange(n))
    n_leaves, max_depth_seen = 1, 0
    while heap and n_leaves < cfg.max_leaves:
        _, _, node, idx, f, b = heapq.heappop(heap)
        go_left = Xb[idx, f] <= b
        li, ri = idx[go_left], idx[~go_left]
        if len(li) == 0 or len(ri) == 0:
            continue
        node.feature, node.threshold = f, binner.threshold(f, b)
        node.left = TreeNode(value=leaf_value(li))
        node.right = TreeNode(value=leaf_value(ri))
        node.value = None
        dep = depth_of[id(node)] + 1
        depth_of[id(node.left)] = depth_of[id(node.right)] = dep
        max_depth_seen = max(max_depth_seen, dep)
        n_leaves += 1
        push(node.left, li)
        push(node.right, ri)
    return Tree(root, n_leaves, max_depth_seen)
