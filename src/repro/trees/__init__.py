from .cart import Binner, CartConfig, FlatTree, Tree, TreeNode, grow_tree
from .gradient_boosting import GradientBoosting, GradientBoostingConfig
from .random_forest import RandomForest, RandomForestConfig

__all__ = [
    "Binner", "CartConfig", "FlatTree", "Tree", "TreeNode", "grow_tree",
    "GradientBoosting", "GradientBoostingConfig",
    "RandomForest", "RandomForestConfig",
]
