"""Adam/AdamW in pure JAX, with optional int8-quantized moment state.

The int8 state is the paper's fixed-point idea (§5) applied to optimizer
memory: ``q(x) = round(x / s · 127)`` with a *per-row* (last-dim) scale so
dynamic-range variation across rows doesn't destroy the second moment. It
cuts Adam state from 8 bytes/param to ~2.03 bytes/param, which is what lets
grok-1-314B / jamba-398B train states fit a single 256-chip pod
(EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str = "f32"        # "f32" | "int8"


# ---------------------------------------------------------------- int8 state
def _q8(x: jnp.ndarray):
    """Row-wise symmetric int8 quantization: returns (q, scale)."""
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(x), 1e-12)
        return jnp.round(x / scale * 127).astype(jnp.int8), scale
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    return jnp.round(x / scale).astype(jnp.int8), scale


def _dq8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _q8_sqrt(x: jnp.ndarray):
    """Sqrt-domain int8 for the second moment: linear int8 underflows
    small v entries to exactly 0 within a row (amax-scaled), and
    ``m/(sqrt(0)+eps)`` then explodes — observed divergence in 3 steps.
    Quantizing sqrt(v) halves the dynamic range, so small entries keep
    ≥1 quantization level."""
    r = jnp.sqrt(jnp.maximum(x, 0.0))
    q, scale = _q8(r)
    return q, scale


def _dq8_sqrt(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    r = _dq8(q, scale)
    return r * r


class Adam:
    def __init__(self, cfg: AdamConfig):
        self.cfg = cfg

    def init(self, params) -> dict:
        def zeros_like_state(p):
            if self.cfg.state_dtype == "int8":
                z = jnp.zeros(p.shape, jnp.int8)
                s = jnp.zeros(p.shape[:-1] + (1,) if p.ndim else (),
                              jnp.float32)
                return {"q": z, "scale": s}
            return jnp.zeros_like(p, dtype=jnp.float32)

        return {
            "m": jax.tree.map(zeros_like_state, params),
            "v": jax.tree.map(zeros_like_state, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _load(self, s, sqrt_domain: bool = False):
        if self.cfg.state_dtype == "int8":
            return (_dq8_sqrt if sqrt_domain else _dq8)(s["q"], s["scale"])
        return s

    def _store(self, x, sqrt_domain: bool = False):
        if self.cfg.state_dtype == "int8":
            q, scale = (_q8_sqrt if sqrt_domain else _q8)(x)
            return {"q": q, "scale": scale}
        return x

    def update(self, grads, state, params):
        cfg = self.cfg
        step = state["step"] + 1
        c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}

        def upd(p, g, m_s, v_s):
            g = g.astype(jnp.float32)
            m = cfg.b1 * self._load(m_s) + (1 - cfg.b1) * g
            v = cfg.b2 * self._load(v_s, sqrt_domain=True) \
                + (1 - cfg.b2) * g * g
            mh, vh = m / c1, v / c2
            ratio = mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.state_dtype == "int8":
                # residual quantization noise guard: Adam's per-element
                # update ratio is ~±1 at convergence; |ratio| ≫ 1 only ever
                # comes from a corrupted second moment
                ratio = jnp.clip(ratio, -10.0, 10.0)
            delta = cfg.lr * ratio
            if cfg.weight_decay:
                delta = delta + cfg.lr * cfg.weight_decay * p
            return ((p - delta).astype(p.dtype), self._store(m),
                    self._store(v, sqrt_domain=True))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.flatten(grads)[0]
        flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
        flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    def state_logical_specs(self, logical_tree):
        """Optimizer-state sharding mirrors the param sharding."""
        is_leaf = lambda x: isinstance(x, tuple)
        if self.cfg.state_dtype == "int8":
            def expand(l):
                return {"q": l, "scale": l}   # scale row dim matches
            mom = jax.tree.map(expand, logical_tree, is_leaf=is_leaf)
        else:
            mom = logical_tree
        return {"m": mom, "v": mom, "step": ()}
