"""Logical-axis → mesh-axis sharding resolution.

Model code annotates every param dim with a logical name (models/layers.py
SpecMaker); this module maps those names onto the production mesh:

  mesh axes: ("data", "model")           — single pod, 16×16
             ("pod", "data", "model")    — 2 pods × 16×16

Rules (resolved per-tensor with divisibility checks; at most one mesh axis
per dim, at most one dim per mesh axis):

  * tensor-parallel axis "model": vocab / ff / experts / ssm_inner first,
    then heads / kv / ssm_heads, then head_dim (fallback when the head count
    does not divide the axis — smollm's 15 heads, command-r's 8 kv heads).
  * FSDP axes ("pod","data"): the "embed" (d_model) dim of every weight —
    ZeRO-3-style parameter sharding; all-gathers happen per-layer inside the
    scan and overlap with compute (XLA latency-hiding scheduler).
  * "layers" / small dims: replicated.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# priority 0 tried first
MODEL_PRIORITY = {
    "vocab": 0, "ff": 0, "experts": 0, "ssm_inner": 0,
    "heads": 1, "kv": 1, "ssm_heads": 1,
    "head_dim": 2,
}
FSDP_CANDIDATES = ("embed",)


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(shape: Sequence[int], logical: Sequence[str],
                 mesh: Mesh) -> P:
    """One tensor: logical names + concrete shape → PartitionSpec."""
    assert len(shape) == len(logical), (shape, logical)
    out: list = [None] * len(shape)
    model_size = mesh.shape["model"]
    # pass 1: tensor-parallel axis
    cands = [(MODEL_PRIORITY[l], i) for i, l in enumerate(logical)
             if l in MODEL_PRIORITY and shape[i] % model_size == 0
             and shape[i] > 0]
    if cands:
        _, i = min(cands)
        out[i] = "model"
    # pass 2: FSDP axes on the embed dim
    fa = fsdp_axes(mesh)
    if fa:
        fs = _axis_size(mesh, fa)
        for i, l in enumerate(logical):
            if l in FSDP_CANDIDATES and out[i] is None and shape[i] % fs == 0:
                out[i] = fa if len(fa) > 1 else fa[0]
                break
    return P(*out)


def tree_shardings(param_tree, logical_tree, mesh: Mesh):
    """Trees of arrays/ShapeDtypeStructs + logical tuples → NamedShardings."""
    def one(arr, logical):
        return NamedSharding(mesh, resolve_spec(arr.shape, logical, mesh))
    return jax.tree.map(one, param_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def data_spec(mesh: Mesh, ndim: int, batch_size: int) -> P:
    """Batch-sharded activation/input spec: dim 0 over (pod, data) when
    divisible, rest replicated."""
    fa = fsdp_axes(mesh)
    if fa and batch_size % _axis_size(mesh, fa) == 0:
        first = fa if len(fa) > 1 else fa[0]
    elif fa and batch_size % mesh.shape[fa[-1]] == 0:
        first = fa[-1]
    else:
        first = None
    return P(first, *([None] * (ndim - 1)))


def decode_state_specs(cfg, state_tree, mesh: Mesh):
    """Sharding for the decode cache: batch over data axes; KV heads over
    "model" when divisible, else head_dim; SSM heads over "model"."""
    model_size = mesh.shape["model"]
    fa = fsdp_axes(mesh)

    def batch_axis(b):
        if fa and b % _axis_size(mesh, fa) == 0:
            return fa if len(fa) > 1 else fa[0]
        if fa and b % mesh.shape[fa[-1]] == 0:
            return fa[-1]
        return None

    def one(path, arr):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = arr.ndim
        if name in ("k", "v"):
            # (U, na, B, Smax, K, hd)
            b = batch_axis(arr.shape[2])
            if arr.shape[4] % model_size == 0:
                return NamedSharding(mesh, P(None, None, b, None, "model", None))
            if arr.shape[5] % model_size == 0:
                return NamedSharding(mesh, P(None, None, b, None, None, "model"))
            return NamedSharding(mesh, P(None, None, b, None, None, None))
        if name in ("k_scale", "v_scale"):
            # (U, na, B, Smax, K) — int8-KV per-position scales
            b = batch_axis(arr.shape[2])
            kk = "model" if arr.shape[4] % model_size == 0 else None
            return NamedSharding(mesh, P(None, None, b, None, kk))
        if name == "ssm_h":
            # (U, ns, B, H, P, N)
            b = batch_axis(arr.shape[2])
            h = "model" if arr.shape[3] % model_size == 0 else None
            return NamedSharding(mesh, P(None, None, b, h, None, None))
        if name == "conv":
            # (U, ns, B, cw-1, d_inner)
            b = batch_axis(arr.shape[2])
            di = "model" if arr.shape[4] % model_size == 0 else None
            return NamedSharding(mesh, P(None, None, b, None, di))
        if name in ("cross_k", "cross_v"):
            # (U, B, Sm, H, hd)
            b = batch_axis(arr.shape[1])
            h = "model" if arr.shape[3] % model_size == 0 else None
            return NamedSharding(mesh, P(None, b, None, h, None))
        if name == "index":
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(one, state_tree)
