"""Fault-tolerant checkpointing (no orbax in the container — built from
scratch).

Layout:  <dir>/step_<N>/
            manifest.msgpack   — tree structure, shapes, dtypes, CRCs, step
            arr_<i>.npy        — one file per leaf (global, host layout)
         <dir>/LATEST          — text file naming the newest complete step

Guarantees:
  * atomic publish: written to ``step_<N>.tmp`` then ``os.rename`` (POSIX
    atomic) — a crash mid-save never corrupts the latest checkpoint;
  * integrity: CRC32 per leaf, verified on restore;
  * elasticity: leaves are saved as *global* arrays with their global shape;
    restore re-shards onto whatever mesh/sharding the new job passes
    (``device_put`` with the target sharding), so the DP axis can grow or
    shrink between runs;
  * multi-host note: on a real cluster each process saves only
    ``addressable_shards`` plus index ranges; the CPU container exercises
    the single-host path, and the manifest format already records the
    global shape needed for reassembly.
"""
from __future__ import annotations

import os
import shutil
import zlib
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any) -> str:
    """Serialize ``tree`` (params/opt state/rng, any pytree of arrays)."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    metas = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fn = os.path.join(tmp, f"arr_{i}.npy")
        np.save(fn, arr)
        with open(fn, "rb") as f:
            crc = zlib.crc32(f.read())
        metas.append({"shape": list(arr.shape), "dtype": str(arr.dtype),
                      "crc": crc})
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": metas,
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    with open(os.path.join(path, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(path, "LATEST.tmp"), os.path.join(path, "LATEST"))
    return final


def latest_step(path: str) -> Optional[int]:
    latest = os.path.join(path, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(path, name)):
        return None
    return int(name.split("_")[1])


def restore(path: str, target_tree: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Load into the structure of ``target_tree``. ``shardings`` (optional
    matching tree of NamedShardings) re-shards for the *current* mesh —
    elastic restart support."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())

    t_leaves, treedef = _flatten(target_tree)
    assert manifest["n_leaves"] == len(t_leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(t_leaves)}"
    s_leaves = jax.tree.flatten(shardings)[0] if shardings is not None \
        else [None] * len(t_leaves)

    out = []
    for i, (meta, tgt, shd) in enumerate(
            zip(manifest["leaves"], t_leaves, s_leaves)):
        fn = os.path.join(d, f"arr_{i}.npy")
        with open(fn, "rb") as f:
            crc = zlib.crc32(f.read())
        if crc != meta["crc"]:
            raise IOError(f"CRC mismatch in {fn} (corrupt checkpoint)")
        arr = np.load(fn)
        assert list(arr.shape) == list(np.shape(tgt)), \
            f"leaf {i}: ckpt {arr.shape} vs target {np.shape(tgt)}"
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step


def cleanup(path: str, keep: int = 3) -> None:
    """Retain the newest ``keep`` checkpoints."""
    if not os.path.isdir(path):
        return
    steps = sorted(n for n in os.listdir(path) if n.startswith("step_")
                   and not n.endswith(".tmp"))
    for n in steps[:-keep]:
        shutil.rmtree(os.path.join(path, n), ignore_errors=True)
