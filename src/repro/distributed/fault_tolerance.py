"""Fault-tolerance machinery: heartbeats, straggler detection, elastic
restart planning.

On a real multi-host cluster each component runs per process; the CPU
container exercises the same code paths single-host (unit-tested state
machines + file protocols). The design targets 1000+ nodes:

  * Heartbeat files are O(1) per host per step — a shared filesystem (or
    object store) scales to thousands of writers because each host touches
    only its own file.
  * The straggler detector is purely local math over observed step times
    (trailing median + multiplier), no coordination.
  * The elastic planner maps surviving host sets onto the largest usable
    mesh (DP axis shrink in powers of two) so restore-after-failure keeps
    every surviving chip busy instead of stalling the fleet.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence


# --------------------------------------------------------------------------- #
# Heartbeats
# --------------------------------------------------------------------------- #
class Heartbeat:
    """Per-process liveness file: ``<dir>/host_<idx>.hb`` containing the last
    step and wall time. Atomic via write-to-tmp + rename."""

    def __init__(self, directory: str, proc_index: int):
        self.dir = directory
        self.idx = proc_index
        os.makedirs(directory, exist_ok=True)

    @property
    def path(self) -> str:
        return os.path.join(self.dir, f"host_{self.idx:05d}.hb")

    def beat(self, step: int, now: Optional[float] = None) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now or time.time()}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def survey(directory: str, timeout_s: float,
               now: Optional[float] = None) -> dict[int, dict]:
        """All hosts' heartbeats; entries older than ``timeout_s`` are marked
        dead. Returns {proc_index: {"step", "time", "alive"}}."""
        now = now or time.time()
        out: dict[int, dict] = {}
        if not os.path.isdir(directory):
            return out
        for name in sorted(os.listdir(directory)):
            if not (name.startswith("host_") and name.endswith(".hb")):
                continue
            idx = int(name[5:10])
            try:
                with open(os.path.join(directory, name)) as f:
                    rec = json.load(f)
            except (json.JSONDecodeError, OSError):
                out[idx] = {"step": -1, "time": 0.0, "alive": False}
                continue
            rec["alive"] = (now - rec["time"]) <= timeout_s
            out[idx] = rec
        return out


# --------------------------------------------------------------------------- #
# Straggler detection
# --------------------------------------------------------------------------- #
@dataclass
class StragglerDetector:
    """Trailing-median step-time watchdog.

    A step slower than ``multiplier ×`` the trailing median is flagged.
    ``grace`` initial steps are ignored (compile + warmup).
    """
    window: int = 32
    multiplier: float = 3.0
    grace: int = 2
    _times: deque = field(default_factory=deque)
    _seen: int = 0

    def observe(self, step_time_s: float) -> bool:
        """Record one step; returns True if this step is a straggler."""
        self._seen += 1
        if self._seen <= self.grace:
            return False
        is_straggler = False
        if len(self._times) >= 4:
            med = sorted(self._times)[len(self._times) // 2]
            is_straggler = step_time_s > self.multiplier * med
        # stragglers don't poison the window
        if not is_straggler:
            self._times.append(step_time_s)
            if len(self._times) > self.window:
                self._times.popleft()
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        if not self._times:
            return None
        return sorted(self._times)[len(self._times) // 2]

    def deadline(self) -> Optional[float]:
        m = self.median
        return None if m is None else self.multiplier * m


# --------------------------------------------------------------------------- #
# Elastic restart planning
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ElasticPlan:
    n_hosts_alive: int
    dp_size: int                 # new data-parallel axis size
    dropped_hosts: tuple         # hosts excluded from the new mesh
    global_batch: int            # preserved (grad-accum absorbs the shrink)
    accum_steps: int             # microbatches per step on the shrunk mesh


def plan_elastic_restart(alive: Sequence[int], total_hosts: int,
                         dp_size: int, global_batch: int) -> ElasticPlan:
    """Shrink the DP axis to the largest power-of-two ≤ alive hosts
    (model axes stay intact: a host loss removes whole DP replicas).
    The global batch is preserved by gradient accumulation, so the loss
    trajectory is unchanged — only wall-clock throughput drops.
    """
    n_alive = len(alive)
    assert n_alive >= 1, "no survivors"
    new_dp = 1
    while new_dp * 2 <= min(n_alive, dp_size):
        new_dp *= 2
    used = sorted(alive)[:new_dp]
    dropped = tuple(h for h in range(total_hosts) if h not in used)
    accum = max(1, dp_size // new_dp)
    return ElasticPlan(n_alive, new_dp, dropped, global_batch, accum)


# --------------------------------------------------------------------------- #
# Preemption flag (SIGTERM → checkpoint-and-exit handshake)
# --------------------------------------------------------------------------- #
class PreemptionFlag:
    """Co-operative shutdown: signal handlers set it, the train loop polls
    it at step boundaries (async-signal-safe: just a bool)."""

    def __init__(self):
        self._flag = False

    def set(self, *_args) -> None:
        self._flag = True

    def __bool__(self) -> bool:
        return self._flag
