"""Int8 error-feedback gradient compression for the DP all-reduce.

The paper's fixed-point quantization (§5) applied to training communication:
before the data-parallel all-reduce each worker quantizes its local gradient
to int8 (row-wise scale), keeps the quantization error as a residual that is
added to the *next* step's gradient (error feedback — Seide et al. 2014,
Karimireddy et al. 2019 guarantee convergence), and all-reduces the int8
payload (4× less ICI traffic than f32, 2× less than bf16).

Two entry points:
  * ``compress`` / ``decompress`` — pure functions, unit-testable anywhere;
  * ``compressed_psum`` — for use inside ``shard_map`` over the data axis:
    quantize → psum int32 accumulator → dequantize.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g: jnp.ndarray, residual: jnp.ndarray):
    """(grad, residual) → (q int8, scale, new_residual)."""
    x = g.astype(jnp.float32) + residual
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(x), 1e-12) / 127.0
    else:
        scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                            1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, x - deq


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals):
    """Tree version: returns (dequantized grads as seen post-allreduce,
    new residuals). Single-device semantics (the communication itself is
    the mesh's psum; this models the lossy codec)."""
    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.flatten(residuals)[0]
    deqs, res = [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = compress(g, r)
        deqs.append(decompress(q, s))
        res.append(nr)
    return jax.tree.unflatten(td, deqs), jax.tree.unflatten(td, res)


def init_residuals(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(g: jnp.ndarray, residual: jnp.ndarray, axis_name: str):
    """Inside shard_map over the DP axis: int8-quantize, integer all-reduce,
    dequantize with the max scale (scales are psum-maxed so the codebook is
    shared)."""
    x = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    deq_local = q.astype(jnp.float32) * scale
    total = jax.lax.psum(q, axis_name).astype(jnp.float32) * scale
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total / n, x - deq_local
