"""RapidScorer (Ye et al. 2018) — equivalent-node merging, TPU form.

Of RapidScorer's three mechanisms (DESIGN.md §2.3):
  * node merging   → transfers: dedupe identical (feature, threshold) pairs
    across the whole ensemble; one comparison drives every occurrence.
  * epitome        → dropped (CPU L1 optimisation; dense words win in VMEM).
  * byte transpose → subsumed by the Pallas kernel's lane-minor layout.

Merged evaluation computes ``cond_u`` once per *unique* node, then scatters
it to all occurrences via a gather. The merging statistics themselves
(Table 4 of the paper) come from ``merge_stats``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .forest import Forest
from .quickscorer import (CompiledQS, acc_dtype_for, compile_qs, exit_leaf,
                          mask_reduce)
from .registry import BasePredictor, register_engine


@dataclass
class CompiledRS:
    qs: CompiledQS
    u_feat: jnp.ndarray      # (U,) int32 unique node features
    u_thr: jnp.ndarray       # (U,) unique thresholds
    inv: jnp.ndarray         # (T, N) int32 node → unique id
    n_unique: int

    def transform_inputs(self, X):
        return self.qs.transform_inputs(X)


def merge_nodes(forest: Forest):
    """Unique (feature, threshold) table + inverse map. Padding nodes map to
    unique id 0 but are masked out by ``valid`` downstream.

    The computation is shared compiler analysis now (the optimizer's
    ``dedup_thresholds`` pass and Table 4 report the same statistic):
    this is ``repro.optim.analysis.unique_splits``, imported lazily so
    the two package inits never deadlock."""
    from ..optim.analysis import unique_splits
    return unique_splits(forest)


def merge_stats(forest: Forest) -> float:
    """Fraction of unique nodes kept after merging (paper Table 4)."""
    from ..optim.analysis import unique_fraction
    return unique_fraction(forest)


def compile_rs(forest: Forest) -> CompiledRS:
    qs = compile_qs(forest)
    u_feat, u_thr, inv, n_unique = merge_nodes(forest)
    return CompiledRS(qs, jnp.asarray(u_feat), jnp.asarray(u_thr),
                      jnp.asarray(inv), n_unique)


def eval_batch(rs: CompiledRS, X: jnp.ndarray) -> jnp.ndarray:
    """X (B, d) → scores (B, C): one comparison per unique node."""
    qs = rs.qs
    cond_u = X[:, rs.u_feat] > rs.u_thr[None]                   # (B, U)
    cond = jnp.take(cond_u, rs.inv, axis=1) & qs.valid[None]    # (B, T, N)
    leafidx = mask_reduce(cond, qs.masks, qs.init_idx)
    leaf = exit_leaf(leafidx)
    vals = jnp.take_along_axis(
        qs.leaf_val[None], leaf[..., None, None], axis=2)[:, :, 0]
    acc_dtype = acc_dtype_for(qs.leaf_val.dtype, qs.acc_bits)
    score = vals.astype(acc_dtype).sum(axis=1, dtype=acc_dtype)
    return score.astype(jnp.float32) / qs.leaf_scale


class RSPredictor(BasePredictor):
    """Node-merged engine wrapper (shared base: quantization + jit)."""

    def __init__(self, rs: CompiledRS, eval_fn=None):
        super().__init__(rs, eval_fn or eval_batch)
        self.rs = rs


# The unique-node table (u_feat/u_thr) is ensemble-global: tree-sharding
# splits only the per-tree inverse map, every shard keeps the full table.
register_engine(
    "rapidscorer", tune_name="rapidscorer", compile=compile_rs,
    evaluate=eval_batch, predictor_cls=RSPredictor, shardable=True,
    replicated=("u_feat", "u_thr"),
    serial_arrays=("u_feat", "u_thr", "inv", "qs.feat", "qs.thr",
                   "qs.valid", "qs.masks", "qs.init_idx", "qs.leaf_val"),
    doc="RapidScorer: node-merged QuickScorer (shared thresholds collapse)")
