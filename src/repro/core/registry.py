"""Unified engine registry — every traversal engine registers exactly once.

The paper's conclusion (the fastest engine depends on forest shape and
device) only pays off if engines are interchangeable.  This module is the
single source of truth that makes them so:

  * ``EngineSpec`` — one record per (engine, backend): how to compile the
    Forest IR into device arrays, how to evaluate them, how to wrap the
    result into a predictor, and whether the engine supports tree-sharded
    execution (``core/shard.py``).
  * ``register_engine(...)`` — decorator/registration call used by the
    engine modules (``quickscorer``, ``rapidscorer``, ``baselines``) and,
    via deferred targets, the Pallas kernels in ``kernels/ops.py``.
  * ``BasePredictor`` — the shared predictor base (input quantization,
    jit cache, ``predict`` / ``predict_class`` / ``predict_proba``) that
    replaces the per-engine ``XPredictor`` copies.

``core.compile_forest``, the autotuner (``core/engine_select.py``), the
pass pipeline (``core/pipeline.py``), benchmarks, and the agreement test
suite all resolve engines through this table — there is no second
engine-name list anywhere in the tree (see docs/DESIGN.md §4).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# Protocols
# --------------------------------------------------------------------------- #
@runtime_checkable
class Predictor(Protocol):
    """What every engine hands back to the user/serving layer."""

    def transform_inputs(self, X: np.ndarray) -> np.ndarray: ...
    def predict(self, X: np.ndarray) -> np.ndarray: ...
    def predict_class(self, X: np.ndarray) -> np.ndarray: ...


@runtime_checkable
class ForestEngine(Protocol):
    """A registered engine: ``compile(forest, **kw) → Predictor``.

    ``EngineSpec`` satisfies this via ``builder()`` — note that the
    spec's ``compile`` *field* is the lower-level array compiler
    (``forest → compiled``), wrapped by ``predictor_cls`` to produce the
    Predictor; register either that pair or a builder, never a callable
    that already returns a Predictor as ``compile=``."""

    def compile(self, forest, **kw) -> Predictor: ...


# --------------------------------------------------------------------------- #
# Shared predictor base
# --------------------------------------------------------------------------- #
def normalize_scores(scores: np.ndarray,
                     votes: Optional[bool] = None) -> np.ndarray:
    """(B, C) raw class scores → per-row probabilities (paper §4).

    ``votes=True`` — non-negative vote mass (averaged RF leaves): rows
    divide by their sum (all-zero rows fall back to uniform).
    ``votes=False`` — logit leaves (boosting): softmax.
    ``votes=None`` infers from the scores at hand — predictors instead
    pass the mode derived from the forest's leaf table, so one input row
    always gets the same probabilities regardless of its batchmates.
    """
    s = np.asarray(scores, dtype=np.float64)
    if s.ndim != 2 or s.shape[1] < 2:
        raise ValueError(
            f"predict_proba needs a classification forest (C >= 2 class "
            f"scores); got shape {s.shape}")
    if votes is None:
        votes = bool((s >= 0).all())
    if votes:
        s = np.maximum(s, 0.0)         # guard: quantization can dip below 0
        tot = s.sum(axis=1, keepdims=True)
        uniform = np.full_like(s, 1.0 / s.shape[1])
        return np.where(tot > 0, s / np.where(tot > 0, tot, 1.0), uniform)
    z = np.exp(s - s.max(axis=1, keepdims=True))
    return z / z.sum(axis=1, keepdims=True)


def votes_mode(forest) -> bool:
    """Whether a forest's class scores are vote mass (RF averaging, all
    leaves >= 0 → sum-normalize) or logits (boosting → softmax).  The
    single source of this inference: ``predict_proba`` here and the
    cascade gate confidences (``repro.cascade.policy``) both use it, so
    served probabilities and gate decisions can never normalize
    differently."""
    return bool((np.asarray(forest.leaf_value) >= 0).all())


def ensure_feature_column(X: np.ndarray) -> np.ndarray:
    """0-feature ensembles (every tree a single leaf) hand engines a
    (B, 0) input, but all engines gather feature column 0 unconditionally
    (padding nodes are masked by ``valid``, never skipped) — give them
    one dummy column instead of an empty gather axis."""
    if X.ndim == 2 and X.shape[1] == 0:
        return np.zeros((X.shape[0], 1), dtype=X.dtype)
    return X


class BasePredictor:
    """Shared engine wrapper: input quantization + jit cache + the full
    prediction surface.  ``eval_fn(compiled, X) → (B, C)`` is the engine's
    pure evaluator; ``compiled`` carries ``transform_inputs`` when the
    forest is quantized."""

    def __init__(self, compiled, eval_fn: Callable):
        self.compiled = compiled
        self._eval = eval_fn
        self._fn = jax.jit(lambda X: eval_fn(compiled, X))

    def transform_inputs(self, X: np.ndarray) -> np.ndarray:
        t = getattr(self.compiled, "transform_inputs", None)
        X = np.asarray(X)
        return t(X) if t is not None else X

    def predict_transformed(self, Xq: np.ndarray) -> np.ndarray:
        """Evaluate inputs that already went through ``transform_inputs``
        — the cascade's per-stage entry point, so a K-stage cascade
        quantizes each row once instead of once per surviving stage."""
        Xq = ensure_feature_column(np.asarray(Xq))
        return np.asarray(self._fn(jnp.asarray(Xq)))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.predict_transformed(self.transform_inputs(X))

    def predict_class(self, X: np.ndarray) -> np.ndarray:
        return self.predict(X).argmax(axis=1)

    def host_forest(self):
        """The host IR, if this predictor can reach one (compiled objects
        carry it for input quantization; CompiledRS nests it under qs)."""
        for owner in (self, getattr(self, "compiled", None),
                      getattr(getattr(self, "compiled", None), "qs", None)):
            f = getattr(owner, "forest", None)
            if f is not None:
                return f
        return None

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        # the normalization mode is a property of the *model*: vote-mass
        # leaves (all >= 0) sum-normalize, logit leaves softmax — decided
        # from the leaf table so results never depend on batch composition
        forest = self.host_forest()
        votes = None if forest is None else votes_mode(forest)
        return normalize_scores(self.predict(X), votes=votes)


# --------------------------------------------------------------------------- #
# The registry
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class EngineSpec:
    """One engine × backend entry.

    Either ``build`` (forest, **kw → predictor) is set directly (Pallas
    backends), or ``compile`` + ``evaluate`` are set and ``build`` is
    derived via ``predictor_cls`` — the split form is what tree-sharding
    needs (it re-runs ``evaluate`` inside ``shard_map``).
    """
    name: str                             # canonical name, e.g. "bitvector"
    backend: str                          # "jax" | "pallas"
    tune_name: str                        # autotuner short name, e.g. "qs"
    build: Optional[Callable] = None      # (forest, **kw) -> Predictor
    compile: Optional[Callable] = None    # (forest, **kw) -> compiled arrays
    evaluate: Optional[Callable] = None   # (compiled, X) -> (B, C) jnp
    predictor_cls: type = BasePredictor
    shardable: bool = False               # supports core.shard tree-sharding
    shard_kw: Optional[Callable] = None   # (padded forest, n_shards) -> kw
    replicated: tuple = ()                # compiled fields never tree-sharded
    layout: Optional[Callable] = None     # (forest, plan) -> detail string;
    #                                       pipeline layout-pass hook
    serial_arrays: tuple = ()             # compiled array fields io.packed
    #                                       may serialize (dotted for nested
    #                                       dataclasses); empty → artifact
    #                                       not serializable, rebuild from
    #                                       the forest instead
    deferred: Optional[str] = None        # "module:attr" lazy build target
    doc: str = ""

    def builder(self) -> Callable:
        """Resolve the (forest, **kw) → predictor callable."""
        if self.build is not None:
            return self.build
        if self.deferred is not None:
            mod, attr = self.deferred.split(":")
            fn = getattr(importlib.import_module(mod), attr)
            object.__setattr__(self, "build", fn)   # cache the resolution
            return fn
        if self.compile is None or self.evaluate is None:
            raise ValueError(f"engine {self.name}/{self.backend} registered "
                             "without build, deferred, or compile+evaluate")

        def build(forest, **kw):
            compiled = self.compile(forest, **kw)
            return self.predictor_cls(compiled, self.evaluate)

        object.__setattr__(self, "build", build)
        return build


_REGISTRY: dict[tuple[str, str], EngineSpec] = {}


def register_engine(name: str, *, backend: str = "jax",
                    tune_name: Optional[str] = None, **spec_kw):
    """Register an engine under (name, backend).

    Two forms:

      * call form — ``register_engine("bitvector", compile=compile_qs,
        evaluate=eval_batch, tune_name="qs", shardable=True)`` registers
        immediately and returns the ``EngineSpec``;
      * decorator form — ``@register_engine("gemm", backend="pallas")``
        above a ``(forest, **kw) → predictor`` builder.
    """
    def _store(spec: EngineSpec) -> EngineSpec:
        _REGISTRY[(spec.name, spec.backend)] = spec
        return spec

    tn = tune_name or name
    if any(k in spec_kw for k in ("build", "compile", "deferred")):
        return _store(EngineSpec(name=name, backend=backend, tune_name=tn,
                                 **spec_kw))

    def deco(fn):
        _store(EngineSpec(name=name, backend=backend, tune_name=tn,
                          build=fn, **spec_kw))
        return fn

    return deco


def register_deferred(name: str, *, backend: str, target: str,
                      tune_name: str, **spec_kw) -> EngineSpec:
    """Register an engine whose builder lives in a module we must not
    import eagerly (the Pallas kernels pull in the whole pallas stack)."""
    return register_engine(name, backend=backend, tune_name=tune_name,
                           deferred=target, **spec_kw)


def get(name: str, backend: str = "jax") -> EngineSpec:
    try:
        return _REGISTRY[(name, backend)]
    except KeyError:
        names = engines(backend)
        raise ValueError(
            f"unknown engine {name!r} for backend {backend!r}; "
            f"registered: {names or tuple(sorted(set(n for n, _ in _REGISTRY)))}"
        ) from None


def specs(backend: Optional[str] = None) -> tuple[EngineSpec, ...]:
    """All registered specs, in registration order."""
    return tuple(s for s in _REGISTRY.values()
                 if backend is None or s.backend == backend)


def engines(backend: Optional[str] = None) -> tuple[str, ...]:
    """Canonical engine names (deduped across backends, in order)."""
    return tuple(dict.fromkeys(s.name for s in specs(backend)))


def backends(name: str) -> tuple[str, ...]:
    return tuple(s.backend for s in _REGISTRY.values() if s.name == name)


def tune_table() -> dict[str, tuple[str, str]]:
    """Autotuner name → (engine, backend) — derived, never re-declared."""
    return {s.tune_name: (s.name, s.backend) for s in _REGISTRY.values()}


def by_tune_name(tune_name: str) -> EngineSpec:
    for s in _REGISTRY.values():
        if s.tune_name == tune_name:
            return s
    raise ValueError(f"unknown autotuner engine {tune_name!r}; "
                     f"registered: {sorted(tune_table())}")


def build(forest, name: str, backend: str = "jax", **kw) -> Predictor:
    """Compile ``forest`` with the registered (name, backend) engine."""
    return get(name, backend).builder()(forest, **kw)
