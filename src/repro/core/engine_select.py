"""Per-forest engine autotuner — the paper's conclusion as an API.

The paper's central finding is that the fastest tree-traversal
implementation depends on both the forest shape and the target hardware.
``choose(forest, batch)`` operationalises that: it microbenchmarks every
candidate engine on the actual forest at the caller's (bucketed) batch
size, returns the winner, and caches the decision — in memory for the
process, and as JSON on disk so later processes (and the serving path,
``inference.server.ForestServer.from_forest``) skip the sweep entirely.

Candidates come from ``core.registry`` (one registration per engine — no
second table here); the autotuner's short names are the registry specs'
``tune_name``.  Beyond the engine axis, the sweep can cover the other
pipeline passes: ``quant_specs=`` adds fixed-point variants (paper §5) as
``<engine>@q<bits>`` candidates, ``opt_levels=`` adds optimizer
middle-end variants (``<engine>@O2`` — ``repro.optim``, docs/OPTIM.md),
``layout_specs=`` adds engine-kw layout variants
(``<engine>@tree_chunk=32``), and ``n_devices=`` tunes the
tree-sharded multi-device wrapper (``core.shard``) instead of
single-device engines.

Cache key: ``(jax backend, n_trees, n_leaves, n_classes, n_features,
max_depth, threshold dtype, batch bucket, n_devices)``.  Runtime is
independent of the learned values, so device + shape/structure + dtype
fully determine the ranking — and a winner measured on CPU is never
replayed on TPU (or vice versa).

Pallas engines run in interpret mode on CPU (orders of magnitude slower
than compiled XLA), so they only enter the candidate set on a real TPU
backend — or explicitly via ``engines=``/``include_pallas=True``.
"""
from __future__ import annotations

import json
import os
import time
from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from . import registry
from ..obs import metrics as _obs_metrics
from ..obs.log import get_logger
from .forest import Forest
from .quantize import QuantSpec, quantize_forest

_LOG = get_logger("autotune")


def _autotune_metrics():
    """The autotuner's metric families on the process default registry
    (docs/OBSERVABILITY.md §Autotune), or ``None`` when observability is
    disabled.  Resolved per call — get-or-create is two dict lookups
    after the first time, and tests that swap the default registry
    (``set_default_registry``) observe their own."""
    reg = _obs_metrics.get_registry()
    if not reg.enabled:
        return None
    return {
        "sweeps": reg.counter(
            "repro_autotune_sweeps_total",
            "Autotune benchmark sweeps executed (decisions that had to "
            "time at least one candidate)"),
        "hits": reg.counter(
            "repro_autotune_cache_hits_total",
            "Autotune decisions answered entirely from cache",
            labels=("layer",)),
        "misses": reg.counter(
            "repro_autotune_cache_misses_total",
            "Autotune decisions that had to benchmark",
            labels=("reason",)),
        "sweep_s": reg.histogram(
            "repro_autotune_sweep_seconds",
            "Wall time of one autotune benchmark sweep, seconds"),
        "benched": reg.counter(
            "repro_autotune_candidates_benched_total",
            "Candidate predictors built and timed by autotune sweeps"),
        "winner": reg.gauge(
            "repro_autotune_winner_info",
            "Autotune winner per shape key (info gauge: value is "
            "always 1; the labels carry the decision)",
            labels=("key", "engine")),
    }


class _TuneTable(Mapping):
    """Live view of ``registry.tune_table()`` — autotuner name →
    (engine, backend).  A mapping object (not a snapshot dict) so engines
    registered after import (plugins, tests) appear automatically."""

    def __getitem__(self, name: str) -> tuple:
        return registry.tune_table()[name]

    def __iter__(self):
        return iter(registry.tune_table())

    def __len__(self):
        return len(registry.tune_table())


ENGINE_SPECS = _TuneTable()


def _make_factory(name: str) -> Callable[[Forest], object]:
    spec = registry.by_tune_name(name)

    def factory(forest: Forest):
        kw = {"interpret": _interpret()} if spec.backend == "pallas" else {}
        return registry.build(forest, spec.name, spec.backend, **kw)

    return factory


class _FactoryTable(Mapping):
    """tune name → predictor factory, resolved through the registry."""

    def __getitem__(self, name: str) -> Callable[[Forest], object]:
        if name not in registry.tune_table():
            raise KeyError(name)
        return _make_factory(name)

    def __iter__(self):
        return iter(registry.tune_table())

    def __len__(self):
        return len(registry.tune_table())


ENGINE_FACTORIES = _FactoryTable()


def xla_engines() -> tuple:
    return tuple(s.tune_name for s in registry.specs("jax"))


def pallas_engines() -> tuple:
    return tuple(s.tune_name for s in registry.specs("pallas"))


def _on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def default_engines(include_pallas: Optional[bool] = None) -> tuple:
    if include_pallas is None:
        include_pallas = _on_tpu()
    return xla_engines() + pallas_engines() if include_pallas \
        else xla_engines()


def bucket_batch(batch: int) -> int:
    """Next power of two — one autotune decision per batch octave."""
    return 1 << max(int(batch) - 1, 0).bit_length()


def bucket_ladder(max_batch: int) -> tuple:
    """Every power-of-two batch bucket up to ``bucket_batch(max_batch)``
    — the complete set of batch shapes the bucketed execution paths
    (Pallas predictors, the fused cascade, and the serving runtime's
    pad-to-bucket dispatch) can ever emit for batches ≤ ``max_batch``.
    ``ServingRuntime.warmup`` pre-traces exactly these shapes so no live
    request pays a trace/compile (docs/SERVING.md)."""
    top = bucket_batch(max_batch)
    out, b = [], 1
    while b <= top:
        out.append(b)
        b *= 2
    return tuple(out)


def shape_key(forest: Forest, batch_bucket: int, n_devices: int = 1) -> str:
    # max_depth is part of the structure key: native/unrolled run
    # O(depth) iterations and bitmm's field packing widens with depth, so
    # a balanced and a chain-shaped forest with identical T/L/C/d rank
    # engines very differently.  n_devices is part of the key because a
    # tree-sharded winner on 8 devices says nothing about 1 device.
    import jax
    return (f"{jax.default_backend()}"
            f"_T{forest.n_trees}_L{forest.n_leaves}_C{forest.n_classes}"
            f"_d{forest.n_features}_D{forest.max_depth}"
            f"_{np.dtype(forest.threshold.dtype).name}_B{batch_bucket}"
            f"_dev{n_devices}")


_CACHE_DEFAULT = object()          # "cache_path not given" sentinel


def default_cache_path() -> str:
    # resolved per call, not at import, so REPRO_ENGINE_CACHE set after
    # `import repro.core` (e.g. pytest monkeypatch) still takes effect
    return os.environ.get(
        "REPRO_ENGINE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "engine_cache.json"))


_MEM_CACHE: dict[str, dict] = {}
# (path, key) pairs whose in-memory entry is known to be on disk already —
# lets cache hits skip the read-merge-rewrite of the JSON file
_PERSISTED: set[tuple[str, str]] = set()


def _valid_entry(entry) -> bool:
    """Structural check for one cache entry: ``{"engine": str,
    "timings": {str: number}}`` with a non-empty timings dict."""
    if not isinstance(entry, dict):
        return False
    timings = entry.get("timings")
    if not isinstance(timings, dict) or not timings:
        return False
    return all(isinstance(k, str) and isinstance(v, (int, float))
               and not isinstance(v, bool) for k, v in timings.items())


def _load_disk(path: str) -> dict:
    """Parse the JSON cache file, dropping anything malformed.

    A truncated or hand-mangled cache (garbage JSON, a non-dict top
    level, entries missing ``timings`` or holding non-numeric values)
    must degrade to a clean re-sweep — and the next ``_store_disk``
    rewrites the file — never to an unhandled exception at serving time."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict):
        return {}
    return {k: v for k, v in data.items() if _valid_entry(v)}


def _merge_entry(old: Optional[dict], new: dict) -> dict:
    """Union of two sweeps' timings — cached coverage only ever grows."""
    if not old:
        return new
    timings = {**old.get("timings", {}), **new.get("timings", {})}
    return {"engine": min(timings, key=timings.get), "timings": timings}


def _store_disk(path: str, key: str, entry: dict) -> None:
    # read-merge-replace without a file lock: concurrent writers can drop
    # each other's timings (last replace wins). Acceptable — the cache is
    # an optimisation, and the cost is one redundant re-sweep later.
    data = _load_disk(path)
    data[key] = _merge_entry(data.get(key), entry)
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1)
        os.replace(tmp, path)
        _PERSISTED.add((path, key))
    except OSError:
        pass                       # cache is an optimisation, never fatal


@dataclass
class EngineChoice:
    engine: str                    # winning candidate name
    key: str                       # shape/batch cache key
    predictor: object              # ready-to-serve predictor for `engine`
    timings: dict = field(default_factory=dict)   # candidate → median secs
    from_cache: bool = False

    def predict(self, X):
        return self.predictor.predict(X)


def _bench_once(pred, X: np.ndarray, repeats: int) -> float:
    pred.predict(X)                # warmup + compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        pred.predict(X)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _layout_tag(kw: dict) -> str:
    return ",".join(f"{k}={kw[k]}" for k in sorted(kw))


def _quant_tag(q: QuantSpec) -> str:
    """Candidate-name tag for a QuantSpec — encodes every field that
    changes the compiled variant, so distinct specs never alias in the
    timing cache (``q16`` for the default, suffixes otherwise)."""
    tag = f"q{q.bits}"
    if q.int_accum:
        tag += "i"                  # integer leaf accumulation (QUANT.md)
    if q.scale is not None:
        tag += f"s{q.scale:g}"
    if not q.quantize_splits:
        tag += "-nosplits"
    if not q.quantize_leaves:
        tag += "-noleaves"
    return tag


def _candidate_factories(forest: Forest, engines: tuple,
                         quant_specs: Optional[tuple],
                         layout_specs: Optional[dict],
                         n_devices: int,
                         cascade_specs: Optional[tuple] = None,
                         opt_levels: Optional[tuple] = None,
                         flint: bool = False
                         ) -> dict[str, Callable]:
    """Candidate name → zero-arg predictor factory.

    The candidate axis is the (engine × quantization × optimization ×
    layout × cascade) product of the pipeline's passes: plain tune names
    for the forest as-is, ``<engine>@q<bits>`` per ``QuantSpec``,
    ``<engine>@O<level>`` per entry of ``opt_levels`` (the optimizer
    middle-end, ``repro.optim``), ``<engine>@<kw=v,...>`` per entry of
    ``layout_specs[engine]`` (engine-kw overrides such as bitmm's
    ``tree_chunk`` or gemm block sizes), and
    ``<engine>@cascade=16/48:<policy>`` per ``CascadeSpec`` (staged
    evaluation, ``repro.cascade``) — or ``<engine>@cascade-fused=...``
    when the spec sets ``fused=True`` (one-jit execution,
    ``cascade/fused.py``; pass both variants to time staged vs fused).
    Opt and cascade tags participate in
    cache entries the same way the ``_dev{n}`` key component does for
    sharding: entries written before those axes existed simply lack the
    tagged timings, so the sweep key-misses them and re-benchmarks
    instead of mis-hitting — ``cascade-fused`` tags likewise key-miss
    every pre-fusion cache entry.  With ``n_devices > 1`` each candidate is
    wrapped tree-sharded (non-shardable engines are rejected up front;
    cascade + sharding is rejected too).

    Every factory compiles through ``compile_plan``, so the winning
    predictor always carries a ``CompilePlan`` — ``choice.predictor
    .plan.describe()`` explains the variant, optimizer stats included."""
    from ..optim import resolve_opt
    if quant_specs and forest.quant_scale is not None:
        raise ValueError("quant_specs sweep needs a float forest "
                         "(this one is already quantized)")
    if cascade_specs and n_devices > 1:
        raise ValueError("cascade_specs cannot combine with n_devices > 1 "
                         "(staged evaluation is single-device)")
    unknown = set(layout_specs or ()) - set(engines)
    if unknown:
        # a silently ignored key would make the caller believe the cached
        # winner was layout-tuned when the sweep never ran
        raise ValueError(f"layout_specs keys {sorted(unknown)} are not in "
                         f"the requested engine set {tuple(engines)} "
                         "(use autotuner tune names, e.g. 'qs-bitmm')")
    for o in opt_levels or ():
        resolve_opt(o)                 # reject garbage levels up front
    if flint and forest.quant_scale is not None:
        raise ValueError("flint=True needs a float forest (FLInt rekeys "
                         "f32 thresholds; this one is already quantized)")
    quants: tuple = (None,) + (tuple(quant_specs) if quant_specs else ())
    opts: tuple = (None,) + (tuple(opt_levels) if opt_levels else ())
    cascades: tuple = (None,) + (tuple(cascade_specs) if cascade_specs
                                 else ())
    # FLInt axis: f32 thresholds rekeyed as monotone int32 (QUANT.md §4).
    # Only the float variant gets it (flint ⊕ quantize), and only jax
    # engines — the Pallas kernels cast inputs f32, losing int32 keys.
    def flints(e: str, q) -> tuple:
        if flint and q is None and \
                registry.by_tune_name(e).backend != "pallas":
            return (False, True)
        return (False,)
    variants: list[tuple] = [
        (e, q, o, kw, casc, fl)
        for e in engines for q in quants for o in opts
        for kw in (None,) + tuple((layout_specs or {}).get(e, ()))
        for casc in cascades for fl in flints(e, q)]

    qforests: dict[int, Forest] = {}   # one quantized forest per spec

    def qf(q: Optional[QuantSpec]) -> Forest:
        if q is None:
            return forest
        if id(q) not in qforests:
            qforests[id(q)] = quantize_forest(forest, None, q)
        return qforests[id(q)]

    def make(name: str, q: Optional[QuantSpec], o,
             kw: Optional[dict], casc, fl: bool = False) -> Callable:
        spec = registry.by_tune_name(name)
        ekw = dict(kw or {})
        if n_devices > 1 and not spec.shardable:
            raise ValueError(
                f"engine {name!r} cannot run tree-sharded "
                f"(n_devices={n_devices}); restrict engines= to "
                f"{[s.tune_name for s in registry.specs() if s.shardable]}")
        if spec.backend == "pallas":
            ekw.setdefault("interpret", _interpret())

        def factory():
            from .pipeline import CompilePlan, compile_plan
            plan = CompilePlan(engine=spec.name, backend=spec.backend,
                               opt=o, n_devices=n_devices, cascade=casc,
                               flint=fl, engine_kw=dict(ekw))
            return compile_plan(qf(q), plan)

        return factory

    def cname(e: str, q: Optional[QuantSpec], o, kw: Optional[dict],
              casc, fl: bool = False) -> str:
        name = e if q is None else f"{e}@{_quant_tag(q)}"
        if fl:
            name = f"{name}@flint"
        if o is not None:
            name = f"{name}@{resolve_opt(o)[1]}"
        if kw is not None:
            name = f"{name}@{_layout_tag(kw)}"
        return name if casc is None else f"{name}@{casc.tag()}"

    return {cname(e, q, o, kw, casc, fl): make(e, q, o, kw, casc, fl)
            for e, q, o, kw, casc, fl in variants}


def choose(forest: Forest, batch: int, *, engines=None,
           include_pallas: Optional[bool] = None,
           quant_specs: Optional[tuple] = None,
           layout_specs: Optional[dict] = None,
           cascade_specs: Optional[tuple] = None,
           opt_levels: Optional[tuple] = None,
           flint: bool = False,
           n_devices: int = 1,
           cache_path=_CACHE_DEFAULT,
           force: bool = False, repeats: int = 3,
           seed: int = 0) -> EngineChoice:
    """Pick the fastest candidate for ``forest`` at this batch-size bucket.

    Candidates are (engine × quantization × optimization × layout ×
    cascade) variants — see ``_candidate_factories``; ``opt_levels=(1,
    2)`` adds optimizer middle-end variants (``qs@O2``, docs/OPTIM.md);
    ``flint=True`` adds ``<engine>@flint`` variants — f32 thresholds
    rekeyed as monotone int32 (docs/QUANT.md, jax engines only)
    whose compiled forests are smaller but oracle-equivalent;
    ``n_devices > 1`` tunes the tree-sharded
    wrapper instead.  Cascade candidates (``cascade_specs=``) time the
    gated path on the synthetic benchmark batch — exit fractions on real
    traffic depend on the data, so treat a cascade winner as a hint and
    benchmark on representative rows when it matters; include
    ``CascadeSpec(..., fused=True)`` entries to race the fused one-jit
    execution against the staged host loop.  Cache hits
    (in-memory, then the JSON file at
    ``cache_path``) skip the sweep and only build the winning predictor.
    A cached entry counts as a hit only if its accumulated sweeps covered
    every candidate the caller asked for — the winner is then re-derived
    over the requested subset — so a narrow ``engines=`` sweep can never
    answer for the full matrix; a partial-coverage miss benchmarks only
    the candidates not yet measured.  New sweeps merge into the cached
    entry (timings union, both layers), so within a process coverage only
    grows and a narrow re-sweep never erases wider measurements;
    cross-process disk merges are best-effort (unlocked
    read-merge-replace — see ``_store_disk``).  Merged timings may come
    from different runs (machine load, ``repeats``) — the cache assumes
    per-shape rankings are stable enough that this is fine.
    When ``cache_path`` is omitted it defaults to ``$REPRO_ENGINE_CACHE``
    (or ``~/.cache/repro/engine_cache.json``); ``cache_path=None``
    disables the disk layer entirely.  ``force=True`` re-benchmarks
    regardless of any cached entry."""
    if engines is None:
        engines = default_engines(include_pallas)
        if n_devices > 1:
            # the *default* set narrows to shardable engines (on TPU it
            # includes pallas, which can't tree-shard); an explicit
            # engines= list still errors loudly on non-shardable entries
            engines = tuple(e for e in engines
                            if registry.by_tune_name(e).shardable)
    else:
        engines = tuple(engines)
    factories = _candidate_factories(forest, engines,
                                     tuple(quant_specs) if quant_specs
                                     else None, layout_specs, n_devices,
                                     tuple(cascade_specs) if cascade_specs
                                     else None,
                                     tuple(opt_levels) if opt_levels
                                     else None, flint=flint)
    candidates = tuple(factories)
    if cache_path is _CACHE_DEFAULT:
        cache_path = default_cache_path()
    bucket = bucket_batch(batch)
    key = shape_key(forest, bucket, n_devices)

    obs = _autotune_metrics()
    prior = _MEM_CACHE.get(key)
    # for the cache-hit layer label: did memory alone cover the request,
    # before the disk layer widened it?
    mem_covered = (prior is not None
                   and set(candidates) <= set(prior.get("timings", {})))
    if cache_path and not (prior is not None
                           and set(candidates)
                           <= set(prior.get("timings", {}))):
        disk = _load_disk(cache_path).get(key)
        if disk is not None:           # warm/widen the memory layer
            if prior is None:
                prior = disk
                _PERSISTED.add((cache_path, key))
            else:
                # memory may hold timings the file lacks — not persisted
                prior = _merge_entry(disk, prior)
                _PERSISTED.discard((cache_path, key))
            _MEM_CACHE[key] = prior
    if not force and prior is not None:
        cached = prior.get("timings", {})
        if set(candidates) <= set(cached):
            winner = min(candidates, key=cached.get)
            if cache_path and (cache_path, key) not in _PERSISTED:
                # write-through: the entry may exist only in memory (e.g.
                # swept earlier with cache_path=None); a merge against the
                # file is idempotent and trivial next to the compile below
                _store_disk(cache_path, key, prior)
            if obs is not None:
                layer = "memory" if mem_covered else "disk"
                obs["hits"].labels(layer=layer).inc()
                obs["winner"].labels(key=key, engine=winner).set(1.0)
            return EngineChoice(engine=winner, key=key,
                                predictor=factories[winner](),
                                timings={e: cached[e] for e in candidates},
                                from_cache=True)

    cached = (prior or {}).get("timings", {})
    to_bench = candidates if force \
        else tuple(e for e in candidates if e not in cached)
    if obs is not None:
        reason = "forced" if force else ("partial" if cached else "cold")
        obs["misses"].labels(reason=reason).inc()
    # n_features_in, not n_features: an already-optimized forest (with a
    # feat_map from drop_unused_features) still takes full-width rows
    X = np.random.default_rng(seed).normal(
        0, 1.0, size=(bucket, forest.n_features_in))
    fresh: dict[str, float] = {}
    best_pred, best_t = None, float("inf")
    sweep_t0 = time.perf_counter()
    for name in to_bench:
        pred = factories[name]()
        fresh[name] = _bench_once(pred, X, repeats)
        # keep only the best-so-far predictor: peak memory stays
        # max(current, best) instead of the sum over the engine matrix
        if fresh[name] < best_t:
            best_pred, best_t = pred, fresh[name]
    sweep_s = time.perf_counter() - sweep_t0
    # partial-coverage miss: cached timings fill in the engines we skipped
    timings = {e: fresh.get(e, cached.get(e)) for e in candidates}
    winner = min(timings, key=timings.get)
    if obs is not None:
        obs["sweeps"].inc()
        obs["sweep_s"].observe(sweep_s)
        obs["benched"].inc(float(len(to_bench)))
        obs["winner"].labels(key=key, engine=winner).set(1.0)
    _LOG.info("sweep", key=key, candidates=len(to_bench),
              seconds=sweep_s, winner=winner)
    if best_pred is not None:
        # cascade predictors count per-stage exits cumulatively; the
        # benchmark rows must not pollute the served exit accounting
        getattr(best_pred, "reset_exit_stats", lambda: None)()
    # the stored engine must be the winner over the entry's own timings
    # (merges re-derive it over the union; lookups re-derive per request)
    entry = {"engine": min(fresh, key=fresh.get), "timings": fresh}
    _MEM_CACHE[key] = _merge_entry(prior, entry)
    # the memory entry just changed: any disk copy of this key is stale
    _PERSISTED.difference_update({pk for pk in _PERSISTED if pk[1] == key})
    if cache_path:
        # persist the merged union, not just this sweep: coverage that so
        # far existed only in memory reaches disk too (file re-merged)
        _store_disk(cache_path, key, _MEM_CACHE[key])
    return EngineChoice(
        engine=winner, key=key,
        predictor=best_pred if winner in fresh
        else factories[winner](),
        timings=timings, from_cache=False)


def clear_cache(cache_path: Optional[str] = None) -> None:
    """Drop the in-memory cache (and the disk file, if a path is given)."""
    _MEM_CACHE.clear()
    _PERSISTED.clear()
    if cache_path:
        try:
            os.remove(cache_path)
        except OSError:
            pass
