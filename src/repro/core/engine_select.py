"""Per-forest engine autotuner — the paper's conclusion as an API.

The paper's central finding is that the fastest tree-traversal
implementation depends on both the forest shape and the target hardware.
``choose(forest, batch)`` operationalises that: it microbenchmarks every
candidate engine on the actual forest at the caller's (bucketed) batch
size, returns the winner, and caches the decision — in memory for the
process, and as JSON on disk so later processes (and the serving path,
``inference.server.ForestServer.from_forest``) skip the sweep entirely.

Cache key: ``(jax backend, n_trees, n_leaves, n_classes, n_features,
max_depth, threshold dtype, batch bucket)``.  Runtime is independent of
the learned values, so device + shape/structure + dtype fully determine
the ranking — and a winner measured on CPU is never replayed on TPU (or
vice versa).

Pallas engines run in interpret mode on CPU (orders of magnitude slower
than compiled XLA), so they only enter the candidate set on a real TPU
backend — or explicitly via ``engines=``/``include_pallas=True``.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .forest import Forest

# autotuner engine name → (core.compile_forest engine, backend); one
# dispatch table, so new engines register once in core/__init__.py and
# appear here with only a name-pair entry.
ENGINE_SPECS: dict[str, tuple[str, str]] = {
    "qs": ("bitvector", "jax"),
    "qs-bitmm": ("bitmm", "jax"),
    "rapidscorer": ("rapidscorer", "jax"),
    "gemm": ("gemm", "jax"),
    "native": ("native", "jax"),
    "unrolled": ("unrolled", "jax"),
    "pallas-qs": ("bitvector", "pallas"),
    "pallas-bitmm": ("bitmm", "pallas"),
    "pallas-gemm": ("gemm", "pallas"),
}


def _make_factory(name: str) -> Callable[[Forest], object]:
    engine, backend = ENGINE_SPECS[name]

    def factory(forest: Forest):
        from . import compile_forest
        kw = {"interpret": _interpret()} if backend == "pallas" else {}
        return compile_forest(forest, engine=engine, backend=backend, **kw)

    return factory


ENGINE_FACTORIES: dict[str, Callable[[Forest], object]] = {
    name: _make_factory(name) for name in ENGINE_SPECS
}

XLA_ENGINES = ("qs", "qs-bitmm", "rapidscorer", "gemm", "native", "unrolled")
PALLAS_ENGINES = ("pallas-qs", "pallas-bitmm", "pallas-gemm")


def _on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def default_engines(include_pallas: Optional[bool] = None) -> tuple:
    if include_pallas is None:
        include_pallas = _on_tpu()
    return XLA_ENGINES + PALLAS_ENGINES if include_pallas else XLA_ENGINES


def bucket_batch(batch: int) -> int:
    """Next power of two — one autotune decision per batch octave."""
    return 1 << max(int(batch) - 1, 0).bit_length()


def shape_key(forest: Forest, batch_bucket: int) -> str:
    # max_depth is part of the structure key: native/unrolled run
    # O(depth) iterations and bitmm's field packing widens with depth, so
    # a balanced and a chain-shaped forest with identical T/L/C/d rank
    # engines very differently.
    import jax
    return (f"{jax.default_backend()}"
            f"_T{forest.n_trees}_L{forest.n_leaves}_C{forest.n_classes}"
            f"_d{forest.n_features}_D{forest.max_depth}"
            f"_{np.dtype(forest.threshold.dtype).name}_B{batch_bucket}")


DEFAULT_CACHE_PATH = os.environ.get(
    "REPRO_ENGINE_CACHE",
    os.path.join(os.path.expanduser("~"), ".cache", "repro",
                 "engine_cache.json"))

_MEM_CACHE: dict[str, dict] = {}


def _load_disk(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store_disk(path: str, key: str, entry: dict) -> None:
    data = _load_disk(path)
    data[key] = entry
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1)
        os.replace(tmp, path)
    except OSError:
        pass                       # cache is an optimisation, never fatal


@dataclass
class EngineChoice:
    engine: str                    # winning engine name
    key: str                       # shape/batch cache key
    predictor: object              # ready-to-serve predictor for `engine`
    timings: dict = field(default_factory=dict)   # engine → median seconds
    from_cache: bool = False

    def predict(self, X):
        return self.predictor.predict(X)


def _bench_once(pred, X: np.ndarray, repeats: int) -> float:
    pred.predict(X)                # warmup + compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        pred.predict(X)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def choose(forest: Forest, batch: int, *, engines=None,
           include_pallas: Optional[bool] = None,
           cache_path: Optional[str] = DEFAULT_CACHE_PATH,
           force: bool = False, repeats: int = 3,
           seed: int = 0) -> EngineChoice:
    """Pick the fastest engine for ``forest`` at this batch-size bucket.

    Cache hits (in-memory, then the JSON file at ``cache_path``) skip the
    sweep and only build the winning predictor.  ``cache_path=None``
    disables the disk layer; ``force=True`` re-benchmarks regardless."""
    engines = tuple(engines) if engines is not None \
        else default_engines(include_pallas)
    bucket = bucket_batch(batch)
    key = shape_key(forest, bucket)

    entry = None
    if not force:
        entry = _MEM_CACHE.get(key)
        if entry is None and cache_path:
            entry = _load_disk(cache_path).get(key)
        if entry is not None and entry.get("engine") not in engines:
            entry = None           # cached winner excluded by the caller
    if entry is not None:
        return EngineChoice(engine=entry["engine"], key=key,
                            predictor=ENGINE_FACTORIES[entry["engine"]](forest),
                            timings=entry.get("timings", {}),
                            from_cache=True)

    X = np.random.default_rng(seed).normal(
        0, 1.0, size=(bucket, forest.n_features))
    timings: dict[str, float] = {}
    best_pred, best_t = None, float("inf")
    for name in engines:
        pred = ENGINE_FACTORIES[name](forest)
        timings[name] = _bench_once(pred, X, repeats)
        # keep only the best-so-far predictor: peak memory stays
        # max(current, best) instead of the sum over the engine matrix
        if timings[name] < best_t:
            best_pred, best_t = pred, timings[name]
    winner = min(timings, key=timings.get)
    entry = {"engine": winner, "timings": timings}
    _MEM_CACHE[key] = entry
    if cache_path:
        _store_disk(cache_path, key, entry)
    return EngineChoice(engine=winner, key=key, predictor=best_pred,
                        timings=timings, from_cache=False)


def clear_cache(cache_path: Optional[str] = None) -> None:
    """Drop the in-memory cache (and the disk file, if a path is given)."""
    _MEM_CACHE.clear()
    if cache_path:
        try:
            os.remove(cache_path)
        except OSError:
            pass
