"""Per-forest engine autotuner — the paper's conclusion as an API.

The paper's central finding is that the fastest tree-traversal
implementation depends on both the forest shape and the target hardware.
``choose(forest, batch)`` operationalises that: it microbenchmarks every
candidate engine on the actual forest at the caller's (bucketed) batch
size, returns the winner, and caches the decision — in memory for the
process, and as JSON on disk so later processes (and the serving path,
``inference.server.ForestServer.from_forest``) skip the sweep entirely.

Cache key: ``(jax backend, n_trees, n_leaves, n_classes, n_features,
max_depth, threshold dtype, batch bucket)``.  Runtime is independent of
the learned values, so device + shape/structure + dtype fully determine
the ranking — and a winner measured on CPU is never replayed on TPU (or
vice versa).

Pallas engines run in interpret mode on CPU (orders of magnitude slower
than compiled XLA), so they only enter the candidate set on a real TPU
backend — or explicitly via ``engines=``/``include_pallas=True``.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .forest import Forest

# autotuner engine name → (core.compile_forest engine, backend); one
# dispatch table, so new engines register once in core/__init__.py and
# appear here with only a name-pair entry.
ENGINE_SPECS: dict[str, tuple[str, str]] = {
    "qs": ("bitvector", "jax"),
    "qs-bitmm": ("bitmm", "jax"),
    "rapidscorer": ("rapidscorer", "jax"),
    "gemm": ("gemm", "jax"),
    "native": ("native", "jax"),
    "unrolled": ("unrolled", "jax"),
    "pallas-qs": ("bitvector", "pallas"),
    "pallas-bitmm": ("bitmm", "pallas"),
    "pallas-gemm": ("gemm", "pallas"),
}


def _make_factory(name: str) -> Callable[[Forest], object]:
    engine, backend = ENGINE_SPECS[name]

    def factory(forest: Forest):
        from . import compile_forest
        kw = {"interpret": _interpret()} if backend == "pallas" else {}
        return compile_forest(forest, engine=engine, backend=backend, **kw)

    return factory


ENGINE_FACTORIES: dict[str, Callable[[Forest], object]] = {
    name: _make_factory(name) for name in ENGINE_SPECS
}

XLA_ENGINES = ("qs", "qs-bitmm", "rapidscorer", "gemm", "native", "unrolled")
PALLAS_ENGINES = ("pallas-qs", "pallas-bitmm", "pallas-gemm")


def _on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def default_engines(include_pallas: Optional[bool] = None) -> tuple:
    if include_pallas is None:
        include_pallas = _on_tpu()
    return XLA_ENGINES + PALLAS_ENGINES if include_pallas else XLA_ENGINES


def bucket_batch(batch: int) -> int:
    """Next power of two — one autotune decision per batch octave."""
    return 1 << max(int(batch) - 1, 0).bit_length()


def shape_key(forest: Forest, batch_bucket: int) -> str:
    # max_depth is part of the structure key: native/unrolled run
    # O(depth) iterations and bitmm's field packing widens with depth, so
    # a balanced and a chain-shaped forest with identical T/L/C/d rank
    # engines very differently.
    import jax
    return (f"{jax.default_backend()}"
            f"_T{forest.n_trees}_L{forest.n_leaves}_C{forest.n_classes}"
            f"_d{forest.n_features}_D{forest.max_depth}"
            f"_{np.dtype(forest.threshold.dtype).name}_B{batch_bucket}")


_CACHE_DEFAULT = object()          # "cache_path not given" sentinel


def default_cache_path() -> str:
    # resolved per call, not at import, so REPRO_ENGINE_CACHE set after
    # `import repro.core` (e.g. pytest monkeypatch) still takes effect
    return os.environ.get(
        "REPRO_ENGINE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "engine_cache.json"))


_MEM_CACHE: dict[str, dict] = {}
# (path, key) pairs whose in-memory entry is known to be on disk already —
# lets cache hits skip the read-merge-rewrite of the JSON file
_PERSISTED: set[tuple[str, str]] = set()


def _load_disk(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _merge_entry(old: Optional[dict], new: dict) -> dict:
    """Union of two sweeps' timings — cached coverage only ever grows."""
    if not old:
        return new
    timings = {**old.get("timings", {}), **new.get("timings", {})}
    return {"engine": min(timings, key=timings.get), "timings": timings}


def _store_disk(path: str, key: str, entry: dict) -> None:
    # read-merge-replace without a file lock: concurrent writers can drop
    # each other's timings (last replace wins). Acceptable — the cache is
    # an optimisation, and the cost is one redundant re-sweep later.
    data = _load_disk(path)
    data[key] = _merge_entry(data.get(key), entry)
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1)
        os.replace(tmp, path)
        _PERSISTED.add((path, key))
    except OSError:
        pass                       # cache is an optimisation, never fatal


@dataclass
class EngineChoice:
    engine: str                    # winning engine name
    key: str                       # shape/batch cache key
    predictor: object              # ready-to-serve predictor for `engine`
    timings: dict = field(default_factory=dict)   # engine → median seconds
    from_cache: bool = False

    def predict(self, X):
        return self.predictor.predict(X)


def _bench_once(pred, X: np.ndarray, repeats: int) -> float:
    pred.predict(X)                # warmup + compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        pred.predict(X)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def choose(forest: Forest, batch: int, *, engines=None,
           include_pallas: Optional[bool] = None,
           cache_path=_CACHE_DEFAULT,
           force: bool = False, repeats: int = 3,
           seed: int = 0) -> EngineChoice:
    """Pick the fastest engine for ``forest`` at this batch-size bucket.

    Cache hits (in-memory, then the JSON file at ``cache_path``) skip the
    sweep and only build the winning predictor.  A cached entry counts as
    a hit only if its accumulated sweeps covered every engine the caller
    asked for — the winner is then re-derived over the requested subset —
    so a narrow ``engines=`` sweep can never answer for the full matrix;
    a partial-coverage miss benchmarks only the engines not yet measured.
    New sweeps merge into the cached entry (timings union, both layers),
    so within a process coverage only grows and a narrow re-sweep never
    erases wider measurements; cross-process disk merges are best-effort
    (unlocked read-merge-replace — see ``_store_disk``).  Merged timings
    may come from different runs (machine load, ``repeats``) — the cache
    assumes per-shape rankings are stable enough that this is fine.
    When ``cache_path`` is omitted it defaults to ``$REPRO_ENGINE_CACHE``
    (or ``~/.cache/repro/engine_cache.json``); ``cache_path=None``
    disables the disk layer entirely.  ``force=True`` re-benchmarks
    regardless of any cached entry."""
    engines = tuple(engines) if engines is not None \
        else default_engines(include_pallas)
    if cache_path is _CACHE_DEFAULT:
        cache_path = default_cache_path()
    bucket = bucket_batch(batch)
    key = shape_key(forest, bucket)

    prior = _MEM_CACHE.get(key)
    if cache_path and not (prior is not None
                           and set(engines) <= set(prior.get("timings", {}))):
        disk = _load_disk(cache_path).get(key)
        if disk is not None:           # warm/widen the memory layer
            if prior is None:
                prior = disk
                _PERSISTED.add((cache_path, key))
            else:
                # memory may hold timings the file lacks — not persisted
                prior = _merge_entry(disk, prior)
                _PERSISTED.discard((cache_path, key))
            _MEM_CACHE[key] = prior
    if not force and prior is not None:
        cached = prior.get("timings", {})
        if set(engines) <= set(cached):
            winner = min(engines, key=cached.get)
            if cache_path and (cache_path, key) not in _PERSISTED:
                # write-through: the entry may exist only in memory (e.g.
                # swept earlier with cache_path=None); a merge against the
                # file is idempotent and trivial next to the compile below
                _store_disk(cache_path, key, prior)
            return EngineChoice(engine=winner, key=key,
                                predictor=ENGINE_FACTORIES[winner](forest),
                                timings={e: cached[e] for e in engines},
                                from_cache=True)

    cached = (prior or {}).get("timings", {})
    to_bench = engines if force \
        else tuple(e for e in engines if e not in cached)
    X = np.random.default_rng(seed).normal(
        0, 1.0, size=(bucket, forest.n_features))
    fresh: dict[str, float] = {}
    best_pred, best_t = None, float("inf")
    for name in to_bench:
        pred = ENGINE_FACTORIES[name](forest)
        fresh[name] = _bench_once(pred, X, repeats)
        # keep only the best-so-far predictor: peak memory stays
        # max(current, best) instead of the sum over the engine matrix
        if fresh[name] < best_t:
            best_pred, best_t = pred, fresh[name]
    # partial-coverage miss: cached timings fill in the engines we skipped
    timings = {e: fresh.get(e, cached.get(e)) for e in engines}
    winner = min(timings, key=timings.get)
    # the stored engine must be the winner over the entry's own timings
    # (merges re-derive it over the union; lookups re-derive per request)
    entry = {"engine": min(fresh, key=fresh.get), "timings": fresh}
    _MEM_CACHE[key] = _merge_entry(prior, entry)
    # the memory entry just changed: any disk copy of this key is stale
    _PERSISTED.difference_update({pk for pk in _PERSISTED if pk[1] == key})
    if cache_path:
        # persist the merged union, not just this sweep: coverage that so
        # far existed only in memory reaches disk too (file re-merged)
        _store_disk(cache_path, key, _MEM_CACHE[key])
    return EngineChoice(
        engine=winner, key=key,
        predictor=best_pred if winner in fresh
        else ENGINE_FACTORIES[winner](forest),
        timings=timings, from_cache=False)


def clear_cache(cache_path: Optional[str] = None) -> None:
    """Drop the in-memory cache (and the disk file, if a path is given)."""
    _MEM_CACHE.clear()
    _PERSISTED.clear()
    if cache_path:
        try:
            os.remove(cache_path)
        except OSError:
            pass
