"""Per-forest engine autotuner — the paper's conclusion as an API.

The paper's central finding is that the fastest tree-traversal
implementation depends on both the forest shape and the target hardware.
``choose(forest, batch)`` operationalises that: it microbenchmarks every
candidate engine on the actual forest at the caller's (bucketed) batch
size, returns the winner, and caches the decision — in memory for the
process, and as JSON on disk so later processes (and the serving path,
``inference.server.ForestServer.from_forest``) skip the sweep entirely.

Candidates come from ``core.registry`` (one registration per engine — no
second table here); the autotuner's short names are the registry specs'
``tune_name``.  Beyond the engine axis, the sweep can cover the other
pipeline passes: ``quant_specs=`` adds fixed-point variants (paper §5) as
``<engine>@q<bits>`` candidates, ``opt_levels=`` adds optimizer
middle-end variants (``<engine>@O2`` — ``repro.optim``, docs/OPTIM.md),
``layout_specs=`` adds engine-kw layout variants
(``<engine>@tree_chunk=32``), and ``n_devices=`` tunes the
tree-sharded multi-device wrapper (``core.shard``) instead of
single-device engines.

Cache key: ``(jax backend, n_trees, n_leaves, n_classes, n_features,
max_depth, threshold dtype, batch bucket, n_devices, device
fingerprint)``.  Runtime is independent of the learned values, so device
+ shape/structure + dtype fully determine the ranking — and a winner
measured on CPU is never replayed on TPU (or vice versa), nor is a cache
file copied between machines replayed on hardware it never measured
(the fingerprint component key-misses it — docs/AUTOTUNE.md).

Beyond measuring, ``choose(mode="predict")`` is the zero-shot ``-Os``
path (ROADMAP item 3, docs/AUTOTUNE.md): a learned cost model trained on
the accumulated cache history (``repro.tune``) ranks the candidates
without compiling any of them; at high confidence only the predicted
winner is built (and quick-benched, feeding the measurement back into
the cache as ground truth), at low confidence the sweep narrows to the
top-k predicted candidates instead of the full product.

Pallas engines run in interpret mode on CPU (orders of magnitude slower
than compiled XLA), so they only enter the candidate set on a real TPU
backend — or explicitly via ``engines=``/``include_pallas=True``.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import time
from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from . import registry
from ..obs import metrics as _obs_metrics
from ..obs.log import get_logger
from .forest import Forest
from .quantize import QuantSpec, quantize_forest

_LOG = get_logger("autotune")


def _autotune_metrics():
    """The autotuner's metric families on the process default registry
    (docs/OBSERVABILITY.md §Autotune), or ``None`` when observability is
    disabled.  Resolved per call — get-or-create is two dict lookups
    after the first time, and tests that swap the default registry
    (``set_default_registry``) observe their own."""
    reg = _obs_metrics.get_registry()
    if not reg.enabled:
        return None
    return {
        "sweeps": reg.counter(
            "repro_autotune_sweeps_total",
            "Autotune benchmark sweeps executed (decisions that had to "
            "time at least one candidate)"),
        "hits": reg.counter(
            "repro_autotune_cache_hits_total",
            "Autotune decisions answered entirely from cache",
            labels=("layer",)),
        "misses": reg.counter(
            "repro_autotune_cache_misses_total",
            "Autotune decisions that had to benchmark",
            labels=("reason",)),
        "sweep_s": reg.histogram(
            "repro_autotune_sweep_seconds",
            "Wall time of one autotune benchmark sweep, seconds"),
        "benched": reg.counter(
            "repro_autotune_candidates_benched_total",
            "Candidate predictors built and timed by autotune sweeps"),
        "winner": reg.gauge(
            "repro_autotune_winner_info",
            "Autotune winner per shape key (info gauge: value is "
            "always 1; the labels carry the decision)",
            labels=("key", "engine")),
        "predict_hits": reg.counter(
            "repro_autotune_predict_hits_total",
            "Zero-shot (-Os) decisions answered by the cost model at "
            "high confidence — one candidate compiled, no sweep"),
        "fallbacks": reg.counter(
            "repro_autotune_fallback_sweeps_total",
            "Predict-mode decisions that fell back to a (narrow) sweep",
            labels=("reason",)),
        "feedback": reg.counter(
            "repro_autotune_feedback_writes_total",
            "Ground-truth measurements written back into the cache by "
            "zero-shot predict decisions"),
        "predict_err": reg.histogram(
            "repro_autotune_predict_rel_error",
            "Relative |predicted − measured| / measured us-per-instance "
            "of zero-shot winners (the model's live quality)"),
        "predict_err_last": reg.gauge(
            "repro_autotune_predict_last_rel_error",
            "Most recent zero-shot prediction's relative error, per "
            "shape key", labels=("key",)),
    }


class _TuneTable(Mapping):
    """Live view of ``registry.tune_table()`` — autotuner name →
    (engine, backend).  A mapping object (not a snapshot dict) so engines
    registered after import (plugins, tests) appear automatically."""

    def __getitem__(self, name: str) -> tuple:
        return registry.tune_table()[name]

    def __iter__(self):
        return iter(registry.tune_table())

    def __len__(self):
        return len(registry.tune_table())


ENGINE_SPECS = _TuneTable()


def _make_factory(name: str) -> Callable[[Forest], object]:
    spec = registry.by_tune_name(name)

    def factory(forest: Forest):
        kw = {"interpret": _interpret()} if spec.backend == "pallas" else {}
        return registry.build(forest, spec.name, spec.backend, **kw)

    return factory


class _FactoryTable(Mapping):
    """tune name → predictor factory, resolved through the registry."""

    def __getitem__(self, name: str) -> Callable[[Forest], object]:
        if name not in registry.tune_table():
            raise KeyError(name)
        return _make_factory(name)

    def __iter__(self):
        return iter(registry.tune_table())

    def __len__(self):
        return len(registry.tune_table())


ENGINE_FACTORIES = _FactoryTable()


def xla_engines() -> tuple:
    return tuple(s.tune_name for s in registry.specs("jax"))


def pallas_engines() -> tuple:
    return tuple(s.tune_name for s in registry.specs("pallas"))


def _on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def default_engines(include_pallas: Optional[bool] = None) -> tuple:
    if include_pallas is None:
        include_pallas = _on_tpu()
    return xla_engines() + pallas_engines() if include_pallas \
        else xla_engines()


def bucket_batch(batch: int) -> int:
    """Next power of two — one autotune decision per batch octave."""
    return 1 << max(int(batch) - 1, 0).bit_length()


def bucket_ladder(max_batch: int) -> tuple:
    """Every power-of-two batch bucket up to ``bucket_batch(max_batch)``
    — the complete set of batch shapes the bucketed execution paths
    (Pallas predictors, the fused cascade, and the serving runtime's
    pad-to-bucket dispatch) can ever emit for batches ≤ ``max_batch``.
    ``ServingRuntime.warmup`` pre-traces exactly these shapes so no live
    request pays a trace/compile (docs/SERVING.md)."""
    top = bucket_batch(max_batch)
    out, b = [], 1
    while b <= top:
        out.append(b)
        b *= 2
    return tuple(out)


def device_fingerprint() -> dict:
    """What the timings were measured *on*: jax backend, the first
    device's kind, and the host ISA.  Part of every cache key (as a
    short hash) and of every schema-v2 entry's ``meta`` (as a cost-model
    feature) — a cache file copied between machines, or a CPU↔TPU switch
    inside one process, must key-miss rather than silently serve a
    winner measured on different hardware."""
    import jax
    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device_kind": str(getattr(dev, "device_kind", type(dev).__name__)),
        "machine": platform.machine(),
    }


def fingerprint_hash(fp: Optional[dict] = None) -> str:
    """Short stable hash of ``device_fingerprint()`` for key embedding."""
    blob = json.dumps(fp or device_fingerprint(), sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:8]


def shape_key(forest: Forest, batch_bucket: int, n_devices: int = 1) -> str:
    # max_depth is part of the structure key: native/unrolled run
    # O(depth) iterations and bitmm's field packing widens with depth, so
    # a balanced and a chain-shaped forest with identical T/L/C/d rank
    # engines very differently.  n_devices is part of the key because a
    # tree-sharded winner on 8 devices says nothing about 1 device.  The
    # trailing fingerprint hash makes pre-fingerprint (schema-v1) entries
    # and foreign-machine cache files key-miss and re-sweep.
    import jax
    return (f"{jax.default_backend()}"
            f"_T{forest.n_trees}_L{forest.n_leaves}_C{forest.n_classes}"
            f"_d{forest.n_features}_D{forest.max_depth}"
            f"_{np.dtype(forest.threshold.dtype).name}_B{batch_bucket}"
            f"_dev{n_devices}_fp{fingerprint_hash()}")


def shape_meta(forest: Forest, batch_bucket: int, n_devices: int = 1) -> dict:
    """The cost-model feature view of one autotune decision (the entry's
    ``meta`` field, docs/AUTOTUNE.md): forest shape + batch bucket +
    device identity.  Everything ``repro.tune.extract`` needs to build a
    training row without re-parsing the shape key."""
    fp = device_fingerprint()
    return {
        "n_trees": int(forest.n_trees), "n_leaves": int(forest.n_leaves),
        "n_classes": int(forest.n_classes),
        "n_features": int(forest.n_features),
        "max_depth": int(forest.max_depth),
        "dtype": np.dtype(forest.threshold.dtype).name,
        "batch": int(batch_bucket), "n_devices": int(n_devices),
        "backend": fp["backend"], "device_kind": fp["device_kind"],
        "machine": fp["machine"], "fingerprint": fingerprint_hash(fp),
    }


_CACHE_DEFAULT = object()          # "cache_path not given" sentinel


def default_cache_path() -> str:
    # resolved per call, not at import, so REPRO_ENGINE_CACHE set after
    # `import repro.core` (e.g. pytest monkeypatch) still takes effect
    return os.environ.get(
        "REPRO_ENGINE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "engine_cache.json"))


_MEM_CACHE: dict[str, dict] = {}
# (path, key) pairs whose in-memory entry is known to be on disk already —
# lets cache hits skip the read-merge-rewrite of the JSON file
_PERSISTED: set[tuple[str, str]] = set()

# Cache entry schema (docs/AUTOTUNE.md).  v1: {"engine", "timings"}.
# v2 adds per-candidate "compile_s" (predictor build + first traced
# predict, seconds) and "bench_us" (steady-state us per instance) kept
# separate — the selection metric stays the steady-state batch timing —
# plus "meta" (shape_meta: the cost model's feature row).  v1 entries
# still parse, but they predate the fingerprinted key and so never match
# a key this module now generates.
SCHEMA_VERSION = 2


def _valid_entry(entry) -> bool:
    """Structural check for one cache entry: ``{"engine": str,
    "timings": {str: number}}`` with a non-empty timings dict (v1);
    the v2 fields are optional and checked only for shape."""
    if not isinstance(entry, dict):
        return False
    timings = entry.get("timings")
    if not isinstance(timings, dict) or not timings:
        return False
    if not all(isinstance(k, str) and isinstance(v, (int, float))
               and not isinstance(v, bool) for k, v in timings.items()):
        return False
    return all(isinstance(entry.get(fld, {}), dict)
               for fld in ("compile_s", "bench_us", "meta"))


def _load_disk(path: str) -> dict:
    """Parse the JSON cache file, dropping anything malformed.

    A truncated or hand-mangled cache (garbage JSON, a non-dict top
    level, entries missing ``timings`` or holding non-numeric values)
    must degrade to a clean re-sweep — and the next ``_store_disk``
    rewrites the file — never to an unhandled exception at serving time."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict):
        return {}
    return {k: v for k, v in data.items() if _valid_entry(v)}


def _merge_entry(old: Optional[dict], new: dict) -> dict:
    """Union of two sweeps' measurements — cached coverage only ever
    grows.  The schema-v2 side dicts (``compile_s``, ``bench_us``) union
    the same way; ``meta`` is shape-determined per key, so the newest
    writer wins."""
    if not old:
        return new
    timings = {**old.get("timings", {}), **new.get("timings", {})}
    out = {"engine": min(timings, key=timings.get), "timings": timings}
    for fld in ("compile_s", "bench_us"):
        d = {**(old.get(fld) or {}), **(new.get(fld) or {})}
        if d:
            out[fld] = d
    meta = new.get("meta") or old.get("meta")
    if meta:
        out["meta"] = meta
    if "v" in new or "v" in old:
        out["v"] = max(int(new.get("v", 1)), int(old.get("v", 1)))
    return out


def _store_disk(path: str, key: str, entry: dict) -> None:
    # read-merge-replace without a file lock: concurrent writers can drop
    # each other's timings (last replace wins). Acceptable — the cache is
    # an optimisation, and the cost is one redundant re-sweep later.
    data = _load_disk(path)
    data[key] = _merge_entry(data.get(key), entry)
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1)
        os.replace(tmp, path)
        _PERSISTED.add((path, key))
    except OSError:
        pass                       # cache is an optimisation, never fatal


@dataclass
class EngineChoice:
    engine: str                    # winning candidate name
    key: str                       # shape/batch cache key
    predictor: object              # ready-to-serve predictor for `engine`
    timings: dict = field(default_factory=dict)   # candidate → median secs
    from_cache: bool = False
    compile_s: dict = field(default_factory=dict)  # candidate → build secs
    confidence: Optional[float] = None  # cost-model confidence (predict mode)
    predicted: bool = False        # True: zero-shot, no sweep ran
    pruned: tuple = ()             # candidates aliased to an identical IR

    def predict(self, X):
        return self.predictor.predict(X)


def _bench_once(pred, X: np.ndarray, repeats: int) -> float:
    pred.predict(X)                # warmup + compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        pred.predict(X)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _bench_candidate(factory: Callable, X: np.ndarray,
                     repeats: int) -> tuple:
    """Build + time one candidate, keeping the two costs separate:
    ``compile_s`` is the predictor build plus the first (traced +
    compiled) predict; the returned bench seconds are the steady-state
    median that ``timings`` persists.  Conflating the two is exactly the
    bug schema v2 fixes — a one-shot caller and a serving fleet weight
    them very differently (docs/AUTOTUNE.md)."""
    t0 = time.perf_counter()
    pred = factory()
    pred.predict(X)                # trace + compile, counted as compile_s
    compile_s = time.perf_counter() - t0
    return pred, compile_s, _bench_once(pred, X, repeats)


def _layout_tag(kw: dict) -> str:
    return ",".join(f"{k}={kw[k]}" for k in sorted(kw))


def _quant_tag(q: QuantSpec) -> str:
    """Candidate-name tag for a QuantSpec — encodes every field that
    changes the compiled variant, so distinct specs never alias in the
    timing cache (``q16`` for the default, suffixes otherwise)."""
    tag = f"q{q.bits}"
    if q.int_accum:
        tag += "i"                  # integer leaf accumulation (QUANT.md)
    if q.scale is not None:
        tag += f"s{q.scale:g}"
    if not q.quantize_splits:
        tag += "-nosplits"
    if not q.quantize_leaves:
        tag += "-noleaves"
    return tag


def _ir_hash(forest: Forest) -> str:
    """Content hash of a Forest IR — two candidates whose post-optimize
    IRs hash equal (same engine / layout / cascade / flint) compile to
    the same predictor, so the sweep benches one and aliases the other
    (optimizer-aware candidate pruning, docs/AUTOTUNE.md)."""
    h = hashlib.sha1()
    for a in (forest.feature, forest.threshold, forest.left, forest.right,
              forest.leaf_value, forest.n_nodes, forest.n_leaves_per_tree):
        h.update(np.ascontiguousarray(a).tobytes())
    for a in (forest.feat_lo, forest.feat_hi, forest.feat_map):
        h.update(b"\0" if a is None else np.ascontiguousarray(a).tobytes())
    h.update(repr((forest.quant_scale, forest.quant_bits,
                   forest.leaf_scale, forest.int_accum, forest.flint,
                   forest.leaf_err_bound, forest.n_features,
                   forest.n_features_src, forest.max_depth)).encode())
    return h.hexdigest()[:16]


def _candidate_factories(forest: Forest, engines: tuple,
                         quant_specs: Optional[tuple],
                         layout_specs: Optional[dict],
                         n_devices: int,
                         cascade_specs: Optional[tuple] = None,
                         opt_levels: Optional[tuple] = None,
                         flint: bool = False,
                         opt_cache: Optional[dict] = None
                         ) -> dict[str, Callable]:
    """Candidate name → zero-arg predictor factory.

    The candidate axis is the (engine × quantization × optimization ×
    layout × cascade) product of the pipeline's passes: plain tune names
    for the forest as-is, ``<engine>@q<bits>`` per ``QuantSpec``,
    ``<engine>@O<level>`` per entry of ``opt_levels`` (the optimizer
    middle-end, ``repro.optim``), ``<engine>@<kw=v,...>`` per entry of
    ``layout_specs[engine]`` (engine-kw overrides such as bitmm's
    ``tree_chunk`` or gemm block sizes), and
    ``<engine>@cascade=16/48:<policy>`` per ``CascadeSpec`` (staged
    evaluation, ``repro.cascade``) — or ``<engine>@cascade-fused=...``
    when the spec sets ``fused=True`` (one-jit execution,
    ``cascade/fused.py``; pass both variants to time staged vs fused).
    Opt and cascade tags participate in
    cache entries the same way the ``_dev{n}`` key component does for
    sharding: entries written before those axes existed simply lack the
    tagged timings, so the sweep key-misses them and re-benchmarks
    instead of mis-hitting — ``cascade-fused`` tags likewise key-miss
    every pre-fusion cache entry.  With ``n_devices > 1`` each candidate is
    wrapped tree-sharded (non-shardable engines are rejected up front;
    cascade + sharding is rejected too).

    Every factory compiles through ``compile_plan``, so the winning
    predictor always carries a ``CompilePlan`` — ``choice.predictor
    .plan.describe()`` explains the variant, optimizer stats included.

    With ``opt_cache`` (a dict, one per sweep) the optimize pass runs
    once per (quantized-forest, opt-tag) point and every engine/layout/
    cascade candidate at that point reuses the cached IR (shared-IR
    sweeps — the PR-5 deferral).  Each returned factory also carries
    ``.axes`` (the candidate's per-axis tags) and ``.group_key()`` (the
    identical-predictor equivalence class used for candidate pruning)."""
    from ..optim import resolve_opt
    if quant_specs and forest.quant_scale is not None:
        raise ValueError("quant_specs sweep needs a float forest "
                         "(this one is already quantized)")
    if cascade_specs and n_devices > 1:
        raise ValueError("cascade_specs cannot combine with n_devices > 1 "
                         "(staged evaluation is single-device)")
    unknown = set(layout_specs or ()) - set(engines)
    if unknown:
        # a silently ignored key would make the caller believe the cached
        # winner was layout-tuned when the sweep never ran
        raise ValueError(f"layout_specs keys {sorted(unknown)} are not in "
                         f"the requested engine set {tuple(engines)} "
                         "(use autotuner tune names, e.g. 'qs-bitmm')")
    for o in opt_levels or ():
        resolve_opt(o)                 # reject garbage levels up front
    if flint and forest.quant_scale is not None:
        raise ValueError("flint=True needs a float forest (FLInt rekeys "
                         "f32 thresholds; this one is already quantized)")
    quants: tuple = (None,) + (tuple(quant_specs) if quant_specs else ())
    opts: tuple = (None,) + (tuple(opt_levels) if opt_levels else ())
    cascades: tuple = (None,) + (tuple(cascade_specs) if cascade_specs
                                 else ())
    # FLInt axis: f32 thresholds rekeyed as monotone int32 (QUANT.md §4).
    # Only the float variant gets it (flint ⊕ quantize), and only jax
    # engines — the Pallas kernels cast inputs f32, losing int32 keys.
    def flints(e: str, q) -> tuple:
        if flint and q is None and \
                registry.by_tune_name(e).backend != "pallas":
            return (False, True)
        return (False,)
    variants: list[tuple] = [
        (e, q, o, kw, casc, fl)
        for e in engines for q in quants for o in opts
        for kw in (None,) + tuple((layout_specs or {}).get(e, ()))
        for casc in cascades for fl in flints(e, q)]

    qforests: dict[int, Forest] = {}   # one quantized forest per spec

    def qf(q: Optional[QuantSpec]) -> Forest:
        if q is None:
            return forest
        if id(q) not in qforests:
            qforests[id(q)] = quantize_forest(forest, None, q)
        return qforests[id(q)]

    def make(name: str, q: Optional[QuantSpec], o,
             kw: Optional[dict], casc, fl: bool = False) -> Callable:
        spec = registry.by_tune_name(name)
        ekw = dict(kw or {})
        if n_devices > 1 and not spec.shardable:
            raise ValueError(
                f"engine {name!r} cannot run tree-sharded "
                f"(n_devices={n_devices}); restrict engines= to "
                f"{[s.tune_name for s in registry.specs() if s.shardable]}")
        if spec.backend == "pallas":
            ekw.setdefault("interpret", _interpret())

        def factory():
            from .pipeline import CompilePlan, compile_plan
            plan = CompilePlan(engine=spec.name, backend=spec.backend,
                               opt=o, n_devices=n_devices, cascade=casc,
                               flint=fl, engine_kw=dict(ekw))
            return compile_plan(qf(q), plan, opt_cache=opt_cache)

        factory.axes = {
            "engine": name,
            "quant": _quant_tag(q) if q is not None else "",
            "opt": resolve_opt(o)[1] if o is not None else "",
            "layout": _layout_tag(kw) if kw is not None else "",
            "cascade": casc.tag() if casc is not None else "",
            "flint": fl,
        }

        def group_key() -> tuple:
            # the post-optimize IR fully determines the compiled artifact
            # alongside engine + layout kw + cascade + flint (the flint
            # pass runs after optimize and is deterministic); with the
            # shared opt_cache this costs one optimize per (quant, opt)
            # point — work the sweep was about to do anyway
            from .pipeline import optimized_forest
            ir = optimized_forest(qf(q), o, opt_cache=opt_cache)
            return (name, factory.axes["layout"], factory.axes["cascade"],
                    fl, _ir_hash(ir))

        factory.group_key = group_key
        return factory

    def cname(e: str, q: Optional[QuantSpec], o, kw: Optional[dict],
              casc, fl: bool = False) -> str:
        name = e if q is None else f"{e}@{_quant_tag(q)}"
        if fl:
            name = f"{name}@flint"
        if o is not None:
            name = f"{name}@{resolve_opt(o)[1]}"
        if kw is not None:
            name = f"{name}@{_layout_tag(kw)}"
        return name if casc is None else f"{name}@{casc.tag()}"

    return {cname(e, q, o, kw, casc, fl): make(e, q, o, kw, casc, fl)
            for e, q, o, kw, casc, fl in variants}


def default_model_path() -> str:
    """Where ``mode="predict"`` looks for the trained cost model when the
    caller passes none: ``$REPRO_COST_MODEL`` or the cache-sibling
    default (``repro.tune.train_from_cache`` writes here too)."""
    return os.environ.get(
        "REPRO_COST_MODEL",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "cost_model.json"))


# path → (mtime, model): a fleet cold-start resolves the same artifact
# once per change, not once per tenant
_MODEL_CACHE: dict[str, tuple] = {}


def _resolve_cost_model(cm):
    """``cost_model=`` argument → a loaded ``repro.tune.CostModel`` or
    ``None`` (predict mode then falls back to a full sweep).  Accepts a
    model object, a path, or ``None`` for ``default_model_path()``.  A
    missing/corrupt *default* artifact degrades to ``None`` (with a log
    warning for corruption); an explicitly passed path raises — the
    caller asked for that file by name."""
    explicit = cm is not None
    if cm is None:
        cm = default_model_path()
    if not isinstance(cm, (str, os.PathLike)):
        return cm
    path = os.fspath(cm)
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        if explicit:
            raise FileNotFoundError(
                f"cost_model path {path!r} does not exist") from None
        return None
    hit = _MODEL_CACHE.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    from ..tune import CostModel
    try:
        model = CostModel.load(path)
    except (OSError, ValueError):
        if explicit:
            raise
        _LOG.warning("cost_model_unreadable", path=path)
        return None
    _MODEL_CACHE[path] = (mtime, model)
    return model


def _bench_rows(forest: Forest, bucket: int, seed: int) -> np.ndarray:
    # n_features_in, not n_features: an already-optimized forest (with a
    # feat_map from drop_unused_features) still takes full-width rows
    return np.random.default_rng(seed).normal(
        0, 1.0, size=(bucket, forest.n_features_in))


def choose(forest: Forest, batch: int, *, engines=None,
           include_pallas: Optional[bool] = None,
           quant_specs: Optional[tuple] = None,
           layout_specs: Optional[dict] = None,
           cascade_specs: Optional[tuple] = None,
           opt_levels: Optional[tuple] = None,
           flint: bool = False,
           n_devices: int = 1,
           cache_path=_CACHE_DEFAULT,
           force: bool = False, repeats: int = 3,
           seed: int = 0,
           mode: str = "measure",
           cost_model=None,
           confidence_threshold: float = 0.8,
           top_k: int = 3,
           share_ir: bool = True,
           feedback: bool = True) -> EngineChoice:
    """Pick the fastest candidate for ``forest`` at this batch-size bucket.

    Candidates are (engine × quantization × optimization × layout ×
    cascade) variants — see ``_candidate_factories``; ``opt_levels=(1,
    2)`` adds optimizer middle-end variants (``qs@O2``, docs/OPTIM.md);
    ``flint=True`` adds ``<engine>@flint`` variants — f32 thresholds
    rekeyed as monotone int32 (docs/QUANT.md, jax engines only)
    whose compiled forests are smaller but oracle-equivalent;
    ``n_devices > 1`` tunes the tree-sharded
    wrapper instead.  Cascade candidates (``cascade_specs=``) time the
    gated path on the synthetic benchmark batch — exit fractions on real
    traffic depend on the data, so treat a cascade winner as a hint and
    benchmark on representative rows when it matters; include
    ``CascadeSpec(..., fused=True)`` entries to race the fused one-jit
    execution against the staged host loop.  Cache hits
    (in-memory, then the JSON file at
    ``cache_path``) skip the sweep and only build the winning predictor.
    A cached entry counts as a hit only if its accumulated sweeps covered
    every candidate the caller asked for — the winner is then re-derived
    over the requested subset — so a narrow ``engines=`` sweep can never
    answer for the full matrix; a partial-coverage miss benchmarks only
    the candidates not yet measured.  New sweeps merge into the cached
    entry (timings union, both layers), so within a process coverage only
    grows and a narrow re-sweep never erases wider measurements;
    cross-process disk merges are best-effort (unlocked
    read-merge-replace — see ``_store_disk``).  Merged timings may come
    from different runs (machine load, ``repeats``) — the cache assumes
    per-shape rankings are stable enough that this is fine.
    When ``cache_path`` is omitted it defaults to ``$REPRO_ENGINE_CACHE``
    (or ``~/.cache/repro/engine_cache.json``); ``cache_path=None``
    disables the disk layer entirely.  ``force=True`` re-benchmarks
    regardless of any cached entry.

    ``mode="predict"`` (alias ``"-Os"``, docs/AUTOTUNE.md) is the
    zero-shot path: after the cache layers miss, a learned cost model
    (``cost_model=`` — a ``repro.tune.CostModel``, a path, or ``None``
    for ``default_model_path()``) ranks the candidates without compiling
    any.  At confidence ≥ ``confidence_threshold`` only the predicted
    winner is built; with ``feedback=True`` (default) it is also
    quick-benched and the measurement written into the cache as ground
    truth for future training rounds.  Below the threshold (or with no
    model) the sweep still runs, narrowed to the ``top_k`` predicted
    candidates (full set when no model could rank them).  The returned
    ``EngineChoice`` carries ``predicted`` / ``confidence``.

    ``share_ir=True`` (default) shares one optimized IR across the
    engine / layout / cascade axes of the sweep — the optimize pass and
    its oracle check run once per (quant, opt) point — and prunes
    candidates whose post-optimize IR is provably identical (their
    timings are aliased to the one benched representative, listed in
    ``EngineChoice.pruned``)."""
    mode = str(mode).lower().lstrip("-")
    if mode == "os":
        mode = "predict"
    if mode not in ("measure", "predict"):
        raise ValueError(
            f"mode must be 'measure' or 'predict' (alias '-Os'), "
            f"got {mode!r}")
    if engines is None:
        engines = default_engines(include_pallas)
        if n_devices > 1:
            # the *default* set narrows to shardable engines (on TPU it
            # includes pallas, which can't tree-shard); an explicit
            # engines= list still errors loudly on non-shardable entries
            engines = tuple(e for e in engines
                            if registry.by_tune_name(e).shardable)
    else:
        engines = tuple(engines)
    opt_cache: Optional[dict] = {} if share_ir else None
    factories = _candidate_factories(forest, engines,
                                     tuple(quant_specs) if quant_specs
                                     else None, layout_specs, n_devices,
                                     tuple(cascade_specs) if cascade_specs
                                     else None,
                                     tuple(opt_levels) if opt_levels
                                     else None, flint=flint,
                                     opt_cache=opt_cache)
    candidates = tuple(factories)
    if cache_path is _CACHE_DEFAULT:
        cache_path = default_cache_path()
    bucket = bucket_batch(batch)
    key = shape_key(forest, bucket, n_devices)

    obs = _autotune_metrics()
    prior = _MEM_CACHE.get(key)
    # for the cache-hit layer label: did memory alone cover the request,
    # before the disk layer widened it?
    mem_covered = (prior is not None
                   and set(candidates) <= set(prior.get("timings", {})))
    if cache_path and not (prior is not None
                           and set(candidates)
                           <= set(prior.get("timings", {}))):
        disk = _load_disk(cache_path).get(key)
        if disk is not None:           # warm/widen the memory layer
            if prior is None:
                prior = disk
                _PERSISTED.add((cache_path, key))
            else:
                # memory may hold timings the file lacks — not persisted
                prior = _merge_entry(disk, prior)
                _PERSISTED.discard((cache_path, key))
            _MEM_CACHE[key] = prior
    if not force and prior is not None:
        cached = prior.get("timings", {})
        if set(candidates) <= set(cached):
            winner = min(candidates, key=cached.get)
            if cache_path and (cache_path, key) not in _PERSISTED:
                # write-through: the entry may exist only in memory (e.g.
                # swept earlier with cache_path=None); a merge against the
                # file is idempotent and trivial next to the compile below
                _store_disk(cache_path, key, prior)
            if obs is not None:
                layer = "memory" if mem_covered else "disk"
                obs["hits"].labels(layer=layer).inc()
                obs["winner"].labels(key=key, engine=winner).set(1.0)
            return EngineChoice(engine=winner, key=key,
                                predictor=factories[winner](),
                                timings={e: cached[e] for e in candidates},
                                from_cache=True)

    cached = (prior or {}).get("timings", {})

    # ---------------- zero-shot (-Os) path ------------------------------
    confidence: Optional[float] = None
    if mode == "predict" and not force:
        model = _resolve_cost_model(cost_model)
        reason = "no_model"
        if model is not None:
            meta = shape_meta(forest, bucket, n_devices)
            assess = model.assess(meta, candidates)
            confidence = float(assess["confidence"])
            if confidence >= confidence_threshold:
                widx = int(assess["order"][0])
                winner = candidates[widx]
                X = _bench_rows(forest, bucket, seed)
                if feedback:
                    pred, c_s, b_s = _bench_candidate(
                        factories[winner], X, repeats)
                    getattr(pred, "reset_exit_stats", lambda: None)()
                    us = b_s / bucket * 1e6
                    entry = {"engine": winner, "timings": {winner: b_s},
                             "compile_s": {winner: c_s},
                             "bench_us": {winner: us}, "meta": meta,
                             "v": SCHEMA_VERSION}
                    _MEM_CACHE[key] = _merge_entry(prior, entry)
                    _PERSISTED.difference_update(
                        {pk for pk in _PERSISTED if pk[1] == key})
                    if cache_path:
                        _store_disk(cache_path, key, _MEM_CACHE[key])
                    rel_err = abs(float(assess["us"][widx]) - us) \
                        / max(us, 1e-12)
                    timings = {winner: b_s}
                else:
                    t0 = time.perf_counter()
                    pred = factories[winner]()
                    pred.predict(X)
                    c_s = time.perf_counter() - t0
                    getattr(pred, "reset_exit_stats", lambda: None)()
                    rel_err, timings = None, {}
                if obs is not None:
                    obs["predict_hits"].inc()
                    obs["winner"].labels(key=key, engine=winner).set(1.0)
                    if rel_err is not None:
                        obs["feedback"].inc()
                        obs["predict_err"].observe(rel_err)
                        obs["predict_err_last"].labels(key=key).set(rel_err)
                _LOG.info("predict", key=key, winner=winner,
                          confidence=confidence, rel_err=rel_err)
                return EngineChoice(
                    engine=winner, key=key, predictor=pred,
                    timings=timings, from_cache=False,
                    compile_s={winner: c_s}, confidence=confidence,
                    predicted=True)
            reason = "low_confidence"
            k = max(1, int(top_k))
            if len(candidates) > k:
                keep = {candidates[int(i)] for i in assess["order"][:k]}
                candidates = tuple(c for c in candidates if c in keep)
        if obs is not None:
            obs["fallbacks"].labels(reason=reason).inc()
        _LOG.info("predict_fallback", key=key, reason=reason,
                  confidence=confidence, candidates=len(candidates))
        if set(candidates) <= set(cached):
            # the narrowed top-k may be fully covered by earlier sweeps
            winner = min(candidates, key=cached.get)
            if obs is not None:
                obs["hits"].labels(
                    layer="memory" if mem_covered else "disk").inc()
                obs["winner"].labels(key=key, engine=winner).set(1.0)
            return EngineChoice(
                engine=winner, key=key, predictor=factories[winner](),
                timings={e: cached[e] for e in candidates},
                from_cache=True, confidence=confidence)

    # ---------------- measured sweep ------------------------------------
    to_bench = candidates if force \
        else tuple(e for e in candidates if e not in cached)
    if obs is not None:
        reason = "forced" if force else ("partial" if cached else "cold")
        obs["misses"].labels(reason=reason).inc()
    X = _bench_rows(forest, bucket, seed)
    # optimizer-aware candidate pruning: candidates in the same
    # identical-predictor equivalence class (same engine / layout /
    # cascade / flint on a bit-identical post-optimize IR) are benched
    # once and aliased — their timings are genuinely equal, the compiled
    # artifact is the same object modulo XLA caching
    if opt_cache is not None and len(to_bench) > 1:
        groups: dict[tuple, list] = {}
        for name in to_bench:
            groups.setdefault(factories[name].group_key(), []).append(name)
        reps = {members[0]: members for members in groups.values()}
    else:
        reps = {name: [name] for name in to_bench}
    pruned = tuple(m for members in reps.values() for m in members[1:])
    fresh: dict[str, float] = {}
    fresh_compile: dict[str, float] = {}
    best_pred, best_t = None, float("inf")
    sweep_t0 = time.perf_counter()
    for name, members in reps.items():
        pred, c_s, b_s = _bench_candidate(factories[name], X, repeats)
        for m in members:
            fresh[m] = b_s
            fresh_compile[m] = c_s
        # keep only the best-so-far predictor: peak memory stays
        # max(current, best) instead of the sum over the engine matrix
        if b_s < best_t:
            best_pred, best_t = pred, b_s
    sweep_s = time.perf_counter() - sweep_t0
    # partial-coverage miss: cached timings fill in the engines we skipped
    timings = {e: fresh.get(e, cached.get(e)) for e in candidates}
    winner = min(timings, key=timings.get)
    if obs is not None:
        obs["sweeps"].inc()
        obs["sweep_s"].observe(sweep_s)
        obs["benched"].inc(float(len(reps)))
        obs["winner"].labels(key=key, engine=winner).set(1.0)
    _LOG.info("sweep", key=key, candidates=len(to_bench),
              benched=len(reps), pruned=len(pruned),
              seconds=sweep_s, winner=winner)
    if best_pred is not None:
        # cascade predictors count per-stage exits cumulatively; the
        # benchmark rows must not pollute the served exit accounting
        getattr(best_pred, "reset_exit_stats", lambda: None)()
    if fresh:
        # the stored engine must be the winner over the entry's own
        # timings (merges re-derive it over the union; lookups re-derive
        # per request)
        entry = {"engine": min(fresh, key=fresh.get), "timings": fresh,
                 "compile_s": fresh_compile,
                 "bench_us": {c: t / bucket * 1e6
                              for c, t in fresh.items()},
                 "meta": shape_meta(forest, bucket, n_devices),
                 "v": SCHEMA_VERSION}
        _MEM_CACHE[key] = _merge_entry(prior, entry)
        # the memory entry just changed: any disk copy of the key is stale
        _PERSISTED.difference_update(
            {pk for pk in _PERSISTED if pk[1] == key})
        if cache_path:
            # persist the merged union, not just this sweep: coverage that
            # so far existed only in memory reaches disk too (re-merged)
            _store_disk(cache_path, key, _MEM_CACHE[key])
    return EngineChoice(
        engine=winner, key=key,
        predictor=best_pred if winner in fresh
        else factories[winner](),
        timings=timings, from_cache=False, compile_s=dict(fresh_compile),
        confidence=confidence, pruned=pruned)


def clear_cache(cache_path: Optional[str] = None) -> None:
    """Drop the in-memory cache (and the disk file, if a path is given)."""
    _MEM_CACHE.clear()
    _PERSISTED.clear()
    _MODEL_CACHE.clear()
    if cache_path:
        try:
            os.remove(cache_path)
        except OSError:
            pass
