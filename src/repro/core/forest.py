"""Forest IR — canonical structure-of-arrays form of a tree ensemble.

All fast engines (QuickScorer bitvector, GEMM, native traversal, Pallas
kernels) compile from this IR. Canonicalisation guarantees:

  * leaves are numbered left-to-right (in-order), so every subtree covers a
    contiguous leaf range [lo, hi) — QuickScorer bitmasks become interval
    masks;
  * internal nodes are numbered in preorder, node 0 is the root;
  * every tree is padded to the ensemble-wide ``n_leaves_max`` (L) /
    ``n_nodes_max`` (L-1) so arrays are rectangular.

Bit convention (differs from the paper, see docs/DESIGN.md §2.2): leaf ``j``
of a tree owns bit ``j % 32`` of word ``j // 32`` (LSB-first). The paper's
"leftmost set bit" becomes "lowest set bit across words", computed with
``popcount((w & -w) - 1)``.

Canonicalisation is the ``canonicalize`` pass of the compile pipeline
(``core/pipeline.py``); ``from_trees`` below is its workhorse.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..trees.cart import Tree, TreeNode

WORD = 32  # leafidx word width


@dataclass
class Forest:
    """Padded SoA ensemble. ``feature[t, n] < 0`` marks a padding node."""
    n_trees: int
    n_leaves: int                 # L (max per tree, padded)
    n_classes: int                # C (1 for ranking/regression)
    n_features: int

    feature: np.ndarray           # (T, L-1) int32, -1 = padding
    threshold: np.ndarray         # (T, L-1) float32
    left: np.ndarray              # (T, L-1) int32; >=0 node id, <0 → leaf -(x+1)
    right: np.ndarray             # (T, L-1) int32
    # QuickScorer interval data: node n removes leaves [lo, mid) when its
    # predicate x > t fires (the left subtree becomes unreachable).
    leaf_lo: np.ndarray           # (T, L-1) int32
    leaf_mid: np.ndarray          # (T, L-1) int32
    leaf_hi: np.ndarray           # (T, L-1) int32
    leaf_value: np.ndarray        # (T, L, C) float32
    n_nodes: np.ndarray           # (T,) int32  real internal-node counts
    n_leaves_per_tree: np.ndarray  # (T,) int32
    max_depth: int = 0

    # quantization metadata (None → float32 forest)
    quant_scale: Optional[float] = None
    quant_bits: Optional[int] = None
    leaf_scale: float = 1.0                # descale factor for int leaves
    feat_lo: Optional[np.ndarray] = None   # per-feature affine normalisation
    feat_hi: Optional[np.ndarray] = None
    # optimizer column remap (repro.optim drop_unused_features): IR column
    # j reads the caller's column feat_map[j]; None → identity.  Applied
    # by quantize_inputs, so callers keep passing full-width rows.
    # n_features_src records the caller-side width at remap time — the
    # map alone can only bound it below (trailing unused columns vanish
    # from max(feat_map)+1).
    feat_map: Optional[np.ndarray] = None
    n_features_src: Optional[int] = None
    # integer end-to-end extensions (docs/QUANT.md)
    int_accum: bool = False               # engines accumulate leaves as ints
    flint: bool = False                   # thresholds are FLInt int32 keys
    leaf_err_bound: Optional[float] = None  # worst-case leaf-sum quant error

    @property
    def n_features_in(self) -> int:
        """Width of the rows callers pass (== n_features unless the
        optimizer dropped unused columns behind a feat_map)."""
        if self.feat_map is None:
            return self.n_features
        if self.n_features_src is not None:
            return int(self.n_features_src)
        # remap of unknown provenance: the tightest provable lower bound
        return int(np.max(self.feat_map, initial=-1)) + 1

    @property
    def n_words(self) -> int:
        return (self.n_leaves + WORD - 1) // WORD

    @property
    def nodes_per_tree(self) -> int:
        return self.n_leaves - 1

    # ---------------------------------------------------------------- oracle
    def predict_oracle(self, X: np.ndarray) -> np.ndarray:
        """Vectorized numpy root-to-leaf traversal — ground truth for every
        engine. Returns (B, C) summed scores."""
        B = X.shape[0]
        out = np.zeros((B, self.n_classes), dtype=np.float64)
        for t in range(self.n_trees):
            node = np.zeros(B, dtype=np.int32)
            done = np.zeros(B, dtype=bool)
            leaf = np.zeros(B, dtype=np.int32)
            if self.n_nodes[t] == 0:      # single-leaf tree
                out += self.leaf_value[t, 0]
                continue
            for _ in range(self.max_depth + 1):
                f = self.feature[t, node]
                go_left = X[np.arange(B), np.maximum(f, 0)] <= self.threshold[t, node]
                nxt = np.where(go_left, self.left[t, node], self.right[t, node])
                is_leaf = nxt < 0
                leaf = np.where(~done & is_leaf, -nxt - 1, leaf)
                done |= is_leaf
                node = np.where(is_leaf, node, nxt)
                if done.all():
                    break
            out += self.leaf_value[t, leaf]
        return out

    def init_leafidx(self) -> np.ndarray:
        """(T, W) uint32 — bits set only for real leaves of each tree."""
        T, L, W = self.n_trees, self.n_leaves, self.n_words
        idx = np.zeros((T, W), dtype=np.uint32)
        for t in range(T):
            idx[t] = _interval_bits(0, int(self.n_leaves_per_tree[t]), W)
        return idx

    def node_masks(self) -> np.ndarray:
        """(T, L-1, W) uint32 QuickScorer bitmasks: ones everywhere except
        the left-subtree leaf interval [lo, mid). Padding nodes → all-ones."""
        T, N, W = self.n_trees, self.nodes_per_tree, self.n_words
        masks = np.full((T, N, W), 0xFFFFFFFF, dtype=np.uint32)
        for t in range(T):
            for n in range(int(self.n_nodes[t])):
                masks[t, n] = ~_interval_bits(
                    int(self.leaf_lo[t, n]), int(self.leaf_mid[t, n]), W)
        return masks


def _interval_bits(lo: int, hi: int, n_words: int) -> np.ndarray:
    """uint32[n_words] with bits [lo, hi) set (LSB-first within words)."""
    out = np.zeros(n_words, dtype=np.uint32)
    for w in range(n_words):
        a, b = max(lo - w * WORD, 0), min(hi - w * WORD, WORD)
        if a < b:
            bits = (np.uint64(1) << np.uint64(b)) - np.uint64(1)
            bits ^= (np.uint64(1) << np.uint64(a)) - np.uint64(1)
            out[w] = np.uint32(bits & np.uint64(0xFFFFFFFF))
    return out


# --------------------------------------------------------------------------- #
# Builder: trainer trees → Forest IR
# --------------------------------------------------------------------------- #
def from_trees(trees: list[Tree], n_features: int, n_classes: int,
               tree_class: Optional[list[int]] = None,
               base_score: float = 0.0) -> Forest:
    """Canonicalise a list of trainer trees. ``tree_class`` embeds scalar
    GBT trees into C-dim leaf vectors (softmax boosting)."""
    T = len(trees)
    L = max(max(t.n_leaves for t in trees), 2)
    C = n_classes
    feature = np.full((T, L - 1), -1, dtype=np.int32)
    threshold = np.zeros((T, L - 1), dtype=np.float32)
    left = np.zeros((T, L - 1), dtype=np.int32)
    right = np.zeros((T, L - 1), dtype=np.int32)
    leaf_lo = np.zeros((T, L - 1), dtype=np.int32)
    leaf_mid = np.zeros((T, L - 1), dtype=np.int32)
    leaf_hi = np.zeros((T, L - 1), dtype=np.int32)
    leaf_value = np.zeros((T, L, C), dtype=np.float32)
    n_nodes = np.zeros(T, dtype=np.int32)
    n_leaves_per_tree = np.zeros(T, dtype=np.int32)
    max_depth = 1

    for t, tree in enumerate(trees):
        nodes: list[TreeNode] = []
        spans: dict[int, tuple[int, int, int]] = {}   # id -> (lo, mid, hi)
        leaf_ctr = 0

        def walk(nd: TreeNode, depth: int) -> tuple[int, int]:
            nonlocal leaf_ctr, max_depth
            max_depth = max(max_depth, depth)
            if nd.is_leaf:
                j = leaf_ctr
                leaf_ctr += 1
                val = nd.value
                if tree_class is not None and tree_class[t] >= 0:
                    v = np.zeros(C)
                    v[tree_class[t]] = val[0]
                    val = v
                leaf_value[t, j, :] = val
                return j, j + 1
            nodes.append(nd)
            lo, mid = walk(nd.left, depth + 1)
            _, hi = walk(nd.right, depth + 1)
            spans[id(nd)] = (lo, mid, hi)
            return lo, hi

        # preorder internal numbering happens via `nodes` append order
        walk(tree.root, 1)
        index = {id(nd): i for i, nd in enumerate(nodes)}

        # second pass fills arrays (leaf ids re-derived in the same order)
        leaf_ctr2 = 0

        def walk2(nd: TreeNode) -> int:
            nonlocal leaf_ctr2
            if nd.is_leaf:
                j = leaf_ctr2
                leaf_ctr2 += 1
                return -(j + 1)
            i = index[id(nd)]
            lcode = walk2(nd.left)
            rcode = walk2(nd.right)
            feature[t, i] = nd.feature
            threshold[t, i] = nd.threshold
            left[t, i] = lcode
            right[t, i] = rcode
            lo, mid, hi = spans[id(nd)]
            leaf_lo[t, i], leaf_mid[t, i], leaf_hi[t, i] = lo, mid, hi
            return i

        walk2(tree.root)
        n_nodes[t] = len(nodes)
        n_leaves_per_tree[t] = leaf_ctr
        if base_score and C == 1:
            leaf_value[t] += base_score / T

    return Forest(T, L, C, n_features, feature, threshold, left, right,
                  leaf_lo, leaf_mid, leaf_hi, leaf_value,
                  n_nodes, n_leaves_per_tree, max_depth=max_depth)


def from_random_forest(rf) -> Forest:
    return from_trees(rf.trees, rf.binner and len(rf.binner.edges) or 0,
                      rf.n_classes)


def from_gradient_boosting(gb) -> Forest:
    n_features = len(gb.binner.edges)
    if gb.cfg.objective == "softmax":
        return from_trees(gb.trees, n_features, gb.n_classes,
                          tree_class=gb.tree_class)
    return from_trees(gb.trees, n_features, 1, base_score=gb.base_score)


# --------------------------------------------------------------------------- #
# Random forests for throughput benchmarking (runtime is independent of the
# learned values; the paper's Table 2 sweeps up to 20k trees, which would be
# wasteful to *train* in CI).
# --------------------------------------------------------------------------- #
def random_forest_ir(n_trees: int, n_leaves: int, n_features: int,
                     n_classes: int = 1, seed: int = 0,
                     full: bool = True) -> Forest:
    rng = np.random.default_rng(seed)
    trees = []
    for _ in range(n_trees):
        trees.append(_random_tree(rng, n_leaves, n_features, n_classes, full))
    return from_trees(trees, n_features, n_classes)


def _random_tree(rng, n_leaves, n_features, n_classes, full) -> Tree:
    from ..trees.cart import Tree as TTree

    def build(n_leaf: int, depth: int):
        if n_leaf == 1:
            return TreeNode(value=rng.normal(0, 1, size=n_classes)), depth
        if full:
            nl = n_leaf // 2
        else:
            nl = int(rng.integers(1, n_leaf))
        l, dl = build(nl, depth + 1)
        r, dr = build(n_leaf - nl, depth + 1)
        nd = TreeNode(feature=int(rng.integers(0, n_features)),
                      threshold=float(rng.normal(0, 1)), left=l, right=r)
        return nd, max(dl, dr)

    root, depth = build(n_leaves, 1)
    return TTree(root, n_leaves, depth)
