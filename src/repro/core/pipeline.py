"""Pass-based forest compiler: canonicalize → quantize → layout → lower.

``compile_forest`` used to be an if/elif ladder and quantization an ad-hoc
mutation the caller had to remember.  This module restructures the
``Forest → predictor`` path into an explicit pass pipeline (docs/DESIGN.md
§3), the way InTreeger treats integer-only lowering and PACSET treats
layout as compiler stages:

  * **canonicalize** — accept a trainer (RandomForest / GradientBoosting),
    a list of CART trees, or an already-canonical ``Forest`` and produce
    the padded SoA IR (in-order leaves, preorder nodes — DESIGN.md §1).
  * **quantize**     — apply ``QuantSpec`` fixed-point lowering (paper §5)
    as a named pass; a no-op when the plan carries no spec or the forest
    is already quantized.
  * **optimize**     — the IR→IR optimizer middle-end (``repro.optim``,
    docs/OPTIM.md): ``plan.opt`` selects a level (-O0/-O1/-O2) or an
    explicit pass list; each optimizer pass records its before/after
    stats as its own ``PassRecord`` and the whole run is oracle-
    equivalence checked (bit-exact on quantized forests).
  * **layout**       — engine-aware memory-layout decisions: bitmm's leaf
    field packing (bits × npack) and tree-tile size, gemm's compute dtype —
    recorded on the plan so the autotuner can sweep them.
  * **lower**        — resolve the engine through ``core.registry`` and
    build the predictor; wraps it in tree-sharded multi-device execution
    (``core/shard.py``) when ``plan.n_devices > 1``.

Every pass appends a ``PassRecord`` to the ``CompilePlan``, so a compiled
predictor can always explain how it was built (``pred.plan.describe()``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from . import registry
from .forest import Forest, from_trees
from .quantize import QuantSpec, quantize_forest


@dataclass(frozen=True)
class PassRecord:
    name: str
    detail: str


@dataclass
class CompilePlan:
    """Declarative compile request + the record of what each pass did.

    ``engine_kw`` is forwarded to the engine's registered builder; passes
    may fill defaults into it (e.g. layout's ``tree_chunk``) but never
    override caller-provided values.
    """
    engine: str = "bitvector"
    backend: str = "jax"
    quant: Optional[QuantSpec] = None     # None → keep the forest's dtypes
    flint: bool = False                   # FLInt int32-key traversal pass
    opt: object = None                    # optim level (0/1/2, "O2") or
    #                                       pass-name tuple; None → O0
    n_devices: int = 1
    cascade: Optional[object] = None      # cascade.CascadeSpec → staged eval
    engine_kw: dict = field(default_factory=dict)
    records: list = field(default_factory=list)

    def record(self, name: str, detail: str) -> None:
        self.records.append(PassRecord(name, detail))

    def describe(self) -> str:
        return " → ".join(f"{r.name}[{r.detail}]" for r in self.records)


# --------------------------------------------------------------------------- #
# Pass registry
# --------------------------------------------------------------------------- #
PASSES: dict[str, Callable] = {}
PIPELINE = ("deserialize", "canonicalize", "quantize", "optimize",
            "flint", "layout", "lower")


def forest_pass(name: str):
    def deco(fn):
        PASSES[name] = fn
        return fn
    return deco


@forest_pass("deserialize")
def deserialize(obj, plan: CompilePlan, ctx: dict):
    """Entry pass: a path (str/PathLike to a model file) becomes an
    in-memory object via ``repro.io`` — XGBoost/LightGBM JSON dumps,
    sklearn-shim JSON, or a packed ``.repro.npz`` forest all compile with
    ``compile_plan("model.json", engine=...)``.  In-memory objects pass
    through untouched."""
    import os
    if not isinstance(obj, (str, os.PathLike)):
        plan.record("deserialize", "skipped (in-memory object)")
        return obj
    from .. import io
    path = os.fspath(obj)
    forest = io.load_model(path, **ctx.get("load_kw") or {})
    plan.record("deserialize", f"loaded {path}")
    return forest


@forest_pass("canonicalize")
def canonicalize(obj, plan: CompilePlan, ctx: dict) -> Forest:
    """Anything tree-shaped → canonical padded SoA ``Forest`` IR."""
    if isinstance(obj, Forest):
        forest = obj
        how = "already canonical"
    elif hasattr(obj, "cfg") and hasattr(obj.cfg, "objective"):
        from .forest import from_gradient_boosting
        forest = from_gradient_boosting(obj)
        how = "from GradientBoosting"
    elif hasattr(obj, "trees") and hasattr(obj, "n_classes"):
        from .forest import from_random_forest
        forest = from_random_forest(obj)
        how = "from RandomForest"
    elif isinstance(obj, (list, tuple)):
        forest = from_trees(list(obj), n_features=ctx["n_features"],
                            n_classes=ctx.get("n_classes", 1))
        how = f"from {len(obj)} trees"
    else:
        raise TypeError(f"cannot canonicalize {type(obj).__name__} into a "
                        "Forest (expected Forest, trainer, or tree list)")
    plan.record("canonicalize",
                f"{how}: T={forest.n_trees} L={forest.n_leaves} "
                f"C={forest.n_classes} d={forest.n_features} "
                f"depth={forest.max_depth}")
    return forest


@forest_pass("quantize")
def quantize(forest: Forest, plan: CompilePlan, ctx: dict) -> Forest:
    """Fixed-point lowering (paper §5) as a compilation stage."""
    if plan.quant is None:
        plan.record("quantize", "skipped (already quantized)"
                    if forest.quant_scale is not None
                    else "skipped (float forest)")
        return forest
    if forest.quant_scale is not None:
        plan.record("quantize", "skipped (already quantized)")
        return forest
    if plan.flint:
        raise ValueError("quant= and flint=True are mutually exclusive: "
                         "FLInt keys float thresholds, quantization "
                         "replaces them")
    qf = quantize_forest(forest, ctx.get("X_calib"), plan.quant)
    calib = "data" if ctx.get("X_calib") is not None else "thresholds"
    detail = (f"{plan.quant.bits}b scale={qf.quant_scale:g} "
              f"leaf_scale={qf.leaf_scale:g} calib={calib}")
    if qf.int_accum:
        detail += f" int_accum err_bound={qf.leaf_err_bound:g}"
    plan.record("quantize", detail)
    return qf


def _optimize_cached(forest: Forest, opt, opt_cache: Optional[dict],
                     X_calib=None):
    """Run (or reuse) the optimizer middle-end for one (forest, opt-tag)
    point.  ``opt_cache`` — a per-sweep dict keyed by ``(id(forest),
    tag)`` — is the shared-IR mechanism (docs/AUTOTUNE.md): within one
    autotune sweep the engine / layout / cascade axes all see the same
    optimized IR, so the optimize pass (and its oracle-equivalence
    check) runs once per (quant, opt) point instead of once per
    candidate.  Returns ``None`` when the level resolves to no passes."""
    from .. import optim
    names, tag = optim.resolve_opt(opt)
    if not names:
        return None
    key = (id(forest), tag)
    if opt_cache is not None and key in opt_cache:
        return opt_cache[key]
    res = optim.optimize(forest, opt, ctx={"X_calib": X_calib})
    if opt_cache is not None:
        opt_cache[key] = res
    return res


def optimized_forest(forest: Forest, opt,
                     opt_cache: Optional[dict] = None,
                     X_calib=None) -> Forest:
    """The IR the optimize pass would hand downstream for ``opt`` —
    through the same shared cache, so the autotuner's candidate pruning
    sees bit-identical objects to what the factories will compile."""
    res = _optimize_cached(forest, opt, opt_cache, X_calib)
    return forest if res is None else res.forest


@forest_pass("optimize")
def optimize(forest: Forest, plan: CompilePlan, ctx: dict) -> Forest:
    """The optimizer middle-end (``repro.optim``, docs/OPTIM.md): run
    the level / pass list named by ``plan.opt`` on the (possibly
    quantized) IR.  Each optimizer pass appends its own
    ``opt.<name>`` record with before/after node / unique-threshold
    stats, followed by one ``optimize`` summary record; the run is
    always oracle-equivalence checked (``optim.OptimizationError`` on
    divergence — never silently wrong scores).  When the ctx carries an
    ``opt_cache`` (autotune sweeps), the result is computed once per
    (forest, tag) point and replayed — records included — for every
    other candidate at that point."""
    from .. import optim
    names, tag = optim.resolve_opt(plan.opt)
    if not names:
        plan.record("optimize", f"skipped ({tag})")
        return forest
    res = _optimize_cached(forest, plan.opt, ctx.get("opt_cache"),
                           X_calib=ctx.get("X_calib"))
    for s in res.stats:
        plan.record(f"opt.{s.name}", s.detail())
    plan.record("optimize", res.describe())
    return res.forest


@forest_pass("flint")
def flint(forest: Forest, plan: CompilePlan, ctx: dict) -> Forest:
    """FLInt lowering (arXiv 2209.04181, docs/QUANT.md): reinterpret the
    float forest's ordered f32 thresholds as monotone int32 keys so every
    engine's ``x <= t`` compare runs on integers with zero quantization
    error.  Runs after the optimizer (which works on the plain float IR
    with straightforward oracle equivalence) and before layout."""
    if not plan.flint:
        plan.record("flint", "skipped (not requested)")
        return forest
    if forest.flint:
        plan.record("flint", "skipped (already FLInt-keyed)")
        return forest
    if forest.quant_scale is not None:
        raise ValueError("flint=True on a quantized forest: thresholds "
                         "are already integers (FLInt applies to float "
                         "forests)")
    if plan.backend == "pallas":
        raise ValueError(
            "FLInt is unsupported on the pallas backend: the kernel "
            "wrappers stage inputs through f32, which cannot represent "
            "int32 keys exactly (docs/QUANT.md)")
    from .quantize import flint_forest
    out = flint_forest(forest)
    plan.record("flint", "f32 thresholds → monotone int32 keys "
                         "(zero quantization error)")
    return out


@forest_pass("layout")
def layout(forest: Forest, plan: CompilePlan, ctx: dict) -> Forest:
    """Engine-aware memory-layout decisions, recorded on the plan.

    Layout belongs to the compiler, not the engine (PACSET): each
    registered engine may carry a ``layout`` hook that chooses packing /
    tiling defaults (written into ``plan.engine_kw`` — caller-provided
    values always win) and returns the recorded detail.  Engines without
    a hook use the IR's tree-major SoA as-is."""
    spec = registry.get(plan.engine, plan.backend)
    if spec.layout is not None:
        plan.record("layout", spec.layout(forest, plan))
    elif plan.backend == "pallas":
        plan.record("layout", "tree-major SoA, VMEM tiles")
    else:
        plan.record("layout", "tree-major SoA")
    return forest


@forest_pass("lower")
def lower(forest: Forest, plan: CompilePlan, ctx: dict):
    """Resolve the engine through the registry and build the predictor.

    With ``plan.cascade`` set, the forest is partitioned into tree-prefix
    stages and each stage lowers through the same engine builder; the
    cascade is recorded as its own plan stage (docs/CASCADE.md).
    ``CascadeSpec(fused=True)`` picks the fused predictor — one jitted
    computation instead of a per-stage host loop."""
    spec = registry.get(plan.engine, plan.backend)
    if plan.cascade is not None:
        if plan.n_devices > 1:
            raise ValueError(
                "cascade + tree-sharded execution is not supported "
                f"(n_devices={plan.n_devices}); pick one")
        from ..cascade import CascadePredictor, FusedCascadePredictor
        fused = bool(getattr(plan.cascade, "fused", False))
        cls = FusedCascadePredictor if fused else CascadePredictor
        pred = cls(forest, plan.cascade, engine=plan.engine,
                   backend=plan.backend, engine_kw=plan.engine_kw)
        plan.record("cascade", pred.describe())
        stage_note = f"{spec.tune_name} × {len(pred.stages)} cascade stages"
        plan.record("lower", stage_note + (" (fused)" if fused else ""))
        pred.plan = plan
        return pred
    if plan.n_devices > 1:
        if plan.backend != "jax":
            raise ValueError(
                f"tree-sharded execution (n_devices={plan.n_devices}) "
                f"supports the jax backend only, not {plan.backend!r}")
        from . import shard
        pred = shard.tree_sharded(forest, plan.engine,
                                  n_devices=plan.n_devices,
                                  **plan.engine_kw)
        plan.record("lower", f"{spec.tune_name} × {plan.n_devices} devices "
                             "(tree-sharded partial sums)")
    else:
        pred = spec.builder()(forest, **plan.engine_kw)
        plan.record("lower", f"{spec.tune_name} ({plan.engine}/{plan.backend})")
    pred.plan = plan
    return pred


def compile_plan(obj, plan: Optional[CompilePlan] = None, *,
                 X_calib: Optional[np.ndarray] = None,
                 n_features: Optional[int] = None, n_classes: int = 1,
                 load_kw: Optional[dict] = None,
                 opt_cache: Optional[dict] = None,
                 **plan_kw):
    """Run the full pipeline on ``obj`` (path / Forest / trainer / trees).

    Either pass a ``CompilePlan`` or keyword fields for one::

        pred = compile_plan(forest, engine="bitmm", quant=QuantSpec(16))
        pred = compile_plan("model.json", engine="bitvector")

    ``X_calib`` feeds the quantize pass's feature ranges; ``n_features`` /
    ``n_classes`` are only needed when ``obj`` is a bare tree list;
    ``load_kw`` forwards to ``io.load_model`` when ``obj`` is a path;
    ``opt_cache`` (a dict the caller owns, normally one per autotune
    sweep) lets repeated compiles of the same IR at the same opt level
    share one optimizer run — see ``_optimize_cached``.
    """
    if plan is None:
        plan = CompilePlan(**plan_kw)
    elif plan_kw:
        raise TypeError("pass either a CompilePlan or plan kwargs, not both")
    ctx = {"X_calib": X_calib, "n_features": n_features,
           "n_classes": n_classes, "load_kw": load_kw,
           "opt_cache": opt_cache}
    for name in PIPELINE:
        obj = PASSES[name](obj, plan, ctx)
    return obj
