"""Tree-sharded multi-device execution (docs/DESIGN.md §5).

Forest scoring is a sum over trees, so the natural multi-device layout is
**tree parallelism**: shard every per-tree compiled array across a 1-D
device mesh, evaluate the engine on each device's tree slice, and combine
the partial scores with a ``psum``.  Because every registered engine
compiles to a dataclass of tree-major arrays and exposes a pure
``evaluate(compiled, X)`` (see ``core/registry.py``), one generic wrapper
serves them all — no per-engine sharding code.

Mechanics:

  * the forest is padded with single-leaf zero-value trees to a multiple
    of the device count (they traverse to leaf 0 and contribute exactly
    0.0, so padding never changes the result);
  * the engine is compiled **once, globally** — static layout decisions
    (bitmm's field width, tree_chunk, gemm's Bvec) are identical on every
    device, which per-shard compilation could not guarantee;
  * compiled arrays whose leading axis is the tree axis get
    ``PartitionSpec("trees")``; everything else (unique-node tables,
    scalars, the host Forest) is replicated — the split is derived from
    the dataclass fields plus the spec's ``replicated`` names;
  * partial scores are exact under quantization: integer leaf sums divide
    by a power-of-two scale, so the psum reassociation is bitwise
    lossless and sharded == single-device.

Works on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see ``tests/test_shard.py``) and unchanged on real TPU meshes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.6 exports it at top level
    from jax import shard_map
except ImportError:                     # 0.4.x
    from jax.experimental.shard_map import shard_map

from . import registry
from .forest import Forest
from .quantize import quantize_inputs
from .registry import BasePredictor, ensure_feature_column


def pad_forest_trees(forest: Forest, mult: int) -> Forest:
    """Pad the ensemble with single-leaf zero trees to ``T % mult == 0``.

    A padding tree has no internal nodes and one leaf worth 0.0: every
    engine routes all instances to leaf 0 and adds nothing."""
    T = forest.n_trees
    pad = (-T) % mult
    if pad == 0:
        return forest

    def rows(a, fill=0):
        shape = (pad,) + a.shape[1:]
        return np.concatenate([a, np.full(shape, fill, dtype=a.dtype)])

    return replace(
        forest,
        n_trees=T + pad,
        feature=rows(forest.feature, -1),        # -1 → padding node
        threshold=rows(forest.threshold),
        left=rows(forest.left),
        right=rows(forest.right),
        leaf_lo=rows(forest.leaf_lo),
        leaf_mid=rows(forest.leaf_mid),
        leaf_hi=rows(forest.leaf_hi),
        leaf_value=rows(forest.leaf_value),
        n_nodes=rows(forest.n_nodes),
        n_leaves_per_tree=rows(forest.n_leaves_per_tree, 1),
    )


# --------------------------------------------------------------------------- #
# Generic compiled-dataclass partitioning
# --------------------------------------------------------------------------- #
def _partition(compiled, n_trees: int, replicated: tuple):
    """Split a compiled dataclass into (sharded, replicated, rebuild).

    Array fields with leading dim == n_trees are tree-sharded, other
    arrays replicated, non-array fields (ints, floats, the host Forest)
    baked in as statics.  Nested compiled dataclasses (CompiledRS.qs)
    recurse.  Returns flat dicts keyed by dotted field path and a
    ``rebuild(sharded, replicated)`` closure usable inside a trace."""
    sharded: dict = {}
    repl: dict = {}

    def walk(obj, prefix: str):
        cls = type(obj)
        statics = {}
        builders = {}
        for f in dataclasses.fields(obj):
            v = getattr(obj, f.name)
            path = f"{prefix}{f.name}"
            if isinstance(v, Forest) or not (
                    dataclasses.is_dataclass(v)
                    or isinstance(v, (jnp.ndarray, np.ndarray))):
                statics[f.name] = v
            elif dataclasses.is_dataclass(v):
                builders[f.name] = walk(v, path + ".")
            elif (v.ndim >= 1 and v.shape[0] == n_trees
                  and f.name not in replicated):
                sharded[path] = jnp.asarray(v)
            else:
                repl[path] = jnp.asarray(v)

        def build(sh, rp, _cls=cls, _statics=statics, _builders=builders,
                  _prefix=prefix):
            kw = dict(_statics)
            for name, sub in _builders.items():
                kw[name] = sub(sh, rp)
            for f in dataclasses.fields(_cls):
                path = f"{_prefix}{f.name}"
                if path in sharded:
                    kw[f.name] = sh[path]
                elif path in repl:
                    kw[f.name] = rp[path]
            return _cls(**kw)

        return build

    rebuild = walk(compiled, "")
    return sharded, repl, rebuild


class ShardedPredictor(BasePredictor):
    """Predictor running one engine tree-sharded over a device mesh."""

    def __init__(self, forest: Forest, spec, fn, sharded, repl,
                 n_devices: int):
        # BasePredictor.__init__ is bypassed: the jit'd fn closes over the
        # mesh, not a single compiled object.
        self.forest = forest
        self.engine = spec.name
        self.spec = spec
        self.n_devices = n_devices
        self._sharded = sharded
        self._repl = repl
        self._fn = fn

    def transform_inputs(self, X: np.ndarray) -> np.ndarray:
        return quantize_inputs(self.forest, np.asarray(X))

    def predict_transformed(self, Xq: np.ndarray) -> np.ndarray:
        Xq = ensure_feature_column(np.asarray(Xq))
        return np.asarray(self._fn(self._sharded, self._repl,
                                   jnp.asarray(Xq)))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.predict_transformed(self.transform_inputs(X))


def tree_sharded(forest: Forest, engine: str = "bitvector", *,
                 n_devices: Optional[int] = None, devices=None,
                 **engine_kw) -> ShardedPredictor:
    """Compile ``engine`` with its trees sharded across ``n_devices``.

    Wraps any registered XLA engine (``spec.shardable``); outputs are
    identical to the single-device predictor (bitwise on quantized
    forests — partial sums reassociate losslessly, see module docstring).
    """
    spec = registry.get(engine, "jax")
    if not spec.shardable:
        raise ValueError(
            f"engine {engine!r} is not shardable (registered engines that "
            f"are: {[s.name for s in registry.specs('jax') if s.shardable]})")
    devs = list(devices if devices is not None else jax.devices())
    D = int(n_devices) if n_devices is not None else len(devs)
    if D > len(devs):
        raise ValueError(f"n_devices={D} but only {len(devs)} devices "
                         "visible (on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    devs = devs[:D]

    padded = pad_forest_trees(forest, D)
    kw = dict(engine_kw)
    if spec.shard_kw is not None:
        for k, v in spec.shard_kw(padded, D).items():
            kw.setdefault(k, v)
    compiled = spec.compile(padded, **kw)
    sharded, repl, rebuild = _partition(compiled, padded.n_trees,
                                        spec.replicated)
    if not sharded:
        # e.g. a caller-forced bitmm tree_chunk that does not divide the
        # padded tree count re-pads inside compile — replicating those
        # arrays would silently double-count trees under psum
        raise ValueError(
            f"engine {engine!r}: no compiled array has the {padded.n_trees}"
            "-tree leading axis; refusing to shard")

    mesh = Mesh(np.asarray(devs), ("trees",))
    s_specs = jax.tree.map(lambda _: P("trees"), sharded)
    r_specs = jax.tree.map(lambda _: P(), repl)

    def _eval(sh, rp, X):
        local = rebuild(sh, rp)
        return jax.lax.psum(spec.evaluate(local, X), "trees")

    fn = jax.jit(shard_map(_eval, mesh=mesh,
                           in_specs=(s_specs, r_specs, P()),
                           out_specs=P()))
    # the quantization metadata lives on the *original* forest; padding
    # preserves it (dataclasses.replace), so transform_inputs matches
    return ShardedPredictor(padded, spec, fn, sharded, repl, D)
