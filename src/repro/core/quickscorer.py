"""QuickScorer / V-QuickScorer on TPU-style lanes — the paper's core.

``CompiledQS`` holds the flat QuickScorer arrays (feature ids, thresholds,
interval bitmasks, leaf table). ``eval_batch`` is the pure-jnp reference
evaluation used as the kernel oracle AND as the XLA engine; the Pallas kernel
in ``repro.kernels.quickscorer_kernel`` computes the same function with
explicit VMEM tiling.

Semantics (paper Algorithm 1, adapted per DESIGN.md §2.1):

  * every node carries a bitmask that clears its *left-subtree* leaf interval;
  * the mask is applied iff ``x[feat] > thr`` (the instance goes right, so
    the left subtree becomes unreachable);
  * the exit leaf is the lowest surviving set bit (LSB-first convention);
  * the prediction is a leaf-table lookup summed over trees.

The per-feature sorted early-``break`` of the CPU algorithm is replaced by
full predication (all nodes evaluated, masked select) — lockstep VPU lanes
make data-dependent early exit counterproductive. A faithful scalar QS with
the sorted-feature early exit is kept in ``eval_scalar_numpy`` for oracle
cross-checks and CPU-semantics benchmarking.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .forest import Forest, WORD
from .quantize import leaf_scale, quantize_inputs


@dataclass
class CompiledQS:
    """Flattened QuickScorer arrays (jnp, device-resident)."""
    feat: jnp.ndarray        # (T, N) int32, padding → 0
    thr: jnp.ndarray         # (T, N) f32 | i16 | i8
    valid: jnp.ndarray       # (T, N) bool
    masks: jnp.ndarray       # (T, N, W) uint32
    init_idx: jnp.ndarray    # (T, W) uint32
    leaf_val: jnp.ndarray    # (T, L, C) f32 | i32
    n_leaves: int
    n_classes: int
    n_features: int
    leaf_scale: float
    forest: Optional[Forest] = None   # host-side IR (for input quantization)

    @property
    def n_trees(self) -> int:
        return self.feat.shape[0]

    @property
    def n_words(self) -> int:
        return self.masks.shape[-1]

    def transform_inputs(self, X: np.ndarray) -> np.ndarray:
        return quantize_inputs(self.forest, X) if self.forest is not None else X


def compile_qs(forest: Forest) -> CompiledQS:
    masks = forest.node_masks()                       # (T, N, W) uint32
    valid = forest.feature >= 0
    return CompiledQS(
        feat=jnp.asarray(np.maximum(forest.feature, 0), dtype=jnp.int32),
        thr=jnp.asarray(forest.threshold),
        valid=jnp.asarray(valid),
        masks=jnp.asarray(masks),
        init_idx=jnp.asarray(forest.init_leafidx()),
        leaf_val=jnp.asarray(forest.leaf_value),
        n_leaves=forest.n_leaves,
        n_classes=forest.n_classes,
        n_features=forest.n_features,
        leaf_scale=leaf_scale(forest),
        forest=forest,
    )


# --------------------------------------------------------------------------- #
# Bit helpers (DESIGN.md §2.2)
# --------------------------------------------------------------------------- #
def ctz32(w: jnp.ndarray) -> jnp.ndarray:
    """Count-trailing-zeros of nonzero uint32: popcount((w & -w) - 1)."""
    w = w.astype(jnp.uint32)
    lsb = w & (jnp.uint32(0) - w)
    return jax.lax.population_count(lsb - jnp.uint32(1)).astype(jnp.int32)


def exit_leaf(leafidx: jnp.ndarray) -> jnp.ndarray:
    """leafidx (..., W) uint32 → lowest set bit index (...,) int32."""
    W = leafidx.shape[-1]
    nz = leafidx != 0
    first_w = jnp.argmax(nz, axis=-1)                           # (...,)
    w = jnp.take_along_axis(leafidx, first_w[..., None], axis=-1)[..., 0]
    return (first_w * WORD + ctz32(w)).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# Reference (pure-jnp) evaluation — also the XLA production engine
# --------------------------------------------------------------------------- #
def mask_reduce(cond: jnp.ndarray, masks: jnp.ndarray,
                init_idx: jnp.ndarray) -> jnp.ndarray:
    """cond (B, T, N) bool × masks (T, N, W) → leafidx (B, T, W).

    AND-reduction over the node axis with predication: nodes whose predicate
    is false contribute the identity mask (all ones)."""
    ones = jnp.uint32(0xFFFFFFFF)
    sel = jnp.where(cond[..., None], masks[None], ones)          # (B,T,N,W)
    red = jax.lax.reduce(sel, ones, jax.lax.bitwise_and, dimensions=(2,))
    return red & init_idx[None]


def eval_batch(qs: CompiledQS, X: jnp.ndarray) -> jnp.ndarray:
    """Full-batch QuickScorer: X (B, d) → scores (B, C). Pure jnp."""
    xf = X[:, qs.feat]                                          # (B, T, N)
    cond = (xf > qs.thr[None]) & qs.valid[None]
    leafidx = mask_reduce(cond, qs.masks, qs.init_idx)          # (B, T, W)
    leaf = exit_leaf(leafidx)                                   # (B, T)
    vals = jnp.take_along_axis(
        qs.leaf_val[None], leaf[..., None, None], axis=2)[:, :, 0]  # (B, T, C)
    acc_dtype = jnp.float32 if qs.leaf_val.dtype == jnp.float32 else jnp.int32
    score = vals.astype(acc_dtype).sum(axis=1)
    return score.astype(jnp.float32) / qs.leaf_scale


class QSPredictor:
    """User-facing engine wrapper: handles input quantization + jit cache."""

    def __init__(self, qs: CompiledQS):
        self.qs = qs
        self._fn = jax.jit(lambda X: eval_batch(self.qs, X))

    def predict(self, X: np.ndarray) -> np.ndarray:
        Xq = self.qs.transform_inputs(np.asarray(X))
        return np.asarray(self._fn(jnp.asarray(Xq)))

    def predict_class(self, X: np.ndarray) -> np.ndarray:
        return self.predict(X).argmax(axis=1)


# --------------------------------------------------------------------------- #
# Faithful scalar QuickScorer (paper Algorithm 1, with the sorted-threshold
# early exit) — numpy, used for oracle cross-checks and CPU-semantics bench.
# --------------------------------------------------------------------------- #
def build_feature_major(forest: Forest):
    """Feature-major node stream: for each feature, nodes sorted ascending by
    threshold — the order Algorithm 1 requires for its ``break``."""
    T, N = forest.feature.shape
    recs = []
    for t in range(T):
        for n in range(int(forest.n_nodes[t])):
            recs.append((int(forest.feature[t, n]),
                         float(forest.threshold[t, n]), t, n))
    recs.sort()
    feat = np.array([r[0] for r in recs], dtype=np.int32)
    thr = np.array([r[1] for r in recs], dtype=np.float64)
    tree = np.array([r[2] for r in recs], dtype=np.int32)
    node = np.array([r[3] for r in recs], dtype=np.int32)
    # feature segment boundaries
    starts = np.searchsorted(feat, np.arange(forest.n_features))
    ends = np.searchsorted(feat, np.arange(forest.n_features), side="right")
    return feat, thr, tree, node, starts, ends


def eval_scalar_numpy(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Algorithm 1 verbatim (per instance, early break per feature)."""
    feat, thr, tree, node, starts, ends = build_feature_major(forest)
    masks = forest.node_masks()
    init = forest.init_leafidx()
    W = forest.n_words
    out = np.zeros((X.shape[0], forest.n_classes))
    lv = forest.leaf_value.astype(np.float64)
    for i, x in enumerate(X):
        leafidx = init.copy()
        for f in range(forest.n_features):
            for j in range(starts[f], ends[f]):
                if x[f] > thr[j]:
                    leafidx[tree[j]] &= masks[tree[j], node[j]]
                else:
                    break                      # thresholds ascending
        # exit leaf: lowest set bit
        for t in range(forest.n_trees):
            leaf = 0
            for w in range(W):
                v = int(leafidx[t, w])
                if v:
                    leaf = w * WORD + (v & -v).bit_length() - 1
                    break
            out[i] += lv[t, leaf]
    return out / leaf_scale(forest)
