"""QuickScorer / V-QuickScorer on TPU-style lanes — the paper's core.

``CompiledQS`` holds the flat QuickScorer arrays (feature ids, thresholds,
interval bitmasks, leaf table). ``eval_batch`` is the pure-jnp reference
evaluation used as the kernel oracle AND as the XLA engine; the Pallas kernel
in ``repro.kernels.quickscorer_kernel`` computes the same function with
explicit VMEM tiling.

Semantics (paper Algorithm 1, adapted per DESIGN.md §2.1):

  * every node carries a bitmask that clears its *left-subtree* leaf interval;
  * the mask is applied iff ``x[feat] > thr`` (the instance goes right, so
    the left subtree becomes unreachable);
  * the exit leaf is the lowest surviving set bit (LSB-first convention);
  * the prediction is a leaf-table lookup summed over trees.

The per-feature sorted early-``break`` of the CPU algorithm is replaced by
full predication (all nodes evaluated, masked select) — lockstep VPU lanes
make data-dependent early exit counterproductive. A faithful scalar QS with
the sorted-feature early exit is kept in ``eval_scalar_numpy`` for oracle
cross-checks and CPU-semantics benchmarking.

``eval_batch_bitmm`` is the bit-matmul reformulation (DESIGN.md §2.4): the
predicated AND-reduction over the node axis is replaced by one batched
matmul ``cleared = cond @ clearbits`` so the dominant reduction runs on the
MXU (BLAS on CPU) instead of VPU AND-chains, and the ``(B, T, N, W)``
intermediate of ``mask_reduce`` is never materialised.  Per-leaf clear
*counts* are packed, several leaves per f32 mantissa lane, and the exit
leaf is recovered with the classic lowest-zero-field borrow trick — exact
for the *lowest* zero field, which is exactly QuickScorer's exit-leaf
semantics.  See ``compile_qs_bitmm`` for the layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .forest import Forest, WORD
from .quantize import accum_bits, leaf_scale, quantize_inputs
from .registry import BasePredictor, register_engine


def forest_acc_bits(forest: Forest) -> int:
    """Accumulator width an engine should compile for: 32 unless the
    forest opted into integer accumulation (``QuantSpec(int_accum=True)``)
    and its worst-case leaf sum provably fits int16 (``accum_bits`` — the
    compile-time no-overflow assertion, docs/QUANT.md)."""
    return accum_bits(forest) if forest.int_accum else 32


def acc_dtype_for(leaf_dtype, acc_bits: int):
    """Leaf storage dtype + compiled accumulator width → jnp accumulator
    dtype.  Float leaves always accumulate f32; integer leaves accumulate
    int32, narrowed to int16 only when the compile-time bound allows."""
    if leaf_dtype == jnp.float32:
        return jnp.float32
    return jnp.int16 if acc_bits == 16 else jnp.int32


@dataclass
class CompiledQS:
    """Flattened QuickScorer arrays (jnp, device-resident)."""
    feat: jnp.ndarray        # (T, N) int32, padding → 0
    thr: jnp.ndarray         # (T, N) f32 | i16 | i8
    valid: jnp.ndarray       # (T, N) bool
    masks: jnp.ndarray       # (T, N, W) uint32
    init_idx: jnp.ndarray    # (T, W) uint32
    leaf_val: jnp.ndarray    # (T, L, C) f32 | i32
    n_leaves: int
    n_classes: int
    n_features: int
    leaf_scale: float
    acc_bits: int = 32                # accumulator width (16 | 32)
    forest: Optional[Forest] = None   # host-side IR (for input quantization)

    @property
    def n_trees(self) -> int:
        return self.feat.shape[0]

    @property
    def n_words(self) -> int:
        return self.masks.shape[-1]

    def transform_inputs(self, X: np.ndarray) -> np.ndarray:
        return quantize_inputs(self.forest, X) if self.forest is not None else X


def compile_qs(forest: Forest) -> CompiledQS:
    masks = forest.node_masks()                       # (T, N, W) uint32
    valid = forest.feature >= 0
    return CompiledQS(
        feat=jnp.asarray(np.maximum(forest.feature, 0), dtype=jnp.int32),
        thr=jnp.asarray(forest.threshold),
        valid=jnp.asarray(valid),
        masks=jnp.asarray(masks),
        init_idx=jnp.asarray(forest.init_leafidx()),
        leaf_val=jnp.asarray(forest.leaf_value),
        n_leaves=forest.n_leaves,
        n_classes=forest.n_classes,
        n_features=forest.n_features,
        leaf_scale=leaf_scale(forest),
        acc_bits=forest_acc_bits(forest),
        forest=forest,
    )


# --------------------------------------------------------------------------- #
# Bit helpers (DESIGN.md §2.2)
# --------------------------------------------------------------------------- #
def ctz32(w: jnp.ndarray) -> jnp.ndarray:
    """Count-trailing-zeros of nonzero uint32: popcount((w & -w) - 1)."""
    w = w.astype(jnp.uint32)
    lsb = w & (jnp.uint32(0) - w)
    return jax.lax.population_count(lsb - jnp.uint32(1)).astype(jnp.int32)


def exit_leaf(leafidx: jnp.ndarray) -> jnp.ndarray:
    """leafidx (..., W) uint32 → lowest set bit index (...,) int32."""
    W = leafidx.shape[-1]
    nz = leafidx != 0
    first_w = jnp.argmax(nz, axis=-1)                           # (...,)
    w = jnp.take_along_axis(leafidx, first_w[..., None], axis=-1)[..., 0]
    return (first_w * WORD + ctz32(w)).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# Reference (pure-jnp) evaluation — also the XLA production engine
# --------------------------------------------------------------------------- #
def mask_reduce(cond: jnp.ndarray, masks: jnp.ndarray,
                init_idx: jnp.ndarray) -> jnp.ndarray:
    """cond (B, T, N) bool × masks (T, N, W) → leafidx (B, T, W).

    AND-reduction over the node axis with predication: nodes whose predicate
    is false contribute the identity mask (all ones)."""
    ones = jnp.uint32(0xFFFFFFFF)
    sel = jnp.where(cond[..., None], masks[None], ones)          # (B,T,N,W)
    red = jax.lax.reduce(sel, ones, jax.lax.bitwise_and, dimensions=(2,))
    return red & init_idx[None]


def eval_batch(qs: CompiledQS, X: jnp.ndarray) -> jnp.ndarray:
    """Full-batch QuickScorer: X (B, d) → scores (B, C). Pure jnp."""
    xf = X[:, qs.feat]                                          # (B, T, N)
    cond = (xf > qs.thr[None]) & qs.valid[None]
    leafidx = mask_reduce(cond, qs.masks, qs.init_idx)          # (B, T, W)
    leaf = exit_leaf(leafidx)                                   # (B, T)
    vals = jnp.take_along_axis(
        qs.leaf_val[None], leaf[..., None, None], axis=2)[:, :, 0]  # (B, T, C)
    acc_dtype = acc_dtype_for(qs.leaf_val.dtype, qs.acc_bits)
    # dtype= keeps the reduction itself in acc_dtype (sum would otherwise
    # widen int16 lanes back to int32 per numpy promotion rules)
    score = vals.astype(acc_dtype).sum(axis=1, dtype=acc_dtype)
    return score.astype(jnp.float32) / qs.leaf_scale


class QSPredictor(BasePredictor):
    """Bitvector-engine wrapper (shared base: quantization + jit cache)."""

    def __init__(self, qs: CompiledQS, eval_fn=None):
        super().__init__(qs, eval_fn or eval_batch)
        self.qs = qs


# --------------------------------------------------------------------------- #
# Bit-matmul QuickScorer (DESIGN.md §2.4) — MXU-resident mask reduction
# --------------------------------------------------------------------------- #
@dataclass
class CompiledBitMM:
    """Packed clear-count arrays for the bit-matmul engine.

    Layout: leaf ``l`` owns a ``bits``-wide field of packed word
    ``l // npack`` (field ``l % npack``, LSB-first).  ``packed[t, n, g]``
    holds node ``n``'s contribution to group ``g``: ``2^(bits*(l%npack))``
    summed over the leaves ``l`` of its clear interval ``[lo, mid)``.
    ``cond @ packed`` therefore accumulates, per leaf field, the number of
    firing ancestors that clear that leaf — exact in f32 because every
    packed word stays below 2^24.  ``bias`` marks padding leaves
    (``l >= n_leaves_per_tree``) as permanently cleared.
    """
    feat: jnp.ndarray        # (Tp, N) int32, padding → 0
    thr: jnp.ndarray         # (Tp, N) f32 | i16 | i8
    valid: jnp.ndarray       # (Tp, N) bool
    packed: jnp.ndarray      # (Tp, N, G) f32 packed clear-count weights
    bias: jnp.ndarray        # (Tp, G) f32 padding-leaf fields (always on)
    leaf_val: jnp.ndarray    # (Tp, L, C) f32 | i32
    bits: int                # field width (holds max clear count)
    npack: int               # leaves per packed word (bits * npack <= 24)
    n_leaves: int
    n_classes: int
    n_features: int
    n_trees: int             # real tree count (Tp >= n_trees is padded)
    tree_chunk: int          # scan tile size over the tree axis
    leaf_scale: float
    acc_bits: int = 32       # accumulator width (16 | 32)
    forest: Optional[Forest] = None

    @property
    def n_groups(self) -> int:
        return self.packed.shape[-1]

    def transform_inputs(self, X: np.ndarray) -> np.ndarray:
        return quantize_inputs(self.forest, X) if self.forest is not None else X


def bitmm_full_word(bits: int, npack: int) -> int:
    """Packed word with every field set to 1 — 'all leaves cleared'.  Used
    for padding-tree bias rows; as a uint32 it is also the borrow-trick
    low mask.  Single source of truth for the field layout."""
    return sum(1 << (bits * i) for i in range(npack))


def bitmm_field_layout(forest: Forest) -> tuple[int, int]:
    """Leaf-packing layout for the bit-matmul engine: (bits, npack).

    ``bits`` is sized from the forest's maximum per-leaf clear count (how
    many ancestors can clear one leaf), ``npack = 24 // bits`` leaves share
    one f32 word.  Exposed separately so the compiler's layout pass
    (``core/pipeline.py``) can record the decision."""
    T, L, N = forest.n_trees, forest.n_leaves, forest.nodes_per_tree
    valid = forest.feature >= 0
    lo = np.where(valid, forest.leaf_lo, 0)
    mid = np.where(valid, forest.leaf_mid, 0)
    # per-leaf clear counts via a difference array → field width
    diff = np.zeros((T, L + 1), dtype=np.int64)
    t_idx = np.repeat(np.arange(T), N)[valid.ravel()]
    np.add.at(diff, (t_idx, lo.ravel()[valid.ravel()]), 1)
    np.add.at(diff, (t_idx, mid.ravel()[valid.ravel()]), -1)
    counts = np.cumsum(diff[:, :L], axis=1)
    field_max = max(int(counts.max(initial=0)), 1)   # bias fields hold 1
    bits = max(int(np.ceil(np.log2(field_max + 1))), 1)
    npack = max(24 // bits, 1)
    return bits, npack


def bitmm_auto_chunk(n_trees: int, nodes_per_tree: int) -> int:
    """Default tree-tile size: ~16k nodes per scan tile."""
    return min(n_trees, max(1, 16384 // max(nodes_per_tree, 1)))


def bitmm_pack_arrays(forest: Forest):
    """Host-side packed clearbits: returns (packed (T,N,G) f32,
    bias (T,G) f32, bits, npack).  Shared by the XLA engine and the Pallas
    kernel wrapper."""
    T, L, N = forest.n_trees, forest.n_leaves, forest.nodes_per_tree
    valid = forest.feature >= 0
    lo = np.where(valid, forest.leaf_lo, 0)
    mid = np.where(valid, forest.leaf_mid, 0)
    bits, npack = bitmm_field_layout(forest)
    G = (L + npack - 1) // npack
    Lp = G * npack

    # packed interval weights via cumulative per-group weight table:
    # CW[l, g] = sum of 2^(bits*(l'%npack)) over l' < l with l'//npack == g,
    # so a node's row is CW[mid] - CW[lo].
    w = np.power(2.0, bits * (np.arange(Lp) % npack))
    gid = np.arange(Lp) // npack
    CW = np.zeros((Lp + 1, G))
    np.add.at(CW, (np.arange(Lp) + 1, gid), w)
    CW = np.cumsum(CW, axis=0)
    packed = (CW[mid] - CW[lo]) * valid[..., None]            # (T, N, G)
    bias = CW[Lp][None] - CW[forest.n_leaves_per_tree]        # (T, G)
    return packed.astype(np.float32), bias.astype(np.float32), bits, npack


def compile_qs_bitmm(forest: Forest,
                     tree_chunk: Optional[int] = None) -> CompiledBitMM:
    """Compile the bit-matmul engine.  ``tree_chunk`` bounds peak memory:
    evaluation scans over tiles of that many trees (auto: ~16k nodes per
    tile, so 1024-tree forests never materialise a full (B, T, ·) buffer)."""
    T, N = forest.n_trees, forest.nodes_per_tree
    packed, bias, bits, npack = bitmm_pack_arrays(forest)
    G = packed.shape[-1]
    if tree_chunk is None:
        tree_chunk = bitmm_auto_chunk(T, N)
    tree_chunk = max(1, min(tree_chunk, T))
    # rebalance so the last tile is nearly full (pad < n_chunks trees)
    n_chunks = -(-T // tree_chunk)
    tree_chunk = -(-T // n_chunks)
    pad = n_chunks * tree_chunk - T

    feat = np.maximum(forest.feature, 0).astype(np.int32)
    valid = forest.feature >= 0
    thr = forest.threshold
    leaf_val = forest.leaf_value
    if pad:
        # padding trees: no valid nodes, every leaf field biased "cleared"
        # → no survivor → leaf 0 → all-zero leaf row → contributes nothing.
        feat = np.concatenate([feat, np.zeros((pad, N), np.int32)])
        thr = np.concatenate([thr, np.zeros((pad, N), thr.dtype)])
        valid = np.concatenate([valid, np.zeros((pad, N), bool)])
        packed = np.concatenate([packed, np.zeros((pad, N, G), np.float32)])
        full = np.float32(bitmm_full_word(bits, npack))
        bias = np.concatenate([bias, np.full((pad, G), full, np.float32)])
        leaf_val = np.concatenate(
            [leaf_val, np.zeros((pad,) + leaf_val.shape[1:],
                                leaf_val.dtype)])
    return CompiledBitMM(
        feat=jnp.asarray(feat), thr=jnp.asarray(thr),
        valid=jnp.asarray(valid), packed=jnp.asarray(packed),
        bias=jnp.asarray(bias), leaf_val=jnp.asarray(leaf_val),
        bits=bits, npack=npack, n_leaves=forest.n_leaves,
        n_classes=forest.n_classes, n_features=forest.n_features,
        n_trees=T, tree_chunk=tree_chunk, leaf_scale=leaf_scale(forest),
        acc_bits=forest_acc_bits(forest), forest=forest,
    )


def bitmm_exit_leaf(words: jnp.ndarray, *, bits: int, npack: int,
                    n_leaves: int) -> jnp.ndarray:
    """Packed clear-count words (..., G) f32 → exit leaf (...,) int32.

    Lowest-zero-field borrow trick: ``(v - lo) & ~v & hi`` flags the high
    bit of every zero field; borrows only corrupt flags *above* the lowest
    genuine zero, so the least-significant set bit is always the true first
    surviving leaf of the word.  Pure jnp — shared by the XLA engine and
    the Pallas kernel.  Rows with no survivor (padding trees) map to 0."""
    G = words.shape[-1]
    lo_mask = jnp.uint32(bitmm_full_word(bits, npack))
    hi_mask = jnp.uint32(bitmm_full_word(bits, npack) << (bits - 1))
    v = words.astype(jnp.uint32)
    t = (v - lo_mask) & ~v & hi_mask
    lsb = t & (jnp.uint32(0) - t)
    fidx = (jax.lax.population_count(lsb - jnp.uint32(1))
            // jnp.uint32(bits)).astype(jnp.int32)
    big = jnp.int32(G * npack + 1)
    giota = jax.lax.broadcasted_iota(jnp.int32, words.shape, words.ndim - 1)
    cand = jnp.where(t != jnp.uint32(0), giota * npack + fidx, big)
    leaf = cand.min(axis=-1)
    return jnp.where(leaf < n_leaves, leaf, 0)


def _bitmm_tile(bm: CompiledBitMM, X: jnp.ndarray, feat, thr, valid,
                packed, bias, lv, acc_dtype) -> jnp.ndarray:
    """Score one tile of trees: X (B, d) × tile arrays → (B, C) partial."""
    xf = X.T[feat]                                        # (Tc, N, B)
    cond = (xf > thr[..., None]) & valid[..., None]
    condT = jnp.transpose(cond, (0, 2, 1)).astype(jnp.float32)   # (Tc, B, N)
    # HIGHEST precision: packed words are exact integers up to 2^23 and a
    # TPU's default bf16 multiplies would truncate them (CPU f32 is exact
    # either way, so CI can't catch the downgrade).
    cleared = jax.lax.dot_general(
        condT, packed, (((2,), (1,)), ((0,), (0,))),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)               # (Tc, B, G) MXU
    words = cleared + bias[:, None, :]
    leaf = bitmm_exit_leaf(words, bits=bm.bits, npack=bm.npack,
                           n_leaves=bm.n_leaves).T        # (B, Tc)
    vals = jnp.take_along_axis(
        lv[None], leaf[..., None, None], axis=2)[:, :, 0]  # (B, Tc, C)
    return vals.astype(acc_dtype).sum(axis=1, dtype=acc_dtype)


def eval_batch_bitmm(bm: CompiledBitMM, X: jnp.ndarray) -> jnp.ndarray:
    """Bit-matmul QuickScorer: X (B, d) → scores (B, C).

    Tree-chunked: a ``lax.scan`` over tiles of ``bm.tree_chunk`` trees keeps
    peak memory at O(B × tree_chunk × max(N, G)) regardless of forest size."""
    B = X.shape[0]
    Tp, N = bm.feat.shape
    G = bm.n_groups
    acc_dtype = acc_dtype_for(bm.leaf_val.dtype, bm.acc_bits)
    nc = Tp // bm.tree_chunk
    if nc <= 1:
        score = _bitmm_tile(bm, X, bm.feat, bm.thr, bm.valid, bm.packed,
                            bm.bias, bm.leaf_val, acc_dtype)
    else:
        Tc = bm.tree_chunk
        tiles = (bm.feat.reshape(nc, Tc, N), bm.thr.reshape(nc, Tc, N),
                 bm.valid.reshape(nc, Tc, N),
                 bm.packed.reshape(nc, Tc, N, G),
                 bm.bias.reshape(nc, Tc, G),
                 bm.leaf_val.reshape((nc, Tc) + bm.leaf_val.shape[1:]))

        def body(acc, tile):
            feat, thr, valid, packed, bias, lv = tile
            return acc + _bitmm_tile(bm, X, feat, thr, valid, packed,
                                     bias, lv, acc_dtype), None

        score, _ = jax.lax.scan(
            body, jnp.zeros((B, bm.n_classes), acc_dtype), tiles)
    return score.astype(jnp.float32) / bm.leaf_scale


class BitMMPredictor(BasePredictor):
    """Bit-matmul engine wrapper (shared base: quantization + jit cache)."""

    def __init__(self, bm: CompiledBitMM, eval_fn=None):
        super().__init__(bm, eval_fn or eval_batch_bitmm)
        self.bm = bm


# --------------------------------------------------------------------------- #
# Faithful scalar QuickScorer (paper Algorithm 1, with the sorted-threshold
# early exit) — numpy, used for oracle cross-checks and CPU-semantics bench.
# --------------------------------------------------------------------------- #
def build_feature_major(forest: Forest):
    """Feature-major node stream: for each feature, nodes sorted ascending by
    threshold — the order Algorithm 1 requires for its ``break``."""
    T, N = forest.feature.shape
    recs = []
    for t in range(T):
        for n in range(int(forest.n_nodes[t])):
            recs.append((int(forest.feature[t, n]),
                         float(forest.threshold[t, n]), t, n))
    recs.sort()
    feat = np.array([r[0] for r in recs], dtype=np.int32)
    thr = np.array([r[1] for r in recs], dtype=np.float64)
    tree = np.array([r[2] for r in recs], dtype=np.int32)
    node = np.array([r[3] for r in recs], dtype=np.int32)
    # feature segment boundaries
    starts = np.searchsorted(feat, np.arange(forest.n_features))
    ends = np.searchsorted(feat, np.arange(forest.n_features), side="right")
    return feat, thr, tree, node, starts, ends


def eval_scalar_numpy(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Algorithm 1 verbatim (per instance, early break per feature)."""
    feat, thr, tree, node, starts, ends = build_feature_major(forest)
    masks = forest.node_masks()
    init = forest.init_leafidx()
    W = forest.n_words
    out = np.zeros((X.shape[0], forest.n_classes))
    lv = forest.leaf_value.astype(np.float64)
    for i, x in enumerate(X):
        leafidx = init.copy()
        for f in range(forest.n_features):
            for j in range(starts[f], ends[f]):
                if x[f] > thr[j]:
                    leafidx[tree[j]] &= masks[tree[j], node[j]]
                else:
                    break                      # thresholds ascending
        # exit leaf: lowest set bit
        for t in range(forest.n_trees):
            leaf = 0
            for w in range(W):
                v = int(leafidx[t, w])
                if v:
                    leaf = w * WORD + (v & -v).bit_length() - 1
                    break
            out[i] += lv[t, leaf]
    return out / leaf_scale(forest)


# --------------------------------------------------------------------------- #
# Registry entries (docs/DESIGN.md §4)
# --------------------------------------------------------------------------- #
def _bitmm_layout(forest: Forest, plan) -> str:
    """Pipeline layout hook: pick the leaf packing + tree tiling."""
    bits, npack = bitmm_field_layout(forest)
    if plan.n_devices > 1:
        # the tile size must divide the per-shard tree count — that is
        # _bitmm_shard_kw's call, made after the forest is device-padded
        return f"leaf-pack {bits}b×{npack}, tree_chunk=per-shard"
    plan.engine_kw.setdefault(
        "tree_chunk", bitmm_auto_chunk(forest.n_trees,
                                       forest.nodes_per_tree))
    return (f"leaf-pack {bits}b×{npack}, "
            f"tree_chunk={plan.engine_kw['tree_chunk']}")


def bitmm_pallas_layout(forest: Forest, plan) -> str:
    """Layout hook for the Pallas bitmm backend (tiling is block_* kw)."""
    bits, npack = bitmm_field_layout(forest)
    return f"leaf-pack {bits}b×{npack}, VMEM tiles"


def _bitmm_shard_kw(forest: Forest, n_shards: int) -> dict:
    """Tree-sharded bitmm needs a ``tree_chunk`` that divides the per-shard
    tree count, so every device reshapes its local tile stack the same way
    (the forest is already padded to a multiple of ``n_shards``)."""
    local = forest.n_trees // n_shards
    target = max(1, min(local, bitmm_auto_chunk(forest.n_trees,
                                                forest.nodes_per_tree)))
    chunk = max(d for d in range(1, target + 1) if local % d == 0)
    return {"tree_chunk": chunk}


register_engine(
    "bitvector", tune_name="qs", compile=compile_qs, evaluate=eval_batch,
    predictor_cls=QSPredictor, shardable=True,
    serial_arrays=("feat", "thr", "valid", "masks", "init_idx", "leaf_val"),
    doc="QuickScorer: predicated interval-mask AND-reduction over nodes")
register_engine(
    "bitmm", tune_name="qs-bitmm", compile=compile_qs_bitmm,
    evaluate=eval_batch_bitmm, predictor_cls=BitMMPredictor,
    shardable=True, shard_kw=_bitmm_shard_kw, layout=_bitmm_layout,
    serial_arrays=("feat", "thr", "valid", "packed", "bias", "leaf_val"),
    doc="bit-matmul QuickScorer: packed clear-count GEMM on the MXU")
