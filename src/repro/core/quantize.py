"""Fixed-point quantization of tree ensembles (paper §5) + the integer
end-to-end extensions (docs/QUANT.md).

``q(x) = floor(s * x)`` with scaling constant ``s`` (paper default 2^15),
applied to split thresholds and/or leaf values, stored in ``bits``-wide
integers. Inputs are quantized with the same ``s`` at inference time, so the
split predicate ``x <= t`` becomes ``floor(s x) <= floor(s t)``.

Because raw features have arbitrary ranges (the paper's datasets do too), a
per-feature order-preserving min-max normalisation to [0, 1) is applied
*before* quantization; it changes no float prediction (monotone per feature)
but makes the fixed-point grid meaningful. Heavy-tailed features (EEG) get
their threshold mass compressed by this — exactly the failure mode the paper
observes in Tables 3/4.

Two integer paths extend the paper's scheme (docs/QUANT.md):

  * ``QuantSpec(int_accum=True)`` — InTreeger-style (arXiv 2505.15391)
    integer end-to-end: quantized leaves carry a tracked worst-case error
    bound (``Forest.leaf_err_bound``) and engines accumulate in the
    narrowest integer dtype that provably cannot overflow
    (``accum_bits`` — asserted at compile time, not checked at runtime).
  * ``flint_forest`` — FLInt-style (arXiv 2209.04181) reinterpretation of
    ordered f32 thresholds/inputs as monotone int32 keys, so *float*
    forests traverse with integer compares and zero quantization error.

In the compile pipeline this is the ``quantize`` pass
(``core/pipeline.py``): pass ``quant=QuantSpec(...)`` to
``core.compile_plan`` instead of mutating the forest by hand, and the
autotuner sweeps it as the ``<engine>@q<bits>`` candidate axis (the FLInt
path is the ``flint`` pass / ``<engine>@flint`` axis).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

from .forest import Forest


@dataclass(frozen=True)
class QuantSpec:
    bits: int = 16                 # 16 (paper) or 8 (beyond-paper)
    scale: Optional[float] = None  # None → 2^(bits-1) for splits
    quantize_splits: bool = True
    quantize_leaves: bool = True
    int_accum: bool = False        # engines accumulate leaves as integers

    @property
    def default_scale(self) -> float:
        return float(2 ** (self.bits - 1))

    @property
    def int_max(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def dtype(self):
        return np.int16 if self.bits == 16 else np.int8


def feature_ranges(forest: Forest, X: Optional[np.ndarray] = None):
    """Per-feature (lo, hi) for min-max normalisation: from data if given,
    else from the forest's own thresholds.

    Non-finite calibration entries (NaN/±inf sensor rows) are masked out
    per column rather than poisoning the range: a single NaN row would
    otherwise propagate through ``min``/``max`` into ``feat_lo``/``feat_hi``
    and make every normalized input NaN with no error raised."""
    d = forest.n_features
    if X is not None:
        Xf = np.asarray(X, dtype=np.float64)
        finite = np.isfinite(Xf)
        if finite.all():
            lo, hi = Xf.min(axis=0), Xf.max(axis=0)
        else:
            lo = np.where(finite, Xf, np.inf).min(axis=0)
            hi = np.where(finite, Xf, -np.inf).max(axis=0)
            # columns with no finite calibration value at all
            lo[~np.isfinite(lo)] = 0.0
            hi[~np.isfinite(hi)] = 1.0
    else:
        lo = np.full(d, np.inf)
        hi = np.full(d, -np.inf)
        valid = forest.feature >= 0
        for t in range(forest.n_trees):
            for n in np.nonzero(valid[t])[0]:
                f = forest.feature[t, n]
                v = forest.threshold[t, n]
                lo[f] = min(lo[f], v)
                hi[f] = max(hi[f], v)
        lo[~np.isfinite(lo)] = 0.0
        hi[~np.isfinite(hi)] = 1.0
    span = hi - lo
    hi = np.where(span <= 0, lo + 1.0, hi)
    return lo, hi


def normalize_features(X: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    return np.clip((X - lo) / (hi - lo), 0.0, 1.0)


def quantize_forest(forest: Forest, X: Optional[np.ndarray] = None,
                    spec: QuantSpec = QuantSpec()) -> Forest:
    """Return a new Forest with int thresholds / leaves per ``spec``.

    The returned forest's ``predict_oracle``/engines require inputs passed
    through ``quantize_inputs`` — engine wrappers do this automatically via
    the stored ``feat_lo``/``feat_hi``/``quant_scale``."""
    assert forest.quant_scale is None, "forest already quantized"
    assert not forest.flint, "FLInt forests carry no quantization grid"
    if spec.int_accum and not spec.quantize_leaves:
        raise ValueError("QuantSpec(int_accum=True) requires quantized "
                         "leaves (quantize_leaves=True)")
    if X is not None and forest.feat_map is not None:
        # optimized forest (repro.optim drop_unused_features): calibration
        # rows are full-width; the per-feature ranges must align with the
        # IR's remapped columns
        X = np.asarray(X)[:, np.asarray(forest.feat_map, dtype=np.int64)]
    lo, hi = feature_ranges(forest, X)
    s = spec.scale if spec.scale is not None else spec.default_scale
    out = replace(forest)

    if spec.quantize_splits:
        tn = normalize_features(forest.threshold.astype(np.float64),
                                lo[np.maximum(forest.feature, 0)],
                                hi[np.maximum(forest.feature, 0)])
        q = np.clip(np.floor(s * tn), -spec.int_max - 1, spec.int_max)
        out.threshold = q.astype(spec.dtype)

    if spec.quantize_leaves:
        if not np.isfinite(forest.leaf_value).all():
            # NaN would silently skip the shrink loop (NaN > x is False)
            # and floor to garbage — reject loudly instead
            raise ValueError("leaf values contain NaN/inf — cannot "
                             "quantize leaves")
        max_abs = float(np.abs(forest.leaf_value).max()) or 1.0
        # paper: s in [M, 2^B]; auto-shrink for GBT leaves that exceed 1.0.
        # Keep shrinking until every quantized leaf fits ±int_max — the old
        # "stop at s_leaf <= 2" floor let floor(s*leaf) wrap on astype for
        # large leaves, silently corrupting predictions.
        s_leaf = s
        while s_leaf * max_abs > spec.int_max:
            s_leaf /= 2.0
        q = np.clip(np.floor(s_leaf * forest.leaf_value),
                    -spec.int_max - 1, spec.int_max)
        out.leaf_value = q.astype(np.int32 if spec.bits == 16 else np.int16)
        out.leaf_scale = s_leaf
        # worst-case |float leaf sum − descaled int sum| under identical
        # traversal: per-tree floor error is in [0, 1/s_leaf)
        out.leaf_err_bound = forest.n_trees / s_leaf

    out.int_accum = bool(spec.int_accum)
    out.quant_scale = s
    out.quant_bits = spec.bits
    out.feat_lo = lo
    out.feat_hi = hi
    return out


def accum_bits(forest: Forest) -> int:
    """Narrowest accumulator width (16 or 32) that provably cannot
    overflow when summing this forest's quantized leaves.

    The bound is structural — Σ_t max|leaf_t| per class — so the check
    runs once at compile time; there is no runtime overflow path by
    construction.  Raises ``ValueError`` if even int32 cannot hold the
    worst case (> 65 k trees at full 16-bit leaf magnitude — the caller
    must fall back to float accumulation)."""
    lv = forest.leaf_value
    if not np.issubdtype(lv.dtype, np.integer):
        raise ValueError("accum_bits needs integer leaves — quantize with "
                         "QuantSpec(quantize_leaves=True) first")
    worst = int(np.abs(lv.astype(np.int64)).max(axis=(1, 2)).sum()) \
        if lv.size else 0
    if worst <= np.iinfo(np.int16).max:
        return 16
    if worst <= np.iinfo(np.int32).max:
        return 32
    raise ValueError(
        f"worst-case leaf sum {worst} overflows int32 — integer "
        "accumulation is unsound for this forest (use float leaves or a "
        "smaller leaf scale)")


# --------------------------------------------------------------------------- #
# FLInt: ordered-float → int32 key reinterpretation (arXiv 2209.04181)
# --------------------------------------------------------------------------- #
def flint_key(x: np.ndarray) -> np.ndarray:
    """Map f32 values to int32 keys preserving total order, so the split
    predicate ``x <= t`` holds on keys iff it holds on floats.

    The map is the standard sign-flip on the raw bit pattern
    (``b ^ ((b >> 31) & 0x7fffffff)``): non-negative floats keep their
    (already ordered) bits, negative floats get their magnitude bits
    inverted so more-negative sorts lower; -0.0 lands just below +0.0.
    NaN canonicalizes to INT32_MAX — above every threshold key (+inf
    keys at 0x7f800000), so NaN inputs always traverse right, matching
    float semantics (``NaN <= t`` is False)."""
    xf = np.ascontiguousarray(np.asarray(x, dtype=np.float32))
    b = xf.view(np.int32)
    key = b ^ ((b >> 31) & np.int32(0x7FFFFFFF))
    return np.where(np.isnan(xf), np.int32(np.iinfo(np.int32).max), key)


def flint_forest(forest: Forest) -> Forest:
    """Return a new Forest whose f32 thresholds are replaced by their
    FLInt int32 keys (``Forest.flint`` set); ``quantize_inputs`` then
    keys raw inputs the same way, and every engine's ``x <= t`` compare
    runs on integers with **zero** quantization error — traversal
    decisions are bit-identical to the float forest's."""
    assert forest.quant_scale is None, \
        "FLInt applies to float forests (quantized thresholds are " \
        "already integers)"
    assert not forest.flint, "forest already FLInt-keyed"
    out = replace(forest)
    out.threshold = flint_key(forest.threshold)
    out.flint = True
    return out


def quantize_inputs(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Apply the forest's stored input transform to raw full-width rows:
    the optimizer's column remap (``feat_map``, if the
    ``drop_unused_features`` pass ran) followed by normalisation +
    fixed-point grid (quantized forests) or the FLInt key map (flint
    forests).  No-op for float forests without a remap."""
    if forest.feat_map is not None:
        X = np.asarray(X)[:, np.asarray(forest.feat_map, dtype=np.int64)]
    if forest.flint:
        return flint_key(X)
    if forest.quant_scale is None:
        return X
    if not np.issubdtype(forest.threshold.dtype, np.integer):
        # leaves-only quantization: splits still float → inputs stay raw
        return X
    Xn = normalize_features(X, forest.feat_lo, forest.feat_hi)
    q = np.floor(forest.quant_scale * Xn)
    imax = 2 ** (forest.quant_bits - 1) - 1
    return np.clip(q, -imax - 1, imax).astype(forest.threshold.dtype)


def leaf_scale(forest: Forest) -> float:
    """Descaling factor for quantized leaf accumulations (1.0 if float)."""
    return float(getattr(forest, "leaf_scale", 1.0)
                 if np.issubdtype(forest.leaf_value.dtype, np.integer) else 1.0)
