"""Fixed-point quantization of tree ensembles (paper §5).

``q(x) = floor(s * x)`` with scaling constant ``s`` (paper default 2^15),
applied to split thresholds and/or leaf values, stored in ``bits``-wide
integers. Inputs are quantized with the same ``s`` at inference time, so the
split predicate ``x <= t`` becomes ``floor(s x) <= floor(s t)``.

Because raw features have arbitrary ranges (the paper's datasets do too), a
per-feature order-preserving min-max normalisation to [0, 1) is applied
*before* quantization; it changes no float prediction (monotone per feature)
but makes the fixed-point grid meaningful. Heavy-tailed features (EEG) get
their threshold mass compressed by this — exactly the failure mode the paper
observes in Tables 3/4.

In the compile pipeline this is the ``quantize`` pass
(``core/pipeline.py``): pass ``quant=QuantSpec(...)`` to
``core.compile_plan`` instead of mutating the forest by hand, and the
autotuner sweeps it as the ``<engine>@q<bits>`` candidate axis.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

from .forest import Forest


@dataclass(frozen=True)
class QuantSpec:
    bits: int = 16                 # 16 (paper) or 8 (beyond-paper)
    scale: Optional[float] = None  # None → 2^(bits-1) for splits
    quantize_splits: bool = True
    quantize_leaves: bool = True

    @property
    def default_scale(self) -> float:
        return float(2 ** (self.bits - 1))

    @property
    def int_max(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def dtype(self):
        return np.int16 if self.bits == 16 else np.int8


def feature_ranges(forest: Forest, X: Optional[np.ndarray] = None):
    """Per-feature (lo, hi) for min-max normalisation: from data if given,
    else from the forest's own thresholds."""
    d = forest.n_features
    if X is not None:
        lo, hi = X.min(axis=0).astype(np.float64), X.max(axis=0).astype(np.float64)
    else:
        lo = np.full(d, np.inf)
        hi = np.full(d, -np.inf)
        valid = forest.feature >= 0
        for t in range(forest.n_trees):
            for n in np.nonzero(valid[t])[0]:
                f = forest.feature[t, n]
                v = forest.threshold[t, n]
                lo[f] = min(lo[f], v)
                hi[f] = max(hi[f], v)
        lo[~np.isfinite(lo)] = 0.0
        hi[~np.isfinite(hi)] = 1.0
    span = hi - lo
    hi = np.where(span <= 0, lo + 1.0, hi)
    return lo, hi


def normalize_features(X: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    return np.clip((X - lo) / (hi - lo), 0.0, 1.0)


def quantize_forest(forest: Forest, X: Optional[np.ndarray] = None,
                    spec: QuantSpec = QuantSpec()) -> Forest:
    """Return a new Forest with int thresholds / leaves per ``spec``.

    The returned forest's ``predict_oracle``/engines require inputs passed
    through ``quantize_inputs`` — engine wrappers do this automatically via
    the stored ``feat_lo``/``feat_hi``/``quant_scale``."""
    assert forest.quant_scale is None, "forest already quantized"
    if X is not None and forest.feat_map is not None:
        # optimized forest (repro.optim drop_unused_features): calibration
        # rows are full-width; the per-feature ranges must align with the
        # IR's remapped columns
        X = np.asarray(X)[:, np.asarray(forest.feat_map, dtype=np.int64)]
    lo, hi = feature_ranges(forest, X)
    s = spec.scale if spec.scale is not None else spec.default_scale
    out = replace(forest)

    if spec.quantize_splits:
        tn = normalize_features(forest.threshold.astype(np.float64),
                                lo[np.maximum(forest.feature, 0)],
                                hi[np.maximum(forest.feature, 0)])
        q = np.clip(np.floor(s * tn), -spec.int_max - 1, spec.int_max)
        out.threshold = q.astype(spec.dtype)

    if spec.quantize_leaves:
        max_abs = float(np.abs(forest.leaf_value).max()) or 1.0
        # paper: s in [M, 2^B]; auto-shrink for GBT leaves that exceed 1.0
        s_leaf = s
        while s_leaf * max_abs > spec.int_max and s_leaf > 2.0:
            s_leaf /= 2.0
        out.leaf_value = np.floor(s_leaf * forest.leaf_value).astype(
            np.int32 if spec.bits == 16 else np.int16)
        out.leaf_scale = s_leaf

    out.quant_scale = s
    out.quant_bits = spec.bits
    out.feat_lo = lo
    out.feat_hi = hi
    return out


def quantize_inputs(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Apply the forest's stored input transform to raw full-width rows:
    the optimizer's column remap (``feat_map``, if the
    ``drop_unused_features`` pass ran) followed by normalisation +
    fixed-point grid.  No-op for float forests without a remap."""
    if forest.feat_map is not None:
        X = np.asarray(X)[:, np.asarray(forest.feat_map, dtype=np.int64)]
    if forest.quant_scale is None:
        return X
    if not np.issubdtype(forest.threshold.dtype, np.integer):
        # leaves-only quantization: splits still float → inputs stay raw
        return X
    Xn = normalize_features(X, forest.feat_lo, forest.feat_hi)
    q = np.floor(forest.quant_scale * Xn)
    imax = 2 ** (forest.quant_bits - 1) - 1
    return np.clip(q, -imax - 1, imax).astype(forest.threshold.dtype)


def leaf_scale(forest: Forest) -> float:
    """Descaling factor for quantized leaf accumulations (1.0 if float)."""
    return float(getattr(forest, "leaf_scale", 1.0)
                 if np.issubdtype(forest.leaf_value.dtype, np.integer) else 1.0)
