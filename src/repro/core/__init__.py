"""repro.core — the paper's contribution: QuickScorer-family tree-ensemble
inference as a composable JAX module, plus fixed-point quantization.

Typical use::

    from repro import core
    forest = core.from_random_forest(rf)              # trainer → IR
    forest = core.quantize_forest(forest, X_train)    # optional, paper §5
    pred = core.compile_forest(forest, engine="bitvector", backend="pallas")
    scores = pred.predict(X)                          # (B, C)
"""
from .forest import (Forest, from_gradient_boosting, from_random_forest,
                     from_trees, random_forest_ir)
from .quantize import (QuantSpec, feature_ranges, leaf_scale,
                       normalize_features, quantize_forest, quantize_inputs)
from .quickscorer import (BitMMPredictor, CompiledBitMM, CompiledQS,
                          QSPredictor, compile_qs, compile_qs_bitmm,
                          eval_batch, eval_batch_bitmm, eval_scalar_numpy,
                          exit_leaf)
from .rapidscorer import (CompiledRS, RSPredictor, compile_rs, merge_nodes,
                          merge_stats)
from .baselines import (BaselinePredictor, compile_gemm, compile_native,
                        eval_gemm, eval_native, gemm_predictor,
                        native_predictor)

ENGINES = ("bitvector", "bitmm", "rapidscorer", "native", "unrolled", "gemm")


def compile_forest(forest: Forest, engine: str = "bitvector",
                   backend: str = "jax", **kw):
    """Build a predictor for ``forest``.

    engine:  bitvector (QS/VQS semantics) | rapidscorer (node merging) |
             native | unrolled | gemm
    backend: jax (XLA) | pallas (explicit TPU kernel; interpret mode on CPU)
    """
    if backend == "pallas":
        from ..kernels import ops
        if engine == "bitvector":
            return ops.pallas_qs_predictor(forest, **kw)
        if engine == "bitmm":
            return ops.pallas_bitmm_predictor(forest, **kw)
        if engine == "gemm":
            return ops.pallas_gemm_predictor(forest, **kw)
        raise ValueError(
            f"pallas backend supports bitvector|bitmm|gemm, got {engine}")
    if engine == "bitvector":
        return QSPredictor(compile_qs(forest))
    if engine == "bitmm":
        return BitMMPredictor(compile_qs_bitmm(forest, **kw))
    if engine == "rapidscorer":
        return RSPredictor(compile_rs(forest))
    if engine == "native":
        return native_predictor(forest, unroll=False)
    if engine == "unrolled":
        return native_predictor(forest, unroll=True)
    if engine == "gemm":
        return gemm_predictor(forest, **kw)
    raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")


__all__ = [
    "Forest", "from_trees", "from_random_forest", "from_gradient_boosting",
    "random_forest_ir", "QuantSpec", "quantize_forest", "quantize_inputs",
    "feature_ranges", "normalize_features", "leaf_scale",
    "CompiledQS", "compile_qs", "QSPredictor", "eval_batch",
    "CompiledBitMM", "compile_qs_bitmm", "BitMMPredictor",
    "eval_batch_bitmm",
    "eval_scalar_numpy", "exit_leaf", "CompiledRS", "compile_rs",
    "RSPredictor", "merge_nodes", "merge_stats", "BaselinePredictor",
    "compile_native", "compile_gemm", "eval_native", "eval_gemm",
    "native_predictor", "gemm_predictor", "compile_forest", "ENGINES",
]
