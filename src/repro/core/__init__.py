"""repro.core — the paper's contribution: QuickScorer-family tree-ensemble
inference as a composable JAX module, plus fixed-point quantization.

Typical use::

    from repro import core
    forest = core.from_random_forest(rf)              # trainer → IR
    forest = core.quantize_forest(forest, X_train)    # optional, paper §5
    pred = core.compile_forest(forest, engine="bitvector", backend="pallas")
    scores = pred.predict(X)                          # (B, C)

Engines live in a single registry (``core.registry``); compilation runs
through an explicit pass pipeline (``core.pipeline``: canonicalize →
quantize → layout → lower), and any XLA engine can execute tree-sharded
across a device mesh (``core.shard``).  See docs/DESIGN.md.
"""
from .forest import (Forest, from_gradient_boosting, from_random_forest,
                     from_trees, random_forest_ir)
from .quantize import (QuantSpec, accum_bits, feature_ranges, flint_forest,
                       flint_key, leaf_scale, normalize_features,
                       quantize_forest, quantize_inputs)
from . import registry
from .registry import (BasePredictor, EngineSpec, ForestEngine, Predictor,
                       normalize_scores, register_engine)
# importing the engine modules registers the XLA engines
from .quickscorer import (BitMMPredictor, CompiledBitMM, CompiledQS,
                          QSPredictor, compile_qs, compile_qs_bitmm,
                          eval_batch, eval_batch_bitmm, eval_scalar_numpy,
                          exit_leaf)
from .rapidscorer import (CompiledRS, RSPredictor, compile_rs, merge_nodes,
                          merge_stats)
from .baselines import (BaselinePredictor, compile_gemm, compile_native,
                        eval_gemm, eval_native, gemm_predictor,
                        native_predictor)

# the Pallas builders register lazily: resolving one imports the kernel
# stack (repro.kernels.ops) on first use, never at `import repro.core`
registry.register_deferred(
    "bitvector", backend="pallas", tune_name="pallas-qs",
    target="repro.kernels.ops:pallas_qs_predictor",
    doc="QuickScorer with explicit VMEM tiling (Pallas kernel)")
from .quickscorer import bitmm_pallas_layout
registry.register_deferred(
    "bitmm", backend="pallas", tune_name="pallas-bitmm",
    target="repro.kernels.ops:pallas_bitmm_predictor",
    layout=bitmm_pallas_layout,
    doc="fused bit-matmul QuickScorer kernel (Pallas)")
registry.register_deferred(
    "gemm", backend="pallas", tune_name="pallas-gemm",
    target="repro.kernels.ops:pallas_gemm_predictor",
    doc="Hummingbird tensor traversal kernel (Pallas)")

from .pipeline import CompilePlan, PassRecord, compile_plan


def __getattr__(name):
    # live view: engines registered after import (plugins, tests) appear
    # in core.ENGINES too, matching registry.engines() at all times
    if name == "ENGINES":
        return registry.engines()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def compile_forest(forest: Forest, engine: str = "bitvector",
                   backend: str = "jax", cascade=None, opt=None,
                   tune=None, tune_batch: int = 256, **kw):
    """Build a predictor for ``forest`` via the pass pipeline.

    engine / backend resolve through ``core.registry`` (no dispatch ladder
    — registered engines: ``core.ENGINES``); ``**kw`` is forwarded to the
    engine builder.  ``cascade=CascadeSpec(...)`` lowers to confidence-
    gated staged evaluation (``repro.cascade``, docs/CASCADE.md).
    ``opt=`` runs the optimizer middle-end (``repro.optim``,
    docs/OPTIM.md) on the IR first: a level (``0``/``1``/``2`` or
    ``"O2"``) or an explicit pass-name tuple; the result is always
    oracle-equivalence checked.  For quantization-as-a-pass or
    multi-device plans use ``core.compile_plan`` directly.

    ``tune=`` hands the *whole* decision to the autotuner instead:
    ``tune="measure"`` sweeps, ``tune="predict"`` (alias ``"-Os"``) asks
    the learned cost model (``repro.tune``, docs/AUTOTUNE.md) for a
    zero-shot plan at the ``tune_batch`` bucket.  With ``tune=`` set,
    ``engine``/``backend`` are chosen *by* the tuner, so they (and
    ``cascade``/``opt``, which become sweep axes via ``cascade_specs=``/
    ``opt_levels=``) must stay at their defaults; ``**kw`` forwards to
    ``engine_select.choose`` (``cost_model=``, ``engines=``, ...).
    """
    if tune is not None:
        if engine != "bitvector" or backend != "jax" or \
                cascade is not None or opt is not None:
            raise ValueError(
                "tune= picks engine/backend (and sweeps cascade/opt "
                "via cascade_specs=/opt_levels=); don't pass them "
                "alongside it")
        from . import engine_select
        return engine_select.choose(forest, tune_batch, mode=tune,
                                    **kw).predictor
    return compile_plan(forest, CompilePlan(engine=engine, backend=backend,
                                            cascade=cascade, opt=opt,
                                            engine_kw=kw))


__all__ = [
    "Forest", "from_trees", "from_random_forest", "from_gradient_boosting",
    "random_forest_ir", "QuantSpec", "quantize_forest", "quantize_inputs",
    "feature_ranges", "normalize_features", "leaf_scale",
    "accum_bits", "flint_forest", "flint_key",
    "CompiledQS", "compile_qs", "QSPredictor", "eval_batch",
    "CompiledBitMM", "compile_qs_bitmm", "BitMMPredictor",
    "eval_batch_bitmm",
    "eval_scalar_numpy", "exit_leaf", "CompiledRS", "compile_rs",
    "RSPredictor", "merge_nodes", "merge_stats", "BaselinePredictor",
    "compile_native", "compile_gemm", "eval_native", "eval_gemm",
    "native_predictor", "gemm_predictor", "compile_forest", "ENGINES",
    "registry", "register_engine", "EngineSpec", "ForestEngine",
    "Predictor", "BasePredictor", "normalize_scores",
    "CompilePlan", "PassRecord", "compile_plan",
]
