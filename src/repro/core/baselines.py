"""Baseline ensemble-traversal engines the paper compares against.

* ``native``   — per-level pointer-chasing traversal over child arrays (the
  paper's NATIVE/PRED baseline, Asadi et al. 2014): implemented as a
  ``fori_loop`` over tree depth with gathered node state.
* ``unrolled`` — the IF-ELSE analogue: identical math with the depth loop
  python-unrolled into straight-line HLO. On CPUs IF-ELSE wins via branch
  prediction; on TPU there are no branches, so this isolates the
  loop-vs-unroll HLO trade-off the paper's IE/NA gap degenerates to.
* ``gemm``     — Hummingbird-style tensor traversal (Nakandala et al. 2020)
  mapped onto the MXU; the paper dismisses this route for MCUs, on TPU it is
  the beyond-paper engine (DESIGN.md §2.3).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .forest import Forest
from .quantize import leaf_scale, quantize_inputs
from .quickscorer import acc_dtype_for, forest_acc_bits
from .registry import BasePredictor, register_engine


# --------------------------------------------------------------------------- #
# NATIVE / IF-ELSE: per-level traversal
# --------------------------------------------------------------------------- #
@dataclass
class CompiledNative:
    feat: jnp.ndarray       # (T, N) int32
    thr: jnp.ndarray        # (T, N)
    left: jnp.ndarray       # (T, N) int32 (<0 → leaf -(x+1))
    right: jnp.ndarray      # (T, N) int32
    leaf_val: jnp.ndarray   # (T, L, C)
    max_depth: int
    leaf_scale: float
    single_leaf: jnp.ndarray  # (T,) bool — degenerate single-leaf trees
    acc_bits: int = 32        # accumulator width (16 | 32)
    forest: Forest = None

    def transform_inputs(self, X):
        return quantize_inputs(self.forest, X) if self.forest is not None else X


def compile_native(forest: Forest) -> CompiledNative:
    return CompiledNative(
        feat=jnp.asarray(np.maximum(forest.feature, 0), dtype=jnp.int32),
        thr=jnp.asarray(forest.threshold),
        left=jnp.asarray(forest.left),
        right=jnp.asarray(forest.right),
        leaf_val=jnp.asarray(forest.leaf_value),
        max_depth=int(forest.max_depth),
        leaf_scale=leaf_scale(forest),
        single_leaf=jnp.asarray(forest.n_nodes == 0),
        acc_bits=forest_acc_bits(forest),
        forest=forest,
    )


def eval_native(nat: CompiledNative, X: jnp.ndarray,
                unroll: bool = False) -> jnp.ndarray:
    """X (B, d) → (B, C). State: current node per (instance, tree); negative
    codes are reached leaves (absorbing)."""
    B = X.shape[0]
    T, N = nat.feat.shape
    node0 = jnp.zeros((B, T), dtype=jnp.int32)

    def step(_, node):
        live = node >= 0
        idx = jnp.maximum(node, 0)
        f = jnp.take_along_axis(nat.feat[None], idx[..., None], axis=2)[..., 0]
        t = jnp.take_along_axis(nat.thr[None], idx[..., None], axis=2)[..., 0]
        x = jnp.take_along_axis(X[:, None, :], f[..., None], axis=2)[..., 0]
        l = jnp.take_along_axis(nat.left[None], idx[..., None], axis=2)[..., 0]
        r = jnp.take_along_axis(nat.right[None], idx[..., None], axis=2)[..., 0]
        nxt = jnp.where(x <= t, l, r)
        return jnp.where(live, nxt, node)

    if unroll:
        node = node0
        for i in range(nat.max_depth):
            node = step(i, node)
    else:
        node = jax.lax.fori_loop(0, nat.max_depth, step, node0)
    leaf = jnp.where(nat.single_leaf[None], 0, -node - 1)
    leaf = jnp.maximum(leaf, 0)                                   # safety
    vals = jnp.take_along_axis(
        nat.leaf_val[None], leaf[..., None, None], axis=2)[:, :, 0]
    acc = acc_dtype_for(nat.leaf_val.dtype, nat.acc_bits)
    score = vals.astype(acc).sum(axis=1, dtype=acc)
    return score.astype(jnp.float32) / nat.leaf_scale


# --------------------------------------------------------------------------- #
# GEMM (Hummingbird) engine
# --------------------------------------------------------------------------- #
@dataclass
class CompiledGEMM:
    feat: jnp.ndarray       # (T, N) int32
    thr: jnp.ndarray        # (T, N)
    valid: jnp.ndarray      # (T, N) bool
    A: jnp.ndarray          # (T, N, L)  +1 left-subtree, -1 right-subtree
    Bvec: jnp.ndarray       # (T, L)  required left-edge count (pad → +inf-ish)
    leaf_val: jnp.ndarray   # (T, L, C) f32 | i32 | i16
    leaf_scale: float
    compute_dtype: jnp.dtype
    acc_bits: int = 32      # accumulator width (16 | 32)
    forest: Forest = None

    def transform_inputs(self, X):
        return quantize_inputs(self.forest, X) if self.forest is not None else X


def compile_gemm(forest: Forest, compute_dtype=jnp.float32) -> CompiledGEMM:
    T, N = forest.feature.shape
    L = forest.n_leaves
    A = np.zeros((T, N, L), dtype=np.float32)
    Bvec = np.full((T, L), np.float32(L + 1))        # padding never matches
    for t in range(T):
        for n in range(int(forest.n_nodes[t])):
            lo, mid, hi = (int(forest.leaf_lo[t, n]), int(forest.leaf_mid[t, n]),
                           int(forest.leaf_hi[t, n]))
            A[t, n, lo:mid] += 1.0
            A[t, n, mid:hi] -= 1.0
        nl = int(forest.n_leaves_per_tree[t])
        Bvec[t, :nl] = A[t, :, :nl].clip(min=0).sum(axis=0)
    return CompiledGEMM(
        feat=jnp.asarray(np.maximum(forest.feature, 0), dtype=jnp.int32),
        thr=jnp.asarray(forest.threshold),
        valid=jnp.asarray(forest.feature >= 0),
        A=jnp.asarray(A, dtype=compute_dtype),
        Bvec=jnp.asarray(Bvec, dtype=compute_dtype),
        # integer leaves keep their dtype: the float leaf-einsum is exact
        # only below 2^24, the integer gather path in eval_gemm always is
        leaf_val=(jnp.asarray(forest.leaf_value)
                  if np.issubdtype(forest.leaf_value.dtype, np.integer)
                  else jnp.asarray(forest.leaf_value, dtype=jnp.float32)),
        leaf_scale=leaf_scale(forest),
        compute_dtype=compute_dtype,
        acc_bits=forest_acc_bits(forest),
        forest=forest,
    )


def eval_gemm(g: CompiledGEMM, X: jnp.ndarray) -> jnp.ndarray:
    """Two batched matmuls per tree block (MXU work):
       S (B,T,N) = 1{x <= t};  R = S @ A;  onehot = (R == Bvec);
       scores = Σ_t onehot @ leaf_val."""
    xf = X[:, g.feat]                                            # (B, T, N)
    S = ((xf <= g.thr[None]) & g.valid[None]).astype(g.compute_dtype)
    R = jnp.einsum("btn,tnl->btl", S, g.A)                       # MXU
    hit = R == g.Bvec[None]                                      # (B, T, L)
    if g.leaf_val.dtype == jnp.float32:
        score = jnp.einsum("btl,tlc->bc", hit.astype(jnp.float32),
                           g.leaf_val)                           # MXU
    else:
        # integer leaves: exactly one leaf per (row, tree) matches its
        # left-edge count, so argmax recovers the exit leaf; the gather-
        # sum stays in the integer accumulator (always exact, unlike a
        # float leaf-einsum above 2^24)
        leaf = jnp.argmax(hit, axis=2)                           # (B, T)
        vals = jnp.take_along_axis(
            g.leaf_val[None], leaf[..., None, None], axis=2)[:, :, 0]
        acc = acc_dtype_for(g.leaf_val.dtype, g.acc_bits)
        score = vals.astype(acc).sum(axis=1, dtype=acc)
    return score.astype(jnp.float32) / g.leaf_scale


def eval_unrolled(nat: CompiledNative, X: jnp.ndarray) -> jnp.ndarray:
    """``native`` with the depth loop python-unrolled (IF-ELSE analogue)."""
    return eval_native(nat, X, unroll=True)


class BaselinePredictor(BasePredictor):
    """Wrapper for the baseline engines (shared base: quantization + jit)."""


def native_predictor(forest: Forest, unroll=False) -> BaselinePredictor:
    nat = compile_native(forest)
    return BaselinePredictor(nat, eval_unrolled if unroll else eval_native)


def gemm_predictor(forest: Forest, compute_dtype=jnp.float32) -> BaselinePredictor:
    g = compile_gemm(forest, compute_dtype)
    return BaselinePredictor(g, eval_gemm)


_NATIVE_ARRAYS = ("feat", "thr", "left", "right", "leaf_val", "single_leaf")
register_engine(
    "native", tune_name="native", compile=compile_native,
    evaluate=eval_native, predictor_cls=BaselinePredictor, shardable=True,
    serial_arrays=_NATIVE_ARRAYS,
    doc="per-level pointer-chasing traversal (fori_loop over depth)")
register_engine(
    "unrolled", tune_name="unrolled", compile=compile_native,
    evaluate=eval_unrolled, predictor_cls=BaselinePredictor, shardable=True,
    serial_arrays=_NATIVE_ARRAYS,
    doc="native with the depth loop unrolled to straight-line HLO")
def _gemm_layout(forest: Forest, plan) -> str:
    dt = plan.engine_kw.get("compute_dtype")
    return (f"dense (T,N,L) traversal matrices, "
            f"dtype={getattr(dt, '__name__', dt) or 'f32'}")


register_engine(
    "gemm", tune_name="gemm", compile=compile_gemm, evaluate=eval_gemm,
    predictor_cls=BaselinePredictor, shardable=True, layout=_gemm_layout,
    serial_arrays=("feat", "thr", "valid", "A", "Bvec", "leaf_val"),
    doc="Hummingbird tensor traversal (two matmuls per tree block)")
