"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave
(attn_period=8, attention at offset 4), MoE every other layer (16e top-2)
[arXiv:2403.19887; hf]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, d_ff=24576,
    vocab=65536, head_dim=128, mlp="swiglu",
    n_experts=16, top_k=2, moe_period=2,
    attn_period=8, attn_offset=4,
    ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_ngroups=8,
)
