"""chameleon-34b [vlm]: early-fusion, VQ image tokens live in the 65536
vocab → backbone consumes plain token ids; VQ tokenizer frontend is a stub
[arXiv:2405.09818; unverified]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8, d_ff=22016,
    vocab=65536, head_dim=128, mlp="swiglu", frontend_stub="vlm",
)
