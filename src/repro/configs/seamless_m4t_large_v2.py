"""seamless-m4t-large-v2 [audio]: encoder-decoder; the speech frontend is a
STUB — input_specs() provides precomputed frame embeddings (B, S_enc, D)
[arXiv:2308.11596; hf]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=8192,
    vocab=256206, head_dim=64, mlp="gelu",
    enc_layers=24, frontend_stub="audio",
)
