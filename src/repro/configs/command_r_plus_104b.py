"""command-r-plus-104b [dense]: GQA, no-bias, 256k vocab
[hf:CohereForAI/c4ai-command-r; unverified]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv=8, d_ff=33792,
    vocab=256000, head_dim=128, mlp="swiglu",
)
