"""starcoder2-3b [dense]: GQA kv=2, RoPE, non-gated GELU MLP
[arXiv:2402.19173; hf]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv=2, d_ff=12288,
    vocab=49152, head_dim=128, mlp="gelu",
)
