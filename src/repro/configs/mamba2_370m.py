"""mamba2-370m [ssm]: attention-free SSD (state-space duality), 48 blocks
[arXiv:2405.21060; unverified]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280, head_dim=64,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
)
