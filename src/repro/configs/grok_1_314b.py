"""grok-1-314b [moe]: 8 experts top-2, every layer MoE
[hf:xai-org/grok-1; unverified]. 8 experts do not divide the 16-wide model
axis → expert weights fall back to tensor-parallel d_ff sharding."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_ff=32768,
    vocab=131072, head_dim=128, mlp="swiglu",
    n_experts=8, top_k=2, moe_period=1,
)
