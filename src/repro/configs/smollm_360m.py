"""smollm-360m [dense]: llama-arch small [hf:HuggingFaceTB/SmolLM; hf].
15 heads / 5 kv heads do not divide the 16-wide model axis → sharding rules
fall back to head_dim sharding (distributed/sharding.py)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv=5, d_ff=2560,
    vocab=49152, head_dim=64, mlp="swiglu",
)
