"""Architecture registry: one module per assigned architecture, plus the
paper's own forest configurations (forest_*)."""
from __future__ import annotations

import importlib

from ..models.config import SHAPES, ArchConfig, ShapeConfig, shape_applicable

ARCH_IDS = [
    "chameleon_34b",
    "smollm_360m",
    "phi3_mini_3_8b",
    "command_r_plus_104b",
    "starcoder2_3b",
    "phi3_5_moe_42b",
    "grok_1_314b",
    "seamless_m4t_large_v2",
    "jamba_1_5_large_398b",
    "mamba2_370m",
]

_ALIASES = {
    "chameleon-34b": "chameleon_34b",
    "smollm-360m": "smollm_360m",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "starcoder2-3b": "starcoder2_3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "grok-1-314b": "grok_1_314b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-370m": "mamba2_370m",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "get_config", "all_configs", "SHAPES", "ArchConfig",
           "ShapeConfig", "shape_applicable"]
