"""End-to-end observability: metrics, tracing, retrace detection,
structured logging, and Prometheus/JSON exposition.

The paper's finding — the best implementation depends on the forest AND
the device — turns a deployment into a stream of runtime decisions
(engine choice, SLO batching knobs, cascade exits, compile events).
This package makes that stream observable (docs/OBSERVABILITY.md):

  * ``obs.metrics``  — thread-safe registry: counters, gauges, bounded
    histograms (``Reservoir``-backed), per-tenant labels, process-wide
    default instance, near-zero cost when disabled;
  * ``obs.trace``    — per-request spans (queue/form/pad/compute/sync
    phases) in a bounded ring buffer, retrievable as JSON;
  * ``obs.retrace``  — jit trace-cache watchers: post-warmup compiles
    surface as anomalies instead of silent latency spikes;
  * ``obs.log``      — structured ``key=value`` logger for the launch
    drivers (quiet-by-default under pytest);
  * ``obs.expo``     — Prometheus text + JSON snapshot served from a
    stdlib HTTP thread (``ServingRuntime.serve_metrics``);
  * ``obs.serving``  — the serving metric catalog (the contract
    ``check_engines.py --obs`` asserts against a live scrape).

Import discipline: nothing here imports the rest of ``repro`` at module
scope (``Reservoir`` is pulled lazily), so the serving runtime, the
autotuner, and the launch drivers can all import ``repro.obs`` freely
without cycles.
"""
from .expo import MetricsServer, json_snapshot
from .log import StructLogger, get_logger, set_level
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, set_default_registry)
from .retrace import CompileWatch, fn_cache_size, jit_cache_size
from .serving import METRIC_CATALOG, ServingMetrics
from .trace import PHASES, Span, TraceBuffer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "set_default_registry",
    "Span", "TraceBuffer", "PHASES",
    "CompileWatch", "fn_cache_size", "jit_cache_size",
    "StructLogger", "get_logger", "set_level",
    "MetricsServer", "json_snapshot",
    "METRIC_CATALOG", "ServingMetrics",
]
