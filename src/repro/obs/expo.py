"""Metric exposition: a tiny stdlib HTTP endpoint + snapshot helpers.

``MetricsServer`` owns a ``ThreadingHTTPServer`` on a daemon thread —
no web framework, no new dependency — and serves:

  * ``GET /metrics``       — Prometheus text format 0.0.4 (what a
    Prometheus/VictoriaMetrics scraper points at);
  * ``GET /metrics.json``  — JSON: ``{"metrics": <registry snapshot>,
    "stats": <extra() if wired>}`` — the same numbers for humans and
    ad-hoc tooling (``curl | jq``), plus the runtime's ``stats()``
    (controller decision history, queue depths) when the server is
    owned by a ``ServingRuntime``;
  * ``GET /traces``        — the recent-span ring as JSON
    (``?n=32`` limits to the newest n);
  * ``GET /healthz``       — liveness (200 "ok").

``port=0`` binds an ephemeral port (tests); ``.port``/``.url`` report
the bound address.  The handler reads the registry under its lock (a
consistent scrape) and never logs per-request lines — scrapes every few
seconds must not spam the serving process's stderr.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional
from urllib.parse import parse_qs, urlparse

from .metrics import MetricsRegistry
from .trace import TraceBuffer

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def json_snapshot(registry: MetricsRegistry,
                  extra: Optional[Callable[[], dict]] = None) -> dict:
    """The /metrics.json payload (also callable without a server)."""
    out = {"metrics": registry.snapshot()}
    if extra is not None:
        out["stats"] = extra()
    return out


class MetricsServer:
    """Scrape endpoint over one registry (+ optional trace ring and
    extra-stats callable).  Start with ``start()``; idempotent
    ``close()`` shuts the socket and joins the thread."""

    def __init__(self, registry: MetricsRegistry, *,
                 traces: Optional[TraceBuffer] = None,
                 extra: Optional[Callable[[], dict]] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self.registry = registry
        self.traces = traces
        self.extra = extra
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):          # noqa: N802 — stdlib name
                pass                            # scrapes must not spam

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):                   # noqa: N802 — stdlib name
                url = urlparse(self.path)
                try:
                    if url.path == "/metrics":
                        self._send(200,
                                   server.registry.prometheus().encode(),
                                   PROMETHEUS_CONTENT_TYPE)
                    elif url.path in ("/metrics.json", "/snapshot"):
                        body = json.dumps(
                            json_snapshot(server.registry, server.extra),
                            indent=1, default=str).encode()
                        self._send(200, body, "application/json")
                    elif url.path == "/traces":
                        q = parse_qs(url.query)
                        n = int(q["n"][0]) if "n" in q else None
                        ring = server.traces
                        body = (ring.to_json(n) if ring is not None
                                else "[]").encode()
                        self._send(200, body, "application/json")
                    elif url.path == "/healthz":
                        self._send(200, b"ok", "text/plain")
                    else:
                        self._send(404, b"not found: try /metrics, "
                                   b"/metrics.json, /traces, /healthz",
                                   "text/plain")
                except Exception as e:          # noqa: BLE001 — a scrape
                    # failure must never kill the serving process
                    self._send(500, repr(e).encode(), "text/plain")

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "MetricsServer":
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="repro-metrics",
                daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
