"""Request tracing: where did this request's 12 ms go?

A ``Span`` is one served request's phase breakdown, stamped by the
serving layer as the batch it rode in moves through dispatch
(``inference.runtime.ServingRuntime._run_batch``):

  * ``queue_ms``   — submit → the dispatch rule fired (arrival-relative,
    measured on the runtime's clock, so virtual-clock tests stamp
    deterministic values);
  * ``form_ms``    — stacking the drained requests into one (B, d) batch;
  * ``pad_ms``     — zero-padding to the power-of-two bucket (plain
    engines only; cascade/Pallas tenants bucket internally);
  * ``compute_ms`` — the predictor call until it *returns* (async
    dispatch: launch cost, not completion);
  * ``sync_ms``    — ``jax.block_until_ready`` until scores are real.

Sub-phase durations come from ``time.perf_counter`` deltas (monotonic —
the same contract as the serving stats); only ``queue_ms`` uses the
injectable runtime clock, which keeps spans meaningful under both the
threaded loop and the virtual-clock ``pump``/``flush`` twin.

``TraceBuffer`` is a bounded, thread-safe ring of recent spans — the
flight recorder an operator pulls as JSON from the metrics endpoint
(``GET /traces``) after a latency spike, without grepping logs or
re-running traffic.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

#: canonical phase order (docs/OBSERVABILITY.md)
PHASES = ("queue_ms", "form_ms", "pad_ms", "compute_ms", "sync_ms")


@dataclass
class Span:
    """One request's trace through the serving runtime."""
    rid: int
    tenant: str
    arrival_s: float
    batch_size: int = 0               # requests in the batch it rode in
    bucket: int = 0                   # padded batch the engine saw
    phases: dict = field(default_factory=dict)      # phase -> ms
    total_ms: Optional[float] = None  # submit -> scores on the host
    exit_stage: Optional[int] = None  # cascade: reserved (batch-level
    #                                   exit counts live in the metrics)
    ok: bool = True
    error: Optional[str] = None

    def to_dict(self) -> dict:
        """JSON-clean dict (what /traces serves)."""
        out = {
            "rid": self.rid,
            "tenant": self.tenant,
            "arrival_s": float(self.arrival_s),
            "batch_size": int(self.batch_size),
            "bucket": int(self.bucket),
            "phases": {k: float(v) for k, v in self.phases.items()},
            "total_ms": (float(self.total_ms)
                         if self.total_ms is not None else None),
            "ok": bool(self.ok),
        }
        if self.error is not None:
            out["error"] = self.error
        if self.exit_stage is not None:
            out["exit_stage"] = int(self.exit_stage)
        return out


class TraceBuffer:
    """Bounded ring of recent spans (newest last), thread-safe."""

    def __init__(self, cap: int = 256):
        if cap < 1:
            raise ValueError(f"trace buffer cap must be >= 1, got {cap}")
        self.cap = cap
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=cap)
        self.n_added = 0              # spans ever recorded (exact)

    def add(self, span: Span) -> None:
        with self._lock:
            self._ring.append(span)
            self.n_added += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def recent(self, n: Optional[int] = None) -> list:
        """The most recent ``n`` spans (all retained by default) as
        JSON-clean dicts, oldest first."""
        with self._lock:
            spans = list(self._ring)
        if n is not None:
            spans = spans[-int(n):]
        return [s.to_dict() for s in spans]

    def to_json(self, n: Optional[int] = None) -> str:
        return json.dumps(self.recent(n), indent=1)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
