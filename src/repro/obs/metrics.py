"""Thread-safe metrics registry: counters, gauges, bounded histograms.

The paper's conclusion — the best implementation depends on the forest
AND the device — means a production deployment is constantly making
runtime decisions: which engine the autotuner picked, how the SLO
controller moved the batching knobs, which cascade stage a request
exited at, whether a live request just paid an XLA retrace.  This
module is the process-wide ledger those decisions are written to, and
``repro.obs.expo`` is how an operator reads it (Prometheus text or a
JSON snapshot — docs/OBSERVABILITY.md has the metric catalog).

Model (a deliberately small subset of the Prometheus data model):

  * ``Counter``   — monotonically increasing float (``inc``).
  * ``Gauge``     — set/inc/dec to any value (queue depth, knobs).
  * ``Histogram`` — bounded value stream: exact count/sum plus
    percentiles from a capped sample (``inference.server.Reservoir`` —
    Algorithm R, so a month of traffic holds O(cap) floats).
  * Every metric is a *family* keyed by name; label names are declared
    at creation and each distinct label-value tuple materializes one
    child series (``family.labels(tenant="alpha").inc()``).

Concurrency: one registry-wide lock guards family creation, child
creation, every mutation, and every scrape — scrapes therefore see a
consistent point-in-time view, and the thread-hammer test in
``tests/test_obs.py`` pins that concurrent submits + scrapes never
corrupt a counter.  The ops inside the lock are a float add or a
reservoir append, so the critical section is nanoseconds.

Cost when disabled: every mutating op checks ``registry.enabled``
before taking the lock — one attribute load and a branch.  The
process-wide default registry honors ``REPRO_OBS=0`` at import, and
``ServingRuntime(obs=False)`` skips instrumentation entirely (the
measured overhead table lives in ``BENCH_serving.json``).
"""
from __future__ import annotations

import json
import re
import threading
from typing import Optional

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: percentiles a histogram exposes (Prometheus summary quantiles)
QUANTILES = (0.5, 0.9, 0.99)


def _reservoir(cap: int):
    # deferred so `import repro.obs` never pulls the serving stack (and
    # with it jax) — obs must stay import-cycle-free: runtime imports
    # obs, obs only ever imports inference lazily
    from ..inference.server import Reservoir
    return Reservoir(cap=cap)


def escape_label_value(v: str) -> str:
    """Prometheus text-format label escaping: backslash, quote, newline."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


class _Child:
    """One concrete time series: a family narrowed to one label tuple."""

    __slots__ = ("_family",)

    def __init__(self, family: "MetricFamily"):
        self._family = family

    @property
    def _lock(self):
        return self._family._reg._lock

    @property
    def _enabled(self) -> bool:
        return self._family._reg.enabled


class Counter(_Child):
    __slots__ = ("_value",)
    kind = "counter"

    def __init__(self, family):
        super().__init__(family)
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if not self._enabled:
            return
        if v < 0:
            raise ValueError(f"counter increments must be >= 0, got {v}")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Child):
    __slots__ = ("_value",)
    kind = "gauge"

    def __init__(self, family):
        super().__init__(family)
        self._value = 0.0

    def set(self, v: float) -> None:
        if not self._enabled:
            return
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        if not self._enabled:
            return
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Child):
    __slots__ = ("_res",)
    kind = "histogram"

    def __init__(self, family):
        super().__init__(family)
        self._res = _reservoir(family.cap)

    def observe(self, v: float) -> None:
        if not self._enabled:
            return
        with self._lock:
            self._res.append(float(v))

    @property
    def count(self) -> int:
        with self._lock:
            return self._res.n

    @property
    def sum(self) -> float:
        with self._lock:
            return self._res.total

    def percentile(self, q: float) -> Optional[float]:
        with self._lock:
            return self._res.percentile(q) if self._res else None


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricFamily:
    """All series sharing one metric name (and one label-name schema)."""

    def __init__(self, reg: "MetricsRegistry", name: str, help: str,
                 kind: str, label_names: tuple, cap: int = 2048):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r} "
                             f"(must match {_NAME_RE.pattern})")
        for ln in label_names:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        self._reg = reg
        self.name = name
        self.help = help
        self.kind = kind
        self.label_names = tuple(label_names)
        self.cap = cap
        self._children: dict[tuple, _Child] = {}

    def labels(self, **kv) -> _Child:
        """The child series for this exact label assignment (created on
        first use).  Label *names* must match the declared schema."""
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[ln]) for ln in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._reg._lock:
                child = self._children.get(key)
                if child is None:
                    child = _KINDS[self.kind](self)
                    self._children[key] = child
        return child

    # ------------------------------------------------- label-free sugar
    def _solo(self) -> _Child:
        if self.label_names:
            raise ValueError(
                f"metric {self.name!r} is labeled {self.label_names}; "
                "narrow it with .labels(...) first")
        return self.labels()

    def inc(self, v: float = 1.0) -> None:
        self._solo().inc(v)

    def set(self, v: float) -> None:
        self._solo().set(v)

    def dec(self, v: float = 1.0) -> None:
        self._solo().dec(v)

    def observe(self, v: float) -> None:
        self._solo().observe(v)

    @property
    def value(self) -> float:
        return self._solo().value

    @property
    def count(self) -> int:
        return self._solo().count

    @property
    def sum(self) -> float:
        return self._solo().sum

    def percentile(self, q: float) -> Optional[float]:
        return self._solo().percentile(q)

    # ---------------------------------------------------------- readout
    def samples(self) -> list:
        """JSON-clean sample dicts for every child (call under the
        registry lock for a consistent scrape)."""
        out = []
        for key, child in self._children.items():
            labels = dict(zip(self.label_names, key))
            if self.kind == "histogram":
                res = child._res
                rec = {"labels": labels, "count": res.n, "sum": res.total}
                for q in QUANTILES:
                    rec[f"p{int(q * 100)}"] = (
                        res.percentile(q * 100) if res else None)
                out.append(rec)
            else:
                out.append({"labels": labels, "value": child._value})
        return out


class MetricsRegistry:
    """Get-or-create metric families + consistent scrapes.

    Re-requesting an existing name returns the same family object —
    with a loud ``ValueError`` if the kind or label schema disagrees
    (two subsystems silently sharing a name with different meanings is
    exactly the bug a registry exists to prevent)."""

    def __init__(self, enabled: bool = True):
        self._lock = threading.RLock()
        self._families: dict[str, MetricFamily] = {}
        self.enabled = bool(enabled)

    def enable(self, on: bool = True) -> None:
        self.enabled = bool(on)

    # ------------------------------------------------------ constructors
    def _family(self, name: str, help: str, kind: str, labels: tuple,
                **kw) -> MetricFamily:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}{fam.label_names}, requested "
                        f"{kind}{tuple(labels)}")
                return fam
            fam = MetricFamily(self, name, help, kind, tuple(labels), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: tuple = ()) -> MetricFamily:
        return self._family(name, help, "counter", labels)

    def gauge(self, name: str, help: str = "",
              labels: tuple = ()) -> MetricFamily:
        return self._family(name, help, "gauge", labels)

    def histogram(self, name: str, help: str = "", labels: tuple = (),
                  cap: int = 2048) -> MetricFamily:
        return self._family(name, help, "histogram", labels, cap=cap)

    def names(self) -> tuple:
        with self._lock:
            return tuple(self._families)

    def get(self, name: str) -> Optional[MetricFamily]:
        with self._lock:
            return self._families.get(name)

    # ---------------------------------------------------------- readout
    def snapshot(self) -> dict:
        """JSON-clean point-in-time view of every family: ``{name:
        {type, help, labelnames, samples}}`` — round-trips through
        ``json.dumps``/``loads`` unchanged (pinned by tests)."""
        with self._lock:
            return {
                name: {
                    "type": fam.kind,
                    "help": fam.help,
                    "labelnames": list(fam.label_names),
                    "samples": fam.samples(),
                }
                for name, fam in self._families.items()
            }

    def prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4).  Histograms are
        exported as summaries: ``name{quantile="0.5"}``, ``name_sum``,
        ``name_count``."""
        lines: list[str] = []
        with self._lock:
            for name, fam in self._families.items():
                if fam.help:
                    lines.append(f"# HELP {name} {fam.help}")
                kind = "summary" if fam.kind == "histogram" else fam.kind
                lines.append(f"# TYPE {name} {kind}")
                for key, child in fam._children.items():
                    pairs = [f'{ln}="{escape_label_value(v)}"'
                             for ln, v in zip(fam.label_names, key)]

                    def series(extra: str = "", base: str = name) -> str:
                        lab = pairs + ([extra] if extra else [])
                        return base + ("{" + ",".join(lab) + "}"
                                       if lab else "")

                    if fam.kind == "histogram":
                        res = child._res
                        if res:
                            for q in QUANTILES:
                                qlab = 'quantile="%g"' % q
                                lines.append(
                                    f"{series(qlab)} "
                                    f"{res.percentile(q * 100):.17g}")
                        lines.append(f"{series(base=name + '_sum')} "
                                     f"{res.total:.17g}")
                        lines.append(f"{series(base=name + '_count')} "
                                     f"{res.n}")
                    else:
                        lines.append(f"{series()} {child._value:.17g}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=1)


# --------------------------------------------------------------------------- #
# Process-wide default registry
# --------------------------------------------------------------------------- #
def _env_enabled() -> bool:
    import os
    return os.environ.get("REPRO_OBS", "1").lower() not in (
        "0", "off", "false", "no")


_DEFAULT = MetricsRegistry(enabled=_env_enabled())


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (``REPRO_OBS=0`` starts it
    disabled).  Subsystems that are not handed an explicit registry —
    the autotuner, ``ServingRuntime(obs=True)`` — write here."""
    return _DEFAULT


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide default (tests isolate themselves with a
    fresh registry); returns the previous one so callers can restore."""
    global _DEFAULT
    old, _DEFAULT = _DEFAULT, reg
    return old
