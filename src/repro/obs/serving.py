"""The serving metric catalog + per-process instrumentation bundle.

One place declares every metric the serving layer emits —
``METRIC_CATALOG`` is the contract that docs/OBSERVABILITY.md
documents, ``scripts/check_engines.py --obs`` asserts against a live
scrape, and dashboards are built on.  ``ServingMetrics`` materializes
the catalog on a registry and is shared by ``ServingRuntime`` (full
instrumentation: phases, spans, retrace detection) and ``ForestServer``
(the synchronous path: latency/phase/throughput).

Labels: ``tenant`` is the model id (``ForestServer`` uses its
``obs_label``); ``phase`` is one of ``repro.obs.trace.PHASES``;
``stage`` is the cascade stage index; ``action`` is the controller
decision (grow/shrink/hold).
"""
from __future__ import annotations

from .metrics import MetricsRegistry
from .trace import TraceBuffer

#: name -> (kind, label names, help).  Every entry is created up front
#: so a scrape always exposes the full catalog (HELP/TYPE lines appear
#: even before the first sample).
METRIC_CATALOG = {
    "repro_requests_total": (
        "counter", ("tenant",),
        "Requests completed (resolved futures), per tenant"),
    "repro_request_errors_total": (
        "counter", ("tenant",),
        "Requests resolved with an exception, per tenant"),
    "repro_batches_total": (
        "counter", ("tenant",),
        "Batches dispatched, per tenant"),
    "repro_batch_size": (
        "histogram", ("tenant",),
        "Requests per dispatched batch"),
    "repro_latency_ms": (
        "histogram", ("tenant",),
        "End-to-end request latency (submit to scores on host), ms"),
    "repro_phase_ms": (
        "histogram", ("tenant", "phase"),
        "Per-phase request latency breakdown "
        "(queue/form/pad/compute/sync), ms"),
    "repro_queue_depth": (
        "gauge", ("tenant",),
        "Requests waiting in the tenant's micro-batcher queue"),
    "repro_effective_max_batch": (
        "gauge", ("tenant",),
        "Effective max_batch after SLO controller decisions"),
    "repro_effective_max_wait_ms": (
        "gauge", ("tenant",),
        "Effective max_wait_ms after SLO controller decisions"),
    "repro_controller_decisions_total": (
        "counter", ("tenant", "action"),
        "SLO controller window decisions (grow/shrink/hold)"),
    "repro_cascade_stage_exits_total": (
        "counter", ("tenant", "stage"),
        "Cascade rows exiting at each stage, per tenant"),
    "repro_compile_events_total": (
        "counter", ("tenant",),
        "Observed XLA trace-cache growths (compiles), per tenant"),
    "repro_retrace_anomalies_total": (
        "counter", ("tenant",),
        "Post-warmup compiles — a shape leaked past the bucket "
        "ladder (should stay 0; docs/OBSERVABILITY.md)"),
}


class ServingMetrics:
    """The catalog, materialized on one registry, plus the trace ring.

    Attribute names are the catalog names minus the ``repro_`` prefix
    and ``_total``/``_ms`` suffixes kept (``self.requests_total``,
    ``self.latency_ms``, ...)."""

    def __init__(self, registry: MetricsRegistry, trace_cap: int = 256):
        self.registry = registry
        self.traces = TraceBuffer(cap=trace_cap)
        for name, (kind, labels, help_) in METRIC_CATALOG.items():
            fam = getattr(registry, kind)(name, help_, labels=labels)
            setattr(self, name.removeprefix("repro_"), fam)

    @property
    def enabled(self) -> bool:
        return self.registry.enabled
