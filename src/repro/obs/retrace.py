"""Compile/retrace detection: the test trick promoted to a signal.

PR 7's warmup contract says a warmed tenant never pays an XLA
trace/compile on a live request, and the tests pin it by comparing
``pred._fn._cache_size()`` before and after traffic.  That comparison
is exactly the production signal an operator needs — a post-warmup
retrace means a shape leaked past the bucket ladder (or a policy swap
invalidated the fused program) and some request just ate a multi-ms
compile inside its latency budget.  This module makes the trick a
first-class monitor:

  * ``fn_cache_size(fn)`` — entries in one jitted callable's trace
    cache (``None`` when the callable doesn't expose one);
  * ``jit_cache_size(pred)`` — total reachable trace-cache entries for
    a predictor: a ``trace_cache_size()`` method wins when the
    predictor defines one (cascade predictors sum their stages, the
    fused variant adds its program cache), otherwise the standard
    surfaces are scanned (``_fn``, ``_jit_cache``);
  * ``CompileWatch`` — per-tenant delta tracker.  ``poll()`` after each
    batch returns ``(compiles, anomalies)``: every cache growth is a
    compile event; growths after ``mark_warm()`` are anomalies.  A
    cache *shrink* (e.g. ``set_policy`` dropping the fused jit cache)
    resets the baseline instead of counting negative.

``ServingRuntime`` polls after every batch and feeds the
``repro_compile_events_total`` / ``repro_retrace_anomalies_total``
counters (docs/OBSERVABILITY.md §Retrace anomalies).
"""
from __future__ import annotations

from typing import Optional


def fn_cache_size(fn) -> Optional[int]:
    """Trace-cache entries of one jitted callable, or ``None`` if it
    has no cache to inspect (plain Python callables, Pallas closures)."""
    cs = getattr(fn, "_cache_size", None)
    if callable(cs):
        try:
            return int(cs())
        except Exception:           # noqa: BLE001 — a jax-internal API:
            return None             # degrade to "unobservable", never raise
    return None


def jit_cache_size(pred) -> Optional[int]:
    """Total reachable trace-cache entries for a predictor, or ``None``
    when nothing observable was found (monitoring then degrades to
    no-op rather than miscounting)."""
    meth = getattr(pred, "trace_cache_size", None)
    if callable(meth):
        return meth()
    total, found = 0, False
    size = fn_cache_size(getattr(pred, "_fn", None))
    if size is not None:
        total, found = total + size, True
    cache = getattr(pred, "_jit_cache", None)
    if isinstance(cache, dict):
        for fn in cache.values():
            size = fn_cache_size(fn)
            if size is not None:
                total, found = total + size, True
    return total if found else None


class CompileWatch:
    """Delta tracker over one predictor's trace caches.

    ``poll()`` is cheap (a few attribute reads per call) and safe on
    predictors with no observable cache — it just reports zeros."""

    def __init__(self, pred):
        self.pred = pred
        self.warmed = False
        self.compiles_total = 0       # every observed cache growth
        self.anomalies_total = 0      # growths after mark_warm()
        self._last = jit_cache_size(pred) or 0

    @property
    def observable(self) -> bool:
        return jit_cache_size(self.pred) is not None

    def refresh(self) -> None:
        """Re-baseline without counting (e.g. right after warmup traced
        the bucket ladder on purpose)."""
        self._last = jit_cache_size(self.pred) or 0

    def mark_warm(self) -> None:
        """From here on, any new trace is an anomaly."""
        self.refresh()
        self.warmed = True

    def poll(self) -> tuple:
        """(new compile events, new anomalies) since the last poll."""
        size = jit_cache_size(self.pred)
        if size is None:
            return 0, 0
        delta = size - self._last
        self._last = size
        if delta <= 0:
            # shrink = a deliberate cache reset (policy swap); re-baseline
            return 0, 0
        self.compiles_total += delta
        if self.warmed:
            self.anomalies_total += delta
            return delta, delta
        return delta, 0
