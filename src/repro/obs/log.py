"""Structured logging: level + component + key=value fields.

Replaces the bare ``print`` calls in the launch drivers with one small,
dependency-free logger whose output is grep- and machine-friendly:

    2026-08-07T12:00:01.123 INFO  serve  fleet_saved manifest=/tmp/m.json

Semantics:

  * Levels ``debug < info < warning < error``; the effective level is
    resolved **per call** from ``REPRO_LOG_LEVEL`` when set, else
    ``warning`` under pytest (quiet-by-default in tests — the suite's
    output stays readable), else ``info``.
  * One line per event, written to ``stderr`` and flushed — stdout
    stays reserved for the drivers' JSON results.
  * Values render as ``key=value``; values containing whitespace or
    ``=`` are quoted via ``repr`` so a line always splits back into
    fields.

This is deliberately not the stdlib ``logging`` module: no handler
graphs, no global config mutation from a library, no formatter state —
the launch drivers are scripts, and a scripted deployment greps these
lines or ships them as-is.
"""
from __future__ import annotations

import datetime
import os
import sys
import threading
from typing import Optional, TextIO

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}

_lock = threading.Lock()
_loggers: dict[str, "StructLogger"] = {}
_forced_level: Optional[str] = None


def set_level(level: Optional[str]) -> None:
    """Force the process-wide level (``None`` restores env resolution)."""
    global _forced_level
    if level is not None and level not in LEVELS:
        raise ValueError(f"unknown log level {level!r}; "
                         f"one of {sorted(LEVELS)}")
    _forced_level = level


def effective_level() -> str:
    """Resolved per call so env/monkeypatch changes take effect live."""
    if _forced_level is not None:
        return _forced_level
    env = os.environ.get("REPRO_LOG_LEVEL", "").lower()
    if env in LEVELS:
        return env
    if "PYTEST_CURRENT_TEST" in os.environ or "pytest" in sys.modules:
        return "warning"              # quiet-by-default under pytest
    return "info"


def _fmt_value(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    if s == "" or any(c in s for c in (" ", "=", '"', "\n", "\t")):
        return repr(s)
    return s


class StructLogger:
    """One component's logger; see the module docstring for the line
    format.  ``stream=`` injects the sink (tests capture a StringIO)."""

    def __init__(self, component: str, stream: Optional[TextIO] = None):
        self.component = component
        self._stream = stream

    def enabled_for(self, level: str) -> bool:
        return LEVELS[level] >= LEVELS[effective_level()]

    def log(self, level: str, event: str, **fields) -> None:
        if level not in LEVELS:
            raise ValueError(f"unknown log level {level!r}")
        if not self.enabled_for(level):
            return
        ts = datetime.datetime.now().isoformat(timespec="milliseconds")
        parts = [ts, level.upper().ljust(5), self.component, event]
        parts += [f"{k}={_fmt_value(v)}" for k, v in fields.items()]
        stream = self._stream if self._stream is not None else sys.stderr
        with _lock:                   # interleaved lines stay whole
            print(" ".join(parts), file=stream, flush=True)

    def debug(self, event: str, **fields) -> None:
        self.log("debug", event, **fields)

    def info(self, event: str, **fields) -> None:
        self.log("info", event, **fields)

    def warning(self, event: str, **fields) -> None:
        self.log("warning", event, **fields)

    def error(self, event: str, **fields) -> None:
        self.log("error", event, **fields)


def get_logger(component: str) -> StructLogger:
    """Process-wide logger per component name (cached)."""
    with _lock:
        lg = _loggers.get(component)
        if lg is None:
            lg = _loggers[component] = StructLogger(component)
        return lg
