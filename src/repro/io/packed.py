"""Packed ``.repro.npz`` serialization — forests and compiled predictors.

PACSET's observation is that deployment latency is dominated by how the
serialized model hits memory, so the on-disk layout should match the
access pattern.  This format stores the IR the way every engine walks
it: node records concatenated **per tree in preorder** (root first —
traversal order), leaf records **in-order** (the canonical left-to-right
numbering), padding stripped (ragged trees carried by offset arrays, not
rectangular padding), so a cold load streams exactly the bytes the
compiler needs and re-pads in one allocation.

Two kinds share the container (``docs/FORMATS.md``):

  * ``kind="forest"`` — the canonical IR; ``save_forest``/``load_forest``.
    Quantization metadata (scale/bits/feature ranges) rides in the header
    so a quantized forest round-trips bit-exactly.
  * ``kind="predictor"`` — a compiled engine artifact: the engine's
    device arrays (the fields its ``EngineSpec.serial_arrays`` declares),
    its scalar config, the recorded ``CompilePlan``, and the embedded
    forest.  ``load_predictor`` rebuilds the predictor **without
    recompiling** (no mask/packing reconstruction), which is the
    cold-start win ``benchmarks/bench_coldstart.py`` measures.

The header is a JSON string in the ``header`` entry; ``version`` gates
compatibility (readers reject newer majors loudly rather than
misinterpreting arrays).
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
from typing import Optional, Union

import numpy as np

from ..core.forest import Forest

FORMAT = "repro.pack"
VERSION = 1

PathLike = Union[str, os.PathLike]


# --------------------------------------------------------------------------- #
# Header plumbing
# --------------------------------------------------------------------------- #
def _norm(path: PathLike) -> str:
    # np.savez silently appends ".npz"; normalize so save/load agree
    p = os.fspath(path)
    return p if p.endswith(".npz") else p + ".npz"


def _write_npz(path: PathLike, header: dict, arrays: dict) -> None:
    header = dict(header, format=FORMAT, version=VERSION)
    np.savez(_norm(path), header=np.asarray(json.dumps(header)),
             **arrays)


def _read_npz(path: PathLike):
    try:
        npz = np.load(_norm(path), allow_pickle=False)
    except Exception as e:
        raise ValueError(f"{path!r} is not a readable .npz file: {e}") from e
    if "header" not in npz.files:
        raise ValueError(f"{path!r} has no header entry — not a "
                         f"{FORMAT} file")
    try:
        header = json.loads(str(npz["header"]))
    except ValueError as e:
        raise ValueError(f"{path!r} has a corrupt header: {e}") from e
    if header.get("format") != FORMAT:
        raise ValueError(f"{path!r}: unknown format "
                         f"{header.get('format')!r} (expected {FORMAT})")
    if int(header.get("version", -1)) > VERSION:
        raise ValueError(
            f"{path!r} is version {header['version']}, newer than this "
            f"reader (max {VERSION}) — upgrade before loading")
    return header, npz


# --------------------------------------------------------------------------- #
# Forest IR <-> packed arrays
# --------------------------------------------------------------------------- #
_NODE_FIELDS = ("feature", "threshold", "left", "right",
                "leaf_lo", "leaf_mid", "leaf_hi")


def _pack_forest(forest: Forest, prefix: str = "") -> tuple[dict, dict]:
    """Forest → (header-meta, arrays): padding stripped, nodes in
    preorder, leaves in-order, ragged boundaries in offset arrays."""
    T = forest.n_trees
    nn = forest.n_nodes.astype(np.int64)
    nl = forest.n_leaves_per_tree.astype(np.int64)
    node_off = np.zeros(T + 1, np.int64)
    leaf_off = np.zeros(T + 1, np.int64)
    np.cumsum(nn, out=node_off[1:])
    np.cumsum(nl, out=leaf_off[1:])

    arrays = {}
    for name in _NODE_FIELDS:
        full = getattr(forest, name)
        arrays[prefix + "node_" + name] = np.concatenate(
            [full[t, :nn[t]] for t in range(T)]) if T else full[:0, 0]
    arrays[prefix + "leaf_value"] = np.concatenate(
        [forest.leaf_value[t, :nl[t]] for t in range(T)])
    arrays[prefix + "node_offset"] = node_off
    arrays[prefix + "leaf_offset"] = leaf_off
    meta = {
        "n_trees": T, "n_leaves": forest.n_leaves,
        "n_classes": forest.n_classes, "n_features": forest.n_features,
        "max_depth": forest.max_depth,
        "quant_scale": forest.quant_scale, "quant_bits": forest.quant_bits,
        "leaf_scale": forest.leaf_scale,
    }
    # integer end-to-end extensions (docs/QUANT.md): written only when
    # set, so pre-existing artifacts stay byte-identical
    if forest.int_accum:
        meta["int_accum"] = True
    if forest.flint:
        meta["flint"] = True
    if forest.leaf_err_bound is not None:
        meta["leaf_err_bound"] = float(forest.leaf_err_bound)
    if forest.feat_lo is not None:
        arrays[prefix + "feat_lo"] = np.asarray(forest.feat_lo)
        arrays[prefix + "feat_hi"] = np.asarray(forest.feat_hi)
    if forest.feat_map is not None:
        # optimized IR (repro.optim drop_unused_features): the column
        # remap rides in its own array entry; n_features_in in the header
        # tells a reader the row width callers still pass (FORMATS.md)
        arrays[prefix + "feat_map"] = np.asarray(forest.feat_map,
                                                 dtype=np.int64)
        meta["n_features_in"] = forest.n_features_in
    return meta, arrays


def _unpack_forest(meta: dict, npz, prefix: str = "") -> Forest:
    T, L = int(meta["n_trees"]), int(meta["n_leaves"])
    C = int(meta["n_classes"])
    node_off = npz[prefix + "node_offset"]
    leaf_off = npz[prefix + "leaf_offset"]
    nn = np.diff(node_off).astype(np.int32)
    nl = np.diff(leaf_off).astype(np.int32)

    # vectorized ragged → rectangular scatter: row-major boolean masks
    # visit tree 0's slots first, matching the per-tree concatenation
    # order of _pack_forest — no Python loop on the cold-start path
    node_mask = np.arange(L - 1)[None, :] < nn[:, None]      # (T, L-1)
    leaf_mask = np.arange(L)[None, :] < nl[:, None]          # (T, L)
    padded = {}
    for name in _NODE_FIELDS:
        flat = npz[prefix + "node_" + name]
        fill = -1 if name == "feature" else 0
        full = np.full((T, L - 1), fill, dtype=flat.dtype)
        full[node_mask] = flat
        padded[name] = full
    lv_flat = npz[prefix + "leaf_value"]
    leaf_value = np.zeros((T, L, C), dtype=lv_flat.dtype)
    leaf_value[leaf_mask] = lv_flat

    feat_lo = npz[prefix + "feat_lo"] if prefix + "feat_lo" in npz.files \
        else None
    feat_hi = npz[prefix + "feat_hi"] if prefix + "feat_hi" in npz.files \
        else None
    feat_map = npz[prefix + "feat_map"] \
        if prefix + "feat_map" in npz.files else None
    n_features_src = None if feat_map is None \
        else meta.get("n_features_in")
    return Forest(
        n_trees=T, n_leaves=L, n_classes=C,
        n_features=int(meta["n_features"]),
        leaf_value=leaf_value, n_nodes=nn, n_leaves_per_tree=nl,
        max_depth=int(meta["max_depth"]),
        quant_scale=meta.get("quant_scale"),
        quant_bits=meta.get("quant_bits"),
        leaf_scale=float(meta.get("leaf_scale", 1.0)),
        feat_lo=feat_lo, feat_hi=feat_hi, feat_map=feat_map,
        n_features_src=n_features_src,
        int_accum=bool(meta.get("int_accum", False)),
        flint=bool(meta.get("flint", False)),
        leaf_err_bound=meta.get("leaf_err_bound"), **padded)


def peek(path: PathLike) -> dict:
    """Read just the header of a packed file (kind, shape, engine, ...)
    without materialising any arrays."""
    header, _ = _read_npz(path)
    return header


def save_forest(forest: Forest, path: PathLike) -> None:
    """Write the canonical IR as a packed ``.repro.npz`` (kind=forest)."""
    meta, arrays = _pack_forest(forest)
    _write_npz(path, {"kind": "forest", "forest": meta}, arrays)


def load_forest(path: PathLike) -> Forest:
    """Load a packed forest (bit-exact round trip, quantization included)."""
    header, npz = _read_npz(path)
    if header.get("kind") != "forest":
        raise ValueError(f"{path!r} holds a {header.get('kind')!r} "
                         "artifact, not a forest (use load_predictor)")
    return _unpack_forest(header["forest"], npz)


# --------------------------------------------------------------------------- #
# Compiled predictor artifacts
# --------------------------------------------------------------------------- #
def _class_path(obj) -> str:
    t = type(obj)
    return f"{t.__module__}:{t.__qualname__}"


def _resolve_class(path: str):
    mod, attr = path.split(":")
    return getattr(importlib.import_module(mod), attr)


def _encode_scalar(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:                                   # dtypes / dtype-likes (gemm)
        return {"__dtype__": np.dtype(v).name}
    except TypeError:
        raise TypeError(f"cannot serialize compiled scalar field "
                        f"{v!r} of type {type(v).__name__}")


def _decode_scalar(v):
    if isinstance(v, dict) and "__dtype__" in v:
        return np.dtype(v["__dtype__"])
    return v


def _getattr_path(obj, dotted: str):
    for part in dotted.split("."):
        obj = getattr(obj, part)
    return obj


def _walk_compiled(compiled, serial_arrays: tuple):
    """Compiled dataclass (possibly nested) → (classes, scalars, arrays).

    ``serial_arrays`` (from the ``EngineSpec``) names the array fields,
    dotted for nesting; every other dataclass field is either a scalar
    (serialized into the header), the host ``forest`` (embedded once), or
    a nested compiled dataclass reached by some dotted name.
    """
    arrays = {name: np.asarray(_getattr_path(compiled, name))
              for name in serial_arrays}
    prefixes = {""}
    for name in serial_arrays:        # every ancestor, not just the parent
        parts = name.split(".")[:-1]
        for i in range(1, len(parts) + 1):
            prefixes.add(".".join(parts[:i]))
    classes, scalars = {}, {}
    for prefix in sorted(prefixes):
        obj = _getattr_path(compiled, prefix) if prefix else compiled
        if not dataclasses.is_dataclass(obj):
            raise TypeError(f"compiled field {prefix or '<root>'!r} is not "
                            "a dataclass — cannot serialize")
        classes[prefix] = _class_path(obj)
        sc = {}
        for f in dataclasses.fields(obj):
            dotted = f"{prefix}.{f.name}" if prefix else f.name
            if dotted in arrays or f.name == "forest" or \
                    dotted in prefixes:
                continue
            sc[f.name] = _encode_scalar(getattr(obj, f.name))
        scalars[prefix] = sc
    return classes, scalars, arrays


def _rebuild_compiled(classes: dict, scalars: dict, npz,
                      forest: Optional[Forest], array_prefix: str = "c."):
    """Inverse of ``_walk_compiled``: instantiate nested dataclasses
    bottom-up from header metadata + npz arrays.  ``array_prefix``
    namespaces the npz entries (``c.`` for plain predictors, ``s{k}.c.``
    per stage of a cascade artifact)."""
    import jax.numpy as jnp
    array_names = [n[len(array_prefix):] for n in npz.files
                   if n.startswith(array_prefix)]
    built = {}
    # nested prefixes first (deepest innermost), the root ("") last
    order = sorted((p for p in classes if p),
                   key=lambda p: -p.count(".")) + [""]
    for prefix in order:
        cls = _resolve_class(classes[prefix])
        kw = dict(scalars.get(prefix, {}))
        kw = {k: _decode_scalar(v) for k, v in kw.items()}
        for f in dataclasses.fields(cls):
            dotted = f"{prefix}.{f.name}" if prefix else f.name
            if dotted in array_names:
                kw[f.name] = jnp.asarray(npz[array_prefix + dotted])
            elif f.name == "forest":
                kw[f.name] = forest
            elif dotted in built:
                kw[f.name] = built[dotted]
        built[prefix] = cls(**kw)
    return built[""]


def _spec_for_predictor(pred):
    """Find the registered EngineSpec a predictor came from: its eval fn
    is the spec's ``evaluate`` (disambiguates native vs unrolled, which
    share compiled arrays)."""
    from ..core import registry
    plan = getattr(pred, "plan", None)
    if plan is not None and getattr(plan, "n_devices", 1) == 1:
        spec = registry.get(plan.engine, plan.backend)
        if spec.evaluate is not None and spec.evaluate is getattr(
                pred, "_eval", None):
            return spec
    for spec in registry.specs():
        if spec.evaluate is not None and \
                spec.evaluate is getattr(pred, "_eval", None):
            return spec
    raise ValueError(
        f"cannot serialize {type(pred).__name__}: no registered engine "
        "matches its evaluate fn (tree-sharded and Pallas predictors "
        "are rebuilt from the forest, not serialized — save the forest)")


def _save_cascade(pred, path: PathLike, extra: Optional[dict]) -> None:
    """Serialize a ``CascadePredictor`` (kind=cascade): each stage's
    compiled device arrays (the engine's ``serial_arrays``, namespaced
    ``s{k}.c.``), the full forest once, and the gate policy's scalar
    config — so a load rebuilds the whole cascade, thresholds included,
    without recompiling any stage."""
    from ..cascade.policy import policy_to_header
    from ..core import registry
    spec = registry.get(pred.engine, pred.backend)
    if not spec.serial_arrays:
        raise ValueError(
            f"engine {pred.engine}/{pred.backend} declares no "
            "serial_arrays — its cascade artifact is not serializable "
            "(save the forest and rebuild)")
    arrays, stage_classes, stage_scalars = {}, [], []
    for k, sp in enumerate(pred.stage_predictors):
        classes, scalars, carrays = _walk_compiled(sp.compiled,
                                                   spec.serial_arrays)
        arrays.update({f"s{k}.c.{n}": v for n, v in carrays.items()})
        stage_classes.append(classes)
        stage_scalars.append(scalars)
    fmeta, farrays = _pack_forest(pred.forest, prefix="f.")
    arrays.update(farrays)
    plan = getattr(pred, "plan", None)
    header = {
        "kind": "cascade",
        "engine": pred.engine, "backend": pred.backend,
        "tune_name": spec.tune_name,
        "fused": bool(getattr(pred, "fused", False)),
        "stages": [int(s) for s in pred.stages],
        "policy": policy_to_header(pred.policy),
        "engine_kw": {k: _encode_scalar(v)
                      for k, v in pred.engine_kw.items()},
        "stage_classes": stage_classes, "stage_scalars": stage_scalars,
        "forest": fmeta,
        "plan": [[r.name, r.detail] for r in plan.records]
        if plan is not None else [],
    }
    if extra:
        header.update(extra)
    _write_npz(path, header, arrays)


def _load_cascade(header: dict, npz, path: PathLike):
    """Rebuild a cascade artifact: unpack the forest once, rebuild each
    stage's compiled arrays against its tree-slice of the IR, restore the
    gate policy from its header config — predictions are bit-identical to
    the saved cascade's (same stage arrays, same thresholds).  The
    ``fused`` header flag restores the fused variant (the loaded stage
    arrays back its single jitted program)."""
    from ..cascade import (CascadePredictor, CascadeSpec,
                           FusedCascadePredictor, tree_slice)
    from ..cascade.policy import policy_from_header
    from ..core import registry
    from ..core.pipeline import CompilePlan
    spec = registry.get(header["engine"], header["backend"])
    forest = _unpack_forest(header["forest"], npz, prefix="f.")
    stages = [int(s) for s in header["stages"]]
    bounds = [0] + stages
    stage_preds = []
    for k, (classes, scalars) in enumerate(zip(header["stage_classes"],
                                               header["stage_scalars"])):
        sub = tree_slice(forest, bounds[k], bounds[k + 1])
        compiled = _rebuild_compiled(classes, scalars, npz, sub,
                                     array_prefix=f"s{k}.c.")
        stage_preds.append(spec.predictor_cls(compiled, spec.evaluate))
    policy = policy_from_header(header["policy"])
    engine_kw = {k: _decode_scalar(v)
                 for k, v in (header.get("engine_kw") or {}).items()}
    fused = bool(header.get("fused", False))
    cls = FusedCascadePredictor if fused else CascadePredictor
    pred = cls(
        forest,
        CascadeSpec(stages=tuple(stages), policy=policy, fused=fused),
        engine=header["engine"], backend=header["backend"],
        engine_kw=engine_kw, stage_predictors=stage_preds)
    plan = CompilePlan(engine=header["engine"], backend=header["backend"])
    for name, detail in header.get("plan", []):
        plan.record(name, detail)
    plan.record("deserialize", f"loaded from {os.fspath(path)}")
    pred.plan = plan
    return pred


def save_predictor(pred, path: PathLike, *, extra: Optional[dict] = None
                   ) -> None:
    """Serialize a compiled predictor (kind=predictor), or a
    ``CascadePredictor`` (kind=cascade — per-stage arrays + gate config).

    The engine must declare its device arrays via
    ``EngineSpec.serial_arrays``; the embedded forest, scalar config, and
    recorded ``CompilePlan`` ride in the header.  ``extra`` merges
    caller metadata (e.g. the serving config) into the header.
    """
    from ..cascade.predictor import CascadePredictor
    if isinstance(pred, CascadePredictor):
        return _save_cascade(pred, path, extra)
    spec = _spec_for_predictor(pred)
    if not spec.serial_arrays:
        raise ValueError(f"engine {spec.name}/{spec.backend} declares no "
                         "serial_arrays — its artifact is not serializable")
    compiled = pred.compiled
    classes, scalars, carrays = _walk_compiled(compiled, spec.serial_arrays)
    forest = getattr(compiled, "forest", None)
    if forest is None and hasattr(compiled, "qs"):
        forest = getattr(compiled.qs, "forest", None)
    arrays = {f"c.{k}": v for k, v in carrays.items()}
    fmeta = None
    if forest is not None:
        fmeta, farrays = _pack_forest(forest, prefix="f.")
        arrays.update(farrays)
    plan = getattr(pred, "plan", None)
    header = {
        "kind": "predictor",
        "engine": spec.name, "backend": spec.backend,
        "tune_name": spec.tune_name,
        "classes": classes, "scalars": scalars,
        "forest": fmeta,
        "plan": [[r.name, r.detail] for r in plan.records]
        if plan is not None else [],
    }
    if extra:
        header.update(extra)
    _write_npz(path, header, arrays)


# --------------------------------------------------------------------------- #
# Autotuner cost-model artifact (repro.tune, docs/AUTOTUNE.md)
# --------------------------------------------------------------------------- #
COSTMODEL_FORMAT = "repro.costmodel"
COSTMODEL_VERSION = 1


def save_cost_model(path: PathLike, payload: dict) -> str:
    """Write a trained autotuner cost model (``repro.tune.CostModel``)
    as versioned JSON, same contract as the packed container: a format
    marker plus a version this reader refuses to exceed.  ``payload`` is
    the model's own serialization — this layer owns only the envelope."""
    path = os.fspath(path)
    doc = {"format": COSTMODEL_FORMAT, "version": COSTMODEL_VERSION,
           **payload}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, path)
    return path


def load_cost_model(path: PathLike) -> dict:
    """Read a ``save_cost_model`` artifact, rejecting unknown formats
    and newer versions loudly — ``-Os`` must never pick plans from a
    half-understood model file."""
    path = os.fspath(path)
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        raise ValueError(
            f"{path!r} is not a readable cost model: {e}") from e
    if not isinstance(doc, dict) or doc.get("format") != COSTMODEL_FORMAT:
        raise ValueError(
            f"{path!r}: unknown cost-model format "
            f"{doc.get('format') if isinstance(doc, dict) else doc!r} "
            f"(expected {COSTMODEL_FORMAT})")
    if int(doc.get("version", -1)) > COSTMODEL_VERSION:
        raise ValueError(
            f"{path!r} is cost-model version {doc['version']}, newer than "
            f"this reader (max {COSTMODEL_VERSION}) — upgrade first")
    return doc


# --------------------------------------------------------------------------- #
# Multi-tenant serving manifest
# --------------------------------------------------------------------------- #
MANIFEST_FORMAT = "repro.tenants"
MANIFEST_VERSION = 1


def save_manifest(path: PathLike, tenants: dict) -> str:
    """Write a multi-tenant serving manifest (plain JSON, versioned like
    the packed container): model id → ``{"artifact": <relative path>,
    "max_batch", "max_wait_ms", "slo"}``.  The artifacts are ordinary
    packed predictor/cascade files stored next to the manifest;
    ``inference.runtime.ServingRuntime.load`` cold-starts the whole
    fleet from one manifest — no sweep, no recompile (docs/SERVING.md,
    docs/FORMATS.md)."""
    path = os.fspath(path)
    for tid, e in tenants.items():
        if not isinstance(e, dict) or "artifact" not in e:
            raise ValueError(f"manifest entry for {tid!r} must be a dict "
                             f"with an 'artifact' path, got {e!r}")
    doc = {"format": MANIFEST_FORMAT, "version": MANIFEST_VERSION,
           "tenants": tenants}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def load_manifest(path: PathLike) -> dict:
    """Read a ``save_manifest`` file (or the directory holding a
    ``manifest.json``); returns model id → entry with the ``artifact``
    path resolved relative to the manifest's directory.  Malformed or
    newer-versioned manifests are rejected loudly — a serving fleet must
    never cold-start from a file it half-understands."""
    path = os.fspath(path)
    if os.path.isdir(path):
        path = os.path.join(path, "manifest.json")
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        raise ValueError(f"{path!r} is not a readable manifest: {e}") from e
    if not isinstance(doc, dict) or doc.get("format") != MANIFEST_FORMAT:
        raise ValueError(f"{path!r}: unknown manifest format "
                         f"{doc.get('format') if isinstance(doc, dict) else doc!r} "
                         f"(expected {MANIFEST_FORMAT})")
    if int(doc.get("version", -1)) > MANIFEST_VERSION:
        raise ValueError(
            f"{path!r} is manifest version {doc['version']}, newer than "
            f"this reader (max {MANIFEST_VERSION}) — upgrade first")
    tenants = doc.get("tenants")
    if not isinstance(tenants, dict) or not tenants:
        raise ValueError(f"{path!r} holds no tenants")
    base = os.path.dirname(os.path.abspath(path))
    out = {}
    for tid, e in tenants.items():
        if not isinstance(e, dict) or "artifact" not in e:
            raise ValueError(f"{path!r}: malformed entry for {tid!r}")
        e = dict(e)
        if not os.path.isabs(e["artifact"]):
            e["artifact"] = os.path.join(base, e["artifact"])
        out[tid] = e
    return out


def load_predictor(pred_or_path: PathLike, *, return_header: bool = False):
    """Rebuild a compiled predictor from a packed artifact — no
    recompilation: the engine's device arrays upload as-saved, so
    load-to-first-prediction skips mask construction, leaf packing, and
    the autotune sweep entirely.  Predictions are bit-identical to the
    saved predictor's (the arrays are the same bits)."""
    from ..core import registry
    from ..core.pipeline import CompilePlan
    path = pred_or_path
    header, npz = _read_npz(path)
    if header.get("kind") == "cascade":
        pred = _load_cascade(header, npz, path)
        return (pred, header) if return_header else pred
    if header.get("kind") != "predictor":
        raise ValueError(f"{path!r} holds a {header.get('kind')!r} "
                         "artifact, not a predictor (use load_forest)")
    spec = registry.get(header["engine"], header["backend"])
    forest = _unpack_forest(header["forest"], npz, prefix="f.") \
        if header.get("forest") is not None else None
    compiled = _rebuild_compiled(header["classes"], header["scalars"],
                                 npz, forest)
    pred = spec.predictor_cls(compiled, spec.evaluate)
    plan = CompilePlan(engine=spec.name, backend=spec.backend)
    for name, detail in header.get("plan", []):
        plan.record(name, detail)
    plan.record("deserialize", f"loaded from {os.fspath(path)}")
    pred.plan = plan
    return (pred, header) if return_header else pred
