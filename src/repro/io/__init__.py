"""repro.io — model ingestion + packed serialization (docs/FORMATS.md).

Front door for forests trained elsewhere and for durable compiled
artifacts::

    from repro import io

    forest = io.load_model("model.json")          # sniffs XGB/LGBM/shim
    forest = io.import_sklearn(fitted_rf)         # duck-typed, no sklearn
    io.save_forest(forest, "forest.repro.npz")    # packed IR

    pred = core.compile_forest(forest, engine="bitmm")
    io.save_predictor(pred, "model.pred.npz")     # compiled artifact
    pred = io.load_predictor("model.pred.npz")    # cold start, no compile
"""
from .importers import (import_lightgbm_json, import_sklearn,
                        import_xgboost_json, load_model,
                        sklearn_shim_from_json)
from .packed import (FORMAT, VERSION, load_cost_model, load_forest,
                     load_manifest, load_predictor, peek, save_cost_model,
                     save_forest, save_manifest, save_predictor)

__all__ = [
    "import_sklearn", "import_xgboost_json", "import_lightgbm_json",
    "load_model", "sklearn_shim_from_json",
    "save_forest", "load_forest", "save_predictor", "load_predictor",
    "save_manifest", "load_manifest",
    "save_cost_model", "load_cost_model",
    "peek", "FORMAT", "VERSION",
]
