"""Importers: externally trained ensembles → canonical ``Forest`` IR.

The paper evaluates forests trained elsewhere (sklearn / XGBoost /
LightGBM on a workstation) and deployed to the constrained target, so
model interchange is a front door, not an afterthought (InTreeger makes
the same argument for its integer-only pipeline).  Three sources:

  * ``import_sklearn`` — duck-typed over the sklearn estimator API
    (``estimators_`` + per-tree ``tree_`` arrays).  No sklearn import
    anywhere: a shim object with the same attributes works identically,
    which is how the golden-fixture tests run in containers without
    sklearn installed.
  * ``import_xgboost_json`` — XGBoost's ``dump_model``/``get_dump``
    JSON (list of recursive node dicts).  Pure-JSON parser, no xgboost
    dependency.
  * ``import_lightgbm_json`` — LightGBM's ``dump_model()`` JSON
    (``tree_info[*].tree_structure``).  Pure-JSON parser.

Split-semantics mapping (docs/FORMATS.md): the IR predicate is
``x <= t → left``.  sklearn and LightGBM already use ``<=``; XGBoost
uses ``x < t → yes``, which is mapped exactly for float32 comparisons by
``t' = nextafter(t, -inf)`` (the largest float32 below ``t``), so
``x < t  ⇔  x <= t'`` for every float32 ``x``.  Missing-value routing
(XGBoost ``missing``, LightGBM ``default_left``) is not modelled — the
engines assume fully observed features; importers reject NaN thresholds.
"""
from __future__ import annotations

import json
import math
import os
from typing import Optional, Sequence, Union

import numpy as np

from ..core.forest import Forest, from_trees
from ..trees.cart import Tree, TreeNode


def _tree_depth(root: TreeNode) -> int:
    if root.is_leaf:
        return 0
    return 1 + max(_tree_depth(root.left), _tree_depth(root.right))


def _count_leaves(root: TreeNode) -> int:
    if root.is_leaf:
        return 1
    return _count_leaves(root.left) + _count_leaves(root.right)


def _as_tree(root: TreeNode) -> Tree:
    return Tree(root, _count_leaves(root), _tree_depth(root))


def _strict_less_threshold(t: float) -> float:
    """Largest float32 below ``t``: maps ``x < t`` onto the IR's
    ``x <= t'`` exactly for float32 inputs.

    Exception: when that predecessor is subnormal (``|t|`` at or below
    the smallest normal float32), XLA's flush-to-zero would silently turn
    it into ±0 and flip the boundary — clamp to the nearest FTZ-safe
    value instead (exact for all normal inputs; subnormal inputs are
    flushed by the engines anyway)."""
    if math.isnan(t):
        raise ValueError("NaN split threshold (missing-value routing is "
                         "not supported by the engines)")
    if math.isinf(t):
        return t
    prev = np.nextafter(np.float32(t), np.float32(-np.inf))
    tiny = np.finfo(np.float32).tiny
    if prev != 0 and abs(prev) < tiny:     # subnormal → FTZ hazard
        prev = np.float32(0.0) if t > 0 else np.float32(-tiny)
    return float(prev)


# --------------------------------------------------------------------------- #
# sklearn (duck-typed)
# --------------------------------------------------------------------------- #
def _sklearn_tree_to_node(tree, node: int, value_fn) -> TreeNode:
    """One sklearn ``tree_`` array bundle → TreeNode graph.

    ``tree`` needs ``children_left``, ``children_right``, ``feature``,
    ``threshold``, ``value`` (sklearn's ``Tree`` object or any shim).
    """
    left = int(tree.children_left[node])
    if left < 0:                                  # TREE_LEAF == -1
        return TreeNode(value=value_fn(np.asarray(tree.value[node])))
    right = int(tree.children_right[node])
    thr = float(tree.threshold[node])
    if math.isnan(thr):
        raise ValueError("NaN split threshold in sklearn tree")
    return TreeNode(feature=int(tree.feature[node]), threshold=thr,
                    left=_sklearn_tree_to_node(tree, left, value_fn),
                    right=_sklearn_tree_to_node(tree, right, value_fn))


def _estimator_trees(model) -> list:
    """``estimators_`` flattened to ``tree_`` bundles (GBT stores a 2-D
    object array of stage × output estimators)."""
    ests = np.asarray(model.estimators_, dtype=object).ravel().tolist()
    return [e.tree_ if hasattr(e, "tree_") else e for e in ests]


def import_sklearn(model, n_features: Optional[int] = None) -> Forest:
    """sklearn ``RandomForestClassifier`` / ``RandomForestRegressor`` /
    ``GradientBoostingRegressor`` (or any duck-typed equivalent) → IR.

    Dispatch is attribute-based (``learning_rate`` ⇒ boosting), so a shim
    carrying the same arrays imports identically — no sklearn import.
    Classifier forests average per-tree class distributions (the IR leaf
    holds ``proba / n_trees``, matching ``predict_proba``); regressor
    forests average raw leaf means; boosting sums ``learning_rate``-scaled
    leaves on top of the init constant.
    """
    trees = _estimator_trees(model)
    if not trees:
        raise ValueError("model has no estimators_ to import")
    T = len(trees)
    d = int(n_features if n_features is not None
            else getattr(model, "n_features_in_"))

    if hasattr(model, "learning_rate"):           # gradient boosting
        if int(getattr(model, "n_classes_", 0) or 0) >= 2:
            # GradientBoostingClassifier: multiclass stores a stage ×
            # class estimator grid that must NOT be summed into one
            # scalar, and even the binary case hides its log-odds prior
            # in an init_ without constant_ — refusing beats silently
            # shifted or garbage scores
            raise ValueError(
                "sklearn gradient-boosting *classifiers* are not "
                "supported (per-class logit grids / log-odds init priors) "
                "— export the booster as an XGBoost/LightGBM JSON dump "
                "and use those importers instead")
        lr = float(model.learning_rate)
        base = 0.0
        init = getattr(model, "init_", None)
        if init is not None and hasattr(init, "constant_"):
            base = float(np.ravel(init.constant_)[0])

        def value_fn(v):
            return np.asarray([float(v.ravel()[0]) * lr])

        roots = [_sklearn_tree_to_node(t, 0, value_fn) for t in trees]
        _check_n_features(d, roots)
        return from_trees([_as_tree(r) for r in roots], n_features=d,
                          n_classes=1, base_score=base)

    is_classifier = getattr(model, "n_classes_", 1) and \
        int(getattr(model, "n_classes_", 1)) > 1
    if is_classifier:
        C = int(model.n_classes_)

        def value_fn(v):
            counts = np.asarray(v, dtype=np.float64).ravel()[:C]
            tot = counts.sum()
            return (counts / tot if tot > 0 else
                    np.full(C, 1.0 / C)) / T
    else:
        C = 1

        def value_fn(v):
            return np.asarray([float(v.ravel()[0]) / T])

    roots = [_sklearn_tree_to_node(t, 0, value_fn) for t in trees]
    _check_n_features(d, roots)
    return from_trees([_as_tree(r) for r in roots], n_features=d,
                      n_classes=C)


# --------------------------------------------------------------------------- #
# XGBoost JSON dump
# --------------------------------------------------------------------------- #
def _xgb_feature_id(split, feat_map: dict, pinned: bool) -> int:
    """Split name → column index.  With caller-``pinned`` names every
    name (``fN`` included) resolves through the map — a miss is appended
    past the pinned range and rejected by the caller; unpinned, ``"f12"``
    parses to 12 and other names get first-appearance indices."""
    s = str(split)
    if s in feat_map:
        return feat_map[s]
    if not pinned and s.startswith("f") and s[1:].isdigit():
        return int(s[1:])
    return feat_map.setdefault(s, len(feat_map))


def _xgb_node(nd: dict, feat_map: dict, pinned: bool) -> TreeNode:
    if "leaf" in nd:
        return TreeNode(value=np.asarray([float(nd["leaf"])]))
    children = {c["nodeid"]: c for c in nd["children"]}
    yes, no = children[nd["yes"]], children[nd["no"]]
    # x < split_condition → yes (left); exact float32 mapping to <=
    thr = _strict_less_threshold(float(nd["split_condition"]))
    return TreeNode(feature=_xgb_feature_id(nd["split"], feat_map, pinned),
                    threshold=thr,
                    left=_xgb_node(yes, feat_map, pinned),
                    right=_xgb_node(no, feat_map, pinned))


def import_xgboost_json(dump: Union[str, Sequence], *,
                        n_features: Optional[int] = None,
                        n_classes: int = 1,
                        base_score: float = 0.0,
                        feature_names: Optional[Sequence[str]] = None
                        ) -> Forest:
    """XGBoost ``Booster.get_dump(dump_format="json")`` /
    ``dump_model(..., dump_format="json")`` output → IR.

    Accepts the parsed list of per-tree dicts, a list of per-tree JSON
    strings (``get_dump``'s return), or one JSON string holding the whole
    array.  ``n_classes > 1`` applies XGBoost's round-robin class
    assignment (tree ``i`` scores class ``i % n_classes``).  ``base_score``
    is not part of the dump — pass the booster's value if it matters
    (raw-score dumps only; sigmoid/softmax heads are the caller's job).
    ``feature_names`` fixes the name → column mapping for dumps with
    non-``fN`` split names (the booster's ``feature_names``, in training
    column order); without it, named features get first-appearance
    indices — fine for single-feature models, a silent column
    permutation otherwise.
    """
    if isinstance(dump, str):
        dump = json.loads(dump)
    trees_json = [json.loads(t) if isinstance(t, str) else t for t in dump]
    if not trees_json:
        raise ValueError("empty XGBoost dump (no trees)")
    pinned = feature_names is not None
    feat_map: dict = {str(n): i for i, n in enumerate(feature_names)} \
        if pinned else {}
    n_named = len(feat_map)
    roots = [_xgb_node(t, feat_map, pinned) for t in trees_json]
    if pinned and len(feat_map) > n_named:
        unknown = sorted(k for k, v in feat_map.items() if v >= n_named)
        raise ValueError(f"dump references features {unknown} missing from "
                         "feature_names")
    trees = [_as_tree(r) for r in roots]
    d = _check_n_features(n_features, roots) if n_features is not None \
        else max(_max_feature(roots) + 1, len(feat_map))
    if n_classes > 1:
        tree_class = [i % n_classes for i in range(len(trees))]
        forest = from_trees(trees, n_features=d, n_classes=n_classes,
                            tree_class=tree_class)
        if base_score:
            # every class margin carries the base: spread it over that
            # class's trees (each contributes exactly one leaf per row)
            counts = np.bincount(tree_class, minlength=n_classes)
            if (counts == 0).any():
                raise ValueError(
                    f"base_score={base_score} needs at least one tree per "
                    f"class (got {counts.tolist()} for {n_classes} classes)")
            for t in range(forest.n_trees):
                c = tree_class[t]
                nl = int(forest.n_leaves_per_tree[t])
                forest.leaf_value[t, :nl, c] += base_score / counts[c]
        return forest
    return from_trees(trees, n_features=d, n_classes=1,
                      base_score=base_score)


# --------------------------------------------------------------------------- #
# LightGBM JSON dump
# --------------------------------------------------------------------------- #
def _lgbm_node(nd: dict) -> TreeNode:
    if "leaf_value" in nd and "split_feature" not in nd:
        return TreeNode(value=np.asarray([float(nd["leaf_value"])]))
    dt = nd.get("decision_type", "<=")
    if dt != "<=":
        raise ValueError(f"unsupported LightGBM decision_type {dt!r} "
                         "(only numerical '<=' splits import)")
    thr = float(nd["threshold"])
    if math.isnan(thr):
        raise ValueError("NaN split threshold in LightGBM tree")
    return TreeNode(feature=int(nd["split_feature"]), threshold=thr,
                    left=_lgbm_node(nd["left_child"]),
                    right=_lgbm_node(nd["right_child"]))


def import_lightgbm_json(dump: Union[str, dict], *,
                         n_features: Optional[int] = None) -> Forest:
    """LightGBM ``Booster.dump_model()`` JSON (string or parsed dict) → IR.

    Multiclass models (``num_class > 1``) use LightGBM's round-robin tree
    → class layout; binary/regression objectives stay scalar (C=1, raw
    scores — apply the link function downstream if you need probabilities).
    """
    if isinstance(dump, str):
        dump = json.loads(dump)
    infos = dump.get("tree_info")
    if not infos:
        raise ValueError("not a LightGBM dump_model JSON (no tree_info)")
    roots = [_lgbm_node(t["tree_structure"]) for t in infos]
    trees = [_as_tree(r) for r in roots]
    C = int(dump.get("num_class", 1))
    if n_features is None:
        mfi = dump.get("max_feature_idx")
        n_features = (int(mfi) + 1 if mfi is not None
                      else _max_feature(roots) + 1)
    else:
        _check_n_features(int(n_features), roots)
    if C > 1:
        tree_class = [i % C for i in range(len(trees))]
        return from_trees(trees, n_features=int(n_features), n_classes=C,
                          tree_class=tree_class)
    return from_trees(trees, n_features=int(n_features), n_classes=1)


def _max_feature(roots: Sequence[TreeNode]) -> int:
    def walk(nd: TreeNode) -> int:
        if nd.is_leaf:
            return -1
        return max(nd.feature, walk(nd.left), walk(nd.right))
    return max((walk(r) for r in roots), default=-1)


def _check_n_features(d: int, roots: Sequence[TreeNode]) -> int:
    """An ``n_features`` hint below the max referenced index would make
    engines gather a clamped (wrong) column with no error — reject it."""
    mf = _max_feature(roots)
    if d <= mf:
        raise ValueError(f"n_features={d} is too small: the model "
                         f"references feature index {mf}")
    return d


# --------------------------------------------------------------------------- #
# Auto-detecting file loader
# --------------------------------------------------------------------------- #
def _accepted_kw(fn, kw: dict) -> dict:
    """Keep only the hints the matched importer's signature accepts —
    self-describing formats (packed npz, LightGBM's ``num_class``) carry
    their own metadata, so inapplicable hints are ignored, not fatal."""
    import inspect
    params = inspect.signature(fn).parameters
    return {k: v for k, v in kw.items() if k in params}


def load_model(path: Union[str, os.PathLike], **kw) -> Forest:
    """One front door for model files: sniffs the format and imports.

      * ``*.npz`` / ``*.repro.npz`` — packed IR (``io.packed``),
      * JSON array of node dicts    — XGBoost dump,
      * JSON object with ``tree_info``   — LightGBM dump,
      * JSON object with ``estimators``  — the sklearn-shim JSON the
        golden fixtures use (``sklearn_shim_from_json``).

    ``**kw`` holds importer hints (``n_classes``, ``feature_names``,
    ...); each hint reaches the matched importer only if its signature
    accepts it — formats that carry the metadata themselves ignore it.
    """
    path = os.fspath(path)
    if path.endswith(".npz"):
        from .packed import load_forest
        return load_forest(path)
    with open(path) as f:
        obj = json.load(f)
    if isinstance(obj, list):
        return import_xgboost_json(obj, **_accepted_kw(
            import_xgboost_json, kw))
    if isinstance(obj, dict) and "tree_info" in obj:
        return import_lightgbm_json(obj, **_accepted_kw(
            import_lightgbm_json, kw))
    if isinstance(obj, dict) and "estimators" in obj:
        return import_sklearn(sklearn_shim_from_json(obj), **_accepted_kw(
            import_sklearn, kw))
    raise ValueError(f"unrecognized model format in {path!r} (expected an "
                     "XGBoost JSON dump, a LightGBM dump_model JSON, a "
                     "sklearn-shim JSON, or a packed .npz)")


# --------------------------------------------------------------------------- #
# sklearn shim (fixture / file form of the duck-typed estimator API)
# --------------------------------------------------------------------------- #
class _ShimTree:
    """Array bundle quacking like ``DecisionTree*.tree_``."""

    def __init__(self, d: dict):
        self.children_left = np.asarray(d["children_left"], np.int64)
        self.children_right = np.asarray(d["children_right"], np.int64)
        self.feature = np.asarray(d["feature"], np.int64)
        self.threshold = np.asarray(d["threshold"], np.float64)
        self.value = np.asarray(d["value"], np.float64)


class _ShimModel:
    """Quacks like a fitted sklearn ensemble, built from plain JSON."""

    def __init__(self, d: dict):
        self.estimators_ = [_ShimTree(t) for t in d["estimators"]]
        self.n_features_in_ = int(d["n_features"])
        if "n_classes" in d:
            self.n_classes_ = int(d["n_classes"])
        if "learning_rate" in d:
            self.learning_rate = float(d["learning_rate"])
            if "init_constant" in d:
                self.init_ = type("Init", (), {
                    "constant_": np.asarray([d["init_constant"]])})()


def sklearn_shim_from_json(d: dict) -> _ShimModel:
    """JSON tree arrays → an object ``import_sklearn`` accepts — the
    serialized form of sklearn models for environments without sklearn
    (and the golden-fixture format under ``tests/fixtures/``)."""
    return _ShimModel(d)
