"""jit'd wrappers around the Pallas forest kernels: padding, dtype prep,
predictor objects matching the XLA engines' interface."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine_select import bucket_batch
from ..core.forest import Forest
from ..core.quantize import leaf_scale, quantize_inputs
from ..core.quickscorer import bitmm_full_word, bitmm_pack_arrays
from ..core.registry import BasePredictor, ensure_feature_column
from . import gemm_forest_kernel, quickscorer_kernel


def _pad_to(x: np.ndarray, axis: int, mult: int, fill=0) -> np.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


def _thr_pad_value(forest: Forest):
    if np.issubdtype(forest.threshold.dtype, np.integer):
        return np.iinfo(forest.threshold.dtype).max
    return np.float32(np.inf)


def bucket_rows(n: int, block_b: int) -> int:
    """Padded batch size: ``block_b × 2^k`` — power-of-two buckets so any
    stream of batch sizes triggers at most O(log B_max) kernel compiles
    instead of one per distinct padded batch.  Same bucketing policy as
    the autotuner's ``engine_select.bucket_batch``, in units of blocks."""
    return block_b * bucket_batch(-(-n // block_b))


def _out_dtype(forest: Forest, block_t: int):
    """Kernel output dtype: int32 cross-tile accumulation for int-accum
    forests.  The per-tile partial stays an f32 leaf matmul, which is
    exact only while ``block_t × max|leaf| < 2^24`` — checked here at
    build time so the bit-exactness claim can never silently degrade
    (docs/QUANT.md)."""
    if not forest.int_accum:
        return jnp.float32
    lv = forest.leaf_value
    max_abs = int(np.abs(lv.astype(np.int64)).max()) if lv.size else 0
    if block_t * max_abs >= 2 ** 24:
        raise ValueError(
            f"pallas int accumulation needs block_t*max|leaf| < 2^24, got "
            f"{block_t}*{max_abs}; lower block_t or quantize to fewer bits")
    return jnp.int32


class _PallasPredictor(BasePredictor):
    """Kernel-backed predictor on the shared base: overrides the predict
    path for batch bucketing/padding, inherits predict_class/proba."""

    def __init__(self, forest: Forest, fn, block_b: int):
        if forest.flint:
            raise ValueError(
                "FLInt forests are unsupported on the pallas backend: the "
                "kernels cast input rows to f32, which cannot represent "
                "int32 FLInt keys (use backend='jax')")
        # no BasePredictor.__init__: fn is already jit'd by the builders
        # and the "compiled" state is the host forest + closure arrays
        self.forest = forest
        self._fn = fn
        self.block_b = block_b
        self.leaf_scale = leaf_scale(forest)
        self._buckets: set[int] = set()

    def transform_inputs(self, X: np.ndarray) -> np.ndarray:
        return quantize_inputs(self.forest,
                               np.asarray(X)).astype(np.float32)

    def predict_transformed(self, Xq: np.ndarray) -> np.ndarray:
        # kernels take f32 rows; coerce here so cascade stages can feed
        # the shared pre-quantized (int) matrix without a per-stage cast
        Xq = ensure_feature_column(np.asarray(Xq, dtype=np.float32))
        B = Xq.shape[0]
        bucket = bucket_rows(B, self.block_b)
        self._buckets.add(bucket)
        Xp = _pad_to(Xq, 0, bucket)
        out = np.asarray(self._fn(jnp.asarray(Xp)))
        # int-accum kernels return int32 totals; the f32 cast + pow2
        # descale matches the XLA engines' rounding bit-for-bit
        return out[:B].astype(np.float32) / self.leaf_scale

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.predict_transformed(self.transform_inputs(X))

    @property
    def n_compiles(self) -> int:
        """Distinct compiled kernel variants: the jit cache is keyed on the
        padded input shape, so distinct buckets == distinct compiles."""
        return len(self._buckets)


def _qs_arrays(forest: Forest, block_t: int):
    """QuickScorer kernel arrays (feat, thr, masks, init_idx, leaf_val),
    tree axis padded to ``block_t`` with inert trees (+inf thresholds →
    no predicate fires, init 0 → leaf 0 → all-zero leaf row).  Shared by
    the per-forest predictor and the fused cascade builder, which preps
    each stage slice independently so stage scores match the staged
    per-stage kernels bit-for-bit."""
    thr_pad = _thr_pad_value(forest)
    feat = _pad_to(np.maximum(forest.feature, 0).astype(np.int32), 0, block_t)
    thr = forest.threshold.astype(np.float32).copy()
    thr[forest.feature < 0] = np.float32(thr_pad) if np.isfinite(
        np.float32(thr_pad)) else np.float32(np.inf)
    thr = _pad_to(thr, 0, block_t, fill=np.float32(np.inf))
    masks = _pad_to(forest.node_masks(), 0, block_t, fill=0xFFFFFFFF)
    init_idx = _pad_to(forest.init_leafidx(), 0, block_t)           # pad: 0
    lv = forest.leaf_value.astype(np.float32)
    leaf_val = _pad_to(lv, 0, block_t)                              # pad: 0
    return feat, thr, masks, init_idx, leaf_val


def pallas_qs_predictor(forest: Forest, block_b: int = 128, block_t: int = 8,
                        interpret: bool = True) -> _PallasPredictor:
    """QuickScorer bitvector engine, Pallas backend."""
    feat, thr, masks, init_idx, leaf_val = _qs_arrays(forest, block_t)
    out_dtype = _out_dtype(forest, block_t)

    feat_j, thr_j = jnp.asarray(feat), jnp.asarray(thr)
    masks_j, init_j = jnp.asarray(masks), jnp.asarray(init_idx)
    leaf_j = jnp.asarray(leaf_val)

    @jax.jit
    def fn(X):
        return quickscorer_kernel.qs_forward(
            X, feat_j, thr_j, masks_j, init_j, leaf_j,
            block_b=block_b, block_t=block_t, interpret=interpret,
            out_dtype=out_dtype)

    return _PallasPredictor(forest, fn, block_b)


def pallas_fused_cascade_qs(forest: Forest, stages, policy, *,
                            block_b: int = 128, block_t: int = 8,
                            interpret: bool = True):
    """Single-kernel cascade for the bitvector engine: all stages + the
    in-kernel gate (``cascade_kernel.py``).  Returns a jitted
    ``(Xp (B, d) f32, valid (B,) bool) -> (scores (B, C) descaled,
    exit_stage (B, 1) i32)`` with ``B`` a multiple of ``block_b``;
    ``FusedCascadePredictor`` owns the batch padding and exit-count
    reduction around it."""
    from ..cascade.predictor import tree_slice
    from . import cascade_kernel

    if forest.flint:
        raise ValueError(
            "FLInt forests are unsupported on the pallas backend: the "
            "fused cascade kernel casts input rows to f32, which cannot "
            "represent int32 FLInt keys (use backend='jax')")
    bounds = (0,) + tuple(stages)
    parts = [_qs_arrays(tree_slice(forest, bounds[k], bounds[k + 1]), block_t)
             for k in range(len(stages))]
    feat, thr, masks, init_idx, leaf_val = (
        np.concatenate([p[i] for p in parts]) for i in range(5))
    stage_bounds = (0,) + tuple(
        np.cumsum([p[0].shape[0] for p in parts]).tolist())
    scale = leaf_scale(forest)

    feat_j, thr_j = jnp.asarray(feat), jnp.asarray(thr)
    masks_j, init_j = jnp.asarray(masks), jnp.asarray(init_idx)
    leaf_j = jnp.asarray(leaf_val)

    @jax.jit
    def fn(Xp, valid):
        scores, exit_stage = cascade_kernel.cascade_qs_forward(
            Xp, valid.astype(jnp.float32)[:, None],
            feat_j, thr_j, masks_j, init_j, leaf_j,
            stage_bounds=stage_bounds, policy=policy,
            inv_scale=1.0 / scale, block_b=block_b, interpret=interpret)
        # power-of-two scale: the multiply is exact on quantized forests
        return scores * jnp.float32(1.0 / scale), exit_stage

    return fn


def pallas_bitmm_predictor(forest: Forest, block_b: int = 128,
                           block_t: int = 8, block_n: int = 128,
                           interpret: bool = True) -> _PallasPredictor:
    """Bit-matmul QuickScorer engine, Pallas backend (DESIGN.md §2.4).

    Fuses cond-compute, the packed clear-count bit-matmul, exit-leaf
    recovery, and the leaf-table lookup in one VMEM-resident tile."""
    packed, bias, bits, npack = bitmm_pack_arrays(forest)
    G = packed.shape[-1]
    feat = _pad_to(np.maximum(forest.feature, 0).astype(np.int32), 0, block_t)
    thr = forest.threshold.astype(np.float32).copy()
    thr[forest.feature < 0] = np.float32(np.inf)
    thr = _pad_to(thr, 0, block_t, fill=np.float32(np.inf))
    packed = _pad_to(packed, 0, block_t)                       # pad: 0
    # padding trees: every leaf field biased "cleared" → no survivor →
    # leaf 0 → all-zero leaf row → contributes nothing.
    bias = _pad_to(bias, 0, block_t, fill=float(bitmm_full_word(bits, npack)))
    leaf_val = _pad_to(forest.leaf_value.astype(np.float32), 0, block_t)
    out_dtype = _out_dtype(forest, block_t)

    feat_j, thr_j = jnp.asarray(feat), jnp.asarray(thr)
    packed_j, bias_j = jnp.asarray(packed), jnp.asarray(bias)
    leaf_j = jnp.asarray(leaf_val)
    n_leaves = forest.n_leaves

    @jax.jit
    def fn(X):
        return quickscorer_kernel.qs_bitmm_forward(
            X, feat_j, thr_j, packed_j, bias_j, leaf_j,
            bits=bits, npack=npack, n_leaves=n_leaves,
            block_b=block_b, block_t=block_t, block_n=block_n,
            interpret=interpret, out_dtype=out_dtype)

    return _PallasPredictor(forest, fn, block_b)


def pallas_gemm_predictor(forest: Forest, block_b: int = 128, block_t: int = 8,
                          interpret: bool = True) -> _PallasPredictor:
    """GEMM (Hummingbird/MXU) engine, Pallas backend."""
    from ..core.baselines import compile_gemm
    g = compile_gemm(forest)                     # reuse A/Bvec construction
    feat = _pad_to(np.asarray(g.feat), 0, block_t)
    # padding nodes: A rows are zero so S value is irrelevant; use -inf so
    # S=0 deterministically.
    thr = np.asarray(g.thr, dtype=np.float32).copy()
    thr[~np.asarray(g.valid)] = -np.inf
    thr = _pad_to(thr, 0, block_t, fill=-np.inf)
    A = _pad_to(np.asarray(g.A, dtype=np.float32), 0, block_t)
    Bvec = _pad_to(np.asarray(g.Bvec, dtype=np.float32), 0, block_t,
                   fill=forest.n_leaves + 1.0)
    leaf_val = _pad_to(np.asarray(g.leaf_val, dtype=np.float32), 0, block_t)
    out_dtype = _out_dtype(forest, block_t)

    feat_j, thr_j = jnp.asarray(feat), jnp.asarray(thr)
    A_j, B_j, leaf_j = jnp.asarray(A), jnp.asarray(Bvec), jnp.asarray(leaf_val)

    @jax.jit
    def fn(X):
        return gemm_forest_kernel.gemm_forward(
            X, feat_j, thr_j, A_j, B_j, leaf_j,
            block_b=block_b, block_t=block_t, interpret=interpret,
            out_dtype=out_dtype)

    return _PallasPredictor(forest, fn, block_b)
