"""jit'd wrappers around the Pallas forest kernels: padding, dtype prep,
predictor objects matching the XLA engines' interface."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.forest import Forest
from ..core.quantize import leaf_scale, quantize_inputs
from . import gemm_forest_kernel, quickscorer_kernel


def _pad_to(x: np.ndarray, axis: int, mult: int, fill=0) -> np.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


def _thr_pad_value(forest: Forest):
    if np.issubdtype(forest.threshold.dtype, np.integer):
        return np.iinfo(forest.threshold.dtype).max
    return np.float32(np.inf)


class _PallasPredictor:
    def __init__(self, forest: Forest, fn, block_b: int):
        self.forest = forest
        self._fn = fn
        self.block_b = block_b
        self.leaf_scale = leaf_scale(forest)

    def predict(self, X: np.ndarray) -> np.ndarray:
        Xq = quantize_inputs(self.forest, np.asarray(X)).astype(np.float32)
        B = Xq.shape[0]
        Xp = _pad_to(Xq, 0, self.block_b)
        out = np.asarray(self._fn(jnp.asarray(Xp)))
        return out[:B] / self.leaf_scale

    def predict_class(self, X: np.ndarray) -> np.ndarray:
        return self.predict(X).argmax(axis=1)


def pallas_qs_predictor(forest: Forest, block_b: int = 128, block_t: int = 8,
                        interpret: bool = True) -> _PallasPredictor:
    """QuickScorer bitvector engine, Pallas backend."""
    thr_pad = _thr_pad_value(forest)
    feat = _pad_to(np.maximum(forest.feature, 0).astype(np.int32), 0, block_t)
    thr = forest.threshold.astype(np.float32).copy()
    thr[forest.feature < 0] = np.float32(thr_pad) if np.isfinite(
        np.float32(thr_pad)) else np.float32(np.inf)
    thr = _pad_to(thr, 0, block_t, fill=np.float32(np.inf))
    masks = _pad_to(forest.node_masks(), 0, block_t, fill=0xFFFFFFFF)
    init_idx = _pad_to(forest.init_leafidx(), 0, block_t)           # pad: 0
    lv = forest.leaf_value.astype(np.float32)
    leaf_val = _pad_to(lv, 0, block_t)                              # pad: 0

    feat_j, thr_j = jnp.asarray(feat), jnp.asarray(thr)
    masks_j, init_j = jnp.asarray(masks), jnp.asarray(init_idx)
    leaf_j = jnp.asarray(leaf_val)

    @jax.jit
    def fn(X):
        return quickscorer_kernel.qs_forward(
            X, feat_j, thr_j, masks_j, init_j, leaf_j,
            block_b=block_b, block_t=block_t, interpret=interpret)

    return _PallasPredictor(forest, fn, block_b)


def pallas_gemm_predictor(forest: Forest, block_b: int = 128, block_t: int = 8,
                          interpret: bool = True) -> _PallasPredictor:
    """GEMM (Hummingbird/MXU) engine, Pallas backend."""
    from ..core.baselines import compile_gemm
    g = compile_gemm(forest)                     # reuse A/Bvec construction
    feat = _pad_to(np.asarray(g.feat), 0, block_t)
    # padding nodes: A rows are zero so S value is irrelevant; use -inf so
    # S=0 deterministically.
    thr = np.asarray(g.thr, dtype=np.float32).copy()
    thr[~np.asarray(g.valid)] = -np.inf
    thr = _pad_to(thr, 0, block_t, fill=-np.inf)
    A = _pad_to(np.asarray(g.A, dtype=np.float32), 0, block_t)
    Bvec = _pad_to(np.asarray(g.Bvec, dtype=np.float32), 0, block_t,
                   fill=forest.n_leaves + 1.0)
    leaf_val = _pad_to(np.asarray(g.leaf_val, dtype=np.float32), 0, block_t)

    feat_j, thr_j = jnp.asarray(feat), jnp.asarray(thr)
    A_j, B_j, leaf_j = jnp.asarray(A), jnp.asarray(Bvec), jnp.asarray(leaf_val)

    @jax.jit
    def fn(X):
        return gemm_forest_kernel.gemm_forward(
            X, feat_j, thr_j, A_j, B_j, leaf_j,
            block_b=block_b, block_t=block_t, interpret=interpret)

    return _PallasPredictor(forest, fn, block_b)
