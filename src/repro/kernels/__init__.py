"""Pallas TPU kernels for the framework's compute hot-spots.

quickscorer_kernel     — bitvector QS/VQS/RS forest engine (VPU + MXU
                         one-hot gathers); the paper's technique, tiled
cascade_kernel         — fused cascade over QS stages: in-kernel gate +
                         survivor mask in scratch (docs/CASCADE.md)
gemm_forest_kernel     — Hummingbird-style MXU forest engine (beyond-paper)
flash_attention_kernel — GQA flash attention (LM-side hot-spot; §Perf 9)
ops                    — jit'd wrappers / predictors
ref                    — pure-jnp oracles
"""
from . import ops, ref
from .cascade_kernel import cascade_qs_forward
from .flash_attention_kernel import flash_attention_bshd, flash_forward
from .gemm_forest_kernel import gemm_forward
from .quickscorer_kernel import qs_forward

__all__ = ["ops", "ref", "cascade_qs_forward", "gemm_forward", "qs_forward",
           "flash_forward", "flash_attention_bshd"]
