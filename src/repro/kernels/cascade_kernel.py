"""Pallas TPU kernel: fused cascade over QuickScorer bitvector stages.

One kernel evaluates *all* K cascade stages for a batch tile: stage
tree-blocks run through the shared ``qs_tile_scores`` traversal, the
gate's pure-jax ``decide`` executes in-kernel on the descaled running
scores, and a per-row survivor mask lives in VMEM scratch.  Every stage
body (and every gate) is wrapped in ``pl.when(any survivor)`` — a batch
tile whose rows are all decided skips the remaining stages' compute
entirely, the in-kernel analogue of the host loop's shrinking batch.

Versus the staged Pallas path this removes K-1 kernel launches, K-1
device→host score round-trips, and all survivor gather/re-pad work: the
input tile is read once, scores accumulate in the output block, and the
only things that ever reach the host are the final scores and a per-row
exit-stage vector (which the wrapper reduces to per-stage exit counts
in-graph).

Grid is 1-D over batch tiles only — stages must run sequentially within
a tile (the gate needs the running score), so the tree axis is a python
loop over static per-stage slices of the stage-concatenated arrays, not
a grid dimension.  Per-stage arrays are padded to ``block_t`` trees with
inert padding (+inf thresholds, zero leaf rows), exactly like the plain
kernel's, so scores match the staged per-stage kernels bit-for-bit on
quantized forests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .quickscorer_kernel import mosaic_params, qs_tile_scores


def _cascade_qs_kernel(x_ref, valid_ref, feat_ref, thr_ref, masks_ref,
                       init_ref, leaf_ref, out_ref, exit_ref, active_ref, *,
                       stage_bounds, policy, inv_scale: float):
    """One batch tile through the whole cascade.

    x_ref      (Bt, d)      f32  — inputs (quantized forests: ints cast f32)
    valid_ref  (Bt, 1)      f32  — 1.0 for real rows, 0.0 for batch padding
    feat_ref   (Tp, N)      i32  — stage-concatenated node features
    thr_ref    (Tp, N)      f32  — thresholds (padding: +inf)
    masks_ref  (Tp, N, W)   u32  — interval bitmasks
    init_ref   (Tp, W)      u32  — initial leafidx (padding trees: 0)
    leaf_ref   (Tp, L, C)   f32  — leaf tables (padding trees: 0)
    out_ref    (Bt, C)      f32  — cumulative scores, raw leaf units
    exit_ref   (Bt, 1)      i32  — exit stage per row (default K-1)
    active_ref (Bt, 1)      f32  — VMEM scratch: the survivor mask

    ``stage_bounds`` are static tree offsets (K+1 entries) into the
    concatenated arrays; ``policy.decide`` runs on ``out * inv_scale``
    (power-of-two scale → the multiply is exact on quantized forests, so
    the gate sees bit-identical scores to the staged host loop's).
    """
    n_stages = len(stage_bounds) - 1
    active_ref[...] = valid_ref[...]
    out_ref[...] = jnp.zeros_like(out_ref)
    exit_ref[...] = jnp.full(exit_ref.shape, n_stages - 1, dtype=jnp.int32)
    x = x_ref[...]
    feat, thr = feat_ref[...], thr_ref[...]
    masks, init_idx, leaf = masks_ref[...], init_ref[...], leaf_ref[...]

    for s in range(n_stages):
        a, b = stage_bounds[s], stage_bounds[s + 1]

        @pl.when(jnp.any(active_ref[...] > 0))
        def _score(a=a, b=b):
            part = qs_tile_scores(x, feat[a:b], thr[a:b], masks[a:b],
                                  init_idx[a:b], leaf[a:b])
            keep = active_ref[...] > 0                        # (Bt, 1)
            out_ref[...] += jnp.where(keep, part, 0.0)

        if s == n_stages - 1:
            break

        @pl.when(jnp.any(active_ref[...] > 0))
        def _gate(s=s):
            keep = active_ref[...][:, 0] > 0                  # (Bt,)
            ex = policy.decide(out_ref[...] * jnp.float32(inv_scale), s) & keep
            exit_ref[...] = jnp.where(ex[:, None], s, exit_ref[...])
            active_ref[...] = jnp.where(ex[:, None], 0.0, active_ref[...])


def cascade_qs_forward(x, valid, feat, thr, masks, init_idx, leaf_val, *,
                       stage_bounds, policy, inv_scale: float,
                       block_b: int = 128, interpret: bool = True):
    """Padded arrays → ``(scores (B, C) raw units, exit_stage (B, 1))``.
    ``B`` must be a multiple of ``block_b`` (ops.py pads); the tree
    arrays travel whole into every batch tile."""
    B, d = x.shape
    T, N = feat.shape
    W = masks.shape[-1]
    L, C = leaf_val.shape[-2:]
    grid = (B // block_b,)
    kernel = functools.partial(_cascade_qs_kernel,
                               stage_bounds=tuple(stage_bounds),
                               policy=policy, inv_scale=inv_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
            pl.BlockSpec((T, N, W), lambda i: (0, 0, 0)),
            pl.BlockSpec((T, W), lambda i: (0, 0)),
            pl.BlockSpec((T, L, C), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, 1), jnp.float32)],
        interpret=interpret,
        compiler_params=mosaic_params("parallel") if not interpret else None,
    )(x, valid, feat, thr, masks, init_idx, leaf_val)
