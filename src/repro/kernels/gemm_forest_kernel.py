"""Pallas TPU kernel: GEMM (Hummingbird-style) forest traversal — the
beyond-paper MXU engine (DESIGN.md §2.3).

Per (batch, tree) tile, entirely in VMEM:
    S      = 1{x[feat] <= thr}            one-hot matmul feature select
    R      = S @ A                        (Tt, Bt, N) × (Tt, N, L) MXU
    onehot = 1{R == Bvec}                 exit-leaf equality test
    out   += onehot @ leaf_val            (Tt, Bt, L) × (Tt, L, C) MXU
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quickscorer_kernel import mosaic_params


def _gemm_kernel(x_ref, feat_ref, thr_ref, a_ref, b_ref, leaf_ref, out_ref):
    """x (Bt,d) f32 | feat (Tt,N) i32 | thr (Tt,N) f32 (padding -inf → S=0…
    actually padding nodes need S irrelevant: A rows are zero) |
    a (Tt,N,L) f32 | b (Tt,L) f32 (padding leaves: L+1 → never matches) |
    leaf (Tt,L,C) f32 | out (Bt,C) f32."""
    Bt, d = x_ref.shape
    Tt, N = feat_ref.shape
    L, C = leaf_ref.shape[-2:]

    x = x_ref[...].astype(jnp.float32)
    feat = feat_ref[...].reshape(Tt * N)
    onehot_f = (jax.lax.broadcasted_iota(jnp.int32, (d, Tt * N), 0)
                == feat[None, :]).astype(jnp.float32)
    xsel = jnp.dot(x, onehot_f, preferred_element_type=jnp.float32)
    S = (xsel.reshape(Bt, Tt, N) <= thr_ref[...][None]).astype(jnp.float32)

    # R[t, b, l] = Σ_n S[b, t, n] A[t, n, l]
    R = jax.lax.dot_general(
        S, a_ref[...],
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32)                      # (Tt, Bt, L)
    hit = (R == b_ref[...][:, None, :]).astype(jnp.float32)      # (Tt, Bt, L)
    part = jax.lax.dot_general(
        hit, leaf_ref[...].astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                      # (Tt, Bt, C)
    # int out_refs: per-tile f32 partial is exact (builder asserts
    # block_t × max|leaf| < 2^24); the cross-tile sum runs in int32.
    part = part.sum(axis=0).astype(out_ref.dtype)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = part

    @pl.when(pl.program_id(1) != 0)
    def _acc():
        out_ref[...] += part


def gemm_forward(x, feat, thr, A, Bvec, leaf_val, *,
                 block_b: int = 128, block_t: int = 8,
                 interpret: bool = True, out_dtype=jnp.float32):
    B, d = x.shape
    T, N = feat.shape
    L, C = leaf_val.shape[-2:]
    grid = (B // block_b, T // block_t)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N, L), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_t, L), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, L, C), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), out_dtype),
        interpret=interpret,
        compiler_params=mosaic_params("parallel", "arbitrary")
        if not interpret else None,
    )(x, feat, thr, A, Bvec, leaf_val)
