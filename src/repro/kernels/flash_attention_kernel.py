"""Pallas TPU kernel: GQA flash attention (the LM-side compute hot-spot).

Motivation (§Perf, smollm prefill_32k): the pure-JAX chunked flash in
models/attention.py materialises each (B, H, qc, kc) score/probability
block at an XLA fusion boundary — ~123 GB of HBM round-trips per layer at
S=32k. This kernel keeps the whole (block_q × block_k) tile plus the
online-softmax state (m, l, acc) in VMEM; HBM traffic collapses to the
linear q/k/v/out streams.

Layout: head-major (BH, S, hd) so the grid is
    (BH, nq, nk)   — "parallel", "parallel", "arbitrary"
with the kv axis innermost: the out block and the (m, l, acc) scratch are
revisited across `j` and live in VMEM for the whole row of kv blocks.

GQA: k/v stay at (B·K, S, hd); the q→kv head mapping happens in the
BlockSpec index_map (h // n_rep), so grouped-query heads never
materialise repeated K/V — same trick as the XLA engine (§Perf iter 4),
one level lower.

Causality is handled per tile: fully-masked tiles are skipped with
`pl.when` (their loads still happen; a production kernel would prune the
grid — noted in EXPERIMENTS.md), diagonal tiles apply an iota mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, n_k: int, causal: bool,
                  scale: float):
    """One (bh, i, j) tile.

    q_ref (1, bq, hd); k_ref/v_ref (1, bk, hd); o_ref (1, bq, hd);
    scratch: m/l (bq,), acc (bq, hd) — persistent across the j axis."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: tile is live iff some q position ≥ some k position
    live = True
    if causal:
        live = (i + 1) * block_q - 1 >= j * block_k

    @pl.when(live if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_forward(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, block_q: int = 512,
                  block_k: int = 512, n_rep: int = 1,
                  interpret: bool = True) -> jnp.ndarray:
    """q (BH, Sq, hd); k/v (BK, Sk, hd) with BH = BK·n_rep (heads of one
    batch element contiguous). Returns (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    BK, Sk, _ = k.shape
    assert BH == BK * n_rep, (BH, BK, n_rep)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_k=nk,
        causal=causal, scale=scale)

    def q_map(b, i, j):
        return (b, i, 0)

    def kv_map(b, i, j):
        return (b // n_rep, j, 0)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(q, k, v)


def flash_attention_bshd(q, k, v, *, causal=True, block_q=512, block_k=512,
                         interpret=True):
    """Convenience wrapper over (B, S, H, hd) q and (B, S, K, hd) k/v —
    the models/attention.py layout."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    n_rep = H // K
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, k.shape[1], hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, v.shape[1], hd)
    out = flash_forward(qh, kh, vh, causal=causal, block_q=block_q,
                        block_k=block_k, n_rep=n_rep, interpret=interpret)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
