"""Pure-jnp oracles for the Pallas kernels (the `ref.py` layer).

These re-export the core engines' batch evaluators: the XLA engine IS the
mathematical reference; tests assert ``pallas(interpret=True) ≈ ref ≈ numpy
traversal oracle`` across shape/dtype sweeps.
"""
from __future__ import annotations

import numpy as np

from ..core.baselines import compile_gemm, eval_gemm
from ..core.forest import Forest
from ..core.quantize import quantize_inputs
from ..core.quickscorer import compile_qs, eval_batch

import jax.numpy as jnp


def ref_qs(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Bitvector-engine reference: (B, d) raw inputs → (B, C) scores."""
    qs = compile_qs(forest)
    Xq = quantize_inputs(forest, np.asarray(X))
    return np.asarray(eval_batch(qs, jnp.asarray(Xq)))


def ref_gemm(forest: Forest, X: np.ndarray) -> np.ndarray:
    g = compile_gemm(forest)
    Xq = quantize_inputs(forest, np.asarray(X))
    return np.asarray(eval_gemm(g, jnp.asarray(Xq)))


def ref_oracle(forest: Forest, X: np.ndarray) -> np.ndarray:
    """Slowest, most-trusted path: vectorized numpy root-to-leaf traversal."""
    from ..core.quantize import leaf_scale
    Xq = quantize_inputs(forest, np.asarray(X))
    return forest.predict_oracle(Xq) / leaf_scale(forest)
