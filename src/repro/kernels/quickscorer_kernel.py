"""Pallas TPU kernel: QuickScorer bitvector traversal (DESIGN.md §2).

Grid ``(batch_tiles, tree_tiles)``; each program evaluates a
``(block_b × block_t)`` tile of (instances × trees) entirely in VMEM and
accumulates partial class scores into the output block, which is revisited
across the tree grid axis.

TPU-native structure (vs the paper's NEON loops):
  * feature select   — one-hot matmul ``X @ 1{iota_d == feat}`` (MXU);
    arbitrary per-node gathers do not vectorise on TPU, matmul does.
  * mask computation — predicated select + AND-reduction over the node axis
    (VPU); batch is the minor/lane dimension of the ``leafidx`` accumulator,
    the word-transposed analogue of RapidScorer's byte-transposed layout.
  * exit leaf        — LSB isolate ``w & -w`` + ``lax.population_count``
    (the NEON ``vrbitq/vclzq`` trick has a one-op TPU equivalent).
  * score            — leaf one-hot matmul against the leaf table (MXU).

Quantized forests (int16/int8 thresholds) flow through the same kernel:
inputs/thresholds are exact small integers, compared in f32 (exact ≤ 2^24);
the win is halved/quartered HBM traffic for the node stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.quickscorer import bitmm_exit_leaf

WORD = 32


def mosaic_params(*semantics: str):
    """Grid dimension semantics via the current Pallas TPU compiler-params
    class (``CompilerParams`` in new JAX, ``TPUCompilerParams`` before the
    rename) — replaces the removed ``dict(mosaic=dict(...))`` form."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=tuple(semantics))


def _ctz(w: jnp.ndarray) -> jnp.ndarray:
    w = w.astype(jnp.uint32)
    lsb = w & (jnp.uint32(0) - w)
    return jax.lax.population_count(lsb - jnp.uint32(1)).astype(jnp.int32)


def qs_tile_scores(x, feat, thr, masks, init_idx, leaf_val):
    """Score one (instances × trees) tile — the QuickScorer traversal
    shared by the plain kernel and the fused cascade kernel
    (``cascade_kernel.py``).  Operates on *values* (already read from
    refs), so callers can slice per-stage tree ranges statically.

    x         (Bt, d)      f32   — inputs (quantized forests: ints cast f32)
    feat      (Tt, N)      i32   — per-node feature id (padding: 0)
    thr       (Tt, N)      f32   — thresholds (padding: +inf → never fires)
    masks     (Tt, N, W)   u32   — interval bitmasks
    init_idx  (Tt, W)      u32   — initial leafidx (padding trees: 0)
    leaf_val  (Tt, L, C)   f32   — leaf table (padding trees: 0)
    returns   (Bt, C)      f32   — tile partial scores (raw leaf units)
    """
    Bt, d = x.shape
    Tt, N = feat.shape
    W = masks.shape[-1]
    L, C = leaf_val.shape[-2:]

    x = x.astype(jnp.float32)
    flat = feat.reshape(Tt * N)
    # ---- feature select via one-hot matmul (MXU) ------------------------- #
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (d, Tt * N), 0)
              == flat[None, :]).astype(jnp.float32)
    # HIGHEST: the select must return x bit-exactly or near-threshold
    # predicates flip under TPU bf16 multiplies.
    xsel = jnp.dot(x, onehot, precision=jax.lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32)           # (Bt, Tt*N)
    cond = xsel.reshape(Bt, Tt, N) > thr[None]                   # (Bt, Tt, N)

    # ---- predicated mask AND-reduction (VPU) ----------------------------- #
    ones = jnp.uint32(0xFFFFFFFF)
    sel = jnp.where(cond[..., None], masks[None], ones)           # (Bt,Tt,N,W)
    leafidx = jax.lax.reduce(sel, ones, jax.lax.bitwise_and,
                             dimensions=(2,))                     # (Bt, Tt, W)
    leafidx = leafidx & init_idx[None]

    # ---- exit leaf: first nonzero word, LSB isolate ----------------------- #
    leaf = jnp.zeros((Bt, Tt), dtype=jnp.int32)
    found = jnp.zeros((Bt, Tt), dtype=jnp.bool_)
    for w in range(W):
        word = leafidx[:, :, w]
        hit = (word != 0) & (~found)
        leaf = jnp.where(hit, w * WORD + _ctz(word), leaf)
        found = found | hit
    # padding trees: found stays False → leaf 0 → leaf_val row is zeros.

    # ---- leaf one-hot × leaf table (MXU) ---------------------------------- #
    lhot = (jax.lax.broadcasted_iota(jnp.int32, (Bt, Tt, L), 2)
            == leaf[..., None]).astype(jnp.float32)
    part = jax.lax.dot_general(
        lhot, leaf_val.astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32)                      # (Tt, Bt, C)
    return part.sum(axis=0)                                      # (Bt, C)


def _qs_kernel(x_ref, feat_ref, thr_ref, masks_ref, init_ref, leaf_ref,
               out_ref, *, n_leaves: int):
    """One (block_b, block_t) tile — ref plumbing around
    ``qs_tile_scores``, accumulating over the tree grid axis.

    Integer accumulation (``out_ref`` int32): the per-tile partial is
    still the f32 leaf matmul — exact, since the builder asserts
    ``block_t × max|leaf| < 2^24`` — but the cross-tile running sum is
    carried in int32, so totals stay exact for any tree count
    (docs/QUANT.md)."""
    part = qs_tile_scores(x_ref[...], feat_ref[...], thr_ref[...],
                          masks_ref[...], init_ref[...], leaf_ref[...])
    part = part.astype(out_ref.dtype)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = part

    @pl.when(pl.program_id(1) != 0)
    def _acc():
        out_ref[...] += part


def qs_forward(x, feat, thr, masks, init_idx, leaf_val, *,
               block_b: int = 128, block_t: int = 8,
               interpret: bool = True, out_dtype=jnp.float32):
    """Padded full arrays → scores (B, C). All leading dims must be multiples
    of the block sizes (ops.py pads).  ``out_dtype=jnp.int32`` selects
    integer cross-tile accumulation for int-leaf forests."""
    B, d = x.shape
    T, N = feat.shape
    W = masks.shape[-1]
    L, C = leaf_val.shape[-2:]
    grid = (B // block_b, T // block_t)
    kernel = functools.partial(_qs_kernel, n_leaves=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N, W), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_t, W), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, L, C), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), out_dtype),
        interpret=interpret,
        compiler_params=mosaic_params("parallel", "arbitrary")
        if not interpret else None,
    )(x, feat, thr, masks, init_idx, leaf_val)


# --------------------------------------------------------------------------- #
# Bit-matmul variant (DESIGN.md §2.4): the node-axis reduction is a batched
# MXU matmul against packed clear-count words instead of a VPU AND-chain.
# --------------------------------------------------------------------------- #
def _qs_bitmm_kernel(x_ref, feat_ref, thr_ref, packed_ref, bias_ref,
                     leaf_ref, out_ref, *, bits: int, npack: int,
                     n_leaves: int, block_n: int):
    """One (block_b, block_t) tile, fully VMEM-resident.

    x_ref      (Bt, d)      f32  — inputs (quantized forests: ints cast f32)
    feat_ref   (Tt, N)      i32  — per-node feature id (padding: 0)
    thr_ref    (Tt, N)      f32  — thresholds (padding: +inf → never fires)
    packed_ref (Tt, N, G)   f32  — packed clear-count weights
    bias_ref   (Tt, G)      f32  — padding-leaf fields (always cleared)
    leaf_ref   (Tt, L, C)   f32  — leaf table (padding trees: 0)
    out_ref    (Bt, C)      f32  — accumulated over the tree grid axis

    Stages: one-hot feature select (MXU) → predicate → bit-matmul over
    ``block_n`` node chunks (MXU) → lowest-zero-field exit leaf (VPU bit
    tricks) → leaf one-hot × leaf table (MXU).
    """
    Bt, d = x_ref.shape
    Tt, N = feat_ref.shape
    G = packed_ref.shape[-1]
    L, C = leaf_ref.shape[-2:]

    x = x_ref[...].astype(jnp.float32)
    feat = feat_ref[...].reshape(Tt * N)
    # ---- feature select via one-hot matmul (MXU) ------------------------- #
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (d, Tt * N), 0)
              == feat[None, :]).astype(jnp.float32)
    # HIGHEST: the select must return x bit-exactly or near-threshold
    # predicates flip under TPU bf16 multiplies.
    xsel = jnp.dot(x, onehot, precision=jax.lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32)           # (Bt, Tt*N)
    cond = (xsel.reshape(Bt, Tt, N)
            > thr_ref[...][None]).astype(jnp.float32)            # (Bt, Tt, N)

    # ---- bit-matmul over node chunks (MXU) -------------------------------- #
    # HIGHEST precision: packed words are exact integers up to 2^23; the
    # TPU default bf16 multiply would truncate their low fields.
    packed = packed_ref[...]
    words = jnp.broadcast_to(bias_ref[...][:, None, :], (Tt, Bt, G))
    for n0 in range(0, N, block_n):
        n1 = min(n0 + block_n, N)
        words = words + jax.lax.dot_general(
            cond[:, :, n0:n1], packed[:, n0:n1, :],
            dimension_numbers=(((2,), (1,)), ((1,), (0,))),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)                  # (Tt, Bt, G)

    # ---- exit leaf: lowest zero field (borrow trick, shared helper) ------- #
    # padding trees (bias all-on) have no survivor → leaf 0 → zero row.
    leaf = bitmm_exit_leaf(words, bits=bits, npack=npack,
                           n_leaves=n_leaves)                    # (Tt, Bt)

    # ---- leaf one-hot × leaf table (MXU) ---------------------------------- #
    # The per-tile leaf matmul stays f32 (exact: the builder asserts
    # block_t × max|leaf| < 2^24); for integer out_refs the cross-tile
    # running sum is carried in int32, so totals stay exact for any tree
    # count (docs/QUANT.md).
    lhot = (jax.lax.broadcasted_iota(jnp.int32, (Tt, Bt, L), 2)
            == leaf[..., None]).astype(jnp.float32)
    part = jax.lax.dot_general(
        lhot, leaf_ref[...].astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)                      # (Tt, Bt, C)
    part = part.sum(axis=0).astype(out_ref.dtype)                # (Bt, C)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = part

    @pl.when(pl.program_id(1) != 0)
    def _acc():
        out_ref[...] += part


def qs_bitmm_forward(x, feat, thr, packed, bias, leaf_val, *, bits: int,
                     npack: int, n_leaves: int, block_b: int = 128,
                     block_t: int = 8, block_n: int = 128,
                     interpret: bool = True, out_dtype=jnp.float32):
    """Padded full arrays → scores (B, C).  B and T must be multiples of the
    block sizes (ops.py pads); ``block_n`` tiles the in-kernel bit-matmul so
    the MXU sees well-shaped contractions on wide forests.
    ``out_dtype=jnp.int32`` selects integer cross-tile accumulation."""
    B, d = x.shape
    T, N = feat.shape
    G = packed.shape[-1]
    L, C = leaf_val.shape[-2:]
    grid = (B // block_b, T // block_t)
    kernel = functools.partial(_qs_bitmm_kernel, bits=bits, npack=npack,
                               n_leaves=n_leaves, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, N, G), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_t, G), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, L, C), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), out_dtype),
        interpret=interpret,
        compiler_params=mosaic_params("parallel", "arbitrary")
        if not interpret else None,
    )(x, feat, thr, packed, bias, leaf_val)
