"""repro.cascade — confidence-gated staged ensemble evaluation.

The forest is split into K tree-prefix stages compiled through the
ordinary engine pipeline; between stages a pluggable ``GatePolicy``
routes confident rows out early and gathers the rest into a shrinking,
power-of-two-bucketed batch.  See docs/CASCADE.md.

Typical use::

    from repro import core
    from repro.cascade import CascadeSpec, MarginGate, calibrate

    pred = core.compile_forest(qforest, engine="bitmm",
                               cascade=CascadeSpec(stages=(16, 48, 192)))
    result = calibrate(pred, X_val, y_val, floor_pp=0.5)
    pred.set_policy(result.policy)
    scores = pred.predict(X)            # early-exits confident rows
    pred.exit_fractions                 # per-stage exit accounting

``CascadeSpec(..., fused=True)`` lowers to ``FusedCascadePredictor``
instead: the same semantics as one compiled computation with zero host
syncs between stages (cascade/fused.py, docs/CASCADE.md §Fused).
"""
from .fused import FusedCascadePredictor
from .policy import (CalibrationResult, GatePolicy, MarginGate, ProbaGate,
                     ScoreBoundGate, calibrate, default_policy_grid,
                     normalize_scores_jnp, policy_from_header,
                     policy_to_header, simulate_gate)
from .predictor import (CascadePredictor, CascadeSpec, default_policy,
                        normalize_stages, tree_slice)

__all__ = [
    "GatePolicy", "MarginGate", "ProbaGate", "ScoreBoundGate",
    "CalibrationResult", "calibrate", "default_policy_grid",
    "normalize_scores_jnp", "simulate_gate", "policy_to_header",
    "policy_from_header", "CascadePredictor", "FusedCascadePredictor",
    "CascadeSpec", "default_policy", "normalize_stages", "tree_slice",
]
